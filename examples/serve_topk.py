"""Serving demo: batched request decoding through the SATA decode route
— incremental per-slot KV-block plan + selective gather kernel — using
the qwen3-family reduced config.  Prints the fetch-byte savings the
plan banks against dense decode over the whole prefix.

Run:  PYTHONPATH=src python examples/serve_topk.py
"""
import dataclasses

from repro.configs.archs import SMOKE
from repro.launch.serve import serve


def main():
    cfg = dataclasses.replace(
        SMOKE["qwen3-4b"],
        topk_impl="bisect",         # bisect thresholds (the SATA predicate)
        sata_decode="on",           # route decode through the plan + kernel
        sata_decode_block=8,        # k-block edge over the 64-token cache
        sata_decode_replan=1,       # full re-plan every step (exact top-k)
    )
    # gen_len spans several k-blocks so top-k (4 keys) actually skips
    # blocks — the fetch-reduction line below is the point of the demo
    out = serve("qwen3-4b", smoke=True, n_requests=6, batch_slots=3,
                gen_len=48, max_len=64, cfg=cfg)
    print(f"[serve_topk] completed {len(out['outputs'])} requests, "
          f"{out['tokens_generated']} tokens in {out['steps']} decode steps "
          f"({out['tok_per_s']:.1f} tok/s on CPU, mean request latency "
          f"{out['latency_mean_s'] * 1e3:.1f} ms)")
    f = out["decode_fetch"]
    # kernel-side accounting: at sata_decode_replan=1 the exact
    # re-plan itself still reads the full prefix's keys each step —
    # raise the interval to shrink selection-side reads too (the
    # exactness/traffic knob; see ops.decode_fetch_stats)
    print(f"[serve_topk] attention-kernel KV fetch: "
          f"{f['kv_fetch_bytes_plan']} B vs {f['kv_fetch_bytes_dense']} B "
          f"dense ({f['fetch_reduction']:.2f}x reduction)")
    first = sorted(out["outputs"])[0]
    print(f"[serve_topk] request {first} tokens: {out['outputs'][first]}")
    assert all(len(v) == 48 for v in out["outputs"].values())
    assert f["kv_fetch_tiles_plan"] < f["kv_fetch_tiles_dense"]


if __name__ == "__main__":
    main()
