"""Serving demo: batched request decoding with top-k selective attention
over the KV cache (the SATA inference workload), using the qwen3-family
reduced config.

Run:  PYTHONPATH=src python examples/serve_topk.py
"""
from repro.launch.serve import serve


def main():
    out = serve("qwen3-4b", smoke=True, n_requests=12, batch_slots=4,
                gen_len=12, max_len=64)
    print(f"[serve_topk] completed {len(out['outputs'])} requests, "
          f"{out['tokens_generated']} tokens in {out['steps']} decode steps "
          f"({out['tok_per_s']:.1f} tok/s on CPU)")
    first = sorted(out["outputs"])[0]
    print(f"[serve_topk] request {first} tokens: {out['outputs'][first]}")
    assert all(len(v) == 12 for v in out["outputs"].values())


if __name__ == "__main__":
    main()
