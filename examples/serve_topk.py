"""Serving demo: batched request decoding through the SATA decode route
— incremental per-slot KV-block plan + selective gather kernel — using
the qwen3-family reduced config.  Prints the fetch-byte savings the
plan banks against dense decode over the whole prefix, and (with
``--paged``) serves from the paged KV pool: half the contiguous HBM
reservation, identical outputs, pool exhaustion absorbed as
backpressure instead of a shape error.

With ``--faults SEED`` the demo turns adversarial: a deterministic
squeeze/crash schedule forces host-swap preemptions and a mid-serve
crash, the allocator's invariant audit stays on throughout, and the
demo asserts the restored outputs are bitwise equal to the fault-free
run with zero re-prefilled tokens and zero cold re-plans.

Run:  PYTHONPATH=src python examples/serve_topk.py
          [--paged] [--summary int8] [--replan-mode sketch]
          [--faults SEED]
"""
import argparse
import dataclasses

from repro.configs.archs import SMOKE
from repro.launch.faults import FaultPlan
from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged KV pool (half the "
                         "contiguous reservation)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="shared-prefix scenario: requests share a "
                         "prompt prefix and the prefix cache maps its "
                         "pages instead of re-prefilling them")
    ap.add_argument("--summary", choices=("fp32", "int8"), default="fp32",
                    help="block-summary backend: int8 stores "
                         "conservatively-quantized bounds (~4x less "
                         "summary traffic; summaries only RANK blocks "
                         "— the exact token threshold still runs over "
                         "the planned blocks' fp32 keys)")
    ap.add_argument("--replan-mode", choices=("exact", "sketch"),
                    default="exact",
                    help="periodic re-plan: 'exact' streams all cached "
                         "K; 'sketch' ranks super-block sketches first "
                         "and reads only surviving candidate blocks "
                         "(sub-linear in cached K; approximate — safe "
                         "when the plan tolerates a missed block until "
                         "the next re-plan, NOT for bitwise-exact "
                         "serving)")
    ap.add_argument("--faults", type=int, default=None, metavar="SEED",
                    help="fault-injection scenario: a deterministic "
                         "squeeze + crash schedule forces host-swap "
                         "preemptions; asserts bitwise-equal restored "
                         "outputs with the invariant audit on")
    args = ap.parse_args()
    cfg = dataclasses.replace(
        SMOKE["qwen3-4b"],
        topk_impl="bisect",         # bisect thresholds (the SATA predicate)
        sata_decode="on",           # route decode through the plan + kernel
        sata_decode_block=8,        # k-block edge over the 64-token cache
        sata_decode_replan=1,       # full re-plan every step (exact top-k)
        sata_summary=args.summary,
        sata_replan_mode=args.replan_mode,
    )
    if args.faults is not None:
        return faults_demo(cfg, args.faults)
    if args.shared_prefix:
        return shared_prefix_demo(cfg)
    if args.paged:
        # pool sized to HALF the contiguous reservation (3 slots × 8
        # pages): short-prefix slots stop reserving max_len worth of
        # HBM, and any transient over-demand stalls a slot for a step
        # instead of failing a shape
        cfg = dataclasses.replace(cfg, kv_cache_layout="paged",
                                  kv_pool_pages=12)
    # gen_len spans several k-blocks so top-k (4 keys) actually skips
    # blocks — the fetch-reduction line below is the point of the demo
    out = serve("qwen3-4b", smoke=True, n_requests=6, batch_slots=3,
                gen_len=48, max_len=64, cfg=cfg)
    print(f"[serve_topk] completed {len(out['outputs'])} requests, "
          f"{out['tokens_generated']} tokens in {out['steps']} decode steps "
          f"({out['tok_per_s']:.1f} tok/s on CPU, mean request latency "
          f"{out['latency_mean_s'] * 1e3:.1f} ms)")
    f = out["decode_fetch"]
    # kernel-side accounting: at sata_decode_replan=1 the exact
    # re-plan itself still reads the full prefix's keys each step —
    # plan_fetch_bytes/true_reduction report that honestly (raise the
    # interval or set sata_decode_replan="auto" to shrink it)
    print(f"[serve_topk] attention-kernel KV fetch: "
          f"{f['kv_fetch_bytes_plan']} B vs {f['kv_fetch_bytes_dense']} B "
          f"dense ({f['fetch_reduction']:.2f}x reduction; "
          f"{f['true_reduction']:.2f}x counting plan traffic, "
          f"summary={f['summary_backend']}, replan={f['replan_mode']})")
    if args.paged:
        o = out["page_occupancy"]
        print(f"[serve_topk] paged pool: peak {o['pages_in_use_peak']}/"
              f"{o['n_pages']} pages, reserved "
              f"{o['reserved_vs_contiguous']:.2f}x less HBM than "
              f"contiguous ({o['stalled_steps']} stalled steps, "
              f"{o['deferred_claims']} deferred claims)")
        assert o["reserved_vs_contiguous"] >= 1.5
    first = sorted(out["outputs"])[0]
    print(f"[serve_topk] request {first} tokens: {out['outputs'][first]}")
    assert all(len(v) == 48 for v in out["outputs"].values())
    assert f["kv_fetch_tiles_plan"] < f["kv_fetch_tiles_dense"]


def faults_demo(cfg, seed):
    """Adversarial serving: a deterministic fault schedule — a hard
    pool squeeze (forces host-swap preemptions), seeded deferrals and
    forced preemptions, and a mid-serve crash — against a fault-free
    reference.  Host-swap restores must reproduce the reference
    bitwise with ZERO re-prefilled tokens and zero cold re-plans, and
    the allocator invariant audit runs after every mutation."""
    cfg = dataclasses.replace(cfg, sata_decode_replan=4,
                              kv_cache_layout="paged", kv_pool_pages=6)
    kw = dict(smoke=True, n_requests=4, batch_slots=2, gen_len=12,
              max_len=32, prompt_len=6)
    base = serve("qwen3-4b", cfg=cfg, **kw)
    faults = (FaultPlan.seeded(seed, steps=24, n_events=3,
                               max_squeeze=2, slots=2)
              .pool_squeeze(2, 3).pool_restore(14)   # forces ≥2 swaps
              .crash_step(20))
    print(f"[serve_topk] fault schedule (seed {seed}):")
    print(faults.describe())
    out = serve("qwen3-4b", cfg=cfg, faults=faults, audit_pages=True,
                **kw)
    o = out["page_occupancy"]
    print(f"[serve_topk] {o['host_swaps']} host-swaps "
          f"({o['tokens_salvaged']} tokens salvaged, {o['swap_restores']} "
          f"restores, re_prefill_tokens={o['re_prefill_tokens']}, "
          f"cold_replans={o['swap_cold_replans']}), "
          f"{o['requeue_preemptions']} requeues, {o['crashes']} crash "
          f"recovered, {o['audits_run']} invariant audits")
    equal = out["outputs"] == base["outputs"]
    print(f"[serve_topk] outputs bitwise equal to fault-free run: {equal}")
    assert equal, "fault recovery changed outputs"
    assert o["host_swaps"] >= 2, "schedule failed to force 2 preemptions"
    assert o["re_prefill_tokens"] == 0 and o["swap_cold_replans"] == 0
    assert o["crashes"] == 1 and o["audits_run"] > 0
    assert all(len(v) == 12 for v in out["outputs"].values())


def shared_prefix_demo(cfg):
    """Six requests share a 16-token system prefix of their 20-token
    prompts: the prefix cache prefills the shared pages ONCE, every
    later claim maps them (refcount bump, zero copy, prefill only over
    the tail), and the outputs stay bitwise identical to serving with
    the cache disabled."""
    base = dataclasses.replace(cfg, kv_cache_layout="paged")
    kw = dict(smoke=True, n_requests=6, batch_slots=3, gen_len=8,
              max_len=64, prompt_len=20)
    off = serve("qwen3-4b", shared_prefix_len=16, cfg=base, **kw)
    on = serve("qwen3-4b", shared_prefix_len=16,
               cfg=dataclasses.replace(base, kv_prefix_cache=True), **kw)
    p = on["prefix_cache"]
    print(f"[serve_topk] shared-prefix: hit-rate {p['hit_rate']:.2f} "
          f"({p['hits']}/{p['requests']}), prefill tokens saved "
          f"{p['prefill_tokens_saved']}/{p['prefill_tokens_total']}, "
          f"{p['cow_copies']} CoW copies, shared-page peak "
          f"{p['shared_pages_peak']}")
    print(f"[serve_topk] outputs bitwise equal to cache-disabled run: "
          f"{on['outputs'] == off['outputs']}")
    assert on["outputs"] == off["outputs"], "prefix cache changed outputs"
    assert p["hit_rate"] > 0 and p["prefill_tokens_saved"] > 0
    assert p["shared_pages_peak"] > 0
    assert all(len(v) == 8 for v in on["outputs"].values())


if __name__ == "__main__":
    main()
