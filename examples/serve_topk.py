"""Serving demo: batched request decoding through the SATA decode route
— incremental per-slot KV-block plan + selective gather kernel — using
the qwen3-family reduced config.  Prints the fetch-byte savings the
plan banks against dense decode over the whole prefix, and (with
``--paged``) serves from the paged KV pool: half the contiguous HBM
reservation, identical outputs, pool exhaustion absorbed as
backpressure instead of a shape error.

With ``--faults SEED`` the demo turns adversarial: a deterministic
squeeze/crash schedule forces host-swap preemptions and a mid-serve
crash, the allocator's invariant audit stays on throughout, and the
demo asserts the restored outputs are bitwise equal to the fault-free
run with zero re-prefilled tokens and zero cold re-plans.

With ``--overload SEED`` the demo runs the overload-resilience
scenario: a seeded load-spike schedule that forces >=2 preemptions
without the QoS ladder completes EVERY request with zero
requeues/timeouts when the ladder absorbs the pressure as per-slot
quality rungs, a corrupted host-swap payload is detected at the
swap-in checksum gate and quarantined (victim recovers by re-prefill),
and a child process killed mid-serve resumes from its checkpoint in
THIS process with bitwise-equal outputs.

With ``--retire`` the demo serves a workload whose live prefixes do
not fit the pool: cascade token retirement frees the coldest blocks'
pages mid-stream and the run completes without the preemptions the
retire-off twin needs.

With ``--replicas N`` the demo runs N serve replicas around one shared
prefix index: replica 0 publishes its shared-prefix pages' digests,
later replicas migrate those pages into their own pools instead of
re-prefilling, and the cross-replica hit rate is reported (outputs
bitwise equal across replicas).

Run:  PYTHONPATH=src python examples/serve_topk.py
          [--paged] [--summary int8] [--replan-mode sketch]
          [--retire] [--replicas N] [--faults SEED] [--overload SEED]
"""
import argparse
import dataclasses
import os
import subprocess
import sys
import tempfile

from repro.configs.archs import SMOKE
from repro.launch.faults import FaultPlan
from repro.launch.serve import (ResilienceOptions, ServeKilled,
                                ServeOptions, serve, serve_replicated)
from repro.models.config import (KVCacheConfig, QosConfig, RetireConfig,
                                 SataDecodeConfig)


def _with_decode(cfg, **kw):
    """Replace fields on ``cfg.sata.decode`` (nested-config idiom)."""
    return dataclasses.replace(
        cfg, sata=dataclasses.replace(
            cfg.sata,
            decode=dataclasses.replace(cfg.sata.decode, **kw)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged KV pool (half the "
                         "contiguous reservation)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="shared-prefix scenario: requests share a "
                         "prompt prefix and the prefix cache maps its "
                         "pages instead of re-prefilling them")
    ap.add_argument("--summary", choices=("fp32", "int8"), default="fp32",
                    help="block-summary backend: int8 stores "
                         "conservatively-quantized bounds (~4x less "
                         "summary traffic; summaries only RANK blocks "
                         "— the exact token threshold still runs over "
                         "the planned blocks' fp32 keys)")
    ap.add_argument("--replan-mode", choices=("exact", "sketch"),
                    default="exact",
                    help="periodic re-plan: 'exact' streams all cached "
                         "K; 'sketch' ranks super-block sketches first "
                         "and reads only surviving candidate blocks "
                         "(sub-linear in cached K; approximate — safe "
                         "when the plan tolerates a missed block until "
                         "the next re-plan, NOT for bitwise-exact "
                         "serving)")
    ap.add_argument("--retire", action="store_true",
                    help="cascade token retirement scenario: a pool too "
                         "small for every live request's full prefix — "
                         "retire-off preempts its way through, retire-on "
                         "frees the coldest blocks' pages mid-stream and "
                         "completes without a single preemption")
    ap.add_argument("--faults", type=int, default=None, metavar="SEED",
                    help="fault-injection scenario: a deterministic "
                         "squeeze + crash schedule forces host-swap "
                         "preemptions; asserts bitwise-equal restored "
                         "outputs with the invariant audit on")
    ap.add_argument("--replicas", type=int, default=0, metavar="N",
                    help="cross-replica prefix index scenario: N serve "
                         "replicas (each with its own page pool) share "
                         "one prefix digest index — later replicas "
                         "migrate replica 0's published prefix pages "
                         "instead of re-prefilling them")
    ap.add_argument("--overload", type=int, default=None, metavar="SEED",
                    help="overload-resilience scenario: seeded load "
                         "spikes absorbed by the QoS degradation "
                         "ladder, a corrupted swap payload quarantined "
                         "at the checksum gate, and a cross-process "
                         "kill/resume from checkpoint — all asserted")
    # internal: overload child mode (run to the kill step, then die)
    ap.add_argument("--_ckpt-dir", dest="_ckpt_dir", default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--_kill-at", dest="_kill_at", type=int, default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    cfg = dataclasses.replace(
        SMOKE["qwen3-4b"],
        topk_impl="bisect",         # bisect thresholds (the SATA predicate)
        sata=dataclasses.replace(
            SMOKE["qwen3-4b"].sata,
            decode=SataDecodeConfig(
                mode="on",          # route decode through the plan + kernel
                block=8,            # k-block edge over the 64-token cache
                replan=1,           # full re-plan every step (exact top-k)
                summary=args.summary,
                replan_mode=args.replan_mode)),
    )
    if args.replicas:
        return replicated_demo(cfg, args.replicas)
    if args.overload is not None:
        child_args = ["--summary", args.summary,
                      "--replan-mode", args.replan_mode]
        return overload_demo(cfg, args.overload, child_args,
                             ckpt_dir=args._ckpt_dir,
                             kill_at=args._kill_at)
    if args.faults is not None:
        return faults_demo(cfg, args.faults)
    if args.retire:
        return retire_demo(cfg)
    if args.shared_prefix:
        return shared_prefix_demo(cfg)
    if args.paged:
        # pool sized to HALF the contiguous reservation (3 slots × 8
        # pages): short-prefix slots stop reserving max_len worth of
        # HBM, and any transient over-demand stalls a slot for a step
        # instead of failing a shape
        cfg = dataclasses.replace(cfg, kv=KVCacheConfig(layout="paged",
                                                        pool_pages=12))
    # gen_len spans several k-blocks so top-k (4 keys) actually skips
    # blocks — the fetch-reduction line below is the point of the demo
    out = serve("qwen3-4b", smoke=True, cfg=cfg,
                options=ServeOptions(n_requests=6, batch_slots=3,
                                     gen_len=48, max_len=64))
    print(f"[serve_topk] completed {len(out['outputs'])} requests, "
          f"{out['tokens_generated']} tokens in {out['steps']} decode steps "
          f"({out['tok_per_s']:.1f} tok/s on CPU, mean request latency "
          f"{out['latency_mean_s'] * 1e3:.1f} ms)")
    f = out["decode_fetch"]
    # kernel-side accounting: at sata_decode_replan=1 the exact
    # re-plan itself still reads the full prefix's keys each step —
    # plan_fetch_bytes/true_reduction report that honestly (raise the
    # interval or set sata_decode_replan="auto" to shrink it)
    print(f"[serve_topk] attention-kernel KV fetch: "
          f"{f['kv_fetch_bytes_plan']} B vs {f['kv_fetch_bytes_dense']} B "
          f"dense ({f['fetch_reduction']:.2f}x reduction; "
          f"{f['true_reduction']:.2f}x counting plan traffic, "
          f"summary={f['summary_backend']}, replan={f['replan_mode']})")
    if args.paged:
        o = out["page_occupancy"]
        print(f"[serve_topk] paged pool: peak {o['pages_in_use_peak']}/"
              f"{o['n_pages']} pages, reserved "
              f"{o['reserved_vs_contiguous']:.2f}x less HBM than "
              f"contiguous ({o['stalled_steps']} stalled steps, "
              f"{o['deferred_claims']} deferred claims)")
        assert o["reserved_vs_contiguous"] >= 1.5
    first = sorted(out["outputs"])[0]
    print(f"[serve_topk] request {first} tokens: {out['outputs'][first]}")
    assert all(len(v) == 48 for v in out["outputs"].values())
    assert f["kv_fetch_tiles_plan"] < f["kv_fetch_tiles_dense"]


def faults_demo(cfg, seed):
    """Adversarial serving: a deterministic fault schedule — a hard
    pool squeeze (forces host-swap preemptions), seeded deferrals and
    forced preemptions, and a mid-serve crash — against a fault-free
    reference.  Host-swap restores must reproduce the reference
    bitwise with ZERO re-prefilled tokens and zero cold re-plans, and
    the allocator invariant audit runs after every mutation."""
    cfg = dataclasses.replace(_with_decode(cfg, replan=4),
                              kv=KVCacheConfig(layout="paged",
                                               pool_pages=6))
    opt = ServeOptions(n_requests=4, batch_slots=2, gen_len=12,
                       max_len=32, prompt_len=6)
    base = serve("qwen3-4b", cfg=cfg, smoke=True, options=opt)
    faults = (FaultPlan.seeded(seed, steps=24, n_events=3,
                               max_squeeze=2, slots=2)
              .pool_squeeze(2, 3).pool_restore(14)   # forces ≥2 swaps
              .crash_step(20))
    print(f"[serve_topk] fault schedule (seed {seed}):")
    print(faults.describe())
    out = serve("qwen3-4b", cfg=cfg, faults=faults, smoke=True,
                options=opt,
                resilience=ResilienceOptions(audit_pages=True))
    o = out["page_occupancy"]
    print(f"[serve_topk] {o['host_swaps']} host-swaps "
          f"({o['tokens_salvaged']} tokens salvaged, {o['swap_restores']} "
          f"restores, re_prefill_tokens={o['re_prefill_tokens']}, "
          f"cold_replans={o['swap_cold_replans']}), "
          f"{o['requeue_preemptions']} requeues, {o['crashes']} crash "
          f"recovered, {o['audits_run']} invariant audits")
    equal = out["outputs"] == base["outputs"]
    print(f"[serve_topk] outputs bitwise equal to fault-free run: {equal}")
    assert equal, "fault recovery changed outputs"
    assert o["host_swaps"] >= 2, "schedule failed to force 2 preemptions"
    assert o["re_prefill_tokens"] == 0 and o["swap_cold_replans"] == 0
    assert o["crashes"] == 1 and o["audits_run"] > 0
    assert all(len(v) == 12 for v in out["outputs"].values())


def _overload_schedule(seed):
    """Seeded load spikes / slow steps, plus a deterministic preempt →
    park → corrupt sequence so the checksum gate provably fires: the
    victim's swap handle sits parked (admission deferred) when the
    corruption lands, and its re-admission must quarantine it."""
    return (FaultPlan.seeded_overload(seed, steps=24, n_corrupt=0)
            .preempt(8).defer_admission(8).defer_admission(9)
            .corrupt_page(9).defer_admission(10))


def overload_demo(cfg, seed, child_args, ckpt_dir=None, kill_at=None):
    """Overload resilience, three pillars asserted end to end:

    1. The QoS ladder turns a load-spike schedule that forces >=2
       preemptions without it into per-slot quality rungs — every
       request completes, zero requeues/timeouts, and requests whose
       slots never degraded are BITWISE equal to the no-fault run.
    2. A byte flipped in a parked swap payload is detected at the
       swap-in checksum gate and quarantined; the victim recovers by
       deterministic re-prefill (outputs unchanged).
    3. A child process killed mid-serve resumes from its checkpoint in
       this process with bitwise-equal outputs."""
    cfg = _with_decode(cfg, replan=4)
    cfg = dataclasses.replace(
        cfg,
        sata=dataclasses.replace(cfg.sata, qos=QosConfig(ladder=True)),
        kv=KVCacheConfig(layout="paged", pool_pages=6))
    opt = ServeOptions(n_requests=4, batch_slots=2, gen_len=12,
                       max_len=32, prompt_len=6)
    faults = _overload_schedule(seed)
    if ckpt_dir is not None:
        # --- child mode: serve into the checkpoint dir until the
        # injected kill, then die (the parent resumes from disk)
        try:
            serve("qwen3-4b", cfg=cfg, faults=faults, smoke=True,
                  options=opt,
                  resilience=ResilienceOptions(checkpoint_dir=ckpt_dir,
                                               checkpoint_every=5,
                                               kill_at_step=kill_at))
        except ServeKilled as e:
            print(f"[serve_topk] child: {e}")
            return
        raise AssertionError("child completed — kill step never reached")
    print(f"[serve_topk] overload schedule (seed {seed}):")
    print(faults.describe())
    base = serve("qwen3-4b", cfg=cfg, smoke=True, options=opt)  # no faults
    out = serve("qwen3-4b", cfg=cfg, faults=faults, smoke=True,
                options=opt)
    off_cfg = dataclasses.replace(
        cfg, sata=dataclasses.replace(cfg.sata, qos=QosConfig(ladder=False)))
    off = serve("qwen3-4b", faults=faults, cfg=off_cfg, smoke=True,
                options=opt)
    o, q = out["page_occupancy"], out["qos"]
    print(f"[serve_topk] ladder OFF: "
          f"{off['page_occupancy']['preemptions']} preemptions; ladder "
          f"ON: {o['preemptions']} ({o['requeue_preemptions']} requeues, "
          f"{len(out['timed_out'])} timeouts), {q['rung_downs']} rung "
          f"downs / {q['rung_ups']} ups over {q['degraded_steps']} "
          f"degraded slot-steps")
    print(f"[serve_topk] degradation timelines: {out['degradation']}")
    # pillar 1 — the ladder absorbs what preemption used to shed
    # (the one remaining ladder-ON preemption is the demo's explicit
    # park-a-handle event, not spike shedding)
    assert off["page_occupancy"]["preemptions"] >= 2, \
        "schedule too soft: ladder-off run must need >= 2 preemptions"
    assert sorted(out["outputs"]) == list(range(opt.n_requests))
    assert o["requeue_preemptions"] == 0 and not out["timed_out"]
    assert all(len(v) == opt.gen_len for v in out["outputs"].values())
    assert any(tl for tl in out["degradation"].values()), \
        "spikes must appear on some request's timeline"
    for r, tl in out["degradation"].items():
        if not tl:
            assert out["outputs"][r] == base["outputs"][r], \
                f"request {r} never degraded but its tokens moved"
    # pillar 2 — the flipped byte is caught BEFORE any page scatters
    print(f"[serve_topk] integrity: {o['corrupt_pages_injected']} "
          f"corruptions injected, {o['corrupt_pages_detected']} detected, "
          f"{o['quarantined_pages']} pages quarantined, "
          f"re_prefill_tokens={o['re_prefill_tokens']}")
    assert o["corrupt_pages_injected"] == 1
    assert o["corrupt_pages_detected"] == 1
    assert o["re_prefill_tokens"] > 0, "victim must recover by re-prefill"
    # pillar 3 — cross-process kill/resume, bitwise
    d = tempfile.mkdtemp(prefix="serve_overload_ckpt_")
    cmd = [sys.executable, os.path.abspath(__file__),
           "--overload", str(seed), "--_ckpt-dir", d,
           "--_kill-at", "13"] + child_args
    subprocess.run(cmd, check=True, env=dict(os.environ))
    res = serve("qwen3-4b", cfg=cfg, faults=faults, smoke=True,
                options=opt,
                resilience=ResilienceOptions(checkpoint_dir=d,
                                             checkpoint_every=5,
                                             resume=True))
    equal = res["outputs"] == out["outputs"]
    print(f"[serve_topk] killed child resumed at step "
          f"{res['checkpoint']['resumed_at']}; outputs bitwise equal to "
          f"uninterrupted overload run: {equal}")
    assert equal, "checkpoint/resume changed outputs"
    print("[serve_topk] overload scenario OK")


def retire_demo(cfg):
    """Six 60-token requests (20 prompt + 40 generated) against a
    16-page pool that can hold only two full prefixes: without
    retirement the pool preempts and stalls its way through; with
    ``sata_retire="on"`` each slot frees its coldest attention blocks'
    pages mid-stream (ranked by the plan's decayed importance
    accumulator — zero extra cache reads), and the same workload
    completes without a single preemption.  Prints the per-request
    retirement timelines and the per-KV-head importance split the
    report prices."""
    base = dataclasses.replace(cfg, kv=KVCacheConfig(layout="paged",
                                                     pool_pages=16))
    opt = ServeOptions(n_requests=6, batch_slots=3, gen_len=40,
                       max_len=64, prompt_len=20, shared_prefix_len=12)
    off = serve("qwen3-4b", cfg=base, smoke=True, options=opt)
    on_cfg = dataclasses.replace(
        base, sata=dataclasses.replace(
            base.sata, retire=RetireConfig(mode="on", watermark=0.4,
                                           keep=0.5)))
    on = serve("qwen3-4b", cfg=on_cfg, smoke=True, options=opt)
    o_off, o_on = off["page_occupancy"], on["page_occupancy"]
    r = on["retirement"]
    print(f"[serve_topk] retire OFF: {o_off['preemptions']} preemptions, "
          f"{o_off['stalled_steps']} stalled steps, "
          f"{o_off['deferred_claims']} deferred claims, "
          f"{off['steps']} loop steps")
    print(f"[serve_topk] retire ON:  {o_on['preemptions']} preemptions, "
          f"{o_on['stalled_steps']} stalled steps, {on['steps']} loop "
          f"steps — {r['pages_reclaimed']} pages reclaimed mid-stream "
          f"over {r['events']} retirement events "
          f"({r['retired_tokens']} tokens, keep budget "
          f"{r['keep_budget']:.2f})")
    for req in sorted(r["timelines"])[:2]:
        print(f"[serve_topk]   request {req} timeline (step, pages): "
              f"{r['timelines'][req]}")
    print(f"[serve_topk] per-KV-head importance mass: "
          f"{[round(x, 1) for x in r['head_importance']]}")
    assert r["pages_reclaimed"] > 0, "retirement never fired"
    assert all(len(v) == opt.gen_len for v in on["outputs"].values())
    assert o_off["preemptions"] + o_off["stalled_steps"] > 0, \
        "pool too large: the off run never felt pressure"
    assert o_on["preemptions"] < o_off["preemptions"], \
        "retirement failed to absorb the preemption pressure"


def replicated_demo(cfg, n_replicas):
    """N serve replicas (each with its own page pool, prefix trie, and
    decode state) around ONE shared prefix index: replica 0 prefills
    the shared system prefix cold and publishes its full pages' digest
    chain; every later replica's lookup hits the index and MIGRATES the
    published pages into its local pool (refcount/CoW semantics intact
    — migration goes through the ordinary claiming slot) instead of
    re-running the shared-prefix prefill.  Prints the cross-replica hit
    rate and the prefill tokens the migrations saved; outputs must be
    bitwise equal across replicas."""
    cfg = dataclasses.replace(
        cfg, kv=KVCacheConfig(layout="paged", prefix_cache=True))
    out = serve_replicated(
        "qwen3-4b", n_replicas=n_replicas, smoke=True, cfg=cfg,
        options=ServeOptions(n_requests=6, batch_slots=3, gen_len=8,
                             max_len=64, prompt_len=20,
                             shared_prefix_len=16))
    idx = out["index"]
    print(f"[serve_topk] {out['n_replicas']} replicas / "
          f"{out['requests']} requests: cross-replica hit rate "
          f"{out['cross_replica_hit_rate']:.2f} "
          f"({out['cross_replica_hits']} hits), {out['migrated_pages']} "
          f"pages migrated ({out['migrated_tokens']} tokens), prefill "
          f"tokens saved {out['prefill_tokens_saved']}")
    print(f"[serve_topk] shared index: {idx['pages_published']} pages "
          f"published, {idx['lookups']} lookups, {idx['remote_hits']} "
          f"remote hits; outputs bitwise equal across replicas: "
          f"{out['outputs_equal']}")
    assert out["outputs_equal"], "migration changed replica outputs"
    assert out["cross_replica_hits"] >= n_replicas - 1
    assert out["migrated_pages"] >= 2
    assert out["prefill_tokens_saved"] > 0


def shared_prefix_demo(cfg):
    """Six requests share a 16-token system prefix of their 20-token
    prompts: the prefix cache prefills the shared pages ONCE, every
    later claim maps them (refcount bump, zero copy, prefill only over
    the tail), and the outputs stay bitwise identical to serving with
    the cache disabled."""
    base = dataclasses.replace(cfg, kv=KVCacheConfig(layout="paged"))
    opt = ServeOptions(n_requests=6, batch_slots=3, gen_len=8,
                       max_len=64, prompt_len=20, shared_prefix_len=16)
    off = serve("qwen3-4b", cfg=base, smoke=True, options=opt)
    on_cfg = dataclasses.replace(
        base, kv=dataclasses.replace(base.kv, prefix_cache=True))
    on = serve("qwen3-4b", cfg=on_cfg, smoke=True, options=opt)
    p = on["prefix_cache"]
    print(f"[serve_topk] shared-prefix: hit-rate {p['hit_rate']:.2f} "
          f"({p['hits']}/{p['requests']}), prefill tokens saved "
          f"{p['prefill_tokens_saved']}/{p['prefill_tokens_total']}, "
          f"{p['cow_copies']} CoW copies, shared-page peak "
          f"{p['shared_pages_peak']}")
    print(f"[serve_topk] outputs bitwise equal to cache-disabled run: "
          f"{on['outputs'] == off['outputs']}")
    assert on["outputs"] == off["outputs"], "prefix cache changed outputs"
    assert p["hit_rate"] > 0 and p["prefill_tokens_saved"] > 0
    assert p["shared_pages_peak"] > 0
    assert all(len(v) == 8 for v in on["outputs"].values())


if __name__ == "__main__":
    main()
