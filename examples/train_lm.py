"""End-to-end driver: train a ~100M-param LM with top-k selective
attention for a few hundred steps on synthetic data, with checkpointing
and restart support.

Config: 12L, d_model=768, 12 heads, d_ff=3072, vocab 32k → ~124M params
(GPT-2-small-class).  Top-k attention (k=32) is the SATA workload; the
same model runs dense attention with --dense for an accuracy A/B.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
(CPU-sized: ~1-2 s/step at batch 8 × seq 128.)
"""
import argparse

from repro.launch.train import train
from repro.models.config import ModelConfig
import repro.launch.train as T
import repro.configs.archs as A


def lm100m(dense: bool = False) -> ModelConfig:
    return ModelConfig(
        name="lm100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=32000,
        head_dim=64, attention_variant="dense" if dense else "topk",
        topk_k=32, q_chunk=128, dtype="float32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/sata_lm100m")
    args = ap.parse_args()

    cfg = lm100m(args.dense)
    print(f"[train_lm] {cfg.name}: {cfg.param_count()/1e6:.0f}M params, "
          f"attention={cfg.attention_variant}")
    # register so the generic launcher can use it (mutate in place — the
    # launcher holds a direct reference to this dict)
    A.SMOKE["lm100m"] = cfg
    out = train("lm100m", smoke=True, steps=args.steps, batch=args.batch,
                seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                log_every=10)
    ls = out["losses"]
    print(f"[train_lm] loss {ls[0]:.3f} → {ls[-1]:.3f} over {len(ls)} steps "
          f"({out['stragglers']} straggler steps flagged)")
    if args.steps >= 50:          # short runs sit inside LR warmup
        assert min(ls[-10:]) < ls[0], "loss did not decrease"


if __name__ == "__main__":
    main()
