"""ASCII rendition of the paper's Fig. 2: mask sorting, query
classification, and the Algo-2 FSM schedule for a small head.

Run:  PYTHONPATH=src python examples/schedule_demo.py
"""
import numpy as np

from repro.core import (QType, build_schedule, coverage_ok,
                        sort_and_classify)


def show_mask(mask, title):
    print(f"\n{title}")
    for row in mask:
        print("  " + "".join("#" if v else "." for v in row))


def main():
    rng = np.random.default_rng(4)
    n, k = 12, 4
    # two query groups with shared key preferences + scattered columns
    base = np.zeros((n, n), dtype=bool)
    base[:6, :5] = True
    base[6:, 7:] = True
    base[2, 8] = base[9, 1] = True          # a couple of GLOB-ish queries
    perm = rng.permutation(n)
    mask = base[:, perm]                     # scramble key order

    show_mask(mask, f"selective mask (N={n}, ~K={k}) — scrambled key order")
    res = sort_and_classify(mask, seed=0)
    show_mask(mask[:, res.kid], f"after Algo-1 key sorting "
              f"(S_h={res.s_h}, head type {res.head_type.name})")
    names = {QType.HEAD: "HEAD", QType.TAIL: "TAIL", QType.GLOB: "GLOB"}
    print("  query classes:",
          " ".join(names[QType(t)] for t in res.qtypes))

    sched = build_schedule([res])
    print("\nAlgo-2 FSM schedule (one head):")
    for s in sched.steps:
        ks = ",".join(map(str, s.k_mac)) or "-"
        qs = ",".join(map(str, s.q_load)) or "-"
        print(f"  {s.phase:8s} MAC keys [{ks:12s}] "
              f"| load queries [{qs}] (active={s.n_active_q})")
    print("\ncoverage check:", coverage_ok(sched, mask[None]))


if __name__ == "__main__":
    main()
