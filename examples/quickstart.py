"""Quickstart: SATA end to end in ~60 seconds on CPU.

1. Build top-k selective masks for a KVT-like workload.
2. Run Algo 1 (sort+classify) + Algo 2 (FSM schedule) and print the
   Tab.-I statistics.
3. Simulate scheduled vs dense/gated execution (Fig. 4a).
4. Plan the TPU-native block-sparse execution and run the Pallas kernel
   (interpret mode) against the exact top-k oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.workloads import WORKLOADS
from repro.core import (HwConfig, plan, simulate_dense, simulate_gated,
                        simulate_tiled_sata)
from repro.core.blockmap import block_skip_fraction
from repro.core.masks import SyntheticTrace, synthetic_masks, topk_mask
from repro.kernels.ops import sata_attention, sata_attention_reference


def main():
    # --- 1-3: the paper's evaluation plane --------------------------------
    w = WORKLOADS["kvt_tiny"]
    masks = synthetic_masks(0, w.trace, w.n_heads)
    p = plan(masks, s_f=w.s_f)
    print(f"workload {w.name}: N={w.n_tokens} K={w.k} S_f={w.s_f}")
    print(f"  post-schedule stats: GlobQ%={p.stats.glob_q_frac:.3f} "
          f"(paper {w.paper_glob_q}), S_h={p.stats.avg_s_h_frac:.3f}N "
          f"(paper {w.paper_s_h_frac}N)")
    hw = HwConfig()
    r = simulate_tiled_sata(p.tiled, w.d_k, hw)
    d = simulate_dense(masks, w.d_k, hw)
    g = simulate_gated(masks, w.d_k, hw)
    print(f"  throughput gain vs dense: {r.throughput_gain(d):.2f}x "
          f"(paper {w.paper_throughput_gain}x)")
    print(f"  energy-eff gain vs dense: {r.energy_eff_gain(d):.2f}x "
          f"(paper {w.paper_energy_gain}x)")
    print(f"  gated baseline saves energy but not time: "
          f"{g.latency_cycles/d.latency_cycles:.2f}x latency, "
          f"{d.energy_pj/g.energy_pj:.2f}x energy")

    # --- 4: the TPU plane --------------------------------------------------
    tr = SyntheticTrace(n_tokens=256, k=32, cluster_scale=3.0,
                        discrete_clusters=8, noise=0.3)
    m = jnp.asarray(synthetic_masks(0, tr, n_heads=2))
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 256, 64)), jnp.float32)
    k_ = jnp.asarray(rng.standard_normal((2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 64)), jnp.float32)
    out, bm = sata_attention(q, k_, v, m, q_block=32, k_block=32)
    ref = sata_attention_reference(q, k_, v, m)
    print(f"pallas kernel: block skip {float(block_skip_fraction(bm)):.2%}, "
          f"max err vs exact top-k oracle "
          f"{float(jnp.max(jnp.abs(out - ref))):.2e}")


if __name__ == "__main__":
    main()
