"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Select suites with
``python -m benchmarks.run [suite ...]``; default runs all.

``--json-dir DIR`` additionally writes one ``BENCH_<suite>.json``
artifact per suite (rows + metadata) so successive PRs accumulate a
perf trajectory — CI runs ``--json-dir results/bench kernel`` to track
dense-grid vs compacted-grid kernel timings and fetch bytes.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import sys
import time


def _suite_artifact(suite: str, rows) -> dict:
    import jax
    return {
        "suite": suite,
        "unix_time": time.time(),
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "rows": [{"name": n, "us_per_call": us, "derived": derived}
                 for n, us, derived in rows],
    }


def main() -> None:
    from benchmarks.paper_tables import ALL
    ap = argparse.ArgumentParser()
    ap.add_argument("suites", nargs="*", default=[],
                    help=f"suites to run (default all): {sorted(ALL)}")
    ap.add_argument("--json-dir", default=None,
                    help="write BENCH_<suite>.json artifacts here")
    args = ap.parse_args()
    wanted = args.suites or list(ALL)
    out_dir = pathlib.Path(args.json_dir) if args.json_dir else None
    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    for suite in wanted:
        if suite not in ALL:
            print(f"# unknown suite {suite}; have {sorted(ALL)}",
                  file=sys.stderr)
            continue
        rows = ALL[suite]()
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        if out_dir:
            path = out_dir / f"BENCH_{suite}.json"
            path.write_text(json.dumps(_suite_artifact(suite, rows),
                                       indent=1))
            print(f"# wrote {path}", file=sys.stderr)


if __name__ == '__main__':
    main()
