"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Select suites with
``python -m benchmarks.run [suite ...]``; default runs all.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks.paper_tables import ALL
    wanted = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    for suite in wanted:
        if suite not in ALL:
            print(f"# unknown suite {suite}; have {sorted(ALL)}",
                  file=sys.stderr)
            continue
        for name, us, derived in ALL[suite]():
            print(f"{name},{us:.1f},{derived}")


if __name__ == '__main__':
    main()
