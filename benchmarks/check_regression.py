"""Bench-regression gate: freshly generated ``BENCH_<suite>.json``
artifacts vs the committed baselines in ``results/bench/``.

The perf-trajectory artifacts were upload-only until PR 5; this turns
them into a firewall.  **Gate contract** (what fails the build):

* **Exact fields** — deterministic counters parsed out of each row's
  ``derived`` string (fetch bytes/tiles, tile visits, re-plan counts,
  reserved/used HBM, prefill tokens saved, hit counts, retirement
  reclaim/completion/divergence counters, the ``quad_SxS_buffer``
  flag, mesh parity booleans and per-shard work splits): must be
  EQUAL to the baseline.  These are
  pure functions of code + seeds — any drift is a real behavior
  change, not noise.
* **Parity fields** — ``max_err`` values: a ``0.0`` baseline is a
  bitwise property and must stay exactly ``0.0``; a nonzero baseline
  (fp accumulation-order tolerance) may not grow beyond 4x (platform
  jitter guard, catches order-of-magnitude breakage).
* **Wall-time rows** (``us_per_call > 0`` in both files): per-row
  ratio fresh/baseline, NORMALIZED by the suite's median ratio — the
  median cancels machine-speed differences between the baseline
  machine and the CI runner, so what is gated is each row's slowdown
  *relative to the rest of the suite*.  A normalized ratio above
  ``--tol-wall`` (default 2.0) fails.  Rows under ``--min-us`` are
  skipped as noise.
* **Coverage** — a baseline row missing from the fresh run fails (a
  silently dropped benchmark reads as "no regression"); new rows are
  reported as trajectory growth and pass.

**Blessing a new baseline** (intended perf change or new rows):
re-run ``make bench bench-select bench-decode`` and commit the
regenerated ``results/bench/BENCH_*.json`` — the gate always compares
against whatever baseline is committed.

A markdown trajectory table is appended to ``$GITHUB_STEP_SUMMARY``
when set (or ``--summary PATH``).  Exit code 1 on any regression.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import re
import sys
from typing import Dict, List, Optional, Tuple

SUITES = ("kernel", "select", "decode")

# deterministic integer counters: (label, regex with one int group)
EXACT_PATTERNS = [
    ("plan_bytes", r"planB (\d+)"),
    ("dense_bytes", r"denseB (\d+)"),
    ("fetch_tiles", r"fetch tiles (\d+)"),
    ("tile_visits", r"visits (\d+)"),
    ("fetch_bytes", r"fetchB (\d+)"),
    ("reserved_bytes", r"reserved (\d+) B"),
    ("used_bytes", r"used (\d+) B"),
    ("step_plan_bytes", r"step (\d+) B plan-route"),
    ("step_dense_bytes", r"vs (\d+) B dense"),
    ("plan_side_bytes", r"plan side (\d+) B"),
    ("full_replans", r"(\d+) full re-plans"),
    ("tokens_saved", r"saved (\d+)/"),
    ("hits", r"\((\d+)/\d+ hits\)"),
    ("cow_copies", r"(\d+) CoW copies"),
    ("tokens_salvaged", r"(\d+) tokens salvaged"),
    ("host_swaps", r"over (\d+) host-swaps"),
    ("re_prefill_tokens", r"re_prefill_tokens=(\d+)"),
    ("cold_replans", r"cold_replans=(\d+)"),
    ("requeue_discarded", r"requeue discarded (\d+) tokens"),
    ("quad_buffer", r"quad_SxS_buffer=(True|False)"),
    ("outputs_equal", r"outputs_equal=(True|False)"),
    # overload-resilience rows (decode/degradation/*)
    ("completed", r"completed (\d+)/\d+ requests"),
    ("requeues", r"requeues=(\d+)"),
    ("timeouts", r"timeouts=(\d+)"),
    ("degraded_steps", r"degraded_steps=(\d+)"),
    ("rung_downs", r"rung_downs=(\d+)"),
    ("rung_ups", r"rung_ups=(\d+)"),
    ("spike_preemptions", r"over (\d+) preemptions"),
    ("corrupt_injected", r"corrupt_injected=(\d+)"),
    ("corrupt_detected", r"corrupt_detected=(\d+)"),
    ("quarantined_pages", r"quarantined_pages=(\d+)"),
    # cascade-retirement rows (decode/retirement/*)
    ("pages_reclaimed", r"reclaimed (\d+) pages"),
    ("retire_events", r"over (\d+) events"),
    ("tokens_retired", r"\((\d+) tokens retired"),
    ("retire_first_step", r"first at step (\d+)/"),
    ("no_preempt_on", r"completions (\d+)/\d+ retire-on"),
    ("no_preempt_off", r"vs (\d+)/\d+ retire-off"),
    ("plan_bytes_keep50", r"traffic (\d+) B at keep 0\.50"),
    ("plan_bytes_keep25", r"(\d+) B at keep 0\.25"),
    ("plan_bytes_retire_off", r"vs (\d+) B retire-off"),
    ("diverge_keep75", r"0\.75 -> ([0-9.]+)"),
    ("diverge_keep50", r"0\.50 -> ([0-9.]+)"),
    ("diverge_keep25", r"0\.25 -> ([0-9.]+)"),
    # mesh-sharded serving rows (decode/mesh/*): parity booleans and
    # the per-shard work split are bitwise properties of the sharding
    # (max_err itself rides the generic MAX_ERR_RE gate below); only
    # tp_scale wall-time is banded, and the docstring in
    # benchmarks/mesh_rows.py explains why wall is informational on a
    # simulated mesh.
    ("mesh_thr_eq", r"thr_eq=(True|False)"),
    ("mesh_plan_eq", r"plan_eq=(True|False)"),
    ("mesh_fetch_sum", r"fetched tiles sum (\d+)"),
    ("mesh_fetch_total", r"sum \d+ of (\d+) single-device"),
    ("mesh_max_shard", r"max shard (\d+)"),
    ("mesh_tp_shard_max", r"planned tiles max (\d+)"),
    ("mesh_tp_plan_tiles", r"tiles max \d+ of (\d+) total"),
]
MAX_ERR_RE = re.compile(r"max_err[_a-z]*\s+([0-9.]+e?[+-]?[0-9]*)")


def _fields(derived: str) -> Dict[str, str]:
    out = {}
    for label, pat in EXACT_PATTERNS:
        m = re.search(pat, derived)
        if m:
            out[label] = m.group(1)
    return out


def _load(path: pathlib.Path) -> Optional[Dict[str, Tuple[float, str]]]:
    if not path.exists():
        return None
    data = json.loads(path.read_text())
    return {r["name"]: (float(r["us_per_call"]), str(r["derived"]))
            for r in data["rows"]}


def check_suite(suite: str, base: Dict, fresh: Dict, *, tol_wall: float,
                min_us: float) -> Tuple[List[str], List[str]]:
    """Returns (failures, table_rows)."""
    fails: List[str] = []
    table: List[str] = []
    common = [n for n in base if n in fresh]
    for name in base:
        if name not in fresh:
            fails.append(f"{suite}: row `{name}` disappeared from the "
                         f"fresh run (coverage regression)")
    # wall-time: normalize by the suite median ratio (cancels machine
    # speed), then band each row
    ratios = {}
    for name in common:
        b_us, f_us = base[name][0], fresh[name][0]
        if b_us > min_us and f_us > 0:
            ratios[name] = f_us / b_us
    median = sorted(ratios.values())[len(ratios) // 2] if ratios else 1.0
    for name in common:
        b_us, b_der = base[name]
        f_us, f_der = fresh[name]
        status = "ok"
        norm = ratios.get(name, 0.0) / median if name in ratios else None
        if norm is not None and norm > tol_wall:
            status = "WALL-REGRESSION"
            fails.append(
                f"{suite}: `{name}` wall time {f_us:.0f}us vs baseline "
                f"{b_us:.0f}us — {norm:.2f}x the suite-median drift "
                f"(tolerance {tol_wall}x)")
        bf, ff = _fields(b_der), _fields(f_der)
        for label, bval in bf.items():
            fval = ff.get(label)
            if fval != bval:
                status = "EXACT-MISMATCH"
                fails.append(
                    f"{suite}: `{name}` field {label}: baseline {bval} "
                    f"vs fresh {fval} (exact-gated)")
        mb = MAX_ERR_RE.search(b_der)
        mf = MAX_ERR_RE.search(f_der)
        if mb and mf:
            be, fe = float(mb.group(1)), float(mf.group(1))
            if be == 0.0 and fe != 0.0:
                status = "PARITY-BROKEN"
                fails.append(f"{suite}: `{name}` bitwise parity broke: "
                             f"max_err {fe:g} (baseline 0.0)")
            elif be > 0.0 and fe > 4.0 * be:
                status = "PARITY-DRIFT"
                fails.append(f"{suite}: `{name}` max_err {fe:g} > 4x "
                             f"baseline {be:g}")
        table.append(f"| {name} | {b_us:.0f} | {f_us:.0f} | "
                     f"{norm:.2f}x | {status} |" if norm is not None else
                     f"| {name} | — | — | — | {status} |")
    for name in fresh:
        if name not in base:
            table.append(f"| {name} | (new) | {fresh[name][0]:.0f} "
                         f"| — | new row |")
    return fails, table


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline-dir", default="results/bench")
    ap.add_argument("--fresh-dir", default="results/bench_fresh")
    ap.add_argument("--suites", nargs="*", default=list(SUITES))
    ap.add_argument("--tol-wall", type=float, default=2.0,
                    help="normalized wall-ratio band (default 2.0x)")
    ap.add_argument("--min-us", type=float, default=100.0,
                    help="skip wall gating under this baseline time")
    ap.add_argument("--summary", default=os.environ.get(
        "GITHUB_STEP_SUMMARY"))
    args = ap.parse_args()
    all_fails: List[str] = []
    lines = ["# Bench regression gate", ""]
    for suite in args.suites:
        base = _load(pathlib.Path(args.baseline_dir)
                     / f"BENCH_{suite}.json")
        fresh = _load(pathlib.Path(args.fresh_dir) / f"BENCH_{suite}.json")
        lines.append(f"## {suite}")
        if base is None:
            lines += [f"_no committed baseline — gate skipped "
                      f"(bless one via `make bench-{suite}`)_", ""]
            print(f"[gate] {suite}: no baseline, skipped", file=sys.stderr)
            continue
        if fresh is None:
            all_fails.append(f"{suite}: fresh artifact missing from "
                             f"{args.fresh_dir}")
            lines += ["_fresh artifact missing_", ""]
            continue
        fails, table = check_suite(suite, base, fresh,
                                   tol_wall=args.tol_wall,
                                   min_us=args.min_us)
        all_fails += fails
        lines += ["| row | baseline us | fresh us | norm ratio | status |",
                  "|---|---|---|---|---|"] + table + [""]
    if all_fails:
        lines += ["## ❌ regressions", ""] + [f"- {f}" for f in all_fails]
    else:
        lines += ["✅ no regressions against the committed baselines"]
    report = "\n".join(lines)
    print(report)
    if args.summary:
        with open(args.summary, "a") as fh:
            fh.write(report + "\n")
    if all_fails:
        print(f"\n[gate] FAILED: {len(all_fails)} regression(s)",
              file=sys.stderr)
        sys.exit(1)
    print("\n[gate] green", file=sys.stderr)


if __name__ == "__main__":
    main()
