"""Mesh scaling rows for BENCH_decode.json — run as a SUBPROCESS.

``bench_decode._bench_mesh`` spawns this module with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so jax
initializes an 8-device simulated CPU mesh (the parent process already
initialized jax single-device; the flag only takes effect before first
init).  Prints one line: ``MESH_ROWS_JSON:<json list of rows>``.

Row semantics (what the regression gate can and cannot pin on a
simulated mesh): parity fields and per-shard planned-tile counts are
EXACT — selection is row-local and decode is per-KV-head local, so
sharded output must be bitwise the single-device run at ``replan=1``
fp32, and per-shard work must partition the single-device plan.
Wall-clock tok/s is informational: the 8 "devices" share one host's
cores, so near-linear wall speedup is a property of a real mesh, not
of this simulation — the linear-scaling evidence CI pins is the
per-shard fetch/work split.
"""
from __future__ import annotations

import json


def main() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import timed
    from repro.core.decode_plan import (decode_plan_update,
                                        init_decode_plan,
                                        update_block_summaries)
    from repro.kernels.ops import sata_decode_attention
    from repro.launch import mesh as M

    assert len(jax.devices()) >= 8, (
        "mesh rows need the forced 8-device host platform")
    rows = []
    rng = np.random.default_rng(17)

    # --- sequence-sharded selection: parity + plan-proportional fetch
    bh, s, sk, d, qb, kb = 4, 256, 256, 32, 32, 32
    q = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, sk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, sk, d)), jnp.float32)
    ref, rstats = M.sequence_local_attention(q, k, v, k_sel=32,
                                             q_block=qb, k_block=kb)
    total_tiles = int(rstats["fetched_tiles"])
    for ways in (2, 4, 8):
        mesh = M.make_shard_mesh(ways)
        out, stats = M.sequence_sharded_attention(mesh, q, k, v,
                                                  k_sel=32, q_block=qb,
                                                  k_block=kb)
        err = float(jnp.abs(out - ref).max())
        thr_eq = bool((stats["thresholds"] == rstats["thresholds"]).all())
        per_shard = np.asarray(stats["fetched_tiles_per_shard"])
        rows.append([f"decode/mesh/seq_parity/W{ways}", 0.0,
                     f"max_err {err:.2e} sharded vs single-device "
                     f"(replan-free prefill selection, fp32, bitwise "
                     f"gate), thr_eq={thr_eq}"])
        rows.append([f"decode/mesh/seq_fetch/W{ways}", 0.0,
                     f"per-shard fetched tiles sum {int(per_shard.sum())} "
                     f"of {total_tiles} single-device plan tiles "
                     f"(plan-proportional halo exchange, max shard "
                     f"{int(per_shard.max())})"])

    # --- tensor-parallel decode: parity + per-shard work + tok/s
    b, kv, g, smax, dkb = 2, 8, 2, 2048, 128
    pos0 = smax - 1
    kc = jnp.asarray(rng.standard_normal((b, smax, kv, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, smax, kv, d)), jnp.float32)
    qg = jnp.asarray(rng.standard_normal((b, kv, g, d)), jnp.float32)
    kn = kc[:, pos0:pos0 + 1]
    pos = jnp.full((b,), pos0, jnp.int32)

    def ref_step(plan):
        plan = update_block_summaries(plan, kn, pos, k_block=dkb)
        plan, thr = decode_plan_update(plan, qg, kc, pos, topk_k=64,
                                       k_block=dkb, replan_interval=1)
        out = sata_decode_attention(qg, kc, vc, plan["kv_indices"],
                                    plan["kv_counts"], thr, pos,
                                    k_block=dkb)
        return out, plan

    oref, pref = ref_step(init_decode_plan(b, kv, smax, d, dkb))
    plan_tiles = int(np.asarray(pref["kv_counts"]).sum())
    for ways in (1, 2, 4, 8):
        plan0 = init_decode_plan(b, kv, smax, d, dkb)
        if ways == 1:
            fn = jax.jit(lambda: ref_step(plan0))
        else:
            mesh = M.make_shard_mesh(ways)
            fn = jax.jit(lambda m=mesh: M.tensor_parallel_decode_step(
                m, qg, kc, vc, kn, pos, plan0, topk_k=64, k_block=dkb,
                replan_interval=1))
        out, pnew = fn()
        jax.block_until_ready(out)
        _, us = timed(lambda: jax.block_until_ready(fn()[0]), repeat=3)
        err = float(jnp.abs(out - oref).max())
        plan_eq = all(bool((np.asarray(pnew[n]) ==
                            np.asarray(pref[n])).all()) for n in pref)
        rows.append([f"decode/mesh/tp_parity/W{ways}", 0.0,
                     f"max_err {err:.2e} sharded vs single-device "
                     f"(replan=1 fp32, bitwise gate), "
                     f"plan_eq={plan_eq}"])
        cnts = np.asarray(pnew["kv_counts"])          # (B, KV)
        shard_tiles = cnts.reshape(b, ways, kv // ways).sum(axis=(0, 2))
        rows.append([f"decode/mesh/tp_scale/W{ways}", us,
                     f"{b * 1e6 / us:.1f} tok/s, per-shard planned "
                     f"tiles max {int(shard_tiles.max())} of "
                     f"{plan_tiles} total (KV-head split, no "
                     f"collectives; wall informational on the "
                     f"simulated mesh)"])
    print("MESH_ROWS_JSON:" + json.dumps(rows))


if __name__ == "__main__":
    main()
