"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import numpy as np

from repro.configs.workloads import WORKLOADS, Workload
from repro.core import (HwConfig, plan, simulate_dense, simulate_gated,
                        simulate_schedule, simulate_tiled_sata)
from repro.core.masks import synthetic_masks

Row = Tuple[str, float, str]          # (name, us_per_call, derived)


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


def workload_reports(name: str, seeds=(0, 1, 2), hw: HwConfig = None):
    """(sata, dense, gated, stats, planning_us) averaged over trace seeds."""
    w = WORKLOADS[name]
    hw = hw or HwConfig()
    gains_t, gains_e, gains_tg, gains_eg = [], [], [], []
    stats = []
    plan_us = []
    for seed in seeds:
        masks = synthetic_masks(seed, w.trace, w.n_heads)
        p, us = timed(plan, masks, s_f=w.s_f)
        plan_us.append(us)
        if w.s_f is not None:
            r = simulate_tiled_sata(p.tiled, w.d_k, hw)
        else:
            r = simulate_schedule(p.schedule, w.d_k, hw)
        d = simulate_dense(masks, w.d_k, hw)
        g = simulate_gated(masks, w.d_k, hw)
        gains_t.append(r.throughput_gain(d))
        gains_e.append(r.energy_eff_gain(d))
        gains_tg.append(r.throughput_gain(g))
        gains_eg.append(r.energy_eff_gain(g))
        stats.append(p.stats)
    return {
        "thr": float(np.mean(gains_t)), "en": float(np.mean(gains_e)),
        "thr_vs_gated": float(np.mean(gains_tg)),
        "en_vs_gated": float(np.mean(gains_eg)),
        "glob_q": float(np.mean([s.glob_q_frac for s in stats])),
        "s_h": float(np.mean([s.avg_s_h_frac for s in stats])),
        "n_dec": float(np.mean([s.avg_n_decrements for s in stats])),
        "glob_head": float(np.mean([s.glob_head_frac for s in stats])),
        "plan_us": float(np.mean(plan_us)),
        "workload": w,
    }
