"""One benchmark per paper table/figure (Tab. I, Fig. 4a/4b/4c,
Sec. IV-C scaling, Sec. IV-D overhead) + the TPU kernel counterpart."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, timed, workload_reports
from repro.configs.workloads import WORKLOADS
from repro.core import (HwConfig, plan, scheduler_cost, simulate_dense,
                        simulate_gated, simulate_schedule,
                        simulate_tiled_sata)
from repro.core.masks import SyntheticTrace, synthetic_masks


# ---------------------------------------------------------------------------
# Tab. I — workload specification & post-schedule statistics
# ---------------------------------------------------------------------------

def bench_tab1() -> List[Row]:
    rows: List[Row] = []
    for name, w in WORKLOADS.items():
        rep = workload_reports(name)
        rows.append((f"tab1/{name}/glob_q", rep["plan_us"],
                     f"{rep['glob_q']:.3f} (paper {w.paper_glob_q})"))
        rows.append((f"tab1/{name}/s_h_frac", rep["plan_us"],
                     f"{rep['s_h']:.3f} (paper {w.paper_s_h_frac})"))
        rows.append((f"tab1/{name}/n_dec", rep["plan_us"],
                     f"{rep['n_dec']:.2f} (paper {w.paper_n_dec})"))
        rows.append((f"tab1/{name}/glob_head_frac", rep["plan_us"],
                     f"{rep['glob_head']:.4f} (paper <0.001 for TTST)"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 4a — QK throughput & energy-efficiency gain per workload
# ---------------------------------------------------------------------------

def bench_fig4a() -> List[Row]:
    rows: List[Row] = []
    for name, w in WORKLOADS.items():
        rep = workload_reports(name)
        rows.append((f"fig4a/{name}/throughput_gain", rep["plan_us"],
                     f"{rep['thr']:.2f}x (paper {w.paper_throughput_gain}x)"))
        rows.append((f"fig4a/{name}/energy_eff_gain", rep["plan_us"],
                     f"{rep['en']:.2f}x (paper {w.paper_energy_gain}x)"))
        rows.append((f"fig4a/{name}/vs_gated_thr", rep["plan_us"],
                     f"{rep['thr_vs_gated']:.2f}x"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 4b — BERT-based model runtime with SATA integration
# ---------------------------------------------------------------------------

def bench_fig4b() -> List[Row]:
    """Self-attention runtime split (Energon-style BERT-base profile):
    static projections keep dense timing, the QK stage is SATA-scheduled;
    derived = normalized self-attention runtime vs the dense baseline."""
    hw = HwConfig()
    n, k, d_k, heads = 384, 48, 64, 12
    tr = SyntheticTrace(n_tokens=n, k=k, cluster_rank=2, cluster_scale=1.0,
                        band_width=24.0, band_scale=2.5, noise=0.35)
    masks = synthetic_masks(0, tr, heads)
    p, us = timed(plan, masks, s_f=32)
    r = simulate_tiled_sata(p.tiled, d_k, hw)
    d = simulate_dense(masks, d_k, hw)
    qk_gain = r.throughput_gain(d)
    # BERT-base profile: QK ≈ 28% of self-attention runtime at N=384
    # (projections 55%, AV 17% — both unchanged by SATA).
    qk_share = 0.28
    normalized = (1 - qk_share) + qk_share / qk_gain
    return [
        ("fig4b/bert_qk_gain", us, f"{qk_gain:.2f}x"),
        ("fig4b/bert_selfattn_runtime", us,
         f"{normalized:.3f} of baseline (paper Fig4b: ~0.8-0.9)"),
    ]


# ---------------------------------------------------------------------------
# Fig. 4c — integrating SATA into SOTA accelerators
# ---------------------------------------------------------------------------

def bench_fig4c() -> List[Row]:
    """A3 / SpAtten / Energon modeled as gated accelerators at their own
    pruning ratios; SATA adds locality scheduling on top.  A3's recursive
    candidate search keeps a serial stage SATA cannot overlap (paper:
    'limited improvement')."""
    hw = HwConfig()
    sotas = {
        # (keep ratio, un-overlappable search fraction of runtime)
        "a3": (0.40, 0.45),
        "spatten": (0.50, 0.10),
        "energon": (0.30, 0.15),
    }
    rows: List[Row] = []
    gains_e, gains_t = [], []
    for name, (keep, serial_frac) in sotas.items():
        n, heads, d_k = 256, 8, 64
        tr = SyntheticTrace(n_tokens=n, k=max(1, int(keep * n)),
                            cluster_rank=2, cluster_scale=1.0,
                            band_width=24.0, band_scale=2.0, noise=0.4)
        masks = synthetic_masks(0, tr, heads)
        p, us = timed(plan, masks, s_f=32)
        r = simulate_tiled_sata(p.tiled, d_k, hw)
        g = simulate_gated(masks, d_k, hw)
        thr = r.throughput_gain(g)
        en = r.energy_eff_gain(g)
        # Amdahl over the accelerator's non-schedulable stage
        thr_eff = 1.0 / (serial_frac + (1 - serial_frac) / thr)
        en_eff = 1.0 / (serial_frac + (1 - serial_frac) / en)
        gains_t.append(thr_eff)
        gains_e.append(en_eff)
        rows.append((f"fig4c/{name}/throughput_gain", us, f"{thr_eff:.2f}x"))
        rows.append((f"fig4c/{name}/energy_gain", us, f"{en_eff:.2f}x"))
    rows.append(("fig4c/avg_energy_gain", 0.0,
                 f"{np.mean(gains_e):.2f}x (paper avg 1.34x)"))
    rows.append(("fig4c/avg_throughput_gain", 0.0,
                 f"{np.mean(gains_t):.2f}x (paper avg 1.30x)"))
    return rows


# ---------------------------------------------------------------------------
# Sec. IV-C — tile-size (S_f) scaling study
# ---------------------------------------------------------------------------

def bench_scaling_sf() -> List[Row]:
    hw = HwConfig()
    w = WORKLOADS["kvt_tiny"]
    masks = synthetic_masks(0, w.trace, w.n_heads)
    d = simulate_dense(masks, w.d_k, hw)
    rows: List[Row] = []
    best = (None, 0.0)
    for s_f in (11, 18, 22, 33, 66, 99, 198):
        p, us = timed(plan, masks, s_f=s_f if s_f < 198 else None)
        if p.tiled is not None:
            r = simulate_tiled_sata(p.tiled, w.d_k, hw)
            zskip = p.tiled.zero_skip_fraction
        else:
            r = simulate_schedule(p.schedule, w.d_k, hw)
            zskip = 0.0
        gain = r.throughput_gain(d)
        if gain > best[1]:
            best = (s_f, gain)
        rows.append((f"scaling_sf/kvt_tiny/sf{s_f}", us,
                     f"thr {gain:.2f}x zskip {zskip:.2f}"))
    rows.append(("scaling_sf/kvt_tiny/best", 0.0,
                 f"S_f={best[0]} at {best[1]:.2f}x "
                 f"(paper optimum S_f=0.11N=22)"))
    return rows


# ---------------------------------------------------------------------------
# Sec. IV-D — scheduler overhead
# ---------------------------------------------------------------------------

def bench_overhead() -> List[Row]:
    hw = HwConfig()
    rows: List[Row] = []
    # energy overhead vs D_k at S_f=22 (paper: <5% when D_k >= 64...)
    for d_k in (16, 32, 64, 128, 4800):
        w = WORKLOADS["kvt_tiny"]
        masks = synthetic_masks(0, w.trace, w.n_heads)
        p, us = timed(plan, masks, s_f=22)
        r = simulate_tiled_sata(p.tiled, d_k, hw)
        frac = r.scheduler_energy_pj / r.energy_pj
        rows.append((f"overhead/energy_dk{d_k}", us,
                     f"{frac*100:.2f}% (paper <5% for D_k>=64)"))
    # latency overhead vs S_f (paper: <5% when S_f <= 24)
    for s_f in (11, 22, 28, 33):
        w = WORKLOADS["kvt_tiny"]
        masks = synthetic_masks(0, w.trace, w.n_heads)
        p, _ = timed(plan, masks, s_f=s_f)
        r = simulate_tiled_sata(p.tiled, w.d_k, hw)
        exposed = max(0.0, r.scheduler_cycles - r.latency_cycles)
        hidden = r.scheduler_cycles / max(r.latency_cycles, 1)
        rows.append((f"overhead/latency_sf{s_f}", 0.0,
                     f"sched/compute {hidden*100:.1f}% "
                     f"exposed {exposed:.0f} cyc"))
    return rows


# ---------------------------------------------------------------------------
# TPU kernel counterpart: block-skip fraction, dense-grid vs compacted-grid
# scheduling (time + tile visits + fetch bytes), interpret-mode parity
# ---------------------------------------------------------------------------

def bench_kernel() -> List[Row]:
    import jax.numpy as jnp
    from repro.core.blockmap import (block_skip_fraction, compact_kv_plan,
                                     fixed_occupancy_map,
                                     identity_block_plan, sata_block_plan)
    from repro.kernels.ops import (default_interpret, kernel_fetch_stats,
                                   sata_attention, sata_attention_reference)
    from repro.kernels.sata_attention import (sata_block_attention,
                                              sata_block_attention_compact)
    import jax
    rows: List[Row] = []
    # object-region attention: shared per-cluster key sets, raster order
    # uninformative — the regime SATA sorting targets
    tr = SyntheticTrace(n_tokens=256, k=32, cluster_scale=3.0,
                        discrete_clusters=8, noise=0.3)
    masks = jnp.asarray(synthetic_masks(0, tr, n_heads=4))
    (kv, qo, bm), us = timed(
        lambda: jax.block_until_ready(sata_block_plan(masks, 32, 32)))
    _, _, bm0 = identity_block_plan(masks, 32, 32)
    rows.append(("kernel/block_skip_sata_cluster", us,
                 f"{float(block_skip_fraction(bm)):.3f}"))
    rows.append(("kernel/block_skip_unsorted_cluster", 0.0,
                 f"{float(block_skip_fraction(bm0)):.3f}"))
    # banded masks (already raster-local): sorting must not hurt
    trb = SyntheticTrace(n_tokens=256, k=32, cluster_scale=0.4,
                         band_width=20, band_scale=4.0, noise=0.15)
    masks_b = jnp.asarray(synthetic_masks(0, trb, n_heads=4))
    _, _, bmb = sata_block_plan(masks_b, 32, 32)
    _, _, bmb0 = identity_block_plan(masks_b, 32, 32)
    rows.append(("kernel/block_skip_sata_banded", 0.0,
                 f"{float(block_skip_fraction(bmb)):.3f}"))
    rows.append(("kernel/block_skip_unsorted_banded", 0.0,
                 f"{float(block_skip_fraction(bmb0)):.3f}"))
    # correctness + wall time of the interpret-mode kernel (CPU)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((4, 256, 64)), jnp.float32)
    k_ = jnp.asarray(rng.standard_normal((4, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((4, 256, 64)), jnp.float32)
    (out, bm2), us = timed(
        lambda: jax.block_until_ready(
            sata_attention(q, k_, v, masks, q_block=32, k_block=32)))
    ref = sata_attention_reference(q, k_, v, masks)
    err = float(jnp.max(jnp.abs(out - ref)))
    rows.append(("kernel/sata_attention_interpret", us,
                 f"max_err {err:.2e} skip {float(block_skip_fraction(bm2)):.3f}"))

    # --- dense grid vs compacted grid: same inputs, same math, only the
    # schedule differs.  50% block sparsity, per-row occupancy exactly
    # nkb/2 (see fixed_occupancy_map on why not Bernoulli).
    interp = default_interpret()
    bq = bk = 32
    sq2 = 512
    nb = sq2 // bk
    rng2 = np.random.default_rng(3)
    bm50 = jnp.asarray(
        fixed_occupancy_map(rng2, 4, nb, nb, nb // 2))
    q2 = jnp.asarray(rng2.standard_normal((4, sq2, 64)), jnp.float32)
    k2 = jnp.asarray(rng2.standard_normal((4, sq2, 64)), jnp.float32)
    v2 = jnp.asarray(rng2.standard_normal((4, sq2, 64)), jnp.float32)
    idx, cnt = compact_kv_plan(bm50, pad_to=nb // 2)
    dense_fn = jax.jit(lambda: sata_block_attention(
        q2, k2, v2, bm50, q_block=bq, k_block=bk, interpret=interp))
    compact_fn = jax.jit(lambda: sata_block_attention_compact(
        q2, k2, v2, idx, cnt, q_block=bq, k_block=bk, interpret=interp))
    jax.block_until_ready(dense_fn())           # warm both traces
    jax.block_until_ready(compact_fn())
    out_d, us_d = timed(lambda: jax.block_until_ready(dense_fn()), repeat=3)
    out_c, us_c = timed(lambda: jax.block_until_ready(compact_fn()), repeat=3)
    err_dc = float(jnp.max(jnp.abs(out_d - out_c)))
    stats = kernel_fetch_stats(bm50, q_block=bq, k_block=bk, d=64,
                               dtype_bytes=4, max_kv_blocks=nb // 2)
    mode = "interpret" if interp else "compiled"
    rows.append((f"kernel/dense_grid_{mode}", us_d,
                 f"visits {stats['tile_visits_dense']} "
                 f"fetchB {stats['kv_fetch_bytes_dense']}"))
    rows.append((f"kernel/compact_grid_{mode}", us_c,
                 f"visits {stats['tile_visits_compact']} "
                 f"fetchB {stats['kv_fetch_bytes_compact']} "
                 f"max_err_vs_dense {err_dc:.2e}"))
    rows.append(("kernel/compact_speedup", 0.0,
                 f"{us_d / max(us_c, 1e-9):.2f}x wall ({mode}), "
                 f"{stats['visit_reduction']:.2f}x visits, "
                 f"{stats['fetch_reduction']:.2f}x fetch-bytes at "
                 f"{stats['block_skip_fraction']:.2f} block sparsity"))
    return rows


# ---------------------------------------------------------------------------
# Selection pipeline: dense (BH, S, S) score materialization vs the
# chunked two-pass threshold pipeline — wall time + peak-memory evidence
# (traced-HLO quadratic-buffer scan and XLA memory analysis)
# ---------------------------------------------------------------------------

def bench_select() -> List[Row]:
    import re

    import jax
    import jax.numpy as jnp
    from repro.core.blockmap import compact_kv_plan, occupancy_bound
    from repro.kernels.ops import default_interpret, sata_attention
    from repro.models.attention import NEG_INF, topk_mask_bisect

    rows: List[Row] = []
    interp = default_interpret()
    bh, s, d, blk, k_sel = 2, 2048, 64, 128, 64
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    k_ = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)

    def chunked(q, k_, v):
        return sata_attention(q, k_, v, q_block=blk, k_block=blk,
                              selection="chunked", topk_k=k_sel,
                              causal=True, interpret=interp,
                              sel_chunk=2 * blk)[0]

    def _dense(q, k_, v, use_sata):
        scores = jnp.einsum("bqd,bkd->bqk", q, k_,
                            preferred_element_type=jnp.float32) \
            / np.sqrt(d)
        adm = jnp.tril(jnp.ones((s, s), dtype=bool))
        sel = topk_mask_bisect(jnp.where(adm[None], scores, NEG_INF),
                               k_sel) & adm[None]
        return sata_attention(q, k_, v, sel, q_block=blk, k_block=blk,
                              use_sata=use_sata, exact=True,
                              interpret=interp, schedule="compact")[0]

    def dense_identity(q, k_, v):
        return _dense(q, k_, v, use_sata=False)

    def dense_sata_plan(q, k_, v):
        return _dense(q, k_, v, use_sata=True)

    quad = re.compile(rf"{s}x{s}x(f32|bf16|i1|i8|i32)")
    outs = {}
    for name, fn in (("chunked", chunked),
                     ("dense_identity", dense_identity),
                     ("dense_sata_plan", dense_sata_plan)):
        lowered = jax.jit(fn).lower(q, k_, v)
        has_quad = bool(quad.search(lowered.as_text()))
        compiled = lowered.compile()
        try:
            mem = compiled.memory_analysis()
            tmp = int(getattr(mem, "temp_size_in_bytes", -1))
        except Exception:                              # backend-dependent
            tmp = -1
        outs[name] = jax.block_until_ready(compiled(q, k_, v))  # warm
        _, us = timed(lambda: jax.block_until_ready(compiled(q, k_, v)),
                      repeat=2)
        rows.append((f"select/{name}/s{s}", us,
                     f"quad_SxS_buffer={has_quad} temp_bytes={tmp}"))
    err = float(jnp.max(jnp.abs(outs["chunked"] - outs["dense_identity"])))
    rows.append((f"select/parity/s{s}", 0.0,
                 f"max_err_chunked_vs_dense {err:.2e}"))
    # occupancy_bound: static grid bound from the chunked plan's stats —
    # selection + plan only, no kernel run needed for calibration
    from repro.core.selection import select_thresholds_chunked
    _, bm = jax.jit(lambda q, k: select_thresholds_chunked(
        q, k, k_sel, causal=True, chunk=2 * blk, q_block=blk,
        k_block=blk))(q, k_)
    _, counts = compact_kv_plan(bm)
    p100 = occupancy_bound(counts)
    p99 = occupancy_bound(counts, pct=99.0)
    rows.append((f"select/occupancy_bound/s{s}", 0.0,
                 f"p100 {p100} p99 {p99} of nkb {s // blk}"))
    return rows


def bench_decode() -> List[Row]:
    """Decode-path SATA: plan + gather kernel vs dense decode (see
    ``benchmarks.bench_decode`` — the serving row of the trajectory)."""
    from benchmarks.bench_decode import bench_decode as _bench_decode
    return _bench_decode()


ALL = {
    "tab1": bench_tab1,
    "fig4a": bench_fig4a,
    "fig4b": bench_fig4b,
    "fig4c": bench_fig4c,
    "scaling_sf": bench_scaling_sf,
    "overhead": bench_overhead,
    "kernel": bench_kernel,
    "select": bench_select,
    "decode": bench_decode,
}
