"""Decode-path SATA benchmark → BENCH_decode.json.

The serving question: per generated token, does attention cost scale
with the *prefix* (dense decode streams every cached block) or with the
*selected* blocks (the SATA decode plan + gather kernel)?  Rows:

  * prefix sweep at a fixed selected-block budget — plan fetch-bytes
    stay flat while dense fetch grows with the prefix;
  * occupancy sweep at a long prefix — wall-clock (tok/s) vs the
    dense-schedule decode kernel (same math, all valid blocks planned),
    the decode analogue of bench_kernel's dense-vs-compacted grid;
  * exactness — with a full re-plan every step (``replan_interval=1``)
    the planned kernel is bitwise equal to the dense-schedule kernel
    (a tile whose entries are all threshold-masked is an exact no-op
    in the online softmax), and matches the pure-jnp top-k decode
    reference to fp32 accumulation tolerance;
  * plan-update cost — incremental (summary-ranked) vs full re-plan;
  * paged pool — page-table-indirect kernel vs the contiguous cache:
    bitwise parity, equal-throughput timing, and reserved-vs-used HBM
    for a mixed short/long-prefix slot mix (the utilization win paging
    exists for);
  * re-plan traffic tradeoff — amortized per-step selection bytes
    across ``sata_decode_replan`` intervals (a full re-plan streams all
    cached K; incremental steps read summaries + planned keys), the
    exactness↔traffic knob in true bytes;
  * prefill→decode handoff — a seeded plan starts decode step 0 on the
    planned incremental path (0 full re-plans) instead of cold;
  * shared-prefix page cache — N requests sharing a prompt prefix pay
    its prefill compute and HBM once (hit-rate, prefill tokens saved,
    peak-pages reduction vs private pages, CoW copies), outputs
    bitwise equal to the cache-disabled run;
  * cascade token retirement — the coldest attention blocks' pages
    freed mid-stream at a fixed pool (no-preemption completion ratio
    vs the retire-off twin), plan-side ranking-byte reduction with the
    retained-token budget, and the accuracy lane's deterministic
    divergence-vs-budget sweep;
  * mesh scaling — 2-/4-/8-way sharded selection and tensor-parallel
    decode on a simulated 8-device CPU mesh (subprocess, because
    XLA_FLAGS must precede jax init): parity vs single-device is
    bitwise-gated, per-shard fetch/work splits are exact, wall tok/s
    informational (see ``benchmarks/mesh_rows.py``).
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, timed


def _rand_plan(rng, b, kv, nkb_valid, sel, pad):
    """Per (slot, kv head): exactly ``sel`` selected blocks among the
    ``nkb_valid`` valid ones, ascending, in compact_kv_plan's padded
    layout with width ``pad``."""
    import jax.numpy as jnp
    idx = np.zeros((b, kv, pad), np.int32)
    cnt = np.full((b, kv), sel, np.int32)
    for i in range(b):
        for j in range(kv):
            pick = np.sort(rng.choice(nkb_valid, size=sel, replace=False))
            idx[i, j, :sel] = pick
            idx[i, j, sel:] = pick[-1]              # resident re-reference
    return jnp.asarray(idx), jnp.asarray(cnt)


def _jnp_topk_decode(qg, k, v, pos, topk_k):
    """Pure-jnp dense top-k (bisect) decode — the oracle the kernel's
    full-re-plan route must reproduce."""
    import jax
    import jax.numpy as jnp
    from repro.core.blockmap import bisect_select
    from repro.core.selection import NEG_INF, kth_largest_bisect
    d = qg.shape[-1]
    sc = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                    k.astype(jnp.float32)) / np.sqrt(d)
    valid = (jnp.arange(k.shape[1]) <= pos[:, None])[:, None, None, :]
    sc = jnp.where(valid, sc, NEG_INF)
    thr = kth_largest_bisect(sc, topk_k)
    sel = bisect_select(jnp.where(valid, sc, -jnp.inf), thr) & valid
    sc = jnp.where(sel, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    p = jnp.where(sel.any(-1, keepdims=True), p, 0.0)
    return jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))


def bench_decode() -> List[Row]:
    import jax
    import jax.numpy as jnp
    from repro.core.decode_plan import full_replan
    from repro.kernels.ops import (decode_fetch_stats, default_interpret,
                                   sata_decode_attention)

    rows: List[Row] = []
    interp = default_interpret()
    mode = "interpret" if interp else "compiled"
    b, kv, g, d, blk = 2, 2, 4, 64, 128
    rng = np.random.default_rng(11)
    thr0 = jnp.zeros((b, kv, g, 1), jnp.float32)   # ~half the tile passes

    def run(s, idx, cnt, thr, pos):
        fn = jax.jit(lambda q, k_, v: sata_decode_attention(
            q, k_, v, idx, cnt, thr, pos, k_block=blk, interpret=interp))
        q = jnp.asarray(rng.standard_normal((b, kv, g, d)), jnp.float32)
        k_ = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
        jax.block_until_ready(fn(q, k_, v))                  # warm
        out, us = timed(lambda: jax.block_until_ready(fn(q, k_, v)),
                        repeat=3)
        return out, us

    # --- prefix sweep, fixed selected-block budget: plan fetch is flat
    sel_fixed = 4
    for s in (1024, 2048, 4096):
        nkb = s // blk
        pos = jnp.full((b,), s - 1, jnp.int32)
        idx, cnt = _rand_plan(rng, b, kv, nkb, sel_fixed, sel_fixed)
        _, us = run(s, idx, cnt, thr0, pos)
        st = decode_fetch_stats(cnt, pos, k_block=blk, d=d)
        rows.append((f"decode/prefix_sweep/S{s}_sel{sel_fixed}", us,
                     f"planB {st['kv_fetch_bytes_plan']} "
                     f"denseB {st['kv_fetch_bytes_dense']} "
                     f"({st['fetch_reduction']:.1f}x)"))

    # --- occupancy sweep at long prefix: tok/s vs dense-schedule kernel
    s = 4096
    nkb = s // blk
    pos = jnp.full((b,), s - 1, jnp.int32)
    idx_d = jnp.broadcast_to(jnp.arange(nkb, dtype=jnp.int32),
                             (b, kv, nkb))
    cnt_d = jnp.full((b, kv), nkb, jnp.int32)
    _, us_dense = run(s, idx_d, cnt_d, thr0, pos)
    tok_dense = b * 1e6 / us_dense
    rows.append((f"decode/dense_{mode}/S{s}", us_dense,
                 f"{tok_dense:.1f} tok/s, fetch tiles {b * kv * nkb}"))
    for occ in (0.25, 0.5):
        sel = max(1, int(occ * nkb))
        idx, cnt = _rand_plan(rng, b, kv, nkb, sel, sel)
        _, us_sata = run(s, idx, cnt, thr0, pos)
        st = decode_fetch_stats(cnt, pos, k_block=blk, d=d)
        tok = b * 1e6 / us_sata
        rows.append((f"decode/sata_{mode}/S{s}_occ{occ:.2f}", us_sata,
                     f"{tok:.1f} tok/s, fetch tiles "
                     f"{st['kv_fetch_tiles_plan']}"))
        rows.append((f"decode/speedup/S{s}_occ{occ:.2f}", 0.0,
                     f"{us_dense / max(us_sata, 1e-9):.2f}x tok/s "
                     f"({mode}), {st['fetch_reduction']:.2f}x fetch-bytes"))

    # --- exactness at replan_interval=1: planner plan vs dense schedule
    s = 1024
    nkb = s // blk
    pos = jnp.full((b,), s - 1, jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, kv, g, d)), jnp.float32)
    k_ = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    topk_k = 64
    idx_p, cnt_p, thr = jax.jit(
        lambda q, k__: full_replan(q, k__, pos, topk_k=topk_k, k_block=blk,
                                   plan_blocks=nkb))(q, k_)
    out_plan = sata_decode_attention(q, k_, v, idx_p, cnt_p, thr, pos,
                                     k_block=blk, interpret=interp)
    idx_d = jnp.broadcast_to(jnp.arange(nkb, dtype=jnp.int32), (b, kv, nkb))
    cnt_d = jnp.full((b, kv), nkb, jnp.int32)
    out_dense = sata_decode_attention(q, k_, v, idx_d, cnt_d, thr, pos,
                                      k_block=blk, interpret=interp)
    err = float(jnp.max(jnp.abs(out_plan - out_dense)))
    occ_plan = float(cnt_p.sum()) / (b * kv * nkb)
    rows.append((f"decode/parity_replan1/S{s}", 0.0,
                 f"max_err {err:.2e} vs dense schedule at "
                 f"{occ_plan:.2f} occupancy"))
    ref = _jnp_topk_decode(q, k_, v, pos, topk_k)
    err_ref = float(jnp.max(jnp.abs(out_plan.astype(jnp.float32) - ref)))
    rows.append((f"decode/parity_vs_jnp/S{s}", 0.0,
                 f"max_err {err_ref:.2e} (fp32 accumulation-order tol)"))

    # --- plan maintenance cost: full re-plan vs incremental update
    from repro.core.decode_plan import (decode_plan_update,
                                        init_decode_plan,
                                        summaries_from_cache)
    plan = init_decode_plan(b, kv, s, d, blk, plan_blocks=nkb // 4)
    k_min, k_max = summaries_from_cache(k_, pos, k_block=blk)
    plan = {**plan, "k_min": k_min, "k_max": k_max,
            "step": jnp.ones((b,), jnp.int32)}      # off the replan beat
    for name, interval in (("full", 1), ("incremental", 1 << 30)):
        fn = jax.jit(lambda p, q_, k__, iv=interval: decode_plan_update(
            p, q_, k__, pos, topk_k=topk_k, k_block=blk,
            replan_interval=iv))
        jax.block_until_ready(fn(plan, q, k_))
        _, us = timed(lambda: jax.block_until_ready(fn(plan, q, k_)),
                      repeat=3)
        rows.append((f"decode/plan_update_{name}/S{s}", us,
                     f"P {nkb // 4} of nkb {nkb}"))

    rows += _bench_paged(rng, interp, mode)
    rows += _bench_replan_traffic()
    rows += _bench_handoff()
    rows += _bench_shared_prefix()
    rows += _bench_fault_swap()
    rows += _bench_degradation()
    rows += _bench_retirement()
    rows += _bench_mesh()
    return rows


def _bench_mesh() -> List[Row]:
    """2-/4-/8-way mesh scaling rows via ``benchmarks.mesh_rows`` in a
    subprocess — the forced host device count must be set before jax
    initializes, and this process's jax is already up single-device."""
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run([sys.executable, "-m", "benchmarks.mesh_rows"],
                          capture_output=True, text=True, env=env,
                          cwd=root, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"mesh_rows subprocess failed:\n{proc.stderr}")
    for line in proc.stdout.splitlines():
        if line.startswith("MESH_ROWS_JSON:"):
            return [tuple(r) for r in
                    json.loads(line[len("MESH_ROWS_JSON:"):])]
    raise RuntimeError(f"mesh_rows emitted no row marker:\n{proc.stdout}")


def _bench_paged(rng, interp, mode) -> List[Row]:
    """Paged pool vs contiguous cache: bitwise parity, equal-throughput
    kernel timing, and reserved-vs-used HBM at a mixed short/long-prefix
    slot mix — the serving-utilization case paging exists for."""
    import jax
    import jax.numpy as jnp
    from repro.core.decode_plan import full_replan
    from repro.core.paging import PageAllocator, logical_kv_view
    from repro.kernels.ops import sata_decode_attention

    rows: List[Row] = []
    b, kv, g, d, blk = 4, 2, 4, 64, 128
    s = 4096
    nkb = s // blk
    # mixed slot mix: one max_len prefix, three short ones — contiguous
    # reserves B·max_len regardless; the pool holds only mapped pages
    pos = jnp.asarray([s - 1, 511, 255, 127], jnp.int32)
    used_pages = int(sum(int(p) // blk + 1 for p in pos))
    n_pages = used_pages + used_pages // 4 + 1      # 25% headroom + ovf
    alloc = PageAllocator(n_pages, b, nkb, blk)
    for i in range(b):
        ok = alloc.ensure(i, int(pos[i]))
        assert ok, (i, int(pos[i]))
    tbl = jnp.asarray(alloc.table)

    k_c = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v_c = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((b, kv, g, d)), jnp.float32)
    # scatter the SAME rows into the pool so both layouts see one cache
    k_p = jnp.zeros((n_pages, blk, kv, d), jnp.float32)
    v_p = jnp.zeros((n_pages, blk, kv, d), jnp.float32)
    for i in range(b):
        for lp in range(int(pos[i]) // blk + 1):
            ph = int(alloc.table[i, lp])
            k_p = k_p.at[ph].set(k_c[i, lp * blk:(lp + 1) * blk])
            v_p = v_p.at[ph].set(v_c[i, lp * blk:(lp + 1) * blk])
    assert bool((logical_kv_view(k_p, tbl) * (
        jnp.arange(s)[None, :, None, None] <= pos[:, None, None, None])
        == k_c * (jnp.arange(s)[None, :, None, None]
                  <= pos[:, None, None, None])).all())

    idx, cnt, thr = jax.jit(lambda q_, k__: full_replan(
        q_, k__, pos, topk_k=64, k_block=blk, plan_blocks=nkb))(q, k_c)

    fn_c = jax.jit(lambda q_, k__, v__: sata_decode_attention(
        q_, k__, v__, idx, cnt, thr, pos, k_block=blk, interpret=interp))
    fn_p = jax.jit(lambda q_, k__, v__: sata_decode_attention(
        q_, k__, v__, idx, cnt, thr, pos, k_block=blk, page_table=tbl,
        interpret=interp))
    out_c = fn_c(q, k_c, v_c)
    out_p = fn_p(q, k_p, v_p)
    err = float(jnp.max(jnp.abs(out_c - out_p)))
    rows.append((f"decode/paged_parity/S{s}_mixed", 0.0,
                 f"max_err {err:.2e} paged vs contiguous (replan=1 plan)"))
    jax.block_until_ready(fn_c(q, k_c, v_c))
    _, us_c = timed(lambda: jax.block_until_ready(fn_c(q, k_c, v_c)),
                    repeat=3)
    jax.block_until_ready(fn_p(q, k_p, v_p))
    _, us_p = timed(lambda: jax.block_until_ready(fn_p(q, k_p, v_p)),
                    repeat=3)
    row_bytes = 2 * kv * d * 4
    reserved_c = b * s * row_bytes
    reserved_p = n_pages * blk * row_bytes
    used_p = used_pages * blk * row_bytes
    rows.append((f"decode/paged_tok_s_{mode}/S{s}_mixed", us_p,
                 f"{b * 1e6 / us_p:.1f} tok/s paged vs "
                 f"{b * 1e6 / us_c:.1f} contiguous "
                 f"({us_c / max(us_p, 1e-9):.2f}x)"))
    rows.append((f"decode/paged_hbm/S{s}_mixed", 0.0,
                 f"reserved {reserved_p} B vs {reserved_c} B contiguous "
                 f"({reserved_c / reserved_p:.2f}x less), used {used_p} B "
                 f"({used_p / reserved_p:.2f} pool occupancy)"))
    return rows


def _bench_replan_traffic() -> List[Row]:
    """Amortized per-step selection+kernel bytes across re-plan
    intervals: interval 1 is exact but streams all cached K every step;
    longer intervals amortize the full re-plan over cheap incremental
    steps (summaries + planned keys).  The backend × mode rows price
    the summary-traffic knobs: int8 summaries shrink every ranking
    read ~4x, and the sketch re-plan replaces the all-cached-K stream
    with summaries + C·F candidate blocks — selection traffic
    sub-linear in cached K even at interval 1."""
    import numpy as np
    from repro.kernels.ops import decode_fetch_stats

    rows: List[Row] = []
    b, kv, d, blk, s = 2, 2, 64, 128, 4096
    nkb = s // blk
    sel = nkb // 4                                 # 25% occupancy plan
    cnt = np.full((b, kv), sel)
    pos = np.full(b, s - 1)
    for interval in (1, 2, 4, 16):
        st = decode_fetch_stats(cnt, pos, k_block=blk, d=d,
                                replan=1.0 / interval, nkb=nkb)
        tag = "exact" if interval == 1 else "approx"
        rows.append((f"decode/replan_traffic/S{s}_iv{interval}", 0.0,
                     f"step {st['step_bytes_plan_route']} B plan-route vs "
                     f"{st['step_bytes_dense_route']} B dense ("
                     f"{st['step_bytes_dense_route'] / st['step_bytes_plan_route']:.2f}x, "
                     f"plan side {st['plan_fetch_bytes_step']} B, {tag})"))
    # summary backend × re-plan mode (fp32+exact above is the baseline)
    plan_side = {}
    for summary, rmode in (("int8", "exact"), ("fp32", "sketch"),
                           ("int8", "sketch")):
        for interval in (1, 4):
            st = decode_fetch_stats(cnt, pos, k_block=blk, d=d,
                                    replan=1.0 / interval, nkb=nkb,
                                    summary=summary, replan_mode=rmode,
                                    sketch_factor=4, plan_blocks=sel)
            plan_side[(summary, rmode, interval)] = \
                st["plan_fetch_bytes_step"]
            rows.append((
                f"decode/replan_traffic/S{s}_iv{interval}_{summary}_{rmode}",
                0.0,
                f"step {st['step_bytes_plan_route']} B plan-route vs "
                f"{st['step_bytes_dense_route']} B dense ("
                f"{st['step_bytes_dense_route'] / st['step_bytes_plan_route']:.2f}x, "
                f"plan side {st['plan_fetch_bytes_step']} B, "
                f"{summary}+{rmode})"))
    fp_exact = decode_fetch_stats(cnt, pos, k_block=blk, d=d, replan=1.0,
                                  nkb=nkb)["plan_fetch_bytes_step"]
    i8_sk = plan_side[("int8", "sketch", 1)]
    rows.append((f"decode/replan_traffic/S{s}_reduction", 0.0,
                 f"int8+sketch plan-side {i8_sk} B vs {fp_exact} B "
                 f"fp32-exact at iv1 "
                 f"({fp_exact / i8_sk:.2f}x selection-traffic reduction)"))
    return rows


def _bench_handoff() -> List[Row]:
    """Prefill→decode handoff on the reduced serving model: a seeded
    plan runs decode step 0 on the planned incremental path (0 full
    re-plans), where a cold claim would re-plan (stream the whole
    prefix) first."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.archs import SMOKE
    from repro.models import decode as dec
    from repro.models import model as mdl

    cfg = dataclasses.replace(SMOKE["qwen3-4b"], topk_impl="bisect",
                              sata_decode="on", sata_decode_block=8,
                              sata_decode_replan=8)
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (1, 8)), jnp.int32)
    max_len = 32

    lg0, state = dec.prefill_prompt(params, cfg, toks, max_len)
    cache = dec.init_cache(cfg, 1, max_len)
    cache = dec.install_prefill(cfg, cache, 0, state)
    nxt = jnp.argmax(lg0, -1)[:, None].astype(jnp.int32)
    _, cache = dec.serve_step(params, cfg, cache, nxt, jnp.int32(8))
    seeded = int(np.asarray(cache["kv"]["plan"]["replans"])[0, 0])
    planned = int(np.asarray(cache["kv"]["plan"]["kv_counts"]).min())

    cold = dec.init_cache(cfg, 1, max_len)
    for t in range(8):
        _, cold = dec.serve_step(params, cfg, cold, toks[:, t:t + 1],
                                 jnp.int32(t))
    _, cold = dec.serve_step(params, cfg, cold, nxt, jnp.int32(8))
    cold_replans = int(np.asarray(cold["kv"]["plan"]["replans"])[0, 0])
    return [("decode/prefill_handoff/step0", 0.0,
             f"seeded: {seeded} full re-plans at decode step 0 "
             f"(plan rows live, min counts {planned}) vs {cold_replans} "
             f"on the cold token-by-token path")]


def _bench_shared_prefix() -> List[Row]:
    """Shared-prefix page cache on the reduced serving model: six
    requests share a 16-token prefix of their 20-token prompts.  With
    the cache, the shared pages prefill once and later claims map them
    (refcount bump); the rows report prefill-compute and peak-HBM
    reduction vs the private-pages (cache-off) twin, plus the
    output-equality flag the regression gate pins exactly."""
    import dataclasses
    import time

    from repro.configs.archs import SMOKE
    from repro.launch.serve import serve

    cfg = dataclasses.replace(
        SMOKE["qwen3-4b"], topk_impl="bisect", sata_decode="on",
        sata_decode_block=8, sata_decode_replan=1,
        kv_cache_layout="paged")
    kw = dict(smoke=True, n_requests=6, batch_slots=3, gen_len=8,
              max_len=64, prompt_len=20, shared_prefix_len=16)
    t0 = time.perf_counter()
    off = serve("qwen3-4b", cfg=cfg, **kw)
    us_off = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    on = serve("qwen3-4b",
               cfg=dataclasses.replace(cfg, kv_prefix_cache=True), **kw)
    us_on = (time.perf_counter() - t0) * 1e6
    p = on["prefix_cache"]
    eq = on["outputs"] == off["outputs"]
    total = p["prefill_tokens_total"]
    saved = p["prefill_tokens_saved"]

    # HBM story: private pages demand peak_off pages; sharing fits the
    # SAME workload in a pool smaller than that demand without any
    # backpressure, because concurrent slots alias the prefix pages
    peak_off = off["page_occupancy"]["pages_in_use_peak"]
    page_b = off["page_occupancy"]["hbm_reserved_bytes"] \
        // off["page_occupancy"]["n_pages"]
    tight = dataclasses.replace(cfg, kv_prefix_cache=True,
                                kv_pool_pages=peak_off - 1)
    on_t = serve("qwen3-4b", cfg=tight, **kw)
    off_t = serve("qwen3-4b",
                  cfg=dataclasses.replace(tight, kv_prefix_cache=False),
                  **kw)
    occ_on, occ_off = on_t["page_occupancy"], off_t["page_occupancy"]
    bp_on = occ_on["stalled_steps"] + occ_on["deferred_claims"] \
        + occ_on["preemptions"]
    bp_off = occ_off["stalled_steps"] + occ_off["deferred_claims"] \
        + occ_off["preemptions"]
    eq_t = on_t["outputs"] == off["outputs"]
    # all rows derived-only (us 0.0): serve wall on CPU is dominated by
    # per-shape jit compiles — fine as trajectory text, too noisy for
    # the regression gate's wall band
    return [
        ("decode/shared_prefix/prefill", 0.0,
         f"saved {saved}/{total} prefill tokens "
         f"({p['hits']}/{p['requests']} hits), "
         f"{total / max(total - saved, 1):.2f}x prefill-compute "
         f"reduction, {p['cow_copies']} CoW copies, shared-page peak "
         f"{p['shared_pages_peak']}, outputs_equal={eq}"),
        ("decode/shared_prefix/hbm", 0.0,
         f"reserved {(peak_off - 1) * page_b} B pool serves the "
         f"workload private pages demand {peak_off * page_b} B for: "
         f"backpressure {bp_on} shared vs {bp_off} private, "
         f"outputs_equal={eq_t}"),
        ("decode/shared_prefix/serve_wall", 0.0,
         f"cache-on {us_on:.0f}us vs cache-off {us_off:.0f}us serve "
         f"wall (jit-inclusive, informational)"),
    ]


def _bench_fault_swap() -> List[Row]:
    """Preemption policy on the reduced serving model: a deterministic
    pool squeeze forces preemptions, served once with host-swap (pages
    + plan state round-trip through host memory, zero re-prefill) and
    once with the legacy requeue fallback (host budget = 0: outputs
    discarded, prompt re-prefilled).  Both must stay bitwise equal to
    the fault-free run — the gate pins the salvage/discard counters and
    equality flags exactly; restore wall is informational."""
    import dataclasses

    from repro.configs.archs import SMOKE
    from repro.launch.faults import FaultPlan
    from repro.launch.serve import serve

    cfg = dataclasses.replace(
        SMOKE["qwen3-4b"], topk_impl="bisect", sata_decode="on",
        sata_decode_block=8, sata_decode_replan=4,
        kv_cache_layout="paged", kv_pool_pages=6)
    kw = dict(smoke=True, n_requests=4, batch_slots=2, gen_len=12,
              max_len=32, prompt_len=6)
    base = serve("qwen3-4b", cfg=cfg, **kw)
    faults = FaultPlan().pool_squeeze(2, 3).pool_restore(14)
    swap = serve("qwen3-4b", cfg=cfg, faults=faults, **kw)
    requeue = serve("qwen3-4b", cfg=cfg, faults=faults,
                    host_swap_bytes=0, **kw)
    s, r = swap["page_occupancy"], requeue["page_occupancy"]
    eq_s = swap["outputs"] == base["outputs"]
    eq_r = requeue["outputs"] == base["outputs"]
    restore_us = s["swap_restore_wall_s"] * 1e6 \
        / max(s["swap_restores"], 1)
    return [
        ("decode/fault_swap/salvage", 0.0,
         f"{s['tokens_salvaged']} tokens salvaged over "
         f"{s['host_swaps']} host-swaps ({s['swap_restores']} restores, "
         f"re_prefill_tokens={s['re_prefill_tokens']}, "
         f"cold_replans={s['swap_cold_replans']}), "
         f"outputs_equal={eq_s}"),
        ("decode/fault_swap/requeue_baseline", 0.0,
         f"requeue discarded {r['requeue_tokens_discarded']} tokens "
         f"over {r['requeue_preemptions']} preemptions, "
         f"re_prefill_tokens={r['re_prefill_tokens']}, "
         f"outputs_equal={eq_r}"),
        ("decode/fault_swap/restore_latency", 0.0,
         f"swap-in restore {restore_us:.0f}us/restore mean, host-swap "
         f"peak {s['host_swap_bytes_peak']} B "
         f"(jit-inclusive, informational)"),
    ]


def _bench_retirement() -> List[Row]:
    """Cascade token retirement on the reduced serving model, two
    lanes.  Pressure lane: a mixed-prefix workload (six 60-token
    requests sharing a 12-token prefix) against a 16-page pool that
    holds barely two full prefixes — retire-off sheds by preemption;
    retire-on frees the coldest blocks' pages mid-stream, and the gate
    pins reclaimed pages, the no-preemption completion ratio (must
    stay >= 1.5x), and the plan-side ranking-byte reduction exactly.
    Accuracy lane (ample pool, so every difference is retirement's):
    deterministic token-divergence vs the retire-off twin across
    retained-token budgets — retirement is lossy BY DESIGN and the
    trajectory must price that, not hide it.  A watermark no slot can
    reach must reproduce retire-off bitwise (the off-path contract)."""
    import dataclasses

    from repro.configs.archs import SMOKE
    from repro.launch.serve import serve

    cfg = dataclasses.replace(
        SMOKE["qwen3-4b"], topk_impl="bisect", sata_decode="on",
        sata_decode_block=8, sata_decode_replan=1,
        kv_cache_layout="paged")
    kw = dict(smoke=True, n_requests=6, batch_slots=3, gen_len=40,
              max_len=64, prompt_len=20, shared_prefix_len=12)
    n = kw["n_requests"]

    def ret(keep, pool, watermark=0.4):
        return serve("qwen3-4b", cfg=dataclasses.replace(
            cfg, kv_pool_pages=pool, sata_retire="on",
            sata_retire_watermark=watermark, sata_retire_keep=keep), **kw)

    # --- pressure lane: fixed 16-page pool
    off_p = serve("qwen3-4b",
                  cfg=dataclasses.replace(cfg, kv_pool_pages=16), **kw)
    on_p = ret(0.5, 16)
    r = on_p["retirement"]
    first_ev = min((t[0][0] for t in r["timelines"].values() if t),
                   default=on_p["steps"])
    oo, op = on_p["page_occupancy"], off_p["page_occupancy"]
    ok_on = n - oo["preempted_requests"]
    ok_off = n - op["preempted_requests"]
    ratio = ok_on / max(ok_off, 1)

    # --- accuracy + traffic lane: ample pool, retirement is the only
    # difference; divergence = token mismatch rate vs the off twin
    off_a = serve("qwen3-4b", cfg=cfg, **kw)
    total = sum(len(v) for v in off_a["outputs"].values())

    def diverge(on):
        d = sum(1 for req, toks in off_a["outputs"].items()
                for j, t in enumerate(toks)
                if on["outputs"][req][j] != t)
        return d / max(total, 1)

    sweep = {keep: ret(keep, 0) for keep in (0.75, 0.5, 0.25)}
    b_off = off_a["decode_fetch"]["plan_fetch_bytes"]
    b50 = sweep[0.5]["decode_fetch"]["plan_fetch_bytes"]
    b25 = sweep[0.25]["decode_fetch"]["plan_fetch_bytes"]
    never = ret(0.5, 0, watermark=2.0)         # can never fire
    eq_never = never["outputs"] == off_a["outputs"]
    return [
        ("decode/retirement/reclaim", 0.0,
         f"reclaimed {r['pages_reclaimed']} pages over {r['events']} "
         f"events ({r['retired_tokens']} tokens retired, keep 0.50, "
         f"16-page pool), first at step {first_ev}/{on_p['steps']} "
         f"(mid-stream)"),
        ("decode/retirement/completion", 0.0,
         f"no-preemption completions {ok_on}/{n} retire-on vs "
         f"{ok_off}/{n} retire-off ({ratio:.2f}x), preemptions "
         f"{oo['preemptions']} vs {op['preemptions']}, stalled steps "
         f"{oo['stalled_steps']} vs {op['stalled_steps']}"),
        ("decode/retirement/plan_bytes", 0.0,
         f"plan-side ranking traffic {b50} B at keep 0.50, {b25} B at "
         f"keep 0.25 vs {b_off} B retire-off "
         f"({b_off / max(b50, 1):.2f}x/{b_off / max(b25, 1):.2f}x "
         f"reduction with the retained-token budget)"),
        ("decode/retirement/accuracy", 0.0,
         f"token divergence vs retained-token budget: keep 0.75 -> "
         f"{diverge(sweep[0.75]):.4f}, 0.50 -> "
         f"{diverge(sweep[0.5]):.4f}, 0.25 -> "
         f"{diverge(sweep[0.25]):.4f} (mismatch rate vs retire-off; "
         f"lossy by design, priced not hidden)"),
        ("decode/retirement/off_bitwise", 0.0,
         f"unreachable watermark: outputs_equal={eq_never} to "
         f"retire-off with {never['retirement']['pages_reclaimed']} "
         f"pages reclaimed"),
    ]


def _bench_degradation() -> List[Row]:
    """Overload policy on the reduced serving model: a deterministic
    load-spike + slow-step schedule served once with the SLO
    degradation ladder (per-slot plan-quality rungs absorb the
    pressure: every request completes, zero requeues/timeouts) and
    once without it (the PR 7 behavior: the spike sheds requests by
    preemption/requeue).  A second schedule parks a swap handle and
    corrupts one payload byte — the swap-in checksum gate must detect
    and quarantine it, with the victim recovering by re-prefill.  The
    gate pins every counter exactly; there are no wall rows."""
    import dataclasses

    from repro.configs.archs import SMOKE
    from repro.launch.faults import FaultPlan
    from repro.launch.serve import serve

    cfg = dataclasses.replace(
        SMOKE["qwen3-4b"], topk_impl="bisect", sata_decode="on",
        sata_decode_block=8, sata_decode_replan=4,
        kv_cache_layout="paged", kv_pool_pages=6, sata_qos_ladder=True)
    cfg_off = dataclasses.replace(cfg, sata_qos_ladder=False)
    kw = dict(smoke=True, n_requests=4, batch_slots=2, gen_len=12,
              max_len=32, prompt_len=6)
    spikes = FaultPlan().load_spike(4, 2).slow_step(5).load_spike(10, 1)
    base = serve("qwen3-4b", cfg=cfg, **kw)
    lad = serve("qwen3-4b", cfg=cfg, faults=spikes, **kw)
    req = serve("qwen3-4b", cfg=cfg_off, faults=spikes, **kw)
    lo, ro, q = lad["page_occupancy"], req["page_occupancy"], lad["qos"]
    # requests the ladder never degraded must be bitwise equal to the
    # no-fault run (per-slot knob isolation)
    eq_undeg = all(lad["outputs"][r] == base["outputs"][r]
                   for r, tl in lad["degradation"].items() if not tl)
    corr = (FaultPlan().preempt(6).defer_admission(6).defer_admission(7)
            .corrupt_page(7).defer_admission(8))
    intg = serve("qwen3-4b", cfg=cfg, faults=corr, **kw)
    io = intg["page_occupancy"]
    eq_intg = intg["outputs"] == base["outputs"]
    return [
        ("decode/degradation/ladder", 0.0,
         f"completed {len(lad['request_latency_s'])}/{kw['n_requests']} "
         f"requests under spike, requeues={lo['requeue_preemptions']}, "
         f"timeouts={len(lad['timed_out'])}, "
         f"degraded_steps={q['degraded_steps']}, "
         f"rung_downs={q['rung_downs']}, rung_ups={q['rung_ups']}, "
         f"outputs_equal={eq_undeg}"),
        ("decode/degradation/requeue_baseline", 0.0,
         f"completed {len(req['request_latency_s'])}/{kw['n_requests']} "
         f"requests under spike, requeue discarded "
         f"{ro['requeue_tokens_discarded']} tokens over "
         f"{ro['preemptions']} preemptions, "
         f"re_prefill_tokens={ro['re_prefill_tokens']}"),
        ("decode/degradation/integrity", 0.0,
         f"corrupt_injected={io['corrupt_pages_injected']}, "
         f"corrupt_detected={io['corrupt_pages_detected']}, "
         f"quarantined_pages={io['quarantined_pages']}, "
         f"re_prefill_tokens={io['re_prefill_tokens']}, "
         f"outputs_equal={eq_intg}"),
    ]
