# Tier-1 verify + perf-trajectory artifacts.  `make test` is what CI runs.

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-mesh dev-deps bench bench-select bench-decode \
	serve-smoke serve-smoke-faults serve-smoke-overload \
	serve-smoke-mesh roofline-kernel check-regression

dev-deps:
	-pip install -r requirements-dev.txt

test:
	python -m pytest -x -q

# Mesh tier-1: the shard_map parity tests (sequence-sharded selection,
# tensor-parallel decode) need >1 device — force an 8-way simulated
# CPU mesh so plain CI runners exercise the sharded paths.  The same
# tests SKIP (not fail) under `make test` on a single device.
test-mesh:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
		python -m pytest -x -q tests/test_mesh_serving.py \
		tests/test_config_api.py

# BENCH_kernel.json: dense-grid vs compacted-grid kernel timings +
# tile-visit / fetch-byte counts — the perf trajectory across PRs.
bench:
	python -m benchmarks.run kernel --json-dir results/bench

# BENCH_select.json: dense-selection vs chunked-selection pipeline
# (interpret mode) — wall time, traced-HLO quadratic-buffer scan, and
# occupancy-bound stats; CI uploads it so the trajectory accumulates.
bench-select:
	python -m benchmarks.run select --json-dir results/bench

# BENCH_decode.json: dense decode vs the SATA decode plan + gather
# kernel (tok/s, fetch bytes, replan-interval traffic tradeoff —
# including the summary-backend × re-plan-mode rows pricing int8
# summaries and the sketch re-plan — paged-vs-contiguous parity + HBM,
# prefill handoff) — the serving row of the perf trajectory.
bench-decode:
	python -m benchmarks.run decode --json-dir results/bench

# End-to-end serving smoke: the SATA decode route on the paged KV pool
# (half the contiguous HBM reservation; exercises admission control,
# stalls, and preemption) — asserts completion + fetch reduction.
# The --shared-prefix scenario then drives the prefix cache: requests
# sharing a prompt prefix map its cached pages (hit-rate > 0, prefill
# tokens saved, CoW on append) with outputs bitwise equal to the
# cache-disabled run.  The --retire scenario serves a workload whose
# live prefixes overflow the pool: cascade token retirement reclaims
# the coldest blocks' pages mid-stream and completes without the
# preemptions the retire-off twin needs.
serve-smoke:
	python examples/serve_topk.py --paged
	python examples/serve_topk.py --summary int8 --replan-mode sketch
	python examples/serve_topk.py --shared-prefix
	python examples/serve_topk.py --retire

# Fault-injection smoke: seeded squeeze/preempt/defer schedule plus a
# hard pool squeeze (forces >=2 host-swap preemptions) and a mid-serve
# crash, with the allocator invariant audit on throughout.  Asserts the
# restored outputs are bitwise equal to the fault-free run with zero
# re-prefilled tokens and zero cold re-plans.
serve-smoke-faults:
	python examples/serve_topk.py --faults 0

# Overload-resilience smoke: seeded load spikes the QoS degradation
# ladder absorbs as per-slot quality rungs (ladder-off needs >=2
# preemptions; ladder-on completes every request with zero requeues and
# zero timeouts), a corrupted swap payload quarantined at the checksum
# gate, and a child process killed mid-serve resumed from checkpoint
# with bitwise-equal outputs.
serve-smoke-overload:
	python examples/serve_topk.py --overload 0

# Cross-replica prefix-index smoke: two serve replicas share one
# prefix digest index — replica 0 publishes its shared-prefix pages,
# replica 1 migrates them into its own pool instead of re-prefilling
# (asserts cross-replica hits, migrated pages, and bitwise-equal
# outputs across replicas).  The forced device count keeps the smoke
# on the same simulated mesh the `mesh` CI job uses.
serve-smoke-mesh:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
		python examples/serve_topk.py --replicas 2

roofline-kernel:
	python -m repro.launch.roofline --kernel

# Bench-regression gate (the CI step behind `make bench*`): regenerate
# the three artifacts into results/bench_fresh and compare against the
# COMMITTED baselines in results/bench.  Contract (details in
# benchmarks/check_regression.py): deterministic counters and
# bitwise-parity (max_err 0.0) fields are gated EXACTLY; wall-time
# ratios are tolerance-banded after normalizing by the suite median
# (cancels machine speed); dropped rows fail, new rows pass.  To bless
# a new baseline after an intended change: `make bench bench-select
# bench-decode` and commit the regenerated results/bench JSONs.
check-regression:
	python -m benchmarks.run kernel select decode \
		--json-dir results/bench_fresh
	python -m benchmarks.check_regression \
		--baseline-dir results/bench --fresh-dir results/bench_fresh
