# Tier-1 verify + perf-trajectory artifacts.  `make test` is what CI runs.

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test dev-deps bench roofline-kernel

dev-deps:
	-pip install -r requirements-dev.txt

test:
	python -m pytest -x -q

# BENCH_kernel.json: dense-grid vs compacted-grid kernel timings +
# tile-visit / fetch-byte counts — the perf trajectory across PRs.
bench:
	python -m benchmarks.run kernel --json-dir results/bench

roofline-kernel:
	python -m repro.launch.roofline --kernel
