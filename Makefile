# Tier-1 verify + perf-trajectory artifacts.  `make test` is what CI runs.

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test dev-deps bench bench-select bench-decode serve-smoke \
	roofline-kernel

dev-deps:
	-pip install -r requirements-dev.txt

test:
	python -m pytest -x -q

# BENCH_kernel.json: dense-grid vs compacted-grid kernel timings +
# tile-visit / fetch-byte counts — the perf trajectory across PRs.
bench:
	python -m benchmarks.run kernel --json-dir results/bench

# BENCH_select.json: dense-selection vs chunked-selection pipeline
# (interpret mode) — wall time, traced-HLO quadratic-buffer scan, and
# occupancy-bound stats; CI uploads it so the trajectory accumulates.
bench-select:
	python -m benchmarks.run select --json-dir results/bench

# BENCH_decode.json: dense decode vs the SATA decode plan + gather
# kernel (tok/s, fetch bytes, replan-interval traffic tradeoff,
# paged-vs-contiguous parity + HBM, prefill handoff) — the serving
# row of the perf trajectory.
bench-decode:
	python -m benchmarks.run decode --json-dir results/bench

# End-to-end serving smoke: the SATA decode route on the paged KV pool
# (half the contiguous HBM reservation; exercises admission control,
# stalls, and preemption) — asserts completion + fetch reduction.
serve-smoke:
	python examples/serve_topk.py --paged

roofline-kernel:
	python -m repro.launch.roofline --kernel
