"""Algo-1 unit + property tests: sorting equivalence, classification
invariants, GLOB-escape loop."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.sorting import (HeadType, QType, classify_queries,
                                classify_with_escape, locality_score,
                                sort_and_classify, sort_keys_direct,
                                sort_keys_jax, sort_keys_psum)


def random_mask(rng, n_q, n_k, k):
    m = np.zeros((n_q, n_k), dtype=bool)
    for i in range(n_q):
        m[i, rng.choice(n_k, size=k, replace=False)] = True
    return m


@pytest.mark.parametrize("n,k,seed", [(8, 3, 0), (24, 8, 1), (48, 12, 2),
                                      (30, 15, 3), (17, 5, 4)])
def test_psum_equals_direct(n, k, seed):
    """Eq. 2 telescopes to Eq. 1: the hardware Psum sorter and the
    textbook dummy-vector sorter produce the identical key order."""
    rng = np.random.default_rng(seed)
    m = random_mask(rng, n, n, k)
    assert np.array_equal(sort_keys_direct(m, seed), sort_keys_psum(m, seed))


@pytest.mark.parametrize("n,k", [(16, 5), (24, 8)])
def test_jax_sorter_matches_host(n, k):
    rng = np.random.default_rng(0)
    m = random_mask(rng, n, n, k)
    got = np.asarray(sort_keys_jax(m[None]))[0]
    assert np.array_equal(got, sort_keys_psum(m, 0))


def test_sorter_output_is_permutation():
    rng = np.random.default_rng(7)
    m = random_mask(rng, 32, 32, 9)
    order = sort_keys_psum(m, 5)
    assert sorted(order.tolist()) == list(range(32))


def test_sorting_improves_locality():
    rng = np.random.default_rng(3)
    # clustered mask: two query groups sharing key sets, shuffled columns
    m = np.zeros((32, 32), dtype=bool)
    m[:16, :12] = True
    m[16:, 20:] = True
    perm = rng.permutation(32)
    m = m[:, perm]
    order = sort_keys_psum(m, 0)
    assert locality_score(m[:, order]) >= locality_score(m)


def test_classify_semantics():
    # sorted mask with obvious HEAD/TAIL/GLOB structure, N=8, s_h=4
    sm = np.zeros((3, 8), dtype=bool)
    sm[0, :3] = True          # HEAD: only first keys
    sm[1, 5:] = True          # TAIL: only last keys
    sm[2, [0, 7]] = True      # GLOB: both ends
    qt = classify_queries(sm, 4)
    assert qt[0] == QType.HEAD
    assert qt[1] == QType.TAIL
    assert qt[2] == QType.GLOB


def test_classify_both_ends_free_goes_head():
    sm = np.zeros((1, 8), dtype=bool)
    sm[0, 3:5] = True          # touches neither first-2 nor last-2
    assert classify_queries(sm, 2)[0] == QType.HEAD


def test_escape_loop_decrements_until_theta():
    rng = np.random.default_rng(11)
    m = random_mask(rng, 16, 16, 8)      # dense-ish → many GLOB at s_h=8
    qt, ht, s_h, n_dec = classify_with_escape(m)
    n_glob = int((qt == QType.GLOB).sum())
    assert n_glob <= 8 or s_h == 0       # escaped, or degenerate GLOB head
    assert s_h + n_dec == 8              # started at N/2


@settings(max_examples=25, deadline=None)
@given(st.integers(6, 40), st.integers(1, 5), st.integers(0, 10_000))
def test_property_sort_permutation_and_equivalence(n, k_small, seed):
    rng = np.random.default_rng(seed)
    k = min(k_small + 1, n)
    m = random_mask(rng, n, n, k)
    o1 = sort_keys_direct(m, seed % n)
    o2 = sort_keys_psum(m, seed % n)
    assert np.array_equal(o1, o2)
    assert sorted(o1.tolist()) == list(range(n))


@settings(max_examples=25, deadline=None)
@given(st.integers(6, 32), st.integers(0, 10_000))
def test_property_classification_invariant(n, seed):
    """HEAD queries never touch the last s_h sorted keys; TAIL never the
    first s_h — the invariant the FSM's overlap correctness rests on."""
    rng = np.random.default_rng(seed)
    k = max(1, n // 4)
    m = random_mask(rng, n, n, k)
    res = sort_and_classify(m, seed=seed % n)
    if res.head_type == HeadType.GLOB:
        return
    sm = m[:, res.kid]
    s_h = res.s_h
    for q, t in enumerate(res.qtypes):
        if t == QType.HEAD:
            assert not sm[q, n - s_h:].any()
        elif t == QType.TAIL:
            assert not sm[q, :s_h].any()
