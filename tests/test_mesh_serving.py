"""Mesh-sharded serving: shard_map parity for sequence-parallel
selection and tensor-parallel decode, plus the cross-replica prefix
index.

The shard_map tests need >= 2 devices — plain CPU tier-1 sees one and
skips; the CI ``mesh`` job forces a simulated mesh with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import mesh as M

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=N)")

BH, S, SK, D = 4, 64, 64, 16
B, KV, G, SMAX, KB = 2, 8, 2, 64, 8


def _qkv(seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, SK, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, SK, D)), jnp.float32)
    return q, k, v


@multi_device
def test_sequence_sharded_selection_parity():
    q, k, v = _qkv()
    ref, rstats = M.sequence_local_attention(q, k, v, k_sel=8,
                                             q_block=8, k_block=8)
    mesh = M.make_shard_mesh(2)
    out, stats = M.sequence_sharded_attention(mesh, q, k, v, k_sel=8,
                                              q_block=8, k_block=8)
    # bitwise: thresholds and occupancy are row-local, the epilogue is
    # shared, and the tile buffers have identical padded layout
    assert (stats["thresholds"] == rstats["thresholds"]).all()
    assert (stats["block_map"] == rstats["block_map"]).all()
    assert float(jnp.abs(out - ref).max()) == 0.0


@multi_device
def test_sequence_sharded_fetch_is_plan_proportional():
    q, k, v = _qkv(1)
    _, rstats = M.sequence_local_attention(q, k, v, k_sel=8,
                                           q_block=8, k_block=8)
    mesh = M.make_shard_mesh(2)
    _, stats = M.sequence_sharded_attention(mesh, q, k, v, k_sel=8,
                                            q_block=8, k_block=8)
    per_shard = np.asarray(stats["fetched_tiles_per_shard"])
    # the shards' compact fetches partition the single-device plan
    assert per_shard.sum() == int(rstats["fetched_tiles"])
    assert (per_shard > 0).all()


def _decode_inputs(seed=2):
    rng = np.random.default_rng(seed)
    pos0 = 32
    kc = jnp.asarray(rng.standard_normal((B, SMAX, KV, D)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, SMAX, KV, D)), jnp.float32)
    kc = kc.at[:, pos0 + 1:].set(0.0)
    vc = vc.at[:, pos0 + 1:].set(0.0)
    qg = jnp.asarray(rng.standard_normal((B, KV, G, D)), jnp.float32)
    return qg, kc, vc, kc[:, pos0:pos0 + 1], jnp.full((B,), pos0,
                                                      jnp.int32)


def _reference_step(qg, kc, vc, kn, pos, plan):
    from repro.core.decode_plan import (decode_plan_update,
                                        update_block_summaries)
    from repro.kernels.ops import sata_decode_attention
    plan = update_block_summaries(plan, kn, pos, k_block=KB)
    plan, thr = decode_plan_update(plan, qg, kc, pos, topk_k=8,
                                   k_block=KB, replan_interval=1)
    out = sata_decode_attention(qg, kc, vc, plan["kv_indices"],
                                plan["kv_counts"], thr, pos, k_block=KB)
    return out, plan


@multi_device
def test_tensor_parallel_decode_parity():
    from repro.core.decode_plan import init_decode_plan
    qg, kc, vc, kn, pos = _decode_inputs()
    oref, pref = _reference_step(qg, kc, vc, kn, pos,
                                 init_decode_plan(B, KV, SMAX, D, KB))
    mesh = M.make_shard_mesh(2)
    out, pnew = M.tensor_parallel_decode_step(
        mesh, qg, kc, vc, kn, pos, init_decode_plan(B, KV, SMAX, D, KB),
        topk_k=8, k_block=KB, replan_interval=1)
    assert float(jnp.abs(out - oref).max()) == 0.0
    for name in pref:
        assert (np.asarray(pnew[name]) == np.asarray(pref[name])).all(), \
            name


@multi_device
def test_tensor_parallel_decode_multi_step_carry():
    """The sharded plan feeds straight back — three steps stay bitwise
    with the single-device carry."""
    from repro.core.decode_plan import init_decode_plan
    rng = np.random.default_rng(3)
    mesh = M.make_shard_mesh(2)
    plan_r = init_decode_plan(B, KV, SMAX, D, KB)
    plan_s = init_decode_plan(B, KV, SMAX, D, KB)
    kc = jnp.zeros((B, SMAX, KV, D), jnp.float32)
    vc = jnp.zeros((B, SMAX, KV, D), jnp.float32)
    for step in range(3):
        p = 16 + step
        pos = jnp.full((B,), p, jnp.int32)
        kn = jnp.asarray(rng.standard_normal((B, 1, KV, D)), jnp.float32)
        vn = jnp.asarray(rng.standard_normal((B, 1, KV, D)), jnp.float32)
        qg = jnp.asarray(rng.standard_normal((B, KV, G, D)), jnp.float32)
        kc = kc.at[:, p:p + 1].set(kn)
        vc = vc.at[:, p:p + 1].set(vn)
        oref, plan_r = _reference_step(qg, kc, vc, kn, pos, plan_r)
        out, plan_s = M.tensor_parallel_decode_step(
            mesh, qg, kc, vc, kn, pos, plan_s, topk_k=8, k_block=KB,
            replan_interval=1)
        assert float(jnp.abs(out - oref).max()) == 0.0, step


def test_plan_pspecs_cover_every_leaf():
    from repro.core.decode_plan import init_decode_plan
    for summary in ("fp32", "int8"):
        plan = init_decode_plan(2, 4, 32, 8, 8, summary=summary,
                                qos=True, retire=True)
        specs = M.plan_pspecs(plan, "kv")
        assert set(specs) == set(plan)
        for name, val in plan.items():
            assert len(specs[name]) == val.ndim, name


def test_shared_prefix_index_publish_lookup():
    from repro.core.paging import SharedPrefixIndex
    idx = SharedPrefixIndex()
    toks = np.arange(16, dtype=np.int64)
    page = 8
    payload = {"k_pages": np.zeros((1, 2, page, 2, 4), np.float32)}
    n = idx.publish(0, toks, page, payload)
    assert n == 2
    # same replica looking up its own pages: no remote pages
    hit = idx.lookup(0, toks)
    assert hit is not None and hit[0] == 16 and hit[2] == 0
    # other replica: both pages are remote-owned
    hit = idx.lookup(1, toks)
    assert hit is not None and hit[0] == 16 and hit[2] == 2
    assert hit[1]["k_pages"].shape[1] == 2
    # re-publish dedups (first publisher wins)
    assert idx.publish(1, toks, page, payload) == 0


def test_serve_replicated_cross_replica_hits():
    import repro.launch.serve as serve_mod
    from repro.configs.archs import SMOKE
    cfg = dataclasses.replace(
        SMOKE["qwen3-4b"], attention_variant="topk", topk_impl="bisect",
        sata_decode="on", sata_decode_block=8, kv_cache_layout="paged",
        kv_page_size=8, kv_prefix_cache=True)
    out = serve_mod.serve_replicated(
        "qwen3-4b", n_replicas=2, smoke=True, cfg=cfg,
        options=serve_mod.ServeOptions(n_requests=4, batch_slots=2,
                                       gen_len=3, max_len=64,
                                       prompt_len=17,
                                       shared_prefix_len=16))
    assert out["outputs_equal"]
    assert out["cross_replica_hits"] >= 1
    assert out["migrated_pages"] >= 2
    assert 0.0 < out["cross_replica_hit_rate"] <= 1.0
