"""Fault-tolerant paged serving: host-swap preemption bitwise equality
(swap == never-preempted, requeue fallback too), deterministic fault
injection driving every backpressure branch, allocator invariant
auditing over random schedules (hypothesis), int8 summary-row swap
round-trips, the deadline watchdog, bounded preemption retries, and the
explicit victim tie-break."""
import dataclasses
import sys
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).parent))
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402

from repro.configs.archs import SMOKE
from repro.core.paging import OVERFLOW_PAGE, PageAllocator
from repro.launch.faults import FaultPlan
from repro.launch.serve import _pick_victim, serve
from repro.models import decode as dec


def _cfg(**kw):
    base = dict(topk_impl="bisect", sata_decode="on",
                sata_decode_block=8, sata_decode_replan=4,
                kv_cache_layout="paged", kv_pool_pages=6)
    base.update(kw)
    return dataclasses.replace(SMOKE["qwen3-4b"], **base)


_KW = dict(n_requests=4, batch_slots=2, gen_len=12, max_len=32,
           prompt_len=6)
_BASELINES = {}


def _baseline(**cfg_kw):
    """Fault-free reference run (memoized — several tests compare
    against the same never-preempted outputs)."""
    key = tuple(sorted(cfg_kw.items()))
    if key not in _BASELINES:
        _BASELINES[key] = serve("qwen3-4b", cfg=_cfg(**cfg_kw), **_KW)
    return _BASELINES[key]


# ---------------------------------------------------------------------------
# Victim selection
# ---------------------------------------------------------------------------

def test_pick_victim_ties_break_by_admission_order():
    """Equal-progress stalled slots used to tie nondeterministically
    across schedule variants; the explicit rule is least progress, then
    YOUNGEST admission."""
    slots = [10, 11, 12]
    outputs = {10: [1, 2], 11: [3, 4], 12: [5, 6, 7]}
    admit_seq = {10: 0, 11: 5, 12: 2}
    # 10 and 11 tie on progress; 11 admitted later → victim
    assert _pick_victim([0, 1, 2], slots, outputs, admit_seq) == 1
    # protection excludes 11 → 10 (next youngest among the tied)
    assert _pick_victim([0, 1, 2], slots, outputs, admit_seq,
                        protected={11}) == 0
    # everyone protected → fall back to the unprotected rule
    assert _pick_victim([0, 1, 2], slots, outputs, admit_seq,
                        protected={10, 11, 12}) == 1


# ---------------------------------------------------------------------------
# Host-swap preemption: the headline bitwise property
# ---------------------------------------------------------------------------

def test_swap_preemption_bitwise_equal_zero_reprefill():
    """A pool squeeze forcing ≥2 preemptions must host-swap the
    victims and restore them with ZERO re-prefilled tokens and zero
    cold re-plans — outputs bitwise equal to the fault-free run, with
    the invariant audit live after every allocator mutation."""
    base = _baseline()
    fp = FaultPlan().pool_squeeze(2, 3).pool_restore(14)
    out = serve("qwen3-4b", cfg=_cfg(), faults=fp, **_KW)
    occ = out["page_occupancy"]
    assert occ["host_swaps"] >= 2, occ
    assert occ["swap_restores"] == occ["host_swaps"]
    assert occ["re_prefill_tokens"] == 0
    assert occ["swap_cold_replans"] == 0
    assert occ["tokens_salvaged"] > 0
    assert occ["requeue_preemptions"] == 0
    assert occ["audits_run"] > 0
    assert out["outputs"] == base["outputs"]
    assert all(len(v) == _KW["gen_len"] for v in out["outputs"].values())


def test_requeue_fallback_when_host_budget_dry():
    """host_swap_bytes=0 disables swap: the livelock handler falls back
    to requeue-and-regenerate, still bitwise equal (deterministic
    regeneration) but paying re-prefill for every victim."""
    base = _baseline()
    fp = FaultPlan().pool_squeeze(2, 3).pool_restore(14)
    out = serve("qwen3-4b", cfg=_cfg(), faults=fp, host_swap_bytes=0,
                **_KW)
    occ = out["page_occupancy"]
    assert occ["host_swaps"] == 0
    assert occ["requeue_preemptions"] > 0
    assert occ["re_prefill_tokens"] > 0
    assert out["outputs"] == base["outputs"]


def test_forced_preempt_and_defer_are_deterministic():
    """A forced-preempt/defer schedule replays identically (same
    counters, same outputs) and never changes the final outputs."""
    base = _baseline()
    fp = FaultPlan().preempt(3).defer_admission(4).preempt(7, slot=1)
    a = serve("qwen3-4b", cfg=_cfg(kv_pool_pages=8), faults=fp, **_KW)
    b = serve("qwen3-4b", cfg=_cfg(kv_pool_pages=8), faults=fp, **_KW)
    assert a["outputs"] == b["outputs"] == base["outputs"]
    for k in ("preemptions", "host_swaps", "swap_restores",
              "tokens_salvaged", "deferred_claims", "stalled_steps"):
        assert a["page_occupancy"][k] == b["page_occupancy"][k], k
    assert a["page_occupancy"]["preemptions"] >= 2


def test_swap_preserves_int8_summary_rows_end_to_end():
    """The int8 summary backend's codes + scale/zero rows ride the
    swap payload; restored slots must keep ranking from bit-identical
    summaries (outputs equal under squeeze-forced swaps)."""
    kw = dict(kv_prefix_cache=True, sata_summary="int8")
    base = _baseline(**kw)
    fp = FaultPlan().pool_squeeze(2, 3).pool_restore(14)
    out = serve("qwen3-4b", cfg=_cfg(**kw), faults=fp, **_KW)
    assert out["outputs"] == base["outputs"]
    assert out["page_occupancy"]["preemptions"] > 0


def test_gather_scatter_round_trips_pages_bitwise():
    """models.decode.gather_phys_pages → scatter_phys_pages moves K/V
    AND summary rows (int8 codes included) bit-identically, even into
    different physical pages."""
    cfg = _cfg(kv_prefix_cache=True, sata_summary="int8",
               kv_pool_pages=8)
    cache = dec.init_cache(cfg, 2, 32)
    rng = np.random.default_rng(0)
    kv = dict(cache["kv"])
    for f in ("k_pages", "v_pages", "page_k_min", "page_k_max",
              "page_k_scale", "page_k_zero"):
        a = np.asarray(kv[f])
        if a.dtype == np.int8:
            kv[f] = jnp.asarray(rng.integers(-128, 128, a.shape), jnp.int8)
        else:
            kv[f] = jnp.asarray(rng.standard_normal(a.shape), a.dtype)
    cache = {**cache, "kv": kv}
    src, dst = [2, 5, 3], [6, 1, 4]
    payload = dec.gather_phys_pages(cache, src)
    assert any(k.endswith("page_k_scale") for k in payload)  # int8 rows ride
    restored = dec.scatter_phys_pages(cache, dst, payload)
    for f in ("k_pages", "v_pages", "page_k_min", "page_k_max",
              "page_k_scale", "page_k_zero"):
        want = np.asarray(cache["kv"][f])[:, src]
        got = np.asarray(restored["kv"][f])[:, dst]
        np.testing.assert_array_equal(got, want, err_msg=f)


# ---------------------------------------------------------------------------
# Crash + watchdog + bounded retries
# ---------------------------------------------------------------------------

def test_watchdog_retires_runaway_requests():
    """max_steps_per_request retires slots gracefully: partial outputs
    stand, pages free (pool drains to zero), requests report as
    timed_out instead of holding the pool forever."""
    out = serve("qwen3-4b", cfg=_cfg(kv_pool_pages=8),
                max_steps_per_request=5, **_KW)
    assert out["timed_out"] == list(range(_KW["n_requests"]))
    assert out["page_occupancy"]["pages_in_use"] == 0
    # 1 prefill token + 5 watchdog-clocked steps of decode
    assert all(len(v) == 6 for v in out["outputs"].values())
    assert all(r in out["request_latency_s"] for r in out["timed_out"])


def test_bounded_retries_reserve_guarantees_completion():
    """A request hammered past the retry limit re-admits under the
    reserved-page guarantee: the run still completes every request
    bitwise-equal, and the occupancy report surfaces the retries."""
    base = _baseline()
    fp = FaultPlan()
    for s in range(2, 26, 2):
        fp.preempt(s, slot=0)
    out = serve("qwen3-4b", cfg=_cfg(), faults=fp,
                preempt_retry_limit=2, **_KW)
    occ = out["page_occupancy"]
    assert occ["preempt_retries_max"] >= 2
    assert occ["protected_admissions"] >= 1
    assert out["outputs"] == base["outputs"]
    assert all(len(v) == _KW["gen_len"] for v in out["outputs"].values())


def test_faults_require_paged_layout():
    cfg = dataclasses.replace(SMOKE["qwen3-4b"], topk_impl="bisect",
                              kv_cache_layout="contiguous")
    with pytest.raises(ValueError, match="paged"):
        serve("qwen3-4b", cfg=cfg, faults=FaultPlan().pool_squeeze(1, 2),
              **_KW)


def test_seeded_fault_plan_is_reproducible():
    a = FaultPlan.seeded(7, steps=40, slots=3, allow_crash=True)
    b = FaultPlan.seeded(7, steps=40, slots=3, allow_crash=True)
    assert a.describe() == b.describe() and not a.empty
    assert a.has_crash
    c = FaultPlan.seeded(8, steps=40, slots=3)
    assert not c.has_crash


# ---------------------------------------------------------------------------
# Allocator invariants under random fault schedules (hypothesis)
# ---------------------------------------------------------------------------

def _synthetic_pages(n_pages, rng):
    """Host-side stand-in for the device pools: one fp32 and one int8
    array per physical page, so gather/scatter round-trips exercise
    both dtypes the real payload carries."""
    return {
        "rows": rng.standard_normal((n_pages, 4)).astype(np.float32),
        "codes": rng.integers(-128, 128, (n_pages, 4)).astype(np.int8),
    }


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 4))
def test_allocator_invariants_under_random_fault_schedules(seed, slots_n):
    """Property: over arbitrary claim/append/squeeze/preempt(swap)/
    swap-in/crash/free schedules, (a) check_invariants holds after
    every event (audit=True runs it inside every mutation), (b) every
    swap round-trips its synthetic page payloads — fp32 AND int8 —
    bit-identically, (c) swapped pages never appear in device tables."""
    rng = np.random.default_rng(seed)
    n_pages = int(rng.integers(6, 14))
    a = PageAllocator(n_pages, slots_n, max_pages=8, page=4, audit=True)
    pools = _synthetic_pages(n_pages, rng)
    truth = {}              # slot → {logical page → (rows, codes)}
    handles = {}            # handle id → {logical page → (rows, codes)}
    audits_total = 0

    def gather(phys):
        return {k: pools[k][phys] for k in pools}

    for _ in range(40):
        op = rng.choice(["claim", "append", "squeeze", "unsqueeze",
                         "swap_out", "swap_in", "free", "crash"])
        slot = int(rng.integers(slots_n))
        if op == "claim" and a.n_mapped[slot] == 0 and a.can_admit(1):
            assert a.ensure(slot, 0)
            p = int(a.table[slot, 0])
            pools["rows"][p] = rng.standard_normal(4).astype(np.float32)
            pools["codes"][p] = rng.integers(-128, 128, 4).astype(np.int8)
            truth[slot] = {0: (pools["rows"][p].copy(),
                               pools["codes"][p].copy())}
        elif op == "append" and 0 < a.n_mapped[slot] < a.max_pages:
            lp = int(a.n_mapped[slot])
            if a.ensure(slot, lp * a.page):
                p = int(a.table[slot, lp])
                pools["rows"][p] = rng.standard_normal(4).astype(np.float32)
                pools["codes"][p] = rng.integers(-128, 128, 4).astype(np.int8)
                truth[slot][lp] = (pools["rows"][p].copy(),
                                   pools["codes"][p].copy())
        elif op == "squeeze":
            a.squeeze(int(rng.integers(1, 4)))
        elif op == "unsqueeze":
            a.unsqueeze()
        elif op == "swap_out" and a.n_mapped[slot] > 0:
            h = a.swap_out(slot, gather)
            handles[id(h)] = (h, truth.pop(slot))
        elif op == "swap_in" and handles:
            hid = list(handles)[int(rng.integers(len(handles)))]
            h, saved = handles[hid]
            free_slots = [s for s in range(slots_n) if a.n_mapped[s] == 0]
            if free_slots and a.can_admit(a.swap_pages_needed(h)):
                dst = free_slots[0]

                def scatter(fresh, payload):
                    for k in pools:
                        pools[k][fresh] = payload[k]

                assert a.swap_in(dst, h, scatter)
                del handles[hid]
                # bit-identical round-trip, including the int8 rows
                for lp, (rows, codes) in saved.items():
                    p = int(a.table[dst, lp])
                    np.testing.assert_array_equal(pools["rows"][p], rows)
                    np.testing.assert_array_equal(pools["codes"][p], codes)
                truth[dst] = saved
        elif op == "free" and a.n_mapped[slot] > 0:
            a.free_slot(slot)
            truth.pop(slot, None)
        elif op == "crash":
            # host-swap everything live, rebuild the allocator, keep
            # the handles: exactly serve()'s crash path, allocator-side
            for s in range(slots_n):
                if a.n_mapped[s] > 0:
                    h = a.swap_out(s, gather)
                    handles[id(h)] = (h, truth.pop(s))
            for h, _ in handles.values():
                a.swap_to_full(h, gather)
            keep = a.swapped
            audits_total += a.audits_run
            a = PageAllocator(n_pages, slots_n, max_pages=8, page=4,
                              audit=True)
            a.swapped = keep
            pools = _synthetic_pages(n_pages, rng)   # device contents lost
        a.check_invariants()
    assert audits_total + a.audits_run > 0


def test_check_invariants_catches_corruption():
    """The audit must actually fire on broken state, not just pass on
    good state."""
    a = PageAllocator(8, 2, max_pages=4, page=4, audit=False)
    assert a.ensure(0, 0)
    a.ref[int(a.table[0, 0])] += 1          # phantom reference
    with pytest.raises(AssertionError, match="refcount"):
        a.check_invariants()
    a2 = PageAllocator(8, 2, max_pages=4, page=4)
    assert a2.ensure(0, 0)
    a2.table[0, 1] = a2.table[0, 0]         # stale mapping beyond n_mapped
    with pytest.raises(AssertionError, match="stale"):
        a2.check_invariants()
    a3 = PageAllocator(8, 2, max_pages=4, page=4)
    a3.ref[OVERFLOW_PAGE] = 1
    with pytest.raises(AssertionError, match="overflow"):
        a3.check_invariants()
