"""Per-architecture smoke tests: reduced config, one forward + loss +
grad step + one decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS, SMOKE
from repro.models import decode as dec
from repro.models import model as mdl


def make_batch(cfg, batch=2, seq=16, key=0):
    rng = np.random.default_rng(key)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq))),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)))}
    if cfg.family == "vlm":
        b["image_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_image_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "audio":
        b["audio_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_len, cfg.d_model)),
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_grad(arch):
    cfg = SMOKE[arch]
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)

    logits, aux = jax.jit(lambda p, b: mdl.forward(p, cfg, b))(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: mdl.loss_fn(p, cfg, b),
                           has_aux=True))(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    gnorm = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                     grads))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0, \
        f"{arch}: bad grad norm"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step(arch):
    cfg = SMOKE[arch]
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    cache = dec.init_cache(cfg, batch=2, max_len=32)
    cache = dec.prefill_context(params, cfg, cache, batch)

    step = jax.jit(lambda p, c, t, pos: dec.serve_step(p, cfg, c, t, pos))
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache = step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite decode"
    logits2, cache = step(params, cache, tok, jnp.int32(1))
    assert bool(jnp.isfinite(logits2).all())


def test_decode_matches_forward_dense_arch():
    """Greedy decode logits == full forward logits (olmo smoke, dense
    attention, no topk mismatch between cache-masked and full paths)."""
    cfg = SMOKE["olmo-1b"]
    cfg = type(cfg)(**{**cfg.__dict__, "attention_variant": "dense"})
    params = mdl.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)))
    full_logits, _ = mdl.forward(params, cfg, {"tokens": toks})

    cache = dec.init_cache(cfg, batch=1, max_len=8)
    outs = []
    for t in range(8):
        lg, cache = dec.serve_step(params, cfg, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full_logits),
                               np.asarray(dec_logits), rtol=2e-4, atol=2e-4)


def test_param_count_plausible():
    for arch, cfg in ARCHS.items():
        n = cfg.param_count()
        assert n > 5e7, f"{arch}: suspiciously few params {n}"
    # spot-check the headline sizes (±40% of nameplate)
    assert 2.5e9 < ARCHS["phi4-mini-3.8b"].param_count() < 5.5e9
    assert 45e9 < ARCHS["deepseek-67b"].param_count() < 90e9
    assert 160e9 < ARCHS["qwen3-moe-235b-a22b"].param_count() < 330e9
    assert 220e9 < ARCHS["grok-1-314b"].param_count() < 440e9
    assert 1.0e9 < ARCHS["rwkv6-1.6b"].param_count() < 2.6e9


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "zamba2-2.7b", "olmo-1b"])
def test_bf16_forward_carry_dtypes(arch):
    """Regression: f32 mix ratios must not promote the bf16 residual
    stream (scan carries are dtype-strict; the full configs run bf16)."""
    import dataclasses
    cfg = dataclasses.replace(SMOKE[arch], dtype="bfloat16")
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, _ = jax.jit(lambda p, b: mdl.forward(p, cfg, b))(params, batch)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
