"""Structured-config API: flat↔nested equivalence, warn-once
deprecation shims, construction-time validation, and nested-config
serialization through the checkpoint meta_blob."""
import dataclasses
import pickle
import warnings

import pytest

import repro.launch.serve as serve_mod
from repro.models import config as config_mod
from repro.models.config import (KVCacheConfig, ModelConfig, QosConfig,
                                 RetireConfig, SataConfig,
                                 SataDecodeConfig, SataKernelConfig)


@pytest.fixture(autouse=True)
def _fresh_warn_registry():
    """Each test observes its own first-use warnings."""
    saved = set(config_mod._warned_flat)
    config_mod._warned_flat.clear()
    yield
    config_mod._warned_flat.clear()
    config_mod._warned_flat.update(saved)


def _cfg(**kw):
    return ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                       attention_variant="topk", topk_k=8, **kw)


# ---------------------------------------------------------------------------
# flat ↔ nested equivalence
# ---------------------------------------------------------------------------

def test_flat_kwargs_fold_into_nested_groups():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        flat = _cfg(sata_block=64, sata_decode="on", sata_decode_block=8,
                    kv_cache_layout="paged", kv_page_size=8,
                    kv_prefix_cache=True, sata_qos_ladder=True,
                    sata_retire="on")
    nested = _cfg(
        sata=SataConfig(kernel=SataKernelConfig(block=64),
                        decode=SataDecodeConfig(mode="on", block=8),
                        qos=QosConfig(ladder=True),
                        retire=RetireConfig(mode="on")),
        kv=KVCacheConfig(layout="paged", page_size=8, prefix_cache=True))
    assert flat == nested
    assert flat.sata.kernel.block == 64
    assert flat.kv.page_size == 8


def test_flat_properties_read_nested_values():
    cfg = _cfg(sata=SataConfig(decode=SataDecodeConfig(mode="on",
                                                       replan=4)))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert cfg.sata_decode == "on"
        assert cfg.sata_decode_replan == 4
        assert cfg.kv_cache_layout == "contiguous"


def test_replace_accepts_flat_and_nested_keys():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        c1 = dataclasses.replace(_cfg(), sata_decode="on", kv_pool_pages=7)
    c2 = dataclasses.replace(
        _cfg(),
        sata=dataclasses.replace(_cfg().sata,
                                 decode=SataDecodeConfig(mode="on")),
        kv=KVCacheConfig(pool_pages=7))
    assert c1 == c2


def test_every_flat_name_is_mapped():
    cfg = _cfg()
    for flat, path in config_mod._FLAT_MAP.items():
        node = cfg
        for part in path:
            node = getattr(node, part)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            assert getattr(cfg, flat) == node, flat


# ---------------------------------------------------------------------------
# deprecation warnings: exactly once per flat name per process
# ---------------------------------------------------------------------------

def test_flat_read_warns_exactly_once():
    cfg = _cfg()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg.sata_block
        cfg.sata_block
        cfg.sata_block
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "sata_block" in str(dep[0].message)
    assert "sata.kernel.block" in str(dep[0].message)


def test_flat_constructor_kwarg_warns_once():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        _cfg(sata_decode="on")
        _cfg(sata_decode="on")
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1


def test_nested_access_never_warns():
    cfg = _cfg()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg.sata.kernel.block
        cfg.sata.decode.mode
        cfg.kv.layout
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]


# ---------------------------------------------------------------------------
# construction-time validation (the page-size/block footgun)
# ---------------------------------------------------------------------------

def test_paged_page_block_mismatch_raises_at_construction():
    with pytest.raises(ValueError, match="kv_page_size == the decode"):
        _cfg(sata=SataConfig(decode=SataDecodeConfig(mode="on", block=4)),
             kv=KVCacheConfig(layout="paged", page_size=8))


def test_paged_matching_page_block_constructs():
    cfg = _cfg(sata=SataConfig(decode=SataDecodeConfig(mode="on",
                                                       block=8)),
               kv=KVCacheConfig(layout="paged", page_size=8))
    assert cfg.kv.page_size == cfg.sata.decode.block == 8


def test_kv_layout_validated():
    with pytest.raises(ValueError, match="layout"):
        KVCacheConfig(layout="interleaved")


# ---------------------------------------------------------------------------
# serialization: nested configs through the PR 8 checkpoint meta_blob
# ---------------------------------------------------------------------------

def test_nested_config_checkpoint_meta_roundtrip(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    cfg = _cfg(sata=SataConfig(kernel=SataKernelConfig(block=64),
                               decode=SataDecodeConfig(mode="on", block=8,
                                                       replan=2)),
               kv=KVCacheConfig(layout="paged", page_size=8,
                                prefix_cache=True))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(0, {"x": __import__("numpy").zeros((2,))},
             meta_blob=pickle.dumps({"cfg": cfg, "step": 0}))
    meta = pickle.loads(mgr.load_meta(0))
    assert meta["cfg"] == cfg
    assert meta["cfg"].sata.decode.replan == 2
    assert hash(meta["cfg"]) == hash(cfg)


def test_pickle_roundtrip_plain():
    cfg = _cfg(sata=SataConfig(decode=SataDecodeConfig(summary="int8")))
    assert pickle.loads(pickle.dumps(cfg)) == cfg


# ---------------------------------------------------------------------------
# serve() signature shim
# ---------------------------------------------------------------------------

def test_serve_legacy_kwargs_fold():
    opt, res = serve_mod._fold_serve_legacy(
        None, None, {"n_requests": 3, "gen_len": 5,
                     "audit_pages": False})
    assert opt.n_requests == 3 and opt.gen_len == 5
    assert res.audit_pages is False


def test_serve_legacy_overrides_options_base():
    base = serve_mod.ServeOptions(n_requests=9, batch_slots=2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        opt, _ = serve_mod._fold_serve_legacy(base, None,
                                              {"n_requests": 3})
    assert opt.n_requests == 3 and opt.batch_slots == 2


def test_serve_unknown_kwarg_raises():
    with pytest.raises(TypeError, match="bogus"):
        serve_mod._fold_serve_legacy(None, None, {"bogus": 1})
