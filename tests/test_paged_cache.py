"""Paged KV-cache serving: allocator behavior, paged-vs-contiguous
bitwise decode parity (property over arbitrary claim/free/append/re-plan
sequences), pool-exhaustion backpressure, the prefill→decode plan
handoff, the churn-adaptive re-plan trigger, the occupancy-bound
dense-grid fallback, and plan-side fetch accounting."""
import dataclasses
import sys
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).parent))
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402

from repro.configs.archs import SMOKE
from repro.core.decode_plan import (decode_plan_update, full_replan,
                                    init_decode_plan, reset_plan_slot,
                                    summaries_from_cache,
                                    update_block_summaries)
from repro.core.paging import OVERFLOW_PAGE, PageAllocator, logical_kv_view
from repro.kernels.ops import (decode_fetch_stats, sata_attention,
                               sata_decode_attention)
from repro.models import attention as attn
from repro.models import decode as dec
from repro.models import model as mdl


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _cfg(**kw):
    base = dict(topk_impl="bisect", sata_decode="on",
                sata_decode_block=4, sata_decode_replan=1)
    base.update(kw)
    return dataclasses.replace(SMOKE["qwen3-4b"], **base)


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------

def test_page_allocator_lifecycle():
    a = PageAllocator(n_pages=6, batch_slots=2, max_pages=4, page=8)
    assert a.free_pages == 5 and a.pages_in_use == 0
    assert a.can_admit(5) and not a.can_admit(6)
    assert a.ensure(0, 0)                    # 1 page
    assert a.ensure(0, 23)                   # grows to 3 pages
    assert a.pages_in_use == 3
    assert (a.table[0, :3] != OVERFLOW_PAGE).all()
    assert (a.table[0, 3:] == OVERFLOW_PAGE).all()
    assert a.ensure(1, 15)                   # 2 pages → pool dry
    assert not a.ensure(1, 16)               # 3rd page: exhausted → stall
    assert a.pages_in_use == 5
    freed = a.free_slot(0)
    assert freed == 3 and a.pages_in_use == 2
    assert (a.table[0] == OVERFLOW_PAGE).all()
    assert a.ensure(1, 16)                   # freed pages recycle
    assert a.pages_in_use_peak == 5


def test_page_allocator_never_hands_out_overflow():
    a = PageAllocator(n_pages=4, batch_slots=1, max_pages=3, page=4)
    assert a.ensure(0, 11)
    assert OVERFLOW_PAGE not in a.table[0, :3].tolist()


def test_logical_view_roundtrips_mapped_pages():
    rng = np.random.default_rng(0)
    pages = jnp.asarray(rng.standard_normal((5, 4, 2, 8)), jnp.float32)
    tbl = jnp.asarray([[2, 4, 0]], jnp.int32)        # logical 2 unmapped
    view = logical_kv_view(pages, tbl)
    assert view.shape == (1, 12, 2, 8)
    np.testing.assert_array_equal(np.asarray(view[0, :4]),
                                  np.asarray(pages[2]))
    np.testing.assert_array_equal(np.asarray(view[0, 4:8]),
                                  np.asarray(pages[4]))


# ---------------------------------------------------------------------------
# Paged decode == contiguous decode, bitwise
# ---------------------------------------------------------------------------

def test_paged_decode_kernel_bitwise_equals_contiguous():
    """Same cache contents, same plan: the page-table-indirect kernel
    must match the contiguous-layout kernel bit for bit (same tiles,
    same flash-loop order — only the DMA source addresses differ)."""
    b, kv, g, s, d, blk = 3, 2, 2, 64, 16, 16
    nkb = s // blk
    q = _rand(jax.random.PRNGKey(0), (b, kv, g, d))
    k = _rand(jax.random.PRNGKey(1), (b, s, kv, d))
    v = _rand(jax.random.PRNGKey(2), (b, s, kv, d))
    pos = jnp.asarray([s - 1, 21, 0], jnp.int32)
    alloc = PageAllocator(b * nkb + 1, b, nkb, blk)
    for i in range(b):
        assert alloc.ensure(i, int(pos[i]))
    tbl = jnp.asarray(alloc.table)
    n_pages = alloc.n_pages
    kp = jnp.zeros((n_pages, blk, kv, d), jnp.float32)
    vp = jnp.zeros((n_pages, blk, kv, d), jnp.float32)
    for i in range(b):
        for lp in range(int(pos[i]) // blk + 1):
            ph = int(alloc.table[i, lp])
            kp = kp.at[ph].set(k[i, lp * blk:(lp + 1) * blk])
            vp = vp.at[ph].set(v[i, lp * blk:(lp + 1) * blk])
    idx, cnt, thr = full_replan(q, k, pos, topk_k=4, k_block=blk,
                                plan_blocks=nkb)
    out_c = sata_decode_attention(q, k, v, idx, cnt, thr, pos,
                                  k_block=blk, interpret=True)
    out_p = sata_decode_attention(q, kp, vp, idx, cnt, thr, pos,
                                  k_block=blk, page_table=tbl,
                                  interpret=True)
    assert float(jnp.max(jnp.abs(out_c - out_p))) == 0.0


def _paged_twin(cfg, max_len):
    return dataclasses.replace(cfg, kv_cache_layout="paged")


def _drive_layouts(seed, n_steps, replan):
    """Drive one attention layer's decode through BOTH layouts with an
    identical claim/free/append sequence and return per-step outputs."""
    cfg_c = _cfg(sata_decode_replan=replan)
    cfg_p = _paged_twin(cfg_c, 16)
    b, max_len, blk = 2, 16, 4
    params = attn.attention_init(jax.random.PRNGKey(0), cfg_c)
    dt = jnp.float32
    cache_c = attn.init_kv_cache(cfg_c, b, max_len, dt)
    cache_p = attn.init_kv_cache(cfg_p, b, max_len, dt)
    alloc = PageAllocator(int(cache_p["k_pages"].shape[0]), b,
                          max_len // blk, blk)
    rng = np.random.default_rng(seed)
    pos = np.zeros(b, np.int32)
    outs = []
    for t in range(n_steps):
        if rng.random() < 0.3:                   # a request completes;
            slot = int(rng.integers(b))          # a new one claims
            for c in (cache_c, cache_p):
                c["plan"] = reset_plan_slot(c["plan"], slot)
            alloc.free_slot(slot)
            pos[slot] = 0
        for i in range(b):
            assert alloc.ensure(i, int(pos[i]))
        cache_p["page_table"] = jnp.asarray(alloc.table)
        x = jnp.asarray(rng.standard_normal((b, 1, cfg_c.d_model)),
                        jnp.float32)
        posj = jnp.asarray(pos)
        y_c, cache_c = attn.attention_decode(params, cfg_c, x, cache_c,
                                             posj)
        y_p, cache_p = attn.attention_decode(params, cfg_p, x, cache_p,
                                             posj)
        outs.append((np.asarray(y_c), np.asarray(y_p)))
        pos = np.minimum(pos + 1, max_len - 1)
    return outs


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8),
           st.sampled_from([1, 3, "auto"]))
    def test_property_paged_decode_bitwise_equals_contiguous(
            seed, n_steps, replan):
        """Over ANY claim/free/append/re-plan sequence, the paged layout
        produces bitwise-identical decode outputs to the contiguous
        cache: same values flow through the same ops, garbage in
        unmapped/recycled pages is position-masked exactly like stale
        contiguous rows, and the plan state machine never observes
        physical placement."""
        for y_c, y_p in _drive_layouts(seed, n_steps, replan):
            np.testing.assert_array_equal(y_c, y_p)
else:                                            # pragma: no cover
    def test_property_paged_decode_bitwise_equals_contiguous():
        for y_c, y_p in _drive_layouts(7, 6, 3):
            np.testing.assert_array_equal(y_c, y_p)


# ---------------------------------------------------------------------------
# Serving loop: backpressure, preemption, occupancy report
# ---------------------------------------------------------------------------

def test_serve_paged_matches_contiguous_outputs():
    from repro.launch.serve import serve
    base = _cfg(sata_decode_block=8)
    a = serve("qwen3-4b", smoke=True, n_requests=4, batch_slots=2,
              gen_len=6, max_len=32, cfg=base)
    b = serve("qwen3-4b", smoke=True, n_requests=4, batch_slots=2,
              gen_len=6, max_len=32,
              cfg=dataclasses.replace(base, kv_cache_layout="paged"))
    assert a["outputs"] == b["outputs"]
    occ = b["page_occupancy"]
    assert occ["pages_in_use"] == 0              # all requests freed
    assert occ["hbm_used_peak_bytes"] <= occ["hbm_reserved_bytes"]


def test_serve_pool_exhaustion_backpressure():
    """An undersized pool (half the contiguous reservation) must still
    complete every request with identical outputs — exhaustion shows up
    as deferred claims / stalls / preemptions, never as a shape error
    or corrupted output."""
    from repro.launch.serve import serve
    base = _cfg(sata_decode_block=8)
    tight = dataclasses.replace(base, kv_cache_layout="paged",
                                kv_pool_pages=4)
    a = serve("qwen3-4b", smoke=True, n_requests=4, batch_slots=2,
              gen_len=10, max_len=32, cfg=base)
    t = serve("qwen3-4b", smoke=True, n_requests=4, batch_slots=2,
              gen_len=10, max_len=32, cfg=tight)
    assert a["outputs"] == t["outputs"]
    assert all(len(v) == 10 for v in t["outputs"].values())
    occ = t["page_occupancy"]
    assert occ["reserved_vs_contiguous"] == 2.0
    assert (occ["stalled_steps"] + occ["deferred_claims"]
            + occ["preemptions"]) > 0
    assert occ["pages_in_use_peak"] <= occ["n_pages"] - 1


def test_serve_rejects_pool_smaller_than_one_request():
    """A pool that cannot hold even ONE request's worst-case working
    set would self-preempt forever and silently truncate outputs —
    serve() must refuse it up front."""
    from repro.launch.serve import serve
    cfg = dataclasses.replace(_cfg(sata_decode_block=8),
                              kv_cache_layout="paged", kv_pool_pages=4)
    with pytest.raises(ValueError, match="working set"):
        serve("qwen3-4b", smoke=True, n_requests=1, batch_slots=1,
              gen_len=40, max_len=64, cfg=cfg)


def test_serve_preemption_recovers_livelock():
    """Concurrent requests whose combined demand exceeds the pool would
    deadlock all slots at page boundaries; preemption (requeue the
    youngest, deterministic regeneration) must complete them all."""
    from repro.launch.serve import serve
    cfg = dataclasses.replace(_cfg(sata_decode_block=8),
                              kv_cache_layout="paged", kv_pool_pages=4)
    out = serve("qwen3-4b", smoke=True, n_requests=3, batch_slots=3,
                gen_len=16, max_len=32, cfg=cfg)
    assert all(len(v) == 16 for v in out["outputs"].values())
    assert out["page_occupancy"]["preemptions"] > 0


# ---------------------------------------------------------------------------
# Prefill → decode handoff
# ---------------------------------------------------------------------------

def test_prefill_prompt_matches_stepwise_decode():
    cfg = _cfg(sata_decode_block=8, sata_decode_replan=4)
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (1, 6)), jnp.int32)
    cache = dec.init_cache(cfg, 1, 32)
    for t in range(6):
        lg_ref, cache = dec.serve_step(params, cfg, cache,
                                       toks[:, t:t + 1], jnp.int32(t))
    lg0, state = dec.prefill_prompt(params, cfg, toks, 32)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg_ref[:, 0]),
                               rtol=1e-4, atol=1e-4)
    # installed cache continues decoding like the stepwise one
    cache2 = dec.install_prefill(cfg, dec.init_cache(cfg, 1, 32), 0, state)
    nxt = jnp.argmax(lg0, -1)[:, None].astype(jnp.int32)
    lg_a, _ = dec.serve_step(params, cfg, cache, nxt, jnp.int32(6))
    lg_b, _ = dec.serve_step(params, cfg, cache2, nxt, jnp.int32(6))
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                               rtol=1e-4, atol=1e-4)


def test_handoff_makes_step0_planned():
    """The seeded plan arrives OFF the re-plan beat with live rows, so
    decode step 0 runs the incremental path: zero full re-plans, where
    the cold path re-plans (streams the whole prefix) immediately."""
    cfg = _cfg(sata_decode_block=8, sata_decode_replan=8)
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (1, 8)), jnp.int32)
    lg0, state = dec.prefill_prompt(params, cfg, toks, 32)
    cache = dec.install_prefill(cfg, dec.init_cache(cfg, 1, 32), 0, state)
    plan = cache["kv"]["plan"]
    assert int(np.asarray(plan["kv_counts"]).min()) > 0   # rows seeded
    assert int(np.asarray(plan["step"])[0, 0]) == 1       # off the beat
    nxt = jnp.argmax(lg0, -1)[:, None].astype(jnp.int32)
    _, cache = dec.serve_step(params, cfg, cache, nxt, jnp.int32(8))
    assert int(np.asarray(cache["kv"]["plan"]["replans"])[0, 0]) == 0


def test_serve_prompt_prefill_paged_and_contiguous_agree():
    from repro.launch.serve import serve
    base = _cfg(sata_decode_block=8, sata_decode_replan=4)
    a = serve("qwen3-4b", smoke=True, n_requests=3, batch_slots=2,
              gen_len=6, max_len=32, cfg=base, prompt_len=5)
    b = serve("qwen3-4b", smoke=True, n_requests=3, batch_slots=2,
              gen_len=6, max_len=32, prompt_len=5,
              cfg=dataclasses.replace(base, kv_cache_layout="paged"))
    assert a["outputs"] == b["outputs"]
    assert all(len(v) == 6 for v in a["outputs"].values())


def test_serve_prefill_output_is_the_greedy_continuation():
    """The prefill's last-position argmax is the FIRST generated token
    and must be part of the served output (the off-by-one this pins:
    feeding it without recording it would shift every completion)."""
    from repro.launch.serve import serve
    cfg = _cfg(sata_decode_block=8, sata_decode_replan=4)
    out = serve("qwen3-4b", smoke=True, n_requests=1, batch_slots=1,
                gen_len=4, max_len=32, cfg=cfg, prompt_len=5)
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 5))
    cache = dec.init_cache(cfg, 1, 32)
    toks = jnp.asarray(prompts, jnp.int32)
    for t in range(5):
        lg, cache = dec.serve_step(params, cfg, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
    gen = [int(jnp.argmax(lg[0, 0]))]
    for t in range(5, 8):
        cur = jnp.asarray([[gen[-1]]], jnp.int32)
        lg, cache = dec.serve_step(params, cfg, cache, cur, jnp.int32(t))
        gen.append(int(jnp.argmax(lg[0, 0])))
    assert out["outputs"][0] == gen


# ---------------------------------------------------------------------------
# Churn-adaptive re-plan
# ---------------------------------------------------------------------------

def _plan_seq(churn_budget, q_fn, n_steps):
    b, kv, s, d, blk = 1, 2, 32, 8, 8
    plan = init_decode_plan(b, kv, s, d, blk, plan_blocks=2)
    cache = jnp.zeros((b, s, kv, d), jnp.float32)
    upd = jax.vmap(lambda c, n, p:
                   jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0))
    for t in range(n_steps):
        k_new = _rand(jax.random.PRNGKey(100 + t), (b, 1, kv, d))
        posj = jnp.asarray([t], jnp.int32)
        cache = upd(cache, k_new, posj)
        plan = update_block_summaries(plan, k_new, posj, k_block=blk)
        plan, _ = decode_plan_update(plan, q_fn(t), cache, posj,
                                     topk_k=4, k_block=blk,
                                     churn_budget=churn_budget)
    return plan


def test_churn_adaptive_replans_on_drift_only():
    q_stable = _rand(jax.random.PRNGKey(0), (1, 2, 2, 8))
    n = 6
    # budget 0: any churn (>= 0) triggers → re-plan every step
    eager = _plan_seq(0.0, lambda t: q_stable, n)
    assert int(eager["replans"][0]) == n          # per-slot (B,) counters
    # huge budget: only the mandatory cold step-0 re-plan fires
    lazy = _plan_seq(1e9, lambda t: q_stable, n)
    assert int(lazy["replans"][0]) == 1
    assert int(lazy["step"][0]) == n
    assert float(lazy["churn"][0]) >= 0.0


def test_auto_replan_serves_finite():
    from repro.launch.serve import serve
    cfg = _cfg(sata_decode_block=8, sata_decode_replan="auto",
               sata_decode_blocks=2)
    out = serve("qwen3-4b", smoke=True, n_requests=2, batch_slots=2,
                gen_len=8, max_len=32, cfg=cfg)
    assert all(len(v) == 8 for v in out["outputs"].values())
    f = out["decode_fetch"]
    assert 0 < f["replans"] <= out["steps"]


def test_integer_interval_bit_compatible():
    """Adding the churn/replans state must not perturb fixed-interval
    plans: interval-driven updates yield the same indices/counts/
    thresholds as before (state rides along untouched)."""
    b, kv, s, d, blk = 1, 2, 32, 8, 8
    plan = init_decode_plan(b, kv, s, d, blk, plan_blocks=2)
    cache = _rand(jax.random.PRNGKey(3), (b, s, kv, d))
    pos = jnp.asarray([s - 1], jnp.int32)
    k_min, k_max = summaries_from_cache(cache, pos, k_block=blk)
    plan = {**plan, "k_min": k_min, "k_max": k_max}
    q = _rand(jax.random.PRNGKey(4), (b, kv, 2, d))
    p2, thr = decode_plan_update(plan, q, cache, pos, topk_k=4,
                                 k_block=blk, replan_interval=3)
    assert float(p2["churn"][0]) == 0.0          # untouched
    idx, cnt, thr_ref = full_replan(q, cache, pos, topk_k=4, k_block=blk,
                                    plan_blocks=2)
    np.testing.assert_array_equal(np.asarray(p2["kv_indices"]),
                                  np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(thr), np.asarray(thr_ref))


# ---------------------------------------------------------------------------
# Occupancy-bound fallback + plan-side fetch accounting
# ---------------------------------------------------------------------------

def test_bound_fallback_dense_is_loss_free():
    rng = np.random.default_rng(0)
    bh, s, d, blk = 2, 128, 16, 32
    q = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    kw = dict(selection="chunked", topk_k=48, causal=True,
              q_block=blk, k_block=blk)
    ref, _ = sata_attention(q, k, v, None, **kw)
    tr, _ = sata_attention(q, k, v, None, max_kv_blocks=2,
                           on_exceed="truncate", **kw)
    de, _ = sata_attention(q, k, v, None, max_kv_blocks=2,
                           on_exceed="dense", **kw)
    assert float(jnp.abs(tr - ref).max()) > 0    # truncation drops tiles
    assert float(jnp.abs(de - ref).max()) == 0.0  # escape hatch is exact


def test_bound_fallback_keeps_narrow_grid_when_within_bound():
    """When no row exceeds the bound, the fallback path must agree with
    plain truncation (both run the narrowed grid, loss-free)."""
    rng = np.random.default_rng(1)
    bh, s, d, blk = 2, 128, 16, 32
    q = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    kw = dict(selection="chunked", topk_k=2, causal=True,
              q_block=blk, k_block=blk)       # tiny k → sparse occupancy
    ref, bm = sata_attention(q, k, v, None, **kw)
    bound = int(np.asarray(bm).sum(-1).max())
    de, _ = sata_attention(q, k, v, None, max_kv_blocks=bound,
                           on_exceed="dense", **kw)
    assert float(jnp.abs(de - ref).max()) == 0.0


def test_decode_fetch_stats_plan_side():
    cnt = np.array([[2, 3], [1, 1]])
    pos = np.array([63, 15])
    st_ = decode_fetch_stats(cnt, pos, k_block=16, d=8, replan=True,
                             nkb=4)
    k_tile = 16 * 8 * 4
    assert st_["plan_fetch_bytes_full"] == 10 * k_tile
    assert st_["plan_fetch_bytes_step"] == st_["plan_fetch_bytes_full"]
    incr = 2 * 4 * 8 * 4 * 2 * 2 + 7 * k_tile
    assert st_["plan_fetch_bytes_incremental"] == incr
    st2 = decode_fetch_stats(cnt, pos, k_block=16, d=8, replan=False,
                             nkb=4)
    assert st2["plan_fetch_bytes_step"] == incr
    assert st2["step_bytes_plan_route"] == \
        st2["kv_fetch_bytes_plan"] + incr
    # fractional replan (per-layer auto triggers) blends linearly
    st3 = decode_fetch_stats(cnt, pos, k_block=16, d=8, replan=0.5,
                             nkb=4)
    assert st3["plan_fetch_bytes_step"] == \
        (st_["plan_fetch_bytes_full"] + incr) // 2


# ---------------------------------------------------------------------------
# Paged init validation
# ---------------------------------------------------------------------------

def test_paged_init_rejects_mismatched_page_size():
    # the page/block equality is validated at CONFIG CONSTRUCTION now
    # (KVCacheConfig.check_decode_block via ModelConfig.__post_init__),
    # not at the first init_kv_cache shape assert
    with pytest.raises(ValueError, match="kv_page_size"):
        dataclasses.replace(_cfg(), kv_cache_layout="paged",
                            kv_page_size=8, sata_decode_block=4)


def test_paged_init_rejects_vlm():
    cfg = dataclasses.replace(SMOKE["llama-3.2-vision-90b"],
                              kv_cache_layout="paged")
    with pytest.raises(NotImplementedError, match="vlm"):
        dec.init_cache(cfg, 2, 16)
