"""Cascade token retirement (SpAtten) + mid-stream page reclamation.

Covers the three contract properties the feature must uphold:
(1) ``sata_retire="off"`` is bitwise identical to the pre-retirement
stack — structurally (the plan pytree gains no fields, so the jitted
trace is unchanged) and behaviorally (retire-on with a watermark that
never fires serves the same outputs, bit for bit);
(2) trie-shared and host-swapped pages are never retired or compacted
(the ``ref > 1`` pin in ``retire_compact`` covers the trie's retention,
another slot's mapping, and a swap handle's resident pin uniformly);
(3) allocator invariants hold over random claim/append/retire/compact/
swap/free schedules (``check_invariants`` runs after every mutation).

Plus deterministic units: the allocator's hole bookkeeping, hole
round-trip through host-swap, ``retire_plan_blocks`` plan repair, the
``decode_fetch_stats`` live-block pricing, and the serve-level
mid-stream reclamation path end to end."""
import dataclasses
import sys
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).parent))
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402

from repro.configs.archs import SMOKE
from repro.core.decode_plan import (init_decode_plan, retire_plan_blocks,
                                    summary_bytes, _plan_occupancy)
from repro.core.paging import OVERFLOW_PAGE, PageAllocator, PrefixCache
from repro.kernels.ops import decode_fetch_stats
from repro.models import attention as attn
from repro.models import decode as dec


def _cfg(**kw):
    base = dict(topk_impl="bisect", sata_decode="on", sata_decode_block=8,
                sata_decode_replan=1, kv_cache_layout="paged")
    base.update(kw)
    return dataclasses.replace(SMOKE["qwen3-4b"], **base)


def _serve(cfg, **kw):
    from repro.launch.serve import serve
    base = dict(smoke=True, n_requests=4, batch_slots=2, gen_len=8,
                max_len=64, prompt_len=16, seed=0)
    base.update(kw)
    return serve("qwen3-4b", cfg=cfg, **base)


# ---------------------------------------------------------------------------
# Allocator: retire_compact semantics
# ---------------------------------------------------------------------------

def test_retire_compact_frees_pages_and_leaves_holes():
    a = PageAllocator(12, 2, 8, 4, audit=True)
    assert a.ensure(0, 15)                       # 4 pages mapped
    row = a.table[0].copy()
    before = a.free_pages
    freed, skipped = a.retire_compact(0, [0, 2])
    assert sorted(freed) == sorted([int(row[0]), int(row[2])])
    assert skipped == []
    assert a.free_pages == before + 2            # returned mid-stream
    assert a.pages_retired == 2
    # holes: table entries reset while n_mapped stands
    assert a.table[0, 0] == OVERFLOW_PAGE and a.table[0, 2] == OVERFLOW_PAGE
    assert a.table[0, 1] == row[1] and a.table[0, 3] == row[3]
    assert int(a.n_mapped[0]) == 4 and a.retired[0] == {0, 2}
    # ensure() maps only NEW logical pages — holes never remap
    assert a.ensure(0, 16)
    assert int(a.n_mapped[0]) == 5
    assert a.table[0, 0] == OVERFLOW_PAGE
    # double retirement of the same hole is a bug, not a no-op
    with pytest.raises(AssertionError):
        a.retire_compact(0, [0])
    # free_slot forgets the holes and releases only the survivors
    a.free_slot(0)
    assert a.retired[0] == set() and a.free_pages == 11


def test_retire_compact_skips_pinned_pages():
    """Property (2), mechanism level: a page anyone else references —
    another slot's mapping, the trie's retention — is skipped, never
    freed."""
    a = PageAllocator(12, 2, 8, 4, audit=True)
    pc = PrefixCache(a)
    assert a.ensure(0, 11)                       # 3 pages
    row = a.table[0].copy()
    pc.register(np.arange(8), row)               # trie retains pages 0-1
    a.map_shared(1, [int(row[2])])               # slot 1 shares page 2
    a.ref[row[2]] += 0                           # (ref now 2)
    freed, skipped = a.retire_compact(0, [0, 1, 2])
    assert freed == [] and skipped == [0, 1, 2]
    assert a.retired[0] == set() and a.pages_retired == 0
    # the slot-sharing pin lifts when the sharer leaves; the trie's
    # retention (pages 0-1) is permanent while the entry lives
    a.free_slot(1)
    freed, skipped = a.retire_compact(0, [0, 2])
    assert len(freed) == 1 and skipped == [0]
    assert int(row[2]) in freed


def test_retire_compact_never_touches_swapped_requests():
    """A host-swapped request has no table row — its pages cannot even
    be NAMED by a retirement pass, and its handle's resident pins block
    retirement of pages it shares."""
    a = PageAllocator(12, 2, 8, 4, audit=True)
    assert a.ensure(0, 7)
    shared = int(a.table[0, 0])
    a.map_shared(1, [shared])                    # slot 1 pins page 0
    handle = a.swap_out(1, lambda phys: {})      # resident pin transfers
    assert int(handle["resident"][0]) == shared
    freed, skipped = a.retire_compact(0, [0, 1])
    assert skipped == [0] and shared not in freed      # pinned by handle
    assert len(freed) == 1
    ok = a.swap_in(1, handle, lambda fresh, payload: None)
    assert ok and int(a.table[1, 0]) == shared


def test_retired_holes_roundtrip_host_swap():
    store = {}

    def gather(phys):
        return {"x": np.asarray([store[p] for p in phys], np.int64)}

    def scatter(fresh, payload):
        for p, v in zip(fresh, payload["x"]):
            store[p] = int(v)

    a = PageAllocator(12, 2, 8, 4, audit=True)
    assert a.ensure(0, 15)
    for lp in range(4):
        store[int(a.table[0, lp])] = 100 + lp
    freed, _ = a.retire_compact(0, [1])
    handle = a.swap_out(0, gather)
    assert handle["retired"] == [1]
    assert a.retired[0] == set()                 # cleared with the slot
    ok = a.swap_in(1, handle, scatter)
    assert ok
    assert a.retired[1] == {1}                   # hole restored as hole
    assert a.table[1, 1] == OVERFLOW_PAGE
    assert int(a.n_mapped[1]) == 4
    # surviving payload pages landed with their contents
    vals = sorted(store[int(a.table[1, lp])] for lp in (0, 2, 3))
    assert vals == [100, 102, 103]


# ---------------------------------------------------------------------------
# Property (3): invariants over random op schedules
# ---------------------------------------------------------------------------

def _drive_allocator(seed: int, n_ops: int) -> None:
    rng = np.random.default_rng(seed)
    a = PageAllocator(14, 3, 8, 4, audit=True)   # audit EVERY mutation
    pos = np.full(3, -1, np.int64)               # -1 = slot empty
    handles = {}

    def live_lps(i):
        return [lp for lp in range(int(a.n_mapped[i]))
                if lp not in a.retired[i]]

    for _ in range(n_ops):
        op = int(rng.integers(0, 6))
        i = int(rng.integers(0, 3))
        if op == 0:                              # claim / append
            if i in handles:
                continue
            nxt = int(pos[i]) + int(rng.integers(1, 6))
            if a.ensure(i, max(nxt, 0)):
                pos[i] = max(nxt, int(pos[i]))
        elif op == 1 and pos[i] >= 0 and i not in handles:   # retire
            cur = int(pos[i]) // 4
            cand = [lp for lp in live_lps(i) if lp < cur]
            if cand:
                k = int(rng.integers(1, len(cand) + 1))
                picks = list(rng.choice(cand, size=k, replace=False))
                a.retire_compact(i, [int(x) for x in picks])
        elif op == 2 and pos[i] >= 0 and i not in handles:   # swap out
            if a.n_mapped[i] > 0:
                handles[i] = a.swap_out(
                    i, lambda phys: {"x": np.asarray(phys, np.int64)})
                pos[i] = -1
        elif op == 3 and i in handles:                        # swap in
            if a.swap_in(i, handles[i], lambda f, p: None):
                h = handles.pop(i)
                pos[i] = h["n_pages"] * 4 - 1
        elif op == 4 and pos[i] >= 0 and i not in handles:    # free
            a.free_slot(i)
            pos[i] = -1
        elif op == 5:                                         # pressure
            if rng.integers(0, 2):
                a.squeeze(int(rng.integers(1, 3)))
            else:
                a.unsqueeze()
    a.check_invariants()                         # closing full audit


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(5, 60))
    def test_property_invariants_under_random_retire_schedules(seed, n_ops):
        _drive_allocator(seed, n_ops)
else:                                                # pragma: no cover
    def test_property_invariants_under_random_retire_schedules():
        for seed in range(30):
            _drive_allocator(seed, 40)


# ---------------------------------------------------------------------------
# Plan-state repair
# ---------------------------------------------------------------------------

def test_retire_plan_blocks_repairs_plan_state():
    plan = init_decode_plan(2, 2, 64, 8, 8, retire=True)     # nkb = 8
    nkb = 8
    # seed slot 0 with a live plan naming blocks {0, 2, 5} and bounded
    # summaries everywhere
    occ = jnp.zeros((2, 2, nkb), bool).at[0, :, jnp.asarray([0, 2, 5])] \
        .set(True)
    from repro.core.decode_plan import _compact_rows
    idx, cnt = _compact_rows(occ, plan["kv_indices"].shape[-1])
    plan = {**plan,
            "kv_indices": idx.astype(plan["kv_indices"].dtype),
            "kv_counts": cnt.astype(plan["kv_counts"].dtype),
            "k_min": jnp.zeros_like(plan["k_min"]),
            "k_max": jnp.ones_like(plan["k_max"]),
            "imp": plan["imp"] + 3.0}
    before1 = {k: np.asarray(v[1]) for k, v in plan.items()}
    out = retire_plan_blocks(plan, 0, [2, 5])
    # dead blocks: unplanned, importance zeroed, summaries empty-sentinel
    assert not np.asarray(out["live_blk"][0])[[2, 5]].any()
    assert np.asarray(out["live_blk"][0])[[0, 1, 3]].all()
    assert np.all(np.asarray(out["imp"][0])[:, [2, 5]] == 0.0)
    assert np.all(np.asarray(out["imp"][0, :, 0]) == 3.0)
    assert np.all(np.asarray(out["k_min"][0])[:, [2, 5]] == np.inf)
    assert np.all(np.asarray(out["k_max"][0])[:, [2, 5]] == -np.inf)
    occ_after = _plan_occupancy(out["kv_indices"], out["kv_counts"], nkb)
    assert np.array_equal(np.asarray(occ_after[0, 0]),
                          np.asarray([True] + [False] * 7))   # only blk 0
    # the untouched slot is bitwise untouched
    for k, v in out.items():
        np.testing.assert_array_equal(np.asarray(v[1]), before1[k],
                                      err_msg=k)


def test_retire_plan_blocks_int8_sentinel():
    plan = init_decode_plan(1, 2, 64, 8, 8, summary="int8", retire=True)
    plan = {**plan, "k_scale": plan["k_scale"] + 2.0,
            "k_min": plan["k_min"] + 1, "k_max": plan["k_max"] + 7}
    out = retire_plan_blocks(plan, 0, [3])
    assert np.all(np.asarray(out["k_scale"][0, :, 3]) == -1.0)
    assert np.all(np.asarray(out["k_zero"][0, :, 3]) == 0.0)
    assert np.all(np.asarray(out["k_min"][0, :, 3]) == 0)
    assert np.all(np.asarray(out["k_max"][0, :, 3]) == 0)
    assert np.all(np.asarray(out["k_scale"][0, :, 0]) == 1.0)  # untouched


def test_retire_state_rides_plan_slot_capture():
    """Retirement state belongs to the REQUEST: capture/install must
    move ``imp``/``live_blk`` so a host-swapped victim's dead blocks
    stay dead after restore."""
    from repro.core.decode_plan import capture_plan_slot, install_plan_slot
    plan = init_decode_plan(2, 2, 64, 8, 8, retire=True)
    plan = retire_plan_blocks({**plan, "imp": plan["imp"] + 1.0}, 0, [1, 4])
    snap = capture_plan_slot(plan, 0)
    assert "live_blk" in snap and "imp" in snap
    fresh = init_decode_plan(2, 2, 64, 8, 8, retire=True)
    back = install_plan_slot(fresh, 1, snap)
    np.testing.assert_array_equal(np.asarray(back["live_blk"][1]),
                                  np.asarray(plan["live_blk"][0]))
    np.testing.assert_array_equal(np.asarray(back["imp"][1]),
                                  np.asarray(plan["imp"][0]))


def test_retire_off_plan_has_no_retire_state():
    """Property (1), structural half: the off-path plan pytree gains NO
    fields, so the jitted serve step's trace — and therefore every
    computed byte — is unchanged by this feature's existence."""
    plan = init_decode_plan(2, 2, 64, 8, 8)
    assert "imp" not in plan and "live_blk" not in plan
    cache = attn.init_kv_cache(_cfg(), 2, 64, jnp.float32)
    assert "imp" not in cache["plan"] and "live_blk" not in cache["plan"]
    cache_on = attn.init_kv_cache(_cfg(sata_retire="on"), 2, 64,
                                  jnp.float32)
    assert "imp" in cache_on["plan"] and "live_blk" in cache_on["plan"]


# ---------------------------------------------------------------------------
# Traffic pricing: retired blocks leave the ranking set
# ---------------------------------------------------------------------------

def test_fetch_stats_live_blocks_pricing():
    cnt = np.asarray([[2, 2], [3, 3]])           # (B, KV)
    pos = np.asarray([31, 47])                   # 4 / 6 valid blocks @8
    kw = dict(k_block=8, d=16, replan=np.asarray([1.0, 0.0]), nkb=8,
              dtype_bytes=4)
    base = decode_fetch_stats(cnt, pos, **kw)
    # full live set: pricing identical bit for bit
    same = decode_fetch_stats(cnt, pos, live_blocks=np.asarray([8, 8]),
                              **kw)
    assert same == base
    # slot 0 retired down to 2 live blocks: its full re-plan streams
    # min(valid=4, live=2)=2 block keys; slot 1's incremental summary
    # read prices at 5 live blocks instead of nkb=8
    lv = np.asarray([2, 5])
    out = decode_fetch_stats(cnt, pos, live_blocks=lv, **kw)
    k_tile = 8 * 16 * 4
    want_step = (2 * 2 * k_tile                        # slot 0 full
                 + summary_bytes(5, 16) * 2            # slot 1 summaries
                 + 6 * k_tile)                         # slot 1 planned keys
    assert out["plan_fetch_bytes_step"] == want_step
    assert out["plan_fetch_bytes_step"] < base["plan_fetch_bytes_step"]
    # kernel-side accounting is untouched (the plan already shrank)
    assert out["kv_fetch_bytes_plan"] == base["kv_fetch_bytes_plan"]
    assert out["kv_fetch_bytes_dense"] == base["kv_fetch_bytes_dense"]


# ---------------------------------------------------------------------------
# Serve level: reclamation, bitwise-off, pinning under sharing
# ---------------------------------------------------------------------------

def test_serve_retirement_reclaims_pages_midstream():
    cfg = _cfg(sata_retire="on", sata_retire_watermark=0.4,
               sata_retire_keep=0.5)
    out = _serve(cfg, n_requests=4, gen_len=24, prompt_len=20)
    r = out["retirement"]
    assert r["pages_reclaimed"] > 0 and r["events"] > 0
    assert any(r["timelines"].values())
    assert out["page_occupancy"]["pages_retired"] == r["pages_reclaimed"]
    assert all(len(v) == 24 for v in out["outputs"].values())
    assert len(r["head_importance"]) == SMOKE["qwen3-4b"].n_kv_heads
    assert any(x > 0 for x in r["head_importance"])


def test_serve_retire_requires_paged_plan():
    with pytest.raises(ValueError, match="sata_retire"):
        _serve(dataclasses.replace(SMOKE["qwen3-4b"], sata_retire="on"))


def _serve_retire_pair(seed, prompt_len, gen_len, watermark):
    base = _cfg()
    kw = dict(n_requests=4, batch_slots=2, gen_len=gen_len, max_len=64,
              prompt_len=prompt_len, seed=seed)
    off = _serve(base, **kw)
    on = _serve(dataclasses.replace(base, sata_retire="on",
                                    sata_retire_watermark=watermark), **kw)
    return off, on


if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(9, 20),
           st.integers(4, 10))
    def test_property_retire_never_fired_is_bitwise_equal(seed, prompt_len,
                                                          gen_len):
        """Property (1), behavioral half: with retire ON but a
        watermark no slot can reach (and an ample pool — no pressure
        sweep), every output token is bitwise equal to retire-off: the
        all-live masks and the importance accumulator are
        output-invisible."""
        off, on = _serve_retire_pair(seed, prompt_len, gen_len, 2.0)
        assert on["outputs"] == off["outputs"]
        assert on["retirement"]["pages_reclaimed"] == 0
else:                                                # pragma: no cover
    def test_property_retire_never_fired_is_bitwise_equal():
        off, on = _serve_retire_pair(0, 16, 8, 2.0)
        assert on["outputs"] == off["outputs"]
        assert on["retirement"]["pages_reclaimed"] == 0


def test_serve_retirement_with_shared_prefix_pins_trie_pages():
    """Property (2), system level: retirement under the prefix cache —
    the allocator audits every mutation (a retired trie page would
    assert), later requests still hit the cache, and every request
    completes."""
    cfg = _cfg(kv_prefix_cache=True, sata_retire="on",
               sata_retire_watermark=0.4, sata_retire_keep=0.5)
    out = _serve(cfg, n_requests=6, batch_slots=2, gen_len=20,
                 prompt_len=24, shared_prefix_len=18)
    assert all(len(v) == 20 for v in out["outputs"].values())
    assert out["prefix_cache"]["hits"] > 0
    assert out["retirement"]["pages_reclaimed"] > 0
    assert out["page_occupancy"]["audits_run"] > 0


def test_serve_retirement_survives_preemption_swap():
    """Holes round-trip through host-swap in the full loop: a preempted
    slot with retired blocks restores with the same holes, the same
    dead plan blocks, and completes."""
    from repro.launch.faults import FaultPlan
    cfg = _cfg(sata_retire="on", sata_retire_watermark=0.4,
               sata_retire_keep=0.5)
    faults = FaultPlan().preempt(10).preempt(14)
    out = _serve(cfg, n_requests=4, gen_len=24, prompt_len=20,
                 faults=faults)
    assert all(len(v) == 24 for v in out["outputs"].values())
    assert out["page_occupancy"]["host_swaps"] > 0
    assert out["retirement"]["pages_reclaimed"] > 0


def test_serve_retirement_accuracy_lane_reports_divergence():
    """Retirement is LOSSY by design — the accuracy lane: divergence
    (first-token-mismatch rate vs the retire-off run) is reported per
    retained-token budget, and a tighter budget can only be measured,
    never silently hidden."""
    base = _cfg()
    kw = dict(n_requests=4, batch_slots=2, gen_len=24, max_len=64,
              prompt_len=20, seed=0)
    off = _serve(base, **kw)
    rows = {}
    for keep in (0.75, 0.5):
        on = _serve(dataclasses.replace(
            base, sata_retire="on", sata_retire_watermark=0.4,
            sata_retire_keep=keep), **kw)
        n = sum(len(v) for v in off["outputs"].values())
        d = sum(1 for r, toks in off["outputs"].items()
                for j, t in enumerate(toks)
                if j >= len(on["outputs"][r]) or on["outputs"][r][j] != t)
        rows[keep] = (d / max(n, 1), on["retirement"]["pages_reclaimed"])
    # the lane MEASURES; it does not demand zero divergence.  But every
    # budget must actually have reclaimed pages, else it measured nothing
    assert all(v[1] > 0 for v in rows.values())
    assert all(0.0 <= v[0] <= 1.0 for v in rows.values())
