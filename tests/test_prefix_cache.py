"""Shared-prefix page cache: trie match/register/evict semantics,
refcounted copy-on-write, the paged write path's shared-page
write-protection, summary-cache bit-identity on the install path, and
the two system properties the cache must uphold over arbitrary
interleaved claim/prefill/append/free sequences with overlapping
prompts — (a) a shared physical page is never written while its
refcount exceeds one, and (b) every request's decoded output is
bitwise equal to a run with the prefix cache disabled."""
import dataclasses
import sys
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).parent))
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402

from repro.configs.archs import SMOKE
from repro.core.decode_plan import reset_plan_slot
from repro.core.paging import (OVERFLOW_PAGE, PageAllocator, PrefixCache,
                               logical_kv_view)
from repro.models import attention as attn
from repro.models import decode as dec
from repro.models import model as mdl


def _cfg(**kw):
    base = dict(topk_impl="bisect", sata_decode="on", sata_decode_block=8,
                sata_decode_replan=1, kv_cache_layout="paged",
                kv_prefix_cache=True)
    base.update(kw)
    return dataclasses.replace(SMOKE["qwen3-4b"], **base)


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# Trie: match / register / evict
# ---------------------------------------------------------------------------

def _pool(n_pages=16, slots=4, max_pages=8, page=4):
    a = PageAllocator(n_pages, slots, max_pages, page)
    return a, PrefixCache(a)


def test_trie_register_then_match_full_and_partial():
    a, pc = _pool(page=4)
    toks = np.array([1, 2, 3, 4, 5, 6, 7, 8, 9, 10])   # 2 full + 2 partial
    assert a.ensure(0, 9)
    row = a.table[0].copy()
    assert pc.register(toks, row) == 3                 # 2 full + 1 partial
    assert a.ref[row[0]] == 2 and a.ref[row[2]] == 2   # slot + trie
    # identical prompt (last token withheld, as the driver matches)
    m, phys, part = pc.match(toks[:-1])
    assert m == 9 and phys == [row[0], row[1], row[2]] and part == 1
    # longer prompt sharing both full pages, diverging in page 2
    m2, phys2, _ = pc.match(np.array([1, 2, 3, 4, 5, 6, 7, 8, 99, 100]))
    assert m2 == 8 and phys2 == [row[0], row[1]]
    # shares only half of page 0: longest-common-prefix partial match
    m3, phys3, part3 = pc.match(np.array([1, 2, 42, 43]))
    assert m3 == 2 and phys3 == [row[0]] and part3 == 2
    # nothing shared
    m4, phys4, _ = pc.match(np.array([9, 9, 9]))
    assert m4 == 0 and phys4 == []


def test_trie_chain_digest_is_depth_dependent():
    """The same page content at a different prefix depth must not
    match: page keys chain the parent digest."""
    a, pc = _pool(page=2)
    assert a.ensure(0, 5)
    pc.register(np.array([7, 7, 7, 7, 7, 7]), a.table[0].copy())
    # [7, 7] as the FIRST page matches; as a continuation of [5, 5] not
    m, _, _ = pc.match(np.array([5, 5, 7, 7]))
    assert m == 0


def test_trie_free_slot_keeps_cached_pages():
    a, pc = _pool()
    toks = np.arange(8)
    assert a.ensure(0, 7)
    row = a.table[0].copy()
    pc.register(toks, row)
    in_use = a.pages_in_use
    a.free_slot(0)                        # request completes
    assert a.pages_in_use == in_use       # trie retention survives
    assert all(a.ref[p] == 1 for p in row[:2])
    m, phys, _ = pc.match(toks)           # still matchable
    assert m == 8 and phys == [row[0], row[1]]


def test_trie_evict_frees_lru_leaves_only():
    a, pc = _pool(n_pages=16, page=4)
    assert a.ensure(0, 7)
    row_a = a.table[0].copy()
    pc.register(np.array([1, 2, 3, 4, 5, 6, 7, 8]), row_a)   # chain A
    assert a.ensure(1, 7)
    row_b = a.table[1].copy()
    pc.register(np.array([1, 2, 3, 4, 9, 9, 9, 9]), row_b)   # shares page 0
    a.free_slot(0)
    a.free_slot(1)
    # everything trie-retained now; drain the pool and evict
    target = len(a.free) + 3
    freed = pc.evict(target)
    assert freed == 3                     # both leaves + one parent round
    # root page (the shared [1,2,3,4] node) evicts only after children
    m, _, _ = pc.match(np.array([1, 2, 3, 4]))
    assert m == 0 or m == 4               # depends on LRU order reached


def test_trie_evict_skips_pages_slots_still_map():
    a, pc = _pool()
    toks = np.arange(8)
    assert a.ensure(0, 7)
    pc.register(toks, a.table[0].copy())  # slot 0 still running: ref 2
    assert pc.evict(len(a.free) + 1) == 0
    assert pc.cached_pages == 2           # nothing destroyed either


# ---------------------------------------------------------------------------
# Allocator: refcounts + copy-on-write
# ---------------------------------------------------------------------------

def test_map_shared_and_cow_lifecycle():
    a, pc = _pool(n_pages=8, page=4)
    assert a.ensure(0, 6)                 # owner writes 2 pages
    row = a.table[0].copy()
    pc.register(np.arange(7), row)        # page 1 partial (3 rows)
    # a second slot maps the shared prefix
    a.map_shared(1, [int(row[0]), int(row[1])])
    assert a.ref[row[0]] == 3 and a.ref[row[1]] == 3
    assert a.shared_pages == 2
    # slot 1 appends at pos 3 — inside shared page 0 → CoW
    ok, cp = a.ensure_writable(1, 3)
    assert ok and cp is not None
    src, dst = cp
    assert src == row[0] and dst != row[0]
    assert a.table[1, 0] == dst and a.ref[dst] == 1 and a.ref[src] == 2
    # exclusive page: no copy
    ok, cp = a.ensure_writable(1, 3)
    assert ok and cp is None
    # unmapped logical page: ensure() maps it, no CoW involved
    ok, cp = a.ensure_writable(1, 8)
    assert ok and cp is None


def test_cow_stalls_when_pool_dry():
    a, pc = _pool(n_pages=4, page=4)      # 3 usable pages
    assert a.ensure(0, 7)                 # 2 pages
    pc.register(np.arange(8), a.table[0].copy())
    a.map_shared(1, [int(a.table[0, 0])])
    assert a.ensure(1, 7)                 # last free page
    ok, cp = a.ensure_writable(1, 0)      # CoW wants a page: dry
    assert not ok and cp is None
    a.free_slot(0)                        # owner completes …
    ok, cp = a.ensure_writable(1, 0)      # … but the trie still holds
    assert not ok                         # both pages: still dry
    assert pc.evict(1) == 1               # reclaim the unmapped leaf
    ok, cp = a.ensure_writable(1, 0)      # now it can copy
    assert ok and cp is not None


def test_free_slot_never_frees_shared_pages():
    """Preemption calls free_slot: pages another slot or the trie
    still references must survive with their contents reachable."""
    a, pc = _pool(n_pages=8, page=4)
    assert a.ensure(0, 3)
    row = a.table[0].copy()
    pc.register(np.arange(4), row)
    a.map_shared(1, [int(row[0])])
    a.free_slot(1)                        # preempt the sharer
    assert a.ref[row[0]] == 2             # owner + trie intact
    a.free_slot(0)                        # preempt the owner too
    assert a.ref[row[0]] == 1             # trie retention remains
    assert int(row[0]) not in a.free


# ---------------------------------------------------------------------------
# Device side: CoW copy + shared-page write-protection
# ---------------------------------------------------------------------------

def test_copy_phys_pages_copies_kv_and_summaries():
    cfg = _cfg()
    cache = dec.init_cache(cfg, 2, 32)
    kv = dict(cache["kv"])
    kv["k_pages"] = kv["k_pages"].at[:, 3].set(1.25)
    kv["v_pages"] = kv["v_pages"].at[:, 3].set(-2.5)
    kv["page_k_min"] = kv["page_k_min"].at[:, 3].set(0.5)
    cache = {**cache, "kv": kv}
    out = dec.copy_phys_pages(cache, [(3, 5)])["kv"]
    np.testing.assert_array_equal(np.asarray(out["k_pages"][:, 5]),
                                  np.asarray(out["k_pages"][:, 3]))
    np.testing.assert_array_equal(np.asarray(out["v_pages"][:, 5]),
                                  np.asarray(out["v_pages"][:, 3]))
    np.testing.assert_array_equal(np.asarray(out["page_k_min"][:, 5]),
                                  np.asarray(out["page_k_min"][:, 3]))


def test_paged_write_protect_reroutes_shared_page_writes():
    """Defense in depth: even if the driver forgot to CoW, a decode
    append aimed at a shared page (refcount > 1) must land in the
    overflow page, never mutate the shared contents."""
    cfg = _cfg(sata_decode="off")         # dense paged decode suffices
    b, max_len = 2, 32
    params = attn.attention_init(jax.random.PRNGKey(0), cfg)
    cache = attn.init_kv_cache(cfg, b, max_len, jnp.float32)
    page = int(cache["k_pages"].shape[1])
    tbl = np.full((b, max_len // page), OVERFLOW_PAGE, np.int32)
    tbl[0, 0] = 2                         # slot 0 writes into page 2
    ref = np.zeros(cache["k_pages"].shape[0], np.int32)
    ref[2] = 2                            # ... which is SHARED
    cache["page_table"] = jnp.asarray(tbl)
    cache["page_ref"] = jnp.asarray(ref)
    before = np.asarray(cache["k_pages"][2])
    x = _rand(jax.random.PRNGKey(1), (b, 1, cfg.d_model))
    _, cache2 = attn.attention_decode(params, cfg, x, cache,
                                      jnp.zeros((b,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(cache2["k_pages"][2]), before)
    # the same write with refcount 1 does mutate the page
    cache["page_ref"] = jnp.asarray(np.where(ref == 2, 1, ref))
    _, cache3 = attn.attention_decode(params, cfg, x, cache,
                                      jnp.zeros((b,), jnp.int32))
    assert np.abs(np.asarray(cache3["k_pages"][2]) - before).max() > 0


# ---------------------------------------------------------------------------
# Install path: summary-cache bit-identity
# ---------------------------------------------------------------------------

def test_hit_install_is_bitwise_identical_to_miss_install():
    """The handoff under sharing: a cache-hit install (tail-only
    prefill + matched pages + per-physical-page summary cache) must
    leave the slot's MATCHED region — logical K/V rows and the plan
    summaries of fully-matched blocks — bitwise identical to the miss
    install (the pages literally are the same bytes, and min/max
    associativity makes the summary-cache seed exact), the plan's
    selected blocks identical, and the tail's fresh rows equal to the
    full prefill's at fp accumulation tolerance (different GEMM
    shapes reduce in different orders; selection never sits within
    that noise of a threshold)."""
    cfg = _cfg(sata_decode_replan=4)
    max_len, b = 32, 2
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    toks = np.random.default_rng(3).integers(0, cfg.vocab_size, (1, 11))
    cache = dec.init_cache(cfg, b, max_len)
    page = int(cache["kv"]["k_pages"].shape[2])
    alloc = PageAllocator(int(cache["kv"]["k_pages"].shape[1]), b,
                          max_len // page, page)
    pc = PrefixCache(alloc)

    # request A: miss → full prefill into slot 0, register
    assert alloc.ensure(0, 10)
    lgA, stateA = dec.prefill_prompt(params, cfg, jnp.asarray(toks), max_len)
    cache = dec.set_page_table(cfg, cache, alloc.table, alloc.ref)
    cache = dec.install_prefill(cfg, cache, 0, stateA,
                                alloc.table[0, :alloc.pages_for(11)])
    pc.register(toks[0], alloc.table[0])

    # request B: identical prompt → hit, tail prefill into slot 1
    m, phys, _ = pc.match(toks[0, :-1])
    assert m == 10
    alloc.map_shared(1, phys)
    ok, cp = alloc.ensure_writable(1, m)
    assert ok
    if cp is not None:
        cache = dec.copy_phys_pages(cache, [cp])
    assert alloc.ensure(1, 10)
    cache = dec.set_page_table(cfg, cache, alloc.table, alloc.ref)
    prefix = dec.gather_prefix_kv(cache, alloc.table[1], m)
    lgB, stateB = dec.prefill_prompt(params, cfg,
                                     jnp.asarray(toks[:, m:]), max_len,
                                     prefix_kv=prefix)
    cache = dec.install_prefill(cfg, cache, 1, stateB,
                                alloc.table[1, :alloc.pages_for(11)],
                                prefix_len=m)

    # same greedy continuation; logits agree to accumulation tolerance
    assert int(jnp.argmax(lgA)) == int(jnp.argmax(lgB))
    np.testing.assert_allclose(np.asarray(lgA), np.asarray(lgB),
                               rtol=1e-4, atol=1e-4)
    kv = cache["kv"]
    view_k = logical_kv_view(kv["k_pages"][0], kv["page_table"][0])
    # matched region (shared page + its CoW copy): the same bytes
    np.testing.assert_array_equal(np.asarray(view_k[0, :m]),
                                  np.asarray(view_k[1, :m]))
    # the tail row is freshly computed in a different-shape program
    np.testing.assert_allclose(np.asarray(view_k[0, m:11]),
                               np.asarray(view_k[1, m:11]),
                               rtol=1e-4, atol=1e-5)
    plan = kv["plan"]
    n_shared = m // page                   # fully-matched blocks
    for name in ("k_min", "k_max"):        # summary-cache seed: bitwise
        np.testing.assert_array_equal(
            np.asarray(plan[name][:, 0, :, :n_shared]),
            np.asarray(plan[name][:, 1, :, :n_shared]), err_msg=name)
        np.testing.assert_allclose(       # tail blocks: fresh compute
            np.asarray(plan[name][:, 0]), np.asarray(plan[name][:, 1]),
            rtol=1e-4, atol=1e-5, err_msg=name)
    for name in ("kv_indices", "kv_counts", "step"):
        np.testing.assert_array_equal(np.asarray(plan[name][:, 0]),
                                      np.asarray(plan[name][:, 1]),
                                      err_msg=name)
    # and the summary cache entries ARE the per-page min/max recompute
    full_pages = 11 // page
    for lp in range(full_pages):
        ph = int(alloc.table[0, lp])
        ref_min = jnp.min(kv["k_pages"][:, ph].astype(jnp.float32), axis=1)
        np.testing.assert_array_equal(np.asarray(kv["page_k_min"][:, ph]),
                                      np.asarray(ref_min))


# ---------------------------------------------------------------------------
# Property (a): shared pages are never written while refcount > 1
# ---------------------------------------------------------------------------

def _drive_shared(seed, n_ops, replan):
    """Interleave claim / lockstep-append / register / free at the
    attention-layer level against the REAL paged cache (one layer, the
    exact decode write path serving scans), with overlapping prompts.
    After every device step, assert no shared page's contents moved —
    including steps where a slot is CoW-STALLED (pool dry) and its
    write must re-route to the overflow page via the in-graph
    write-protection, exactly like the serving loop's stall re-feed."""
    cfg = _cfg(sata_decode_block=4, sata_decode_replan=replan)
    b, max_len, page = 2, 16, 4
    params = attn.attention_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    vocab = 50
    # two prompt families sharing 6 of 7 tokens → full page-0 sharing
    # plus a partial-page overlap in page 1
    base = rng.integers(0, vocab, 7)
    prompts = [base.copy(), base.copy()]
    prompts[1][-1] = (prompts[1][-1] + 1) % vocab

    kvc = dict(attn.init_kv_cache(cfg, b, max_len, jnp.float32))
    alloc = PageAllocator(int(kvc["k_pages"].shape[0]), b,
                          max_len // page, page)
    pc = PrefixCache(alloc)
    pos = np.zeros(b, np.int32)
    live = [False, False]
    hist = [[], []]             # tokens whose rows occupy positions < pos
    feed = [[], []]             # tokens still to append
    r = np.random.default_rng(seed + 1)

    def _snapshot():
        kp = np.asarray(kvc["k_pages"])
        return {int(p): kp[p].copy() for p in np.nonzero(alloc.ref > 1)[0]}

    for _ in range(n_ops):
        op = int(r.integers(0, 4))
        slot = int(r.integers(b))
        if op == 0 and not live[slot]:                       # claim
            toks = prompts[int(r.integers(2))]
            m, phys, _ = pc.match(toks[:-1])
            if m:
                alloc.map_shared(slot, phys)
            if "plan" in kvc:
                kvc["plan"] = reset_plan_slot(kvc["plan"], slot)
            live[slot] = True
            pos[slot] = m
            hist[slot] = list(toks[:m])
            feed[slot] = list(toks[m:]) + [int(x) for x in
                                           r.integers(0, vocab, 4)]
        elif op == 1 and any(live):          # one lockstep decode step
            advance = []
            copies = []
            for i in range(b):
                if not live[i]:
                    continue
                ok, cp = alloc.ensure_writable(i, int(pos[i]))
                if ok and cp is not None:
                    copies.append(cp)
                if ok and alloc.ensure(i, int(pos[i])) \
                        and pos[i] < max_len - 1:
                    advance.append(i)        # else: stalled, token re-fed
            for src, dst in copies:          # driver-side CoW (1 layer)
                for f in ("k_pages", "v_pages"):
                    kvc[f] = kvc[f].at[dst].set(kvc[f][src])
            kvc["page_table"] = jnp.asarray(alloc.table)
            if "page_ref" in kvc:
                kvc["page_ref"] = jnp.asarray(alloc.ref, jnp.int32)
            before = _snapshot()
            x = np.zeros((b, 1, cfg.d_model), np.float32)
            for i in range(b):
                if live[i]:                  # stalled slots write too —
                    tok = feed[i][0] if feed[i] else 1    # like serving
                    x[i, 0] = np.asarray(_rand(jax.random.PRNGKey(tok),
                                               (cfg.d_model,)))
            _, kvc = attn.attention_decode(params, cfg, jnp.asarray(x),
                                           kvc, jnp.asarray(pos))
            kvc = dict(kvc)
            after = np.asarray(kvc["k_pages"])
            for p, old in before.items():    # property (a), device truth
                np.testing.assert_array_equal(after[p], old)
            for i in advance:
                hist[i].append(feed[i].pop(0) if feed[i] else 1)
                pos[i] += 1
        elif op == 2 and live[slot] and pos[slot] > 0:       # register
            pc.register(np.asarray(hist[slot][:int(pos[slot])]),
                        alloc.table[slot])
        elif op == 3 and live[slot]:                         # free
            alloc.free_slot(slot)
            live[slot] = False
    # closing bookkeeping invariant: refcounts == table refs + trie refs
    refs = np.zeros(alloc.n_pages, np.int64)
    for i in range(b):
        for lp in range(int(alloc.n_mapped[i])):
            refs[alloc.table[i, lp]] += 1
    stack = [pc.root]
    while stack:
        n = stack.pop()
        for c in list(n.children.values()) + n.partials:
            refs[c.phys] += 1
            stack.append(c)
    np.testing.assert_array_equal(refs[1:], np.asarray(alloc.ref[1:]))


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(4, 14),
           st.sampled_from([1, 3, "auto"]))
    def test_property_shared_pages_immutable(seed, n_ops, replan):
        _drive_shared(seed, n_ops, replan)
else:                                                # pragma: no cover
    def test_property_shared_pages_immutable():
        _drive_shared(11, 12, 1)


# ---------------------------------------------------------------------------
# Property (b): outputs bitwise equal to the cache-disabled run
# ---------------------------------------------------------------------------

def _serve_pair(seed, n_requests, slots, prompt_len, shared_len, gen_len,
                pool_pages, replan):
    from repro.launch.serve import serve
    base = _cfg(kv_prefix_cache=False, sata_decode_replan=replan,
                kv_pool_pages=pool_pages)
    kw = dict(smoke=True, n_requests=n_requests, batch_slots=slots,
              gen_len=gen_len, max_len=64, prompt_len=prompt_len,
              shared_prefix_len=shared_len, seed=seed)
    off = serve("qwen3-4b", cfg=base, **kw)
    on = serve("qwen3-4b",
               cfg=dataclasses.replace(base, kv_prefix_cache=True), **kw)
    return off, on


if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(9, 20),
           st.integers(2, 17), st.sampled_from([1, 3, "auto"]))
    def test_property_outputs_bitwise_equal_cache_off(seed, prompt_len,
                                                      shared_len, replan):
        """System-level: arbitrary prompt/shared-prefix geometry and
        re-plan mode, claim/prefill/append/free interleaved by the
        serving loop itself — the prefix cache must be output-invisible
        bit for bit."""
        off, on = _serve_pair(seed, 4, 2, prompt_len,
                              min(shared_len, prompt_len - 1), 5, 0,
                              replan)
        assert on["outputs"] == off["outputs"]
        assert on["prefix_cache"]["hits"] > 0
else:                                                # pragma: no cover
    def test_property_outputs_bitwise_equal_cache_off():
        off, on = _serve_pair(0, 4, 2, 17, 12, 5, 0, 1)
        assert on["outputs"] == off["outputs"]


def test_serve_shared_prefix_reports_savings():
    off, on = _serve_pair(0, 6, 3, 20, 16, 6, 0, 1)
    assert on["outputs"] == off["outputs"]
    p = on["prefix_cache"]
    assert p["hit_rate"] > 0.5
    assert p["prefill_tokens_saved"] >= 5 * 16
    assert p["cow_copies"] > 0
    assert p["shared_pages_peak"] > 0
    occ = on["page_occupancy"]
    assert occ["shared_pages_peak"] > 0


def test_serve_prefix_cache_under_pool_pressure():
    """A pool too small to retain everything forces evictions and
    backpressure — outputs must still be bitwise equal and complete."""
    off, on = _serve_pair(1, 5, 2, 16, 8, 8, 7, 1)
    assert on["outputs"] == off["outputs"]
    assert all(len(v) == 8 for v in on["outputs"].values())
    occ = on["page_occupancy"]
    assert (occ["stalled_steps"] + occ["deferred_claims"]
            + occ["preemptions"] + on["prefix_cache"]["evictions"]) > 0


def test_serve_preemption_preserves_shared_pages():
    """Preempting a sharer must not free trie-retained pages: later
    requests still hit, and outputs stay equal."""
    off, on = _serve_pair(2, 4, 3, 16, 12, 12, 9, 1)
    assert on["outputs"] == off["outputs"]
    assert on["prefix_cache"]["hits"] > 0


def test_prefix_cache_requires_paged_layout():
    with pytest.raises(ValueError, match="paged"):
        attn.prefix_cache_on(dataclasses.replace(
            _cfg(), kv_cache_layout="contiguous"))


# ---------------------------------------------------------------------------
# Lazy CoW write leases (kv_lazy_cow)
# ---------------------------------------------------------------------------

def test_lazy_cow_lease_lifecycle():
    """The owner appending past its registered prompt takes a write
    lease instead of a copy; the lease self-invalidates the moment a
    third reference appears, after which the eager copy path runs."""
    a, pc = _pool(n_pages=12, slots=3)
    a.lazy_cow = True
    assert a.ensure(0, 5)                        # 2 pages; lp 1 partial
    assert pc.register(np.arange(6), a.table[0]) == 2
    p1 = int(a.table[0, 1])
    assert int(a.ref[p1]) == 2                   # slot 0 + trie
    assert pc.covered_rows(p1) == 2              # partial node: 2 rows
    ok, cp = a.ensure_writable(0, 6)             # append at row 2: past
    assert ok and cp is None                     # coverage -> lease
    assert a.lazy_cow_skips == 1 and a.cow_leases == {p1: 0}
    view = a.writable_ref_view()
    assert view[p1] == 1 and int(a.ref[p1]) == 2     # device sees 1
    ok, cp = a.ensure_writable(0, 6)             # idempotent re-check
    assert ok and cp is None
    # a second matcher maps the page: third reference -> the next
    # device push re-protects the page and the lease is gone
    a.map_shared(2, [p1])
    view = a.writable_ref_view()
    assert view[p1] == 3 and p1 not in a.cow_leases
    # next append: eager copy (the holder's in-place rows ride along)
    ok, cp = a.ensure_writable(0, 7)
    assert ok and cp is not None and cp[0] == p1
    assert int(a.ref[p1]) == 2                   # slot 0 went private
    a.check_invariants(pc)


def test_lazy_cow_no_lease_inside_covered_rows():
    """A partial matcher whose tail starts INSIDE the trie node's
    covered rows must eager-copy even at ref == 2 — an in-place write
    there would corrupt the cached prefix for future matchers."""
    a, pc = _pool(n_pages=12, slots=3)
    a.lazy_cow = True
    assert a.ensure(0, 5)
    assert pc.register(np.arange(6), a.table[0]) == 2
    p0, p1 = int(a.table[0, 0]), int(a.table[0, 1])
    a.free_slot(0)                               # trie retention remains
    a.map_shared(1, [p0, p1])                    # matcher admission
    assert int(a.ref[p1]) == 2                   # slot 1 + trie
    ok, cp = a.ensure_writable(1, 5)             # row 1 < covered 2
    assert ok and cp is not None and cp[0] == p1
    assert a.lazy_cow_skips == 0 and not a.cow_leases
    a.check_invariants(pc)


def test_lazy_cow_lease_dropped_with_slot():
    a, pc = _pool(n_pages=12, slots=3)
    a.lazy_cow = True
    assert a.ensure(0, 5)
    pc.register(np.arange(6), a.table[0])
    ok, cp = a.ensure_writable(0, 6)
    assert ok and cp is None and a.cow_leases
    a.free_slot(0)                               # lease dies with the slot
    assert not a.cow_leases
    a.check_invariants(pc)


def test_serve_lazy_cow_skips_eager_copies():
    """Serve triple at a geometry where every registered prompt ends
    mid-page (prompt 20, page 8): the owner's first append after
    registering lands inside the trie-retained partial page.  Eager
    CoW copies it; lazy CoW leases it.  Outputs must stay bitwise
    equal to the cache-off run either way — the satellite's pin is the
    copy counter, which must strictly drop."""
    from repro.launch.serve import serve
    base = _cfg(kv_prefix_cache=False)
    kw = dict(smoke=True, n_requests=6, batch_slots=3, gen_len=6,
              max_len=64, prompt_len=20, shared_prefix_len=18, seed=0)
    off = serve("qwen3-4b", cfg=base, **kw)
    eager = serve("qwen3-4b",
                  cfg=dataclasses.replace(base, kv_prefix_cache=True),
                  **kw)
    lazy = serve("qwen3-4b",
                 cfg=dataclasses.replace(base, kv_prefix_cache=True,
                                         kv_lazy_cow=True), **kw)
    assert eager["outputs"] == off["outputs"]
    assert lazy["outputs"] == off["outputs"]
    assert eager["prefix_cache"]["cow_copies"] > 0
    assert (lazy["prefix_cache"]["cow_copies"]
            < eager["prefix_cache"]["cow_copies"])
    assert lazy["page_occupancy"]["lazy_cow_skips"] > 0
    assert lazy["prefix_cache"]["hits"] > 0
