"""Block-summary backends + hierarchical sketch re-plan: int8
conservativeness (quantized bounds always CONTAIN the fp32 bounds, so
upper-bound ranking never under-estimates a block), fp32 bitwise
invariance at replan=1, paged==contiguous parity under int8, sketch
degeneracy to the exact full re-plan, the gather-based mixed-step
partial re-plan, and the dtype-/mode-aware fetch accounting."""
import dataclasses
import sys
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent))
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402

from repro.configs.archs import SMOKE
from repro.core.decode_plan import (decode_plan_update, dequantize_summaries,
                                    full_replan, incremental_plan,
                                    init_decode_plan, plan_from_prefill,
                                    plan_summary_bounds, quantize_summaries,
                                    reset_plan_slot, sketch_geometry,
                                    sketch_replan, summaries_from_cache,
                                    summary_bytes, update_block_summaries)
from repro.kernels.ops import decode_fetch_stats
from repro.models import decode as dec
from repro.models import model as mdl


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _grow(summary, keys, s, blk, resets=()):
    """Drive a backend through the serving append lifecycle.  keys:
    (B, T, KV, D) appended at per-slot positions 0, 1, ... over a
    length-``s`` cache; ``resets`` maps step -> slot to re-claim (cache
    zeroed, plan slot reset, position restarted).  Returns
    (plan, cache, final per-slot pos)."""
    b, t_total, kv, d = keys.shape
    assert t_total <= s
    plan = init_decode_plan(b, kv, s, d, blk, summary=summary)
    cache = jnp.zeros((b, s, kv, d), jnp.float32)
    upd = jax.vmap(lambda c, n, p:
                   jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0))
    pos = np.zeros(b, np.int32)
    resets = dict(resets)
    for t in range(t_total):
        if t in resets:
            slot = resets[t]
            cache = cache.at[slot].set(0.0)
            plan = reset_plan_slot(plan, slot)
            pos[slot] = 0
        k_new = keys[:, t:t + 1]
        posj = jnp.asarray(pos)
        cache = upd(cache, k_new, posj)
        plan = update_block_summaries(plan, k_new, posj, k_block=blk)
        last = pos.copy()
        pos = pos + 1
    return plan, cache, jnp.asarray(last)


# ---------------------------------------------------------------------------
# int8 backend: conservativeness
# ---------------------------------------------------------------------------

def _assert_contains(plan8, ref_min, ref_max):
    lo8, hi8 = plan_summary_bounds(plan8)
    assert bool((lo8 <= ref_min).all()), "int8 k_min must be <= fp32 k_min"
    assert bool((hi8 >= ref_max).all()), "int8 k_max must be >= fp32 k_max"


def test_int8_bounds_contain_fp32_with_midstream_reset():
    """Incremental int8 maintenance over the serving lifecycle (ragged
    growth, one slot reset and re-claimed) stays conservative vs the
    exact from-scratch bounds."""
    b, kv, s, d, blk = 2, 2, 32, 8, 8
    keys = _rand(jax.random.PRNGKey(0), (b, 24, kv, d)) * 3.0
    plan8, cache, pos = _grow("int8", keys, s, blk, resets={13: 1})
    ref_min, ref_max = summaries_from_cache(cache, pos, k_block=blk)
    _assert_contains(plan8, ref_min, ref_max)
    # ...and the fp32 backend over the same sequence stays exact
    planf, cache_f, pos_f = _grow("fp32", keys, s, blk, resets={13: 1})
    ref_f, _ = summaries_from_cache(cache_f, pos_f, k_block=blk)
    np.testing.assert_array_equal(np.asarray(planf["k_min"]),
                                  np.asarray(ref_f))


def test_int8_conservative_across_magnitudes():
    """Per-block scale adapts to the block's own range: wildly mixed
    magnitudes (1e-3 .. 1e3) must all stay contained."""
    b, kv, s, d, blk = 1, 2, 32, 4, 8
    rng = np.random.default_rng(7)
    mags = 10.0 ** rng.uniform(-3, 3, size=(1, s, 1, 1))
    keys = jnp.asarray(rng.standard_normal((b, s, kv, d)) * mags,
                       jnp.float32)
    plan8, cache, pos = _grow("int8", keys, s, blk)
    ref_min, ref_max = summaries_from_cache(cache, pos, k_block=blk)
    _assert_contains(plan8, ref_min, ref_max)


def test_int8_constant_and_offset_blocks_conservative():
    """Degenerate ranges: a block of identical keys (range 0 -> the
    scale floor) and a tiny range far from zero (scale floored by
    |zero| so dequantization rounding cannot flip containment)."""
    for base, jitter in ((3.7, 0.0), (1.0e4, 1e-3), (-512.0, 1e-5)):
        k = jnp.full((1, 8, 2, 4), base, jnp.float32)
        if jitter:
            k = k + jitter * _rand(jax.random.PRNGKey(1), k.shape)
        plan8, cache, pos = _grow("int8", k, 8, 8)
        ref_min, ref_max = summaries_from_cache(cache, pos, k_block=8)
        _assert_contains(plan8, ref_min, ref_max)


def test_quantize_dequantize_roundtrip_contains():
    """One-shot quantization (the prefill-handoff / page-summary path)
    is conservative, and empty blocks round-trip to the ±inf init."""
    rng = np.random.default_rng(3)
    lo = jnp.asarray(rng.standard_normal((2, 3, 4, 8)), jnp.float32)
    hi = lo + jnp.asarray(rng.uniform(0, 2, (2, 3, 4, 8)), jnp.float32)
    q_lo, q_hi, sc, zp = quantize_summaries(lo, hi)
    dlo, dhi = dequantize_summaries(q_lo, q_hi, sc, zp)
    assert bool((dlo <= lo).all()) and bool((dhi >= hi).all())
    # empty sentinel
    e_lo = jnp.full((1, 1, 8), jnp.inf)
    e_hi = jnp.full((1, 1, 8), -jnp.inf)
    q_lo, q_hi, sc, zp = quantize_summaries(e_lo, e_hi)
    assert float(sc[0, 0]) == -1.0
    dlo, dhi = dequantize_summaries(q_lo, q_hi, sc, zp)
    assert bool(jnp.isposinf(dlo).all()) and bool(jnp.isneginf(dhi).all())


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 28), st.integers(0, 2 ** 31 - 1),
           st.integers(-1, 27), st.floats(-2.0, 2.0))
    def test_property_int8_conservative(n_steps, seed, reset_at, log_mag):
        """Over ANY append / re-plan / reset sequence the quantized
        bounds contain the exact fp32 bounds elementwise — the invariant
        that makes upper-bound ranking superset-safe."""
        b, kv, s, d, blk = 2, 2, 32, 4, 8
        rng = np.random.default_rng(seed)
        keys = jnp.asarray(
            rng.standard_normal((b, n_steps, kv, d)) * 10.0 ** log_mag,
            jnp.float32)
        resets = {reset_at: 1} if 0 <= reset_at < n_steps else {}
        plan8, cache, pos = _grow("int8", keys, s, blk, resets=resets)
        ref_min, ref_max = summaries_from_cache(cache, pos, k_block=blk)
        _assert_contains(plan8, ref_min, ref_max)


# ---------------------------------------------------------------------------
# fp32 backend: bitwise invariance; int8 at replan=1
# ---------------------------------------------------------------------------

def test_fp32_backend_replan1_bitwise_unchanged():
    """The default backend at ``replan_interval=1`` is exactly the
    pre-backend state machine: the plan dict carries no quantization
    keys and ``decode_plan_update`` IS ``full_replan``."""
    b, kv, s, d, blk = 2, 2, 32, 8, 8
    keys = _rand(jax.random.PRNGKey(2), (b, 20, kv, d))
    plan, cache, pos = _grow("fp32", keys, s, blk)
    assert "k_scale" not in plan and plan["k_min"].dtype == jnp.float32
    q = _rand(jax.random.PRNGKey(3), (b, kv, 2, d))
    new, thr = decode_plan_update(plan, q, cache, pos, topk_k=8,
                                  k_block=blk, replan_interval=1)
    fi, fc, ft = full_replan(q, cache, pos, topk_k=8, k_block=blk,
                             plan_blocks=s // blk)
    np.testing.assert_array_equal(np.asarray(new["kv_indices"]),
                                  np.asarray(fi))
    np.testing.assert_array_equal(np.asarray(new["kv_counts"]),
                                  np.asarray(fc))
    np.testing.assert_array_equal(np.asarray(thr), np.asarray(ft))


def test_int8_exact_replan1_matches_fp32():
    """The exact full re-plan never reads the summaries, so at
    ``replan_interval=1`` the int8 backend's plans and thresholds are
    bitwise the fp32 backend's."""
    b, kv, s, d, blk = 2, 2, 32, 8, 8
    keys = _rand(jax.random.PRNGKey(4), (b, 20, kv, d))
    plan8, cache, pos = _grow("int8", keys, s, blk)
    planf, _, _ = _grow("fp32", keys, s, blk)
    q = _rand(jax.random.PRNGKey(5), (b, kv, 2, d))
    n8, t8 = decode_plan_update(plan8, q, cache, pos, topk_k=8,
                                k_block=blk, replan_interval=1)
    nf, tf = decode_plan_update(planf, q, cache, pos, topk_k=8,
                                k_block=blk, replan_interval=1)
    np.testing.assert_array_equal(np.asarray(n8["kv_indices"]),
                                  np.asarray(nf["kv_indices"]))
    np.testing.assert_array_equal(np.asarray(n8["kv_counts"]),
                                  np.asarray(nf["kv_counts"]))
    np.testing.assert_array_equal(np.asarray(t8), np.asarray(tf))


def test_reset_plan_slot_int8_restores_init():
    b, kv, s, d, blk = 2, 2, 16, 4, 8
    keys = _rand(jax.random.PRNGKey(6), (b, 10, kv, d))
    plan, _, _ = _grow("int8", keys, s, blk)
    plan = reset_plan_slot(plan, 0)
    ref = init_decode_plan(b, kv, s, d, blk, summary="int8")
    for name in ("k_min", "k_max", "k_scale", "k_zero"):
        np.testing.assert_array_equal(np.asarray(plan[name][0]),
                                      np.asarray(ref[name][0]),
                                      err_msg=name)


# ---------------------------------------------------------------------------
# Paged == contiguous parity under int8
# ---------------------------------------------------------------------------

def _paged_from_contiguous(cache, blk):
    """Scatter a contiguous (B, S, KV, D) cache into a page pool +
    per-slot table (page == blk; physical page 0 left reserved)."""
    b, s, kv, d = cache.shape
    nkb = s // blk
    pool = jnp.zeros((b * nkb + 1, blk, kv, d), cache.dtype)
    table = np.zeros((b, nkb), np.int32)
    for i in range(b):
        for lp in range(nkb):
            ph = 1 + i * nkb + lp
            pool = pool.at[ph].set(cache[i, lp * blk:(lp + 1) * blk])
            table[i, lp] = ph
    return pool, jnp.asarray(table)


def test_paged_matches_contiguous_int8():
    """The int8 plan is layout-independent (summaries absorb appended
    keys, not cache addresses): incremental and sketch planning over
    the paged pool equal the contiguous run bitwise."""
    b, kv, s, d, blk = 2, 2, 64, 8, 16
    keys = _rand(jax.random.PRNGKey(8), (b, 40, kv, d))
    plan, cache, pos = _grow("int8", keys, s, blk)
    pool, table = _paged_from_contiguous(cache, blk)
    q = _rand(jax.random.PRNGKey(9), (b, kv, 2, d))
    for fn, kw in ((incremental_plan, {}),
                   (sketch_replan, dict(sketch_factor=2))):
        ci, cc, ct = fn(q, cache, plan, pos, topk_k=8, k_block=blk, **kw)
        pi, pc, pt = fn(q, pool, plan, pos, topk_k=8, k_block=blk,
                        page_table=table, **kw)
        np.testing.assert_array_equal(np.asarray(ci), np.asarray(pi))
        np.testing.assert_array_equal(np.asarray(cc), np.asarray(pc))
        np.testing.assert_array_equal(np.asarray(ct), np.asarray(pt))


# ---------------------------------------------------------------------------
# Sketch re-plan
# ---------------------------------------------------------------------------

def test_sketch_equals_full_when_candidates_cover_all_blocks():
    """With the plan width at full nkb, ``C·F >= nkb`` makes every
    valid block a candidate and the two-level pass degenerates to the
    exact re-plan bitwise (the bisection threshold is a function of
    the live score multiset only)."""
    b, kv, s, d, blk = 2, 2, 64, 8, 16
    keys = _rand(jax.random.PRNGKey(10), (b, 50, kv, d))
    for summary in ("fp32", "int8"):
        plan, cache, pos = _grow(summary, keys, s, blk)
        q = _rand(jax.random.PRNGKey(11), (b, kv, 2, d))
        fi, fc, ft = full_replan(q, cache, pos, topk_k=8, k_block=blk,
                                 plan_blocks=s // blk)
        si, sc_, st_ = sketch_replan(q, cache, plan, pos, topk_k=8,
                                     k_block=blk, sketch_factor=2)
        np.testing.assert_array_equal(np.asarray(fi), np.asarray(si))
        np.testing.assert_array_equal(np.asarray(fc), np.asarray(sc_))
        np.testing.assert_array_equal(np.asarray(ft), np.asarray(st_))


def test_sketch_respects_plan_width_and_validity():
    """A narrow plan (P < nkb): the sketch pass keeps counts within P
    and never selects a block past a slot's valid prefix — including a
    freshly re-claimed (shorter) slot."""
    b, kv, s, d, blk, p = 2, 2, 128, 8, 16, 3
    keys = _rand(jax.random.PRNGKey(12), (b, 100, kv, d))
    plan, cache, pos = _grow("int8", keys, s, blk, resets={60: 1})
    q = _rand(jax.random.PRNGKey(13), (b, kv, 2, d))
    plan = {**plan, "kv_indices": plan["kv_indices"][..., :p]}
    si, sc_, st_ = sketch_replan(q, cache, plan, pos, topk_k=8,
                                 k_block=blk, sketch_factor=4)
    assert si.shape == (b, kv, p)
    assert bool((sc_ <= p).all()) and bool((sc_ >= 1).all())
    nvalid = (np.asarray(pos) // blk) + 1                     # (B,)
    live = np.arange(p)[None, None, :] < np.asarray(sc_)[..., None]
    assert bool((np.asarray(si) < nvalid[:, None, None])[live].all())
    assert bool(jnp.isfinite(st_).all())


def test_sketch_geometry_static_arithmetic():
    assert sketch_geometry(32, 8, 4) == (4, 8, 2, 8)
    assert sketch_geometry(32, 32, 4) == (4, 8, 8, 32)   # full coverage
    assert sketch_geometry(30, 8, 4) == (3, 10, 3, 9)    # divisor fallback
    assert sketch_geometry(8, 3, 16) == (8, 1, 1, 8)     # factor clamped


# ---------------------------------------------------------------------------
# Mixed-step partial re-plan (gather-based, per-slot cond)
# ---------------------------------------------------------------------------

def test_mixed_step_matches_per_slot_reference():
    """A step mixing triggered and untriggered slots must equal running
    each slot's own branch in isolation — the gather-based partial
    re-plan semantics the serving scan relies on."""
    b, kv, s, d, blk = 3, 2, 64, 8, 16
    keys = _rand(jax.random.PRNGKey(14), (b, 40, kv, d))
    plan, cache, pos = _grow("fp32", keys, s, blk)
    plan = {**plan, "step": jnp.asarray([0, 1, 2], jnp.int32)}
    q = _rand(jax.random.PRNGKey(15), (b, kv, 2, d))
    new, thr = jax.jit(
        lambda pl, qq: decode_plan_update(pl, qq, cache, pos, topk_k=8,
                                          k_block=blk, replan_interval=2)
    )(plan, q)
    for i in range(b):
        one = lambda a: a[i:i + 1]
        if i % 2 == 0:       # steps 0 and 2 are on the re-plan beat
            ri, rc, rt = full_replan(one(q), one(cache), one(pos),
                                     topk_k=8, k_block=blk,
                                     plan_blocks=s // blk)
        else:
            sub = {k: one(v) for k, v in plan.items()}
            ri, rc, rt = incremental_plan(one(q), one(cache), sub,
                                          one(pos), topk_k=8, k_block=blk)
        np.testing.assert_array_equal(np.asarray(new["kv_indices"][i]),
                                      np.asarray(ri[0]),
                                      err_msg=f"slot {i}")
        np.testing.assert_array_equal(np.asarray(new["kv_counts"][i]),
                                      np.asarray(rc[0]))
        np.testing.assert_array_equal(np.asarray(thr[i]),
                                      np.asarray(rt[0]))


def test_fetch_stats_per_slot_replan_vector():
    """The fetch-byte pin for the partial re-plan: a (B,) replan vector
    charges full-replan bytes only to triggering slots, a broadcast
    scalar reproduces the blended total exactly, and the mixed step
    sits strictly between all-incremental and all-full."""
    cnt = np.array([[2, 3], [1, 1]])
    pos = np.array([63, 63])
    kw = dict(k_block=16, d=8, nkb=4)
    full = decode_fetch_stats(cnt, pos, replan=1.0, **kw)
    incr = decode_fetch_stats(cnt, pos, replan=0.0, **kw)
    mixed = decode_fetch_stats(cnt, pos, replan=np.array([1.0, 0.0]), **kw)
    k_tile = 16 * 8 * 4
    sum_head = summary_bytes(4, 8)
    full_slot0 = 4 * 2 * k_tile                         # 4 valid blocks
    incr_slot1 = sum_head * 2 + (1 + 1) * k_tile
    assert mixed["plan_fetch_bytes_step"] == full_slot0 + incr_slot1
    assert (incr["plan_fetch_bytes_step"]
            < mixed["plan_fetch_bytes_step"]
            < full["plan_fetch_bytes_step"])
    half_v = decode_fetch_stats(cnt, pos, replan=np.array([0.5, 0.5]), **kw)
    half_s = decode_fetch_stats(cnt, pos, replan=0.5, **kw)
    assert (half_v["plan_fetch_bytes_step"]
            == half_s["plan_fetch_bytes_step"])


# ---------------------------------------------------------------------------
# Dtype-/mode-aware fetch accounting
# ---------------------------------------------------------------------------

def test_fetch_stats_summary_dtype_and_sketch_bytes():
    """The ISSUE's headline shape (S=4096, blk=128, d=64, b=kv=2, P=8):
    fp32/exact reproduces the committed bench baseline 4194304 B;
    int8+sketch cuts plan-side bytes >= 3x at interval 1."""
    cnt = np.full((2, 2), 8)
    pos = np.full(2, 4095)
    kw = dict(k_block=128, d=64, nkb=32)
    fp = decode_fetch_stats(cnt, pos, replan=1.0, **kw)
    assert fp["plan_fetch_bytes_step"] == 4194304
    i8s = decode_fetch_stats(cnt, pos, replan=1.0, summary="int8",
                             replan_mode="sketch", plan_blocks=8, **kw)
    assert i8s["plan_fetch_bytes_step"] == \
        summary_bytes(32, 64, "int8") * 4 + 4 * 8 * 128 * 64 * 4
    assert fp["plan_fetch_bytes_step"] / i8s["plan_fetch_bytes_step"] >= 3.0
    # incremental summary reads shrink by the dtype ratio
    fpi = decode_fetch_stats(cnt, pos, replan=0.0, **kw)
    i8i = decode_fetch_stats(cnt, pos, replan=0.0, summary="int8", **kw)
    assert (fpi["plan_fetch_bytes_incremental"]
            - i8i["plan_fetch_bytes_incremental"]
            == (summary_bytes(32, 64) - summary_bytes(32, 64, "int8")) * 4)


# ---------------------------------------------------------------------------
# End-to-end model routing
# ---------------------------------------------------------------------------

def _greedy_logits(cfg, params, toks, max_len):
    cache = dec.init_cache(cfg, batch=toks.shape[0], max_len=max_len)
    outs = []
    for t in range(toks.shape[1]):
        lg, cache = dec.serve_step(params, cfg, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(lg[:, 0])
    return jnp.stack(outs, axis=1), cache


def test_sata_decode_int8_replan1_matches_dense():
    """int8 backend + exact replan=1 end-to-end: the full re-plan never
    consults the summaries, so the route stays dense-top-k exact."""
    base = dataclasses.replace(SMOKE["qwen3-4b"], topk_impl="bisect")
    cfg_d = dataclasses.replace(base, sata_decode="off")
    cfg_s = dataclasses.replace(base, sata_decode="on",
                                sata_decode_block=8, sata_decode_replan=1,
                                sata_summary="int8")
    params = mdl.init_params(jax.random.PRNGKey(0), cfg_d)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, base.vocab_size, (2, 6)), jnp.int32)
    ld, _ = _greedy_logits(cfg_d, params, toks, max_len=16)
    ls, cache = _greedy_logits(cfg_s, params, toks, max_len=16)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(ls),
                               rtol=2e-5, atol=2e-5)
    plan = cache["kv"]["plan"]
    assert plan["k_min"].dtype == jnp.int8 and "k_scale" in plan


def test_sata_decode_int8_sketch_route_runs():
    """The approximate stack end-to-end (int8 summaries + sketch
    re-plan + incremental steps): finite logits, plan width respected,
    per-slot step counters advancing."""
    cfg = dataclasses.replace(SMOKE["qwen3-4b"], topk_impl="bisect",
                              sata_decode="on", sata_decode_block=8,
                              sata_decode_blocks=2, sata_decode_replan=3,
                              sata_summary="int8",
                              sata_replan_mode="sketch",
                              sata_sketch_factor=2)
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 7)), jnp.int32)
    lg, cache = _greedy_logits(cfg, params, toks, max_len=16)
    assert bool(jnp.isfinite(lg).all())
    plan = cache["kv"]["plan"]
    assert int(jnp.max(plan["kv_counts"])) <= 2
    assert int(plan["step"][0, 0]) == 7          # (L, B) per-slot steps


def test_prefill_handoff_seeds_int8_summaries():
    """``plan_from_prefill(summary="int8")`` quantizes the from-scratch
    bounds one-shot: conservative vs the fp32 seed, and the plan rows
    (which come from the exact tail re-plan) are bitwise unchanged."""
    b, kv, s, d, blk = 2, 2, 32, 8, 8
    keys = _rand(jax.random.PRNGKey(16), (b, s, kv, d))
    pos = jnp.asarray([20, 11], jnp.int32)
    q = _rand(jax.random.PRNGKey(17), (b, kv, 2, d))
    sf = plan_from_prefill(keys, q, pos, topk_k=8, k_block=blk)
    s8 = plan_from_prefill(keys, q, pos, topk_k=8, k_block=blk,
                           summary="int8")
    np.testing.assert_array_equal(np.asarray(sf["kv_indices"]),
                                  np.asarray(s8["kv_indices"]))
    np.testing.assert_array_equal(np.asarray(sf["kv_counts"]),
                                  np.asarray(s8["kv_counts"]))
    _assert_contains(s8, sf["k_min"], sf["k_max"])
    assert int(s8["step"][0]) == 1
