"""Cross-path consistency: decode-vs-forward equivalence for the
recurrent families, chunked-vs-unchunked scan equivalence, and the
query-chunked attention path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import SMOKE
from repro.models import decode as dec
from repro.models import model as mdl


def _greedy_decode_logits(cfg, params, toks, extra=None):
    cache = dec.init_cache(cfg, batch=1, max_len=toks.shape[1])
    if extra:
        cache = dec.prefill_context(params, cfg, cache, extra)
    outs = []
    for t in range(toks.shape[1]):
        lg, cache = dec.serve_step(params, cfg, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(lg[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "zamba2-2.7b"])
def test_recurrent_decode_matches_forward(arch):
    """The O(1)-state decode recurrence must reproduce the parallel
    (chunked-scan) forward logits token by token."""
    cfg = dataclasses.replace(SMOKE[arch], attention_variant="dense")
    params = mdl.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)))
    full, _ = mdl.forward(params, cfg, {"tokens": toks})
    step = _greedy_decode_logits(cfg, params, toks)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_chunked_scan_matches_unchunked():
    """The remat-chunked time scan is numerically identical to the plain
    scan (pure re-association of the same recurrence)."""
    from repro.models import rwkv6
    cfg = dataclasses.replace(SMOKE["rwkv6-1.6b"], rwkv_chunk=4)
    cfg_unchunked = dataclasses.replace(cfg, rwkv_chunk=1 << 30)
    params = rwkv6.rwkv6_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (2, 16, cfg.d_model)), jnp.float32)
    y1, s1, _ = rwkv6.rwkv6_time_mix(params, cfg, x)
    y2, s2, _ = rwkv6.rwkv6_time_mix(params, cfg_unchunked, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


def test_mamba_chunk_size_invariance():
    """SSD output must not depend on the chunk size (different matmul
    blockings of the same recurrence)."""
    from repro.models import mamba2
    base = SMOKE["zamba2-2.7b"]
    params = mamba2.mamba2_init(jax.random.PRNGKey(3), base)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(
        (2, 16, base.d_model)), jnp.float32)
    outs = []
    for chunk in (4, 8, 16):
        cfg = dataclasses.replace(base, ssm_chunk=chunk)
        outs.append(np.asarray(mamba2.mamba2_apply(params, cfg, x)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs[1], outs[2], rtol=1e-4, atol=1e-4)


def test_query_chunking_invariance():
    """Attention output must not depend on q_chunk (the lax.map tiling
    the CP layout removes)."""
    cfg8 = SMOKE["olmo-1b"]
    cfg_full = dataclasses.replace(cfg8, q_chunk=1 << 30)
    params = mdl.init_params(jax.random.PRNGKey(4), cfg8)
    toks = jnp.asarray(np.random.default_rng(3).integers(
        0, cfg8.vocab_size, (2, 16)))
    l1, _ = mdl.forward(params, cfg8, {"tokens": toks})
    l2, _ = mdl.forward(params, cfg_full, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


def test_micro_step_gradient_equivalence():
    """micro_steps=4 grad accumulation == single-batch gradients."""
    from repro.optim.adamw import OptConfig
    from repro.train.step import init_train_state, make_train_step
    cfg = SMOKE["olmo-1b"]
    opt = OptConfig(warmup_steps=1, decay_steps=10)
    rng = np.random.default_rng(5)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)))}
    s1 = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    s2 = jax.tree.map(jnp.copy, s1)
    n1, m1 = make_train_step(cfg, opt, micro_steps=1)(s1, batch)
    n4, m4 = make_train_step(cfg, opt, micro_steps=4)(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    l1 = jax.tree_util.tree_leaves(n1["params"])
    l4 = jax.tree_util.tree_leaves(n4["params"])
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-4)
