"""Compacted-grid SATA kernel: parity vs the jnp oracle across occupancy
regimes, fetch-schedule invariants (grid scales with occupied tiles and
the DMA index stream never introduces an unoccupied tile), and the
end-to-end ops wiring (schedule="compact" vs "dense" vs reference)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blockmap import compact_kv_plan
from repro.core.masks import SyntheticTrace, synthetic_masks, topk_mask
from repro.kernels.ops import (default_interpret, kernel_fetch_stats,
                               sata_attention, sata_attention_reference)
from repro.kernels.ref import ref_block_attention
from repro.kernels.sata_attention import sata_block_attention_compact

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def rand_qkv(key, bh, sq, sk, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (bh, sq, d), jnp.float32).astype(dtype)
    k_ = jax.random.normal(k2, (bh, sk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (bh, sk, d), jnp.float32).astype(dtype)
    return q, k_, v


def random_block_map(key, bh, nqb, nkb, p):
    return jax.random.bernoulli(key, p, (bh, nqb, nkb))


# ---------------------------------------------------------------------------
# Kernel parity across occupancy patterns
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("p", [0.0, 0.25, 0.5, 1.0])
def test_compact_matches_ref_random_occupancy(p, dtype):
    """Random maps from all-empty (zero output) to fully dense."""
    bq = bk = 32
    sq = sk = 128
    q, k_, v = rand_qkv(jax.random.PRNGKey(0), 2, sq, sk, 64, dtype)
    bm = random_block_map(jax.random.PRNGKey(int(p * 100)), 2,
                          sq // bq, sk // bk, p)
    idx, cnt = compact_kv_plan(bm)
    out = sata_block_attention_compact(q, k_, v, idx, cnt,
                                       q_block=bq, k_block=bk,
                                       interpret=True)
    ref = ref_block_attention(q, k_, v, bm, q_block=bq, k_block=bk)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_compact_all_empty_rows_zero_output():
    """A q-block row with zero occupied k-blocks must return zeros (and
    not poison neighbouring rows through the inherited padding index)."""
    bq = bk = 32
    sq = sk = 128
    q, k_, v = rand_qkv(jax.random.PRNGKey(1), 2, sq, sk, 64)
    bm = random_block_map(jax.random.PRNGKey(9), 2, 4, 4, 0.6)
    bm = bm.at[0, 0].set(False).at[0, 2].set(False).at[1, 3].set(False)
    idx, cnt = compact_kv_plan(bm)
    out = sata_block_attention_compact(q, k_, v, idx, cnt,
                                       q_block=bq, k_block=bk,
                                       interpret=True)
    ref = ref_block_attention(q, k_, v, bm, q_block=bq, k_block=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(out[0, 0:bq]).max()) == 0.0
    assert float(jnp.abs(out[0, 2 * bq:3 * bq]).max()) == 0.0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_compact_exact_mode_elementwise_mask(dtype):
    bq = bk = 32
    sq = sk = 128
    q, k_, v = rand_qkv(jax.random.PRNGKey(3), 2, sq, sk, 64, dtype)
    mask = jax.random.bernoulli(jax.random.PRNGKey(11), 0.3, (2, sq, sk))
    bm = mask.reshape(2, sq // bq, bq, sk // bk, bk).any(axis=(2, 4))
    idx, cnt = compact_kv_plan(bm)
    out = sata_block_attention_compact(q, k_, v, idx, cnt, mask=mask,
                                       q_block=bq, k_block=bk,
                                       interpret=True)
    ref = ref_block_attention(q, k_, v, bm, mask=mask,
                              q_block=bq, k_block=bk)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("schedule", ["compact", "dense"])
def test_exact_mode_fully_masked_query_row_is_zero(schedule):
    """A query row whose element mask is all-False — while sitting inside
    tiles occupied by other queries — must emit zeros, not mean(V)
    (NEG_INF sentinel: exp(NEG_INF - NEG_INF) == 1 unless masked p is
    zeroed explicitly)."""
    from repro.kernels.sata_attention import sata_block_attention

    bq = bk = 32
    sq = sk = 64
    q, k_, v = rand_qkv(jax.random.PRNGKey(2), 1, sq, sk, 32)
    mask = jnp.ones((1, sq, sk), dtype=bool).at[0, 5, :].set(False)
    bm = mask.reshape(1, sq // bq, bq, sk // bk, bk).any(axis=(2, 4))
    if schedule == "compact":
        idx, cnt = compact_kv_plan(bm)
        out = sata_block_attention_compact(q, k_, v, idx, cnt, mask=mask,
                                           q_block=bq, k_block=bk,
                                           interpret=True)
    else:
        out = sata_block_attention(q, k_, v, bm, mask=mask,
                                   q_block=bq, k_block=bk, interpret=True)
    assert float(jnp.abs(out[0, 5]).max()) == 0.0
    ref = ref_block_attention(q, k_, v, bm, mask=mask,
                              q_block=bq, k_block=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_compact_pad_to_shrinks_grid_preserves_output():
    """pad_to = max occupancy slices the slot dim (the kernel grid's
    innermost extent) without changing the result."""
    bq = bk = 16
    sq = sk = 128
    q, k_, v = rand_qkv(jax.random.PRNGKey(4), 2, sq, sk, 64)
    bm = random_block_map(jax.random.PRNGKey(5), 2, 8, 8, 0.3)
    idx_full, cnt = compact_kv_plan(bm)
    m = int(cnt.max())
    idx, cnt2 = compact_kv_plan(bm, pad_to=m)
    assert idx.shape[-1] == m < bm.shape[-1]
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt2))
    out_full = sata_block_attention_compact(q, k_, v, idx_full, cnt,
                                            q_block=bq, k_block=bk,
                                            interpret=True)
    out = sata_block_attention_compact(q, k_, v, idx, cnt,
                                       q_block=bq, k_block=bk,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_full))


# ---------------------------------------------------------------------------
# Fetch-schedule invariants: the plan never fetches an unoccupied tile
# ---------------------------------------------------------------------------

def test_compact_plan_indices_are_exactly_occupied_set():
    bm = random_block_map(jax.random.PRNGKey(7), 3, 8, 8, 0.4)
    idx, cnt = compact_kv_plan(bm)
    bm_np, idx_np, cnt_np = (np.asarray(bm), np.asarray(idx),
                             np.asarray(cnt))
    for b in range(bm_np.shape[0]):
        for i in range(bm_np.shape[1]):
            occ = set(np.nonzero(bm_np[b, i])[0].tolist())
            active = idx_np[b, i, :cnt_np[b, i]].tolist()
            assert active == sorted(occ)            # ascending, complete

def test_compact_plan_padding_never_triggers_new_fetch():
    """Walk the grid's index stream in execution order: a K/V fetch
    happens where the index changes between consecutive steps.  Every
    fetch must land on a slot j < count (an occupied tile); padding and
    empty rows only re-reference the already-resident block."""
    bm = random_block_map(jax.random.PRNGKey(8), 3, 8, 8, 0.35)
    # empty rows in the middle AND leading position
    bm = bm.at[0, 3].set(False).at[2, 0].set(False)
    idx, cnt = compact_kv_plan(bm)
    bm_np, idx_np, cnt_np = np.asarray(bm), np.asarray(idx), np.asarray(cnt)
    bh, nqb, n_slots = idx_np.shape
    for b in range(bh):
        if not bm_np[b].any():
            continue                                  # fallback-0 batch
        prev = None
        fetches = 0
        for i in range(nqb):
            for j in range(n_slots):
                cur = idx_np[b, i, j]
                if cur != prev:
                    fetches += 1
                    if prev is None:
                        # the grid's first step must fetch *something*;
                        # the plan points it at the tile the first
                        # non-empty row needs first, never a dead tile.
                        first_row = np.nonzero(cnt_np[b] > 0)[0][0]
                        assert cur == idx_np[b, first_row, 0]
                        assert bm_np[b, first_row, cur]
                    else:
                        assert j < cnt_np[b, i], (b, i, j)
                        assert bm_np[b, i, cur], (b, i, cur)
                prev = cur
        assert fetches <= int(bm_np[b].sum())


def test_compact_plan_rejects_undersized_pad_to():
    bm = jnp.ones((1, 2, 4), dtype=bool)
    with pytest.raises(ValueError, match="pad_to"):
        compact_kv_plan(bm, pad_to=2)


def test_compact_zero_slot_plan_returns_zeros():
    """Entirely-empty map + pad_to=0 → zero-extent grid dim; the kernel
    must return zeros, not an unwritten buffer."""
    q, k_, v = rand_qkv(jax.random.PRNGKey(13), 2, 64, 64, 32)
    bm = jnp.zeros((2, 2, 2), dtype=bool)
    idx, cnt = compact_kv_plan(bm, pad_to=0)
    out = sata_block_attention_compact(q, k_, v, idx, cnt,
                                       q_block=32, k_block=32,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_fetch_stats_scale_with_occupancy():
    bm = np.zeros((2, 8, 8), dtype=bool)
    bm[:, :, :4] = True                               # 50% occupancy, max=4
    stats = kernel_fetch_stats(bm, q_block=32, k_block=32, d=64,
                               max_kv_blocks=4)
    assert stats["grid_compact"] == [2, 8, 4]
    assert stats["tile_visits_compact"] * 2 == stats["tile_visits_dense"]
    assert stats["kv_fetch_bytes_compact"] * 2 == stats["kv_fetch_bytes_dense"]
    assert stats["visit_reduction"] == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# End-to-end ops wiring
# ---------------------------------------------------------------------------

def test_ops_compact_equals_dense_schedule_and_reference():
    bh, s, d = 3, 128, 64
    q, k_, v = rand_qkv(jax.random.PRNGKey(5), bh, s, s, d)
    scores = jnp.einsum("bqd,bkd->bqk", q, k_)
    mask = topk_mask(scores, 24)
    out_c, bm_c = sata_attention(q, k_, v, mask, q_block=16, k_block=16,
                                 exact=True, interpret=True,
                                 schedule="compact")
    out_d, bm_d = sata_attention(q, k_, v, mask, q_block=16, k_block=16,
                                 exact=True, interpret=True,
                                 schedule="dense")
    ref = sata_attention_reference(q, k_, v, mask)
    np.testing.assert_array_equal(np.asarray(bm_c), np.asarray(bm_d))
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_d),
                               rtol=1e-6, atol=1e-6)


def test_ops_block_mode_needs_no_dense_mask():
    """exact=False must not materialize the (BH, Sq, Sk) mask; the
    compact schedule still matches the block-mode oracle."""
    tr = SyntheticTrace(n_tokens=128, k=16, cluster_rank=2,
                        cluster_scale=2.0, noise=0.3)
    masks = jnp.asarray(synthetic_masks(2, tr, n_heads=2))
    q, k_, v = rand_qkv(jax.random.PRNGKey(6), 2, 128, 128, 64)
    out, bm = sata_attention(q, k_, v, masks, q_block=16, k_block=16,
                             exact=False, interpret=True,
                             schedule="compact")
    assert out.shape == q.shape
    assert jnp.isfinite(out).all()


def test_ops_max_kv_blocks_static_bound():
    bh, s, d = 2, 128, 64
    q, k_, v = rand_qkv(jax.random.PRNGKey(12), bh, s, s, d)
    scores = jnp.einsum("bqd,bkd->bqk", q, k_)
    mask = topk_mask(scores, 24)
    ref, _ = sata_attention(q, k_, v, mask, q_block=16, k_block=16,
                            exact=True, interpret=True, schedule="compact")
    # full nkb is always a safe static bound
    out, _ = sata_attention(q, k_, v, mask, q_block=16, k_block=16,
                            exact=True, interpret=True, schedule="compact",
                            max_kv_blocks=s // 16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_default_interpret_matches_backend():
    assert default_interpret() == (jax.default_backend() != "tpu")


# ---------------------------------------------------------------------------
# Model-layer routing (config flag)
# ---------------------------------------------------------------------------

def test_model_attention_sata_kernel_flag_parity():
    import dataclasses

    from repro.models.attention import attention_apply, attention_init
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                      attention_variant="topk", topk_k=16, dtype="float32",
                      sata_block=32)
    params = attention_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 64), jnp.float32)
    base = attention_apply(params, cfg, x)
    kern = attention_apply(
        params, dataclasses.replace(cfg, use_sata_kernel=True), x)
    np.testing.assert_allclose(np.asarray(base), np.asarray(kern),
                               rtol=1e-4, atol=1e-4)


def test_model_routing_falls_back_on_unaligned_seq():
    """Sequence lengths that don't tile by sata_block must take the
    _attend fallback, never a misshaped kernel launch."""
    from repro.models.attention import _sata_kernel_ok
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                      attention_variant="topk", use_sata_kernel=True,
                      sata_block=32)
    assert _sata_kernel_ok(cfg, 128, cross=False)
    assert not _sata_kernel_ok(cfg, 100, cross=False)   # not a multiple
    assert not _sata_kernel_ok(cfg, 24, cross=False)    # shorter than blk
    assert not _sata_kernel_ok(cfg, 128, cross=True)


def test_model_attention_sata_kernel_differentiable():
    """The kernel route must train: its custom VJP (reference recompute)
    has to match the fallback path's gradients."""
    import dataclasses

    from repro.models.attention import attention_apply, attention_init
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      attention_variant="topk", topk_k=8, dtype="float32",
                      sata_block=16)
    params = attention_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32), jnp.float32)

    def loss(p, c):
        return (attention_apply(p, c, x) ** 2).sum()

    g_base = jax.grad(loss)(params, cfg)
    g_kern = jax.grad(loss)(
        params, dataclasses.replace(cfg, use_sata_kernel=True))
    for name in g_base:
        np.testing.assert_allclose(np.asarray(g_base[name]),
                                   np.asarray(g_kern[name]),
                                   rtol=1e-3, atol=1e-4, err_msg=name)
