"""Decode-path SATA: incremental plan maintenance properties, decode
gather-kernel parity vs dense decode (ragged per-slot lengths, empty
plan, first token), end-to-end model routing, the per-slot serving
loop, and the cross-attention context-length mask."""
import dataclasses
import sys
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).parent))
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402

from repro.configs.archs import SMOKE
from repro.core.blockmap import bisect_select
from repro.core.decode_plan import (decode_plan_update, full_replan,
                                    incremental_plan, init_decode_plan,
                                    reset_plan_slot, summaries_from_cache,
                                    update_block_summaries)
from repro.core.selection import NEG_INF, kth_largest_bisect
from repro.kernels.ops import decode_fetch_stats, sata_decode_attention
from repro.models import decode as dec
from repro.models import model as mdl
from repro.models.attention import sata_decode_on


def _jnp_topk_decode(qg, k, v, pos, topk_k):
    """Dense top-k (bisect) decode oracle: qg (B, KV, G, D);
    k/v (B, S, KV, D); pos (B,)."""
    d = qg.shape[-1]
    sc = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                    k.astype(jnp.float32)) / np.sqrt(d)
    valid = (jnp.arange(k.shape[1]) <= pos[:, None])[:, None, None, :]
    sc = jnp.where(valid, sc, NEG_INF)
    thr = kth_largest_bisect(sc, topk_k)
    sel = bisect_select(jnp.where(valid, sc, -jnp.inf), thr) & valid
    sc = jnp.where(sel, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    p = jnp.where(sel.any(-1, keepdims=True), p, 0.0)
    return jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# Plan maintenance properties
# ---------------------------------------------------------------------------

def _append_sequence(keys, b, kv, s, d, blk, positions):
    """Drive the incremental summary state through an append sequence
    and return (state, cache, final per-slot pos)."""
    plan = init_decode_plan(b, kv, s, d, blk)
    cache = jnp.zeros((b, s, kv, d), jnp.float32)
    pos = -np.ones(b, np.int32)
    for t, step_pos in enumerate(positions):
        pos = np.asarray(step_pos, np.int32)
        k_new = _rand(jax.random.PRNGKey(1000 + t), (b, 1, kv, d))
        posj = jnp.asarray(pos)
        upd = jax.vmap(lambda c, n, p:
                       jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0))
        cache = upd(cache, k_new, posj)
        plan = update_block_summaries(plan, k_new, posj, k_block=blk)
    return plan, cache, jnp.asarray(pos)


def test_incremental_summaries_match_from_scratch():
    """Append-only maintenance (ragged slot lengths, one slot reset and
    re-claimed mid-stream — the serving lifecycle) leaves the summaries
    bit-identical to recomputing them from the cache."""
    b, kv, s, d, blk = 2, 2, 32, 8, 8
    plan = init_decode_plan(b, kv, s, d, blk)
    cache = jnp.zeros((b, s, kv, d), jnp.float32)
    upd = jax.vmap(lambda c, n, p:
                   jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0))
    pos = np.zeros(b, np.int32)
    for t in range(10):
        if t == 6:
            # slot 1 finishes; a new request claims it: cache region
            # zeroed, plan slot reset, position back to 0
            cache = cache.at[1].set(0.0)
            plan = reset_plan_slot(plan, 1)
            pos[1] = 0
        k_new = _rand(jax.random.PRNGKey(1000 + t), (b, 1, kv, d))
        posj = jnp.asarray(pos)
        cache = upd(cache, k_new, posj)
        plan = update_block_summaries(plan, k_new, posj, k_block=blk)
        last = pos.copy()
        pos += 1
    posj = jnp.asarray(last)
    ref_min, ref_max = summaries_from_cache(cache, posj, k_block=blk)
    np.testing.assert_array_equal(np.asarray(plan["k_min"]),
                                  np.asarray(ref_min))
    np.testing.assert_array_equal(np.asarray(plan["k_max"]),
                                  np.asarray(ref_max))


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 12), st.integers(0, 2 ** 31 - 1))
    def test_property_incremental_plan_equals_replan(n_steps, seed):
        """After ANY append sequence, the incrementally-maintained state
        yields exactly the plan a from-scratch re-plan produces: the
        summaries are bitwise equal to ``summaries_from_cache``, so
        ``incremental_plan`` from the maintained state == from the
        rebuilt state, and the full re-plan is a pure function of the
        cache either way."""
        b, kv, s, d, blk = 1, 2, 32, 8, 8
        positions = [[t] for t in range(n_steps)]
        plan, cache, pos = _append_sequence(None, b, kv, s, d, blk,
                                            positions)
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.standard_normal((b, kv, 2, d)), jnp.float32)
        # rebuild the state from scratch off the same cache
        k_min, k_max = summaries_from_cache(cache, pos, k_block=blk)
        rebuilt = {**plan, "k_min": k_min, "k_max": k_max}
        out_inc = incremental_plan(q, cache, plan, pos,
                                   topk_k=4, k_block=blk)
        out_scr = incremental_plan(q, cache, rebuilt, pos,
                                   topk_k=4, k_block=blk)
        for a, bb in zip(out_inc, out_scr):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


def test_full_replan_covers_all_selected_tokens():
    """Every token the bisect threshold selects lives in a planned
    block (P = nkb: nothing may be dropped)."""
    b, kv, g, s, d, blk = 2, 2, 2, 64, 8, 8
    nkb = s // blk
    q = _rand(jax.random.PRNGKey(0), (b, kv, g, d))
    k = _rand(jax.random.PRNGKey(1), (b, s, kv, d))
    pos = jnp.asarray([s - 1, 17], jnp.int32)
    idx, cnt, thr = full_replan(q, k, pos, topk_k=4, k_block=blk,
                                plan_blocks=nkb)
    sc = jnp.einsum("bkgd,bskd->bkgs", q, k) / np.sqrt(d)
    valid = (jnp.arange(s) <= pos[:, None])[:, None, None, :]
    sel = bisect_select(jnp.where(valid, sc, -jnp.inf), thr) & valid
    sel_blocks = sel.reshape(b, kv, g, nkb, blk).any(axis=(2, 4))
    idxn, cntn = np.asarray(idx), np.asarray(cnt)
    for i in range(b):
        for j in range(kv):
            planned = set(idxn[i, j, :cntn[i, j]].tolist())
            needed = set(np.nonzero(np.asarray(sel_blocks[i, j]))[0].tolist())
            assert needed <= planned, (needed, planned)
            # ascending unique live entries (compact_kv_plan layout)
            live = idxn[i, j, :cntn[i, j]]
            assert (np.diff(live) > 0).all()


def test_incremental_plan_enters_and_retires_blocks():
    """A freshly appended block enters the plan the step its first
    token lands; with a tight budget, a colder block retires."""
    b, kv, s, d, blk = 1, 1, 32, 8, 8
    plan = init_decode_plan(b, kv, s, d, blk, plan_blocks=2)
    cache = jnp.zeros((b, s, kv, d), jnp.float32)
    q = _rand(jax.random.PRNGKey(5), (b, kv, 1, d))
    # block 0: weak keys; block 1: strong keys aligned with q
    strong = 10.0 * q[:, :, 0][:, None, :, :]                # (B,1,KV,D)
    upd = jax.vmap(lambda c, n, p:
                   jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0))
    for t in range(blk):
        kn = 0.01 * _rand(jax.random.PRNGKey(t), (b, 1, kv, d))
        cache = upd(cache, kn, jnp.asarray([t], jnp.int32))
        plan = update_block_summaries(plan, kn, jnp.asarray([t]),
                                      k_block=blk)
    idx0, cnt0, _ = incremental_plan(q, cache, plan,
                                     jnp.asarray([blk - 1]), topk_k=2,
                                     k_block=blk)
    assert int(cnt0[0, 0]) == 1 and int(idx0[0, 0, 0]) == 0
    cache = upd(cache, strong, jnp.asarray([blk], jnp.int32))
    plan = update_block_summaries(plan, strong, jnp.asarray([blk]),
                                  k_block=blk)
    idx1, cnt1, _ = incremental_plan(q, cache, plan, jnp.asarray([blk]),
                                     topk_k=2, k_block=blk)
    assert 1 in idx1[0, 0, :int(cnt1[0, 0])]                 # entered


def test_block_upper_bound_never_underestimates():
    """The Quest bound must dominate every true token score in the
    block for mixed-sign queries (the whole point of ranking blocks by
    it: a block holding a top-k key may never be evicted because its
    bound undershot)."""
    from repro.core.decode_plan import block_upper_bounds
    b, kv, g, s, d, blk = 2, 2, 3, 64, 8, 8
    q = _rand(jax.random.PRNGKey(20), (b, kv, g, d))
    k = _rand(jax.random.PRNGKey(21), (b, s, kv, d))
    pos = jnp.full((b,), s - 1, jnp.int32)
    k_min, k_max = summaries_from_cache(k, pos, k_block=blk)
    ub = block_upper_bounds(q, k_min, k_max, sm_scale=1.0 / np.sqrt(d))
    sc = jnp.einsum("bkgd,bskd->bkgs", q, k) / np.sqrt(d)
    true_max = sc.reshape(b, kv, g, s // blk, blk).max(axis=-1)
    assert float(jnp.min(ub - true_max)) >= -1e-6


def test_reset_plan_slot_restores_init():
    b, kv, s, d, blk = 2, 2, 16, 4, 8
    plan = init_decode_plan(b, kv, s, d, blk)
    k_new = _rand(jax.random.PRNGKey(0), (b, 1, kv, d))
    plan = update_block_summaries(plan, k_new, jnp.zeros(b, jnp.int32),
                                  k_block=blk)
    plan = {**plan, "kv_counts": plan["kv_counts"] + 3}
    reset = reset_plan_slot(plan, 0)
    fresh = init_decode_plan(b, kv, s, d, blk)
    for name in ("k_min", "k_max", "kv_indices", "kv_counts"):
        np.testing.assert_array_equal(np.asarray(reset[name][0]),
                                      np.asarray(fresh[name][0]))
        if name in ("k_min", "k_max"):                       # slot 1 kept
            np.testing.assert_array_equal(np.asarray(reset[name][1]),
                                          np.asarray(plan[name][1]))


# ---------------------------------------------------------------------------
# Decode gather kernel
# ---------------------------------------------------------------------------

def test_decode_kernel_matches_dense_topk_ragged():
    """Planned kernel vs the dense bisect-top-k oracle at ragged
    per-slot lengths, including a first-token slot (pos=0)."""
    b, kv, g, s, d, blk = 3, 2, 2, 64, 16, 16
    nkb = s // blk
    q = _rand(jax.random.PRNGKey(0), (b, kv, g, d))
    k = _rand(jax.random.PRNGKey(1), (b, s, kv, d))
    v = _rand(jax.random.PRNGKey(2), (b, s, kv, d))
    pos = jnp.asarray([s - 1, 21, 0], jnp.int32)
    idx, cnt, thr = full_replan(q, k, pos, topk_k=4, k_block=blk,
                                plan_blocks=nkb)
    out = sata_decode_attention(q, k, v, idx, cnt, thr, pos,
                                k_block=blk, interpret=True)
    ref = _jnp_topk_decode(q, k, v, pos, topk_k=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_decode_kernel_bitwise_equal_to_dense_schedule():
    """Planned schedule vs all-valid-blocks schedule, same thresholds:
    a fully-masked tile is an exact no-op in the online softmax, so the
    outputs must be BITWISE equal — the replan_interval=1 exactness the
    bench pins."""
    b, kv, g, s, d, blk = 2, 2, 1, 64, 8, 8
    nkb = s // blk
    q = _rand(jax.random.PRNGKey(3), (b, kv, g, d))
    k = _rand(jax.random.PRNGKey(4), (b, s, kv, d))
    v = _rand(jax.random.PRNGKey(5), (b, s, kv, d))
    pos = jnp.asarray([s - 1, 30], jnp.int32)
    idx, cnt, thr = full_replan(q, k, pos, topk_k=3, k_block=blk,
                                plan_blocks=nkb)
    out_plan = sata_decode_attention(q, k, v, idx, cnt, thr, pos,
                                     k_block=blk, interpret=True)
    idx_d = jnp.broadcast_to(jnp.arange(nkb, dtype=jnp.int32),
                             (b, kv, nkb))
    cnt_d = jnp.full((b, kv), nkb, jnp.int32)
    out_dense = sata_decode_attention(q, k, v, idx_d, cnt_d, thr, pos,
                                      k_block=blk, interpret=True)
    assert float(jnp.max(jnp.abs(out_plan - out_dense))) == 0.0


def test_decode_kernel_empty_plan_zero_output():
    """kv_counts == 0 (nothing planned yet) must emit zeros, not stale
    or NaN accumulator state."""
    b, kv, g, s, d, blk = 2, 1, 2, 32, 8, 8
    q = _rand(jax.random.PRNGKey(6), (b, kv, g, d))
    k = _rand(jax.random.PRNGKey(7), (b, s, kv, d))
    v = _rand(jax.random.PRNGKey(8), (b, s, kv, d))
    idx = jnp.zeros((b, kv, 2), jnp.int32)
    cnt = jnp.zeros((b, kv), jnp.int32).at[1, 0].set(1)
    thr = jnp.full((b, kv, g, 1), -1e9, jnp.float32)
    out = sata_decode_attention(q, k, v, idx, cnt, thr,
                                jnp.asarray([0, 0], jnp.int32),
                                k_block=blk, interpret=True)
    assert float(jnp.abs(out[0]).max()) == 0.0
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.abs(out[1]).max()) > 0.0       # row with work attends


def test_decode_fetch_stats_scale_with_plan():
    cnt = np.array([[2, 3], [1, 1]])
    pos = np.array([63, 15])
    st_ = decode_fetch_stats(cnt, pos, k_block=16, d=8)
    assert st_["kv_fetch_tiles_plan"] == 7
    assert st_["kv_fetch_tiles_dense"] == (4 + 1) * 2
    assert st_["kv_fetch_bytes_plan"] == 7 * 2 * 16 * 8 * 4


# ---------------------------------------------------------------------------
# Model routing + end-to-end decode
# ---------------------------------------------------------------------------

def _greedy_logits(cfg, params, toks, max_len):
    cache = dec.init_cache(cfg, batch=toks.shape[0], max_len=max_len)
    outs = []
    for t in range(toks.shape[1]):
        lg, cache = dec.serve_step(params, cfg, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(lg[:, 0])
    return jnp.stack(outs, axis=1), cache


@pytest.mark.parametrize("arch,kv_heads", [("qwen3-4b", 4),
                                           ("olmo-1b", 2)])
def test_sata_decode_matches_dense_decode(arch, kv_heads):
    """End-to-end serve_step parity: SATA decode route (full re-plan
    every step) vs dense decode, same bisect selection — GQA grouping
    (G > 1) covered by the olmo variant."""
    base = dataclasses.replace(SMOKE[arch], n_kv_heads=kv_heads,
                               topk_impl="bisect")
    cfg_d = dataclasses.replace(base, sata_decode="off")
    cfg_s = dataclasses.replace(base, sata_decode="on",
                                sata_decode_block=8, sata_decode_replan=1)
    params = mdl.init_params(jax.random.PRNGKey(0), cfg_d)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, base.vocab_size, (2, 6)), jnp.int32)
    ld, _ = _greedy_logits(cfg_d, params, toks, max_len=16)
    ls, cache = _greedy_logits(cfg_s, params, toks, max_len=16)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(ls),
                               rtol=2e-5, atol=2e-5)
    assert "plan" in cache["kv"]


def test_sata_decode_incremental_route_runs():
    """replan_interval > 1 exercises the summary-ranked incremental
    branch (approximate): finite logits, plan counts within budget."""
    cfg = dataclasses.replace(SMOKE["qwen3-4b"], topk_impl="bisect",
                              sata_decode="on", sata_decode_block=8,
                              sata_decode_blocks=2, sata_decode_replan=3)
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 7)), jnp.int32)
    lg, cache = _greedy_logits(cfg, params, toks, max_len=16)
    assert bool(jnp.isfinite(lg).all())
    plan = cache["kv"]["plan"]
    assert int(jnp.max(plan["kv_counts"])) <= 2
    assert int(plan["step"][0, 0]) == 7          # (L, B) per-slot steps


def test_sata_decode_routing():
    cfg = SMOKE["qwen3-4b"]
    assert not sata_decode_on(cfg, 64)                  # auto, short cache
    assert sata_decode_on(
        dataclasses.replace(cfg, sata_decode="on", sata_decode_block=16), 64)
    assert not sata_decode_on(
        dataclasses.replace(cfg, sata_decode="on", sata_decode_block=16,
                            attention_variant="dense"), 64)
    with pytest.raises(ValueError):
        sata_decode_on(
            dataclasses.replace(cfg, sata_decode="on",
                                sata_decode_block=48), 64)
    # auto follows the bisect decision at the cache length
    assert sata_decode_on(dataclasses.replace(cfg, topk_impl="bisect"), 64)


# ---------------------------------------------------------------------------
# Serving loop: per-slot positions + slot reset
# ---------------------------------------------------------------------------

def test_serve_outputs_independent_of_slot_count():
    """The lockstep-bug regression: a request's tokens depend only on
    its own prompt — reusing a freed slot (fewer slots than requests)
    must not leak the previous occupant's cache or position."""
    from repro.launch.serve import serve
    a = serve("olmo-1b", smoke=True, n_requests=4, batch_slots=2,
              gen_len=4, max_len=32)
    b = serve("olmo-1b", smoke=True, n_requests=4, batch_slots=4,
              gen_len=4, max_len=32)
    assert a["outputs"] == b["outputs"]
    assert set(a["request_latency_s"]) == {0, 1, 2, 3}
    assert all(v > 0 for v in a["request_latency_s"].values())


def test_serve_reports_per_request_latency():
    from repro.launch.serve import serve
    out = serve("olmo-1b", smoke=True, n_requests=3, batch_slots=3,
                gen_len=3, max_len=16)
    assert len(out["request_latency_s"]) == 3
    assert out["latency_mean_s"] > 0


# ---------------------------------------------------------------------------
# Cross-attention context-length mask
# ---------------------------------------------------------------------------

def test_cross_attention_decode_masks_padded_context():
    """Two different paddings of the same image context must decode
    identically once ``context_lengths`` is threaded — and differ
    without it (the silent-ignore bug this pins).  The vlm family's
    context K/V is per-position (no encoder mixing), so the decode-time
    mask fully isolates the padded region."""
    cfg = SMOKE["llama-3.2-vision-90b"]
    params = mdl.init_params(jax.random.PRNGKey(0), cfg)
    # the gated x-attn inits closed (tanh(0) = 0) — open it so the
    # context actually reaches the logits
    params["cross_layers"] = {**params["cross_layers"],
                              "gate": jnp.ones_like(
                                  params["cross_layers"]["gate"])}
    rng = np.random.default_rng(2)
    b, s_ctx, length = 2, cfg.n_image_tokens, 5
    real = rng.standard_normal((b, s_ctx, cfg.d_model))
    pad_a, pad_b = real.copy(), real.copy()
    pad_a[:, length:] = rng.standard_normal((b, s_ctx - length,
                                             cfg.d_model))
    pad_b[:, length:] = 5.0 * rng.standard_normal((b, s_ctx - length,
                                                   cfg.d_model))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 1)), jnp.int32)
    lengths = np.full(b, length)

    def run(embeds, with_lengths):
        batch = {"image_embeds": jnp.asarray(embeds, jnp.float32)}
        if with_lengths:
            batch["context_lengths"] = jnp.asarray(lengths)
        cache = dec.init_cache(cfg, batch=b, max_len=8)
        cache = dec.prefill_context(params, cfg, cache, batch)
        lg, _ = dec.serve_step(params, cfg, cache, toks, jnp.int32(0))
        return np.asarray(lg)

    np.testing.assert_array_equal(run(pad_a, True), run(pad_b, True))
    assert np.abs(run(pad_a, False) - run(pad_b, False)).max() > 0
