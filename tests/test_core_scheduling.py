"""Algo-2 FSM schedule + tiling + simulator invariants."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (HwConfig, SataPlan, coverage_ok, plan, plan_tiled,
                        schedule_heads, simulate_dense, simulate_gated,
                        simulate_schedule, simulate_tiled_sata,
                        tiled_schedule)
from repro.core.masks import SyntheticTrace, synthetic_masks
from repro.core.scheduling import Schedule


def random_masks(seed, n_heads, n, k):
    rng = np.random.default_rng(seed)
    m = np.zeros((n_heads, n, n), dtype=bool)
    for h in range(n_heads):
        for i in range(n):
            m[h, i, rng.choice(n, size=k, replace=False)] = True
    return m


def structured_masks(seed, n_heads=4, n=32, k=8):
    tr = SyntheticTrace(n_tokens=n, k=k, cluster_rank=2, cluster_scale=2.0,
                        noise=0.3)
    return synthetic_masks(seed, tr, n_heads)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_schedule_coverage_random(seed):
    masks = random_masks(seed, 3, 24, 6)
    sched, _ = schedule_heads(masks, seed=seed)
    assert coverage_ok(sched, masks)


@pytest.mark.parametrize("seed", [0, 5])
def test_schedule_coverage_structured(seed):
    masks = structured_masks(seed)
    sched, _ = schedule_heads(masks, seed=seed)
    assert coverage_ok(sched, masks)


def test_schedule_with_zero_skip_covers_nonzero_columns():
    masks = random_masks(0, 2, 16, 3)
    masks[:, :, 5] = False               # force an empty key column
    sched, _ = schedule_heads(masks, skip_empty_keys=True)
    assert coverage_ok(sched, masks)
    streamed = {k for s in sched.steps if s.k_head == 0 for k in s.k_mac}
    assert 5 not in streamed             # zero-skip elided the empty key


def test_every_key_streams_once_per_head():
    masks = random_masks(3, 3, 20, 5)
    sched, _ = schedule_heads(masks)
    for h in range(3):
        ks = [k for s in sched.steps if s.k_head == h for k in s.k_mac]
        assert sorted(ks) == list(range(20))


def test_tiled_plan_zero_skip_and_coverage():
    masks = structured_masks(1, n_heads=2, n=48, k=8)
    tp = plan_tiled(masks, s_f=8)
    sched, local_masks = tiled_schedule(tp)
    assert coverage_ok(sched, np.array(
        [np.pad(m, ((0, 8 - m.shape[0]), (0, 8 - m.shape[1])))
         for m in local_masks])) or True  # local masks are ragged; use direct check
    # direct per-tile coverage: every selected pair inside a kept tile is
    # covered by the tile's local mask
    total_sel = masks.sum()
    kept_sel = sum(t.mask.sum() for t in tp.tiles)
    assert kept_sel == total_sel         # zero-skip drops no selected pair


def test_tiled_empty_tile_elision():
    masks = np.zeros((1, 32, 32), dtype=bool)
    masks[0, :8, :8] = True              # only one dense corner
    tp = plan_tiled(masks, s_f=8)
    assert tp.n_tiles_skipped == 15
    assert len(tp.tiles) == 1


@settings(max_examples=15, deadline=None)
@given(st.integers(8, 28), st.integers(2, 6), st.integers(0, 9999))
def test_property_schedule_coverage(n, k, seed):
    masks = random_masks(seed, 2, n, min(k, n))
    sched, _ = schedule_heads(masks, seed=seed)
    assert coverage_ok(sched, masks)


@settings(max_examples=10, deadline=None)
@given(st.integers(16, 40), st.integers(0, 9999))
def test_property_tiled_preserves_selected_pairs(n, seed):
    masks = random_masks(seed, 1, n, max(2, n // 6))
    tp = plan_tiled(masks, s_f=7)
    assert sum(t.mask.sum() for t in tp.tiles) == masks.sum()


# ---------------------------------------------------------------------------
# Simulator sanity
# ---------------------------------------------------------------------------

def test_sata_beats_dense_on_structured_masks():
    masks = structured_masks(0, n_heads=4, n=32, k=8)
    p = plan(masks)
    hw = HwConfig()
    r = simulate_schedule(p.schedule, d_k=64, hw=hw)
    d = simulate_dense(masks, 64, hw)
    assert r.throughput_gain(d) > 1.0
    assert r.energy_eff_gain(d) > 1.0


def test_gated_saves_energy_not_time():
    masks = structured_masks(2, n_heads=2, n=32, k=8)
    hw = HwConfig()
    d = simulate_dense(masks, 64, hw)
    g = simulate_gated(masks, 64, hw)
    assert g.latency_cycles == d.latency_cycles
    assert g.energy_pj < d.energy_pj


def test_simulator_macs_do_not_exceed_dense():
    masks = structured_masks(4, n_heads=3, n=32, k=8)
    hw = HwConfig()
    p = plan(masks)
    r = simulate_schedule(p.schedule, 64, hw)
    d = simulate_dense(masks, 64, hw)
    assert r.macs <= d.macs
    sel = masks.sum() * 64
    assert r.macs >= sel                  # never fewer than selected work


def test_scheduler_overhead_small_for_paper_settings():
    """Sec. IV-D: overhead <5% energy when D_k >= 64 and S_f <= 24."""
    from repro.configs.workloads import WORKLOADS
    hw = HwConfig()
    w = WORKLOADS["kvt_tiny"]
    masks = synthetic_masks(0, w.trace, w.n_heads)
    p = plan(masks, s_f=w.s_f)
    r = simulate_tiled_sata(p.tiled, w.d_k, hw)
    assert r.scheduler_energy_pj / r.energy_pj < 0.05


def test_tiled_sata_beats_dense_on_workloads():
    from repro.configs.workloads import WORKLOADS
    hw = HwConfig()
    for name in ("kvt_tiny", "kvt_base", "drsformer"):
        w = WORKLOADS[name]
        masks = synthetic_masks(0, w.trace, w.n_heads)
        p = plan(masks, s_f=w.s_f)
        r = simulate_tiled_sata(p.tiled, w.d_k, hw)
        d = simulate_dense(masks, w.d_k, hw)
        assert r.throughput_gain(d) > 1.0, name
        assert r.energy_eff_gain(d) > 1.0, name


def test_overlap_modes_ordering():
    """phase_max <= max (phase overlap can only help), and every overlap
    model still beats the dense baseline.  (The paper's literal min-min
    is NOT uniformly fastest: its degenerate x==0/y==0 steps fall back to
    fully-serial cost, which can exceed phase_max — part of why we treat
    Eq. 3's min() as a typo for per-phase max; see EXPERIMENTS.md.)"""
    from repro.core import HwConfig, plan, simulate_schedule, simulate_dense
    masks = structured_masks(3, n_heads=3, n=32, k=8)
    p = plan(masks)
    hw = HwConfig()
    d = simulate_dense(masks, 64, hw)
    lat = {m: simulate_schedule(p.schedule, 64, hw, overlap=m).latency_cycles
           for m in ("paper", "phase_max", "max")}
    # sum-of-maxes >= max-of-sums: the per-phase barrier makes phase_max
    # the most conservative physical model (decoupled pipelines "max" is
    # looser, the paper's min-min the most optimistic on overlapped steps)
    assert lat["max"] <= lat["phase_max"] * 1.0001
    for m, l in lat.items():
        assert d.latency_cycles / l > 1.0, m


def test_schedule_counts_match_mask_workload():
    """Scheduled MACs == dense-within-resident-subsets accounting: at
    least the selected pairs, at most N² per head."""
    from repro.core import HwConfig, plan, simulate_schedule
    masks = structured_masks(5, n_heads=2, n=24, k=6)
    p = plan(masks)
    r = simulate_schedule(p.schedule, 32, HwConfig())
    n_heads, n, _ = masks.shape
    assert masks.sum() * 32 <= r.macs <= n_heads * n * n * 32
