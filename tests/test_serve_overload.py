"""Overload-resilient serving: the SLO degradation ladder (monotone
under pressure, hysteretic recovery, full-quality return — property-
tested standalone), page-integrity checksums (any flipped payload byte
detected before restore), quarantine + re-prefill recovery, the
deferred-admission backoff, sampled ("light") allocator audits, and
mid-serve checkpoint → kill → resume bitwise equality."""
import dataclasses
import sys
import pathlib

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).parent))
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402

from repro.configs.archs import SMOKE
from repro.core.paging import PageAllocator, PageIntegrityError
from repro.launch.faults import FaultPlan
from repro.launch.serve import QoSController, ServeKilled, serve


def _cfg(**kw):
    base = dict(topk_impl="bisect", sata_decode="on",
                sata_decode_block=8, sata_decode_replan=4,
                kv_cache_layout="paged", kv_pool_pages=6,
                sata_qos_ladder=True)
    base.update(kw)
    return dataclasses.replace(SMOKE["qwen3-4b"], **base)


_KW = dict(n_requests=4, batch_slots=2, gen_len=12, max_len=32,
           prompt_len=6)
_BASELINES = {}


def _baseline(**cfg_kw):
    key = tuple(sorted(cfg_kw.items()))
    if key not in _BASELINES:
        _BASELINES[key] = serve("qwen3-4b", cfg=_cfg(**cfg_kw), **_KW)
    return _BASELINES[key]


# ---------------------------------------------------------------------------
# QoS ladder controller (standalone — no model, no jax)
# ---------------------------------------------------------------------------

def test_rung_knob_table():
    """The documented rung → knob mapping, exactly."""
    q = QoSController(1, p0=8, iv0=2, clear_steps=4)
    expect = {0: (8, 2, False, False), 1: (4, 2, False, False),
              2: (4, 8, False, False), 3: (4, 8, True, False),
              4: (4, 8, True, True)}
    for rung, knobs in expect.items():
        q.rung[0] = rung
        assert q.knobs(0) == knobs, rung


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6), st.integers(1, 4))
def test_ladder_monotone_hysteretic_recovers(seed, clear_steps, n_slots):
    """Any pressure schedule: (1) a press never raises quality and a
    pressure step never recovers a rung; (2) two recoveries of one slot
    are >= clear_steps apart AND >= clear_steps after the last pressure
    (hysteresis — no flapping); (3) once pressure clears for good,
    every slot returns to full quality."""
    rng = np.random.default_rng(seed)
    qos = QoSController(n_slots, p0=8, iv0=2, clear_steps=clear_steps)
    active = list(range(n_slots))
    horizon = 40
    pressured = rng.random(horizon) < 0.4
    severity = rng.integers(1, 3, horizon)
    last_up = {}
    last_pressure = -10 ** 9
    for t in range(horizon):
        before = list(qos.rung)
        if pressured[t]:
            qos.press(active, int(severity[t]))
            assert all(qos.rung[i] >= before[i] for i in active)
            last_pressure = t
        ups = qos.tick(active, bool(pressured[t]))
        if pressured[t]:
            assert not ups
        for i in ups:
            assert t - last_pressure >= clear_steps
            if i in last_up:
                assert t - last_up[i] >= clear_steps
            last_up[i] = t
        assert all(0 <= r <= qos.MAX_RUNG for r in qos.rung)
    for _ in range(clear_steps * qos.MAX_RUNG):
        qos.tick(active, False)
    assert qos.rung == [0] * n_slots, "pressure cleared but quality didn't"


def test_admission_resets_rung():
    q = QoSController(2, p0=8, iv0=2, clear_steps=4)
    q.press([0, 1], 3)
    assert q.reset(0) and q.rung == [0, 3]
    assert not q.reset(0)                     # idempotent, reports no-op


# ---------------------------------------------------------------------------
# Page integrity: checksums over parked swap payloads
# ---------------------------------------------------------------------------

def _swapped_handle(seed):
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(8, 2, 4, 4, audit=True)
    assert alloc.ensure(0, 10)                # maps 3 pages

    def gather(phys):
        a = rng.standard_normal((len(phys), 4, 2)).astype(np.float32)
        return {"k": a, "v": (a + 1.0).astype(np.float32)}

    return alloc, alloc.swap_out(0, gather)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.0, 1.0))
def test_any_flipped_byte_detected(seed, frac):
    """Flip ANY single byte anywhere in a parked swap payload — the
    swap-in checksum gate must raise before any page restores; flip it
    back and the handle verifies clean again."""
    alloc, handle = _swapped_handle(seed)
    alloc.verify_handle(handle)               # pristine passes
    arrays = [a for _, pl in handle["chunks"] for _, a in sorted(pl.items())]
    total = sum(a.nbytes for a in arrays)
    target = min(int(frac * total), total - 1)
    off = 0
    for a in arrays:
        if target < off + a.nbytes:
            flat = a.view(np.uint8).reshape(-1)
            flat[target - off] ^= 0xFF
            break
        off += a.nbytes
    with pytest.raises(PageIntegrityError):
        alloc.verify_handle(handle)
    flat[target - off] ^= 0xFF                # undo → clean again
    alloc.verify_handle(handle)


def test_discard_handle_releases_state():
    alloc, handle = _swapped_handle(0)
    assert alloc.swapped == [handle]
    alloc.discard_handle(handle)
    assert alloc.swapped == []
    alloc.check_invariants()


# ---------------------------------------------------------------------------
# Serving: ladder vs spike, quarantine, light audit, checkpoint/resume
# ---------------------------------------------------------------------------

def test_ladder_absorbs_spike_no_requeues():
    """A spike schedule that forces >= 2 preemptions without the ladder
    completes EVERY request with zero requeues/timeouts with it; each
    request reports its degradation timeline and requests whose slots
    never degraded stay bitwise equal to the no-fault run."""
    base = _baseline()
    spikes = FaultPlan().load_spike(4, 2).slow_step(5)
    on = serve("qwen3-4b", cfg=_cfg(), faults=spikes, **_KW)
    off = serve("qwen3-4b", cfg=_cfg(sata_qos_ladder=False),
                faults=spikes, **_KW)
    assert off["page_occupancy"]["preemptions"] >= 2
    o = on["page_occupancy"]
    assert o["preemptions"] == 0 and o["requeue_preemptions"] == 0
    assert not on["timed_out"]
    assert sorted(on["outputs"]) == list(range(_KW["n_requests"]))
    assert all(len(v) == _KW["gen_len"] for v in on["outputs"].values())
    assert on["qos"]["rung_downs"] > 0 and on["qos"]["degraded_steps"] > 0
    assert set(on["degradation"]) == set(on["outputs"])
    degraded = [r for r, tl in on["degradation"].items() if tl]
    assert degraded, "the spike must land on some request's timeline"
    for r, tl in on["degradation"].items():
        if not tl:
            assert on["outputs"][r] == base["outputs"][r], r


def test_corrupt_page_quarantined_and_reprefilled():
    """A byte flipped in a PARKED handle: detected at the swap-in gate
    (never restored), quarantined, and the victim re-prefills to the
    same final outputs as the fault-free run."""
    base = _baseline()
    faults = (FaultPlan().preempt(4).defer_admission(4).defer_admission(5)
              .corrupt_page(5).defer_admission(6))
    out = serve("qwen3-4b", cfg=_cfg(), faults=faults, **_KW)
    o = out["page_occupancy"]
    assert o["corrupt_pages_injected"] == 1
    assert o["corrupt_pages_detected"] == 1
    assert o["swap_restores"] == 0, "corrupted payload must never restore"
    assert o["quarantined_pages"] > 0
    assert o["re_prefill_tokens"] > 0
    assert out["outputs"] == base["outputs"]


def test_light_audit_mode():
    """audit_pages='light' samples the full invariant audit and runs
    the cheap refcount-sum check otherwise — same outputs, nonzero
    counters for both modes."""
    base = _baseline()
    out = serve("qwen3-4b", cfg=_cfg(), audit_pages="light", **_KW)
    assert out["outputs"] == base["outputs"]
    assert out["page_occupancy"]["light_audits_run"] > 0
    assert out["page_occupancy"]["audits_run"] > 0


def test_checkpoint_kill_resume_bitwise(tmp_path):
    """Kill the loop mid-serve (after a checkpoint), resume from disk
    in fresh serve state: outputs bitwise equal to the uninterrupted
    run — allocator, trie, swap handles, queue, RNG and QoS rungs all
    ride the checkpoint."""
    base = _baseline()
    d = str(tmp_path / "ckpt")
    faults = FaultPlan().preempt(4).defer_admission(4).defer_admission(5)
    with pytest.raises(ServeKilled):
        serve("qwen3-4b", cfg=_cfg(), faults=faults, checkpoint_dir=d,
              checkpoint_every=5, kill_at_step=7, **_KW)
    out = serve("qwen3-4b", cfg=_cfg(), faults=faults, checkpoint_dir=d,
                checkpoint_every=5, resume=True, **_KW)
    assert out["checkpoint"]["resumed_at"] == 5
    assert out["outputs"] == base["outputs"]


def test_deferred_backoff_skips_and_completes():
    """Under a sustained squeeze the deferred head request skips its
    scheduled-out steps (bounded backoff) instead of re-checking every
    step — and still completes everything deterministically."""
    base = _baseline()
    faults = FaultPlan().pool_squeeze(2, 3).pool_restore(14)
    out = serve("qwen3-4b", cfg=_cfg(), faults=faults, **_KW)
    o = out["page_occupancy"]
    assert o["deferred_retries_skipped"] > 0
    assert o["deferred_claims"] > 0
    assert out["outputs"] == base["outputs"]


def test_seeded_overload_deterministic():
    a = FaultPlan.seeded_overload(7, steps=30)
    b = FaultPlan.seeded_overload(7, steps=30)
    assert a.describe() == b.describe()
    kinds = {k for evs in a._events.values() for k, _ in evs}
    assert "load_spike" in kinds
