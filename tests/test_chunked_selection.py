"""Chunked-score selection pipeline: threshold consistency between the
dense bisect and the chunked pass-1 (property tests over adversarial
inputs), ops-level and model-level parity of the chunked route vs dense
selection, plan-from-chunks / occupancy_bound invariants, and traced-HLO
proof that the chunked route never materializes a quadratic buffer."""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.blockmap import (block_occupancy, compact_kv_plan,
                                 occupancy_bound,
                                 occupancy_from_scores_chunked,
                                 resolve_sel_chunk)
from repro.kernels.ops import sata_attention
from repro.models.attention import (NEG_INF, _select_chunked,
                                    kth_largest_bisect, topk_mask_bisect)

QUAD = "{s}x{s}x(f32|bf16|f64|i1|i8|i32)"


def causal_adm(s):
    return jnp.tril(jnp.ones((s, s), dtype=bool))


def rand_qkv(key, bh, s, d):
    k1, k2, k3 = jax.random.split(key, 3)
    return (jax.random.normal(k1, (bh, s, d), jnp.float32),
            jax.random.normal(k2, (bh, s, d), jnp.float32),
            jax.random.normal(k3, (bh, s, d), jnp.float32))


def dense_bisect_route(q, k_, v, k_sel, *, q_block, k_block, causal=True,
                       interpret=True):
    """Reference pipeline: full (BH, S, S) scores → bisect mask →
    identity-plan exact kernel — the selection semantics the chunked
    route must reproduce without the quadratic buffers."""
    bh, s, d = q.shape
    scores = jnp.einsum("bqd,bkd->bqk", q, k_,
                        preferred_element_type=jnp.float32) / np.sqrt(d)
    adm = causal_adm(s) if causal else jnp.ones((s, s), dtype=bool)
    sel = topk_mask_bisect(jnp.where(adm[None], scores, NEG_INF), k_sel)
    sel = sel & adm[None]
    out, bm = sata_attention(q, k_, v, sel, q_block=q_block,
                             k_block=k_block, use_sata=False, exact=True,
                             interpret=interpret, schedule="compact")
    return out, bm, sel


# ---------------------------------------------------------------------------
# Threshold consistency: chunked pass-1 == dense bisect (property tests)
# ---------------------------------------------------------------------------

def chunked_threshold(scores, k, chunk):
    """The chunked pass-1 threshold on a precomputed score matrix:
    kth_largest_bisect applied per row-chunk (its reductions are
    row-local, so this must equal the full-matrix call bit-for-bit)."""
    parts = [kth_largest_bisect(scores[:, i:i + chunk], k)
             for i in range(0, scores.shape[1], chunk)]
    return jnp.concatenate(parts, axis=1)


def _assert_threshold_consistent(scores, k, chunk):
    full = kth_largest_bisect(scores, k)
    part = chunked_threshold(scores, k, chunk)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(part))
    m_full = topk_mask_bisect(scores, k)
    cnt_src = jnp.where(scores > NEG_INF / 2, scores,
                        -jnp.inf).astype(jnp.bfloat16)
    m_part = cnt_src >= part.astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(m_full), np.asarray(m_part))
    # superset guarantee: >= min(k, #valid) selected per row
    valid = np.asarray(scores > NEG_INF / 2)
    want = np.minimum(k, valid.sum(-1))
    got = np.asarray(m_full & valid).sum(-1)
    assert (got >= want).all(), (got, want)


@pytest.mark.parametrize("case", ["plateau", "masked_rows", "k_ge_s"])
def test_threshold_consistency_adversarial(case):
    rng = np.random.default_rng(17)
    n, k = 64, 12
    if case == "plateau":
        # bf16 tie plateaus: scores drawn from 3 distinct values
        sc = rng.choice(np.float32([0.5, 0.5009766, -1.0]), size=(2, n, n))
    elif case == "masked_rows":
        sc = rng.standard_normal((2, n, n)).astype(np.float32)
        sc[0, 5, :] = NEG_INF                       # fully-masked row
        sc[1, :, n // 2:] = NEG_INF                 # half the keys invalid
    else:
        sc = rng.standard_normal((2, n, n)).astype(np.float32)
        k = n + 7                                   # k >= S selects all
    _assert_threshold_consistent(jnp.asarray(sc), k, chunk=16)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 2 ** 31 - 1), k=st.integers(1, 40),
       chunk=st.sampled_from([4, 8, 16]), plateau=st.booleans(),
       n_dead_rows=st.integers(0, 3))
def test_threshold_consistency_property(seed, k, chunk, plateau,
                                        n_dead_rows):
    rng = np.random.default_rng(seed)
    n = 32
    if plateau:
        vals = rng.standard_normal(3).astype(np.float32)
        sc = rng.choice(vals, size=(2, n, n))
    else:
        sc = rng.standard_normal((2, n, n)).astype(np.float32)
    for _ in range(n_dead_rows):
        sc[rng.integers(2), rng.integers(n), :] = NEG_INF
    _assert_threshold_consistent(jnp.asarray(sc), k, chunk)


# ---------------------------------------------------------------------------
# Ops-level parity: chunked route vs dense-bisect route
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("structure", ["random", "cluster", "banded"])
def test_ops_chunked_matches_dense_selection(structure):
    """Same selected superset, same block map, same output — across
    score structures (clustered key groups, banded locality, random)."""
    bh, s, d, k_sel = 2, 128, 32, 24
    key = jax.random.PRNGKey(5)
    q, k_, v = rand_qkv(key, bh, s, d)
    if structure == "cluster":
        # shared centroids → shared per-cluster key sets in the scores
        cent = jax.random.normal(jax.random.PRNGKey(9), (4, d)) * 2.0
        assign = jax.random.randint(jax.random.PRNGKey(10), (s,), 0, 4)
        k_ = k_ * 0.3 + cent[assign][None]
    elif structure == "banded":
        pos = jnp.arange(s, dtype=jnp.float32)
        band = jnp.exp(-((pos[:, None] - pos[None, :]) / 12.0) ** 2)
        q = q + band[:, :d] if d <= s else q
    out_c, bm_c = sata_attention(q, k_, v, q_block=32, k_block=32,
                                 selection="chunked", topk_k=k_sel,
                                 causal=True, interpret=True, sel_chunk=64)
    out_d, bm_d, sel = dense_bisect_route(q, k_, v, k_sel,
                                          q_block=32, k_block=32)
    np.testing.assert_array_equal(np.asarray(bm_c), np.asarray(bm_d))
    np.testing.assert_array_equal(
        np.asarray(bm_c), np.asarray(block_occupancy(sel, 32, 32)))
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_d),
                               rtol=1e-5, atol=1e-5)


def test_ops_chunked_noncausal():
    bh, s, d = 2, 64, 32
    q, k_, v = rand_qkv(jax.random.PRNGKey(3), bh, s, d)
    out_c, bm_c = sata_attention(q, k_, v, q_block=32, k_block=32,
                                 selection="chunked", topk_k=16,
                                 causal=False, interpret=True)
    out_d, bm_d, _ = dense_bisect_route(q, k_, v, 16, q_block=32,
                                        k_block=32, causal=False)
    np.testing.assert_array_equal(np.asarray(bm_c), np.asarray(bm_d))
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_d),
                               rtol=1e-5, atol=1e-5)


def test_ops_chunked_block_mode_no_mask():
    """exact=False on the chunked route: block-mode kernel fed by the
    streamed occupancy map — dense math inside occupied tiles, but a
    causal request must still gate future keys (no leakage across the
    diagonal tiles), and the block map must match the exact route's."""
    from repro.kernels.ref import ref_block_attention
    bh, s, d = 2, 64, 32
    q, k_, v = rand_qkv(jax.random.PRNGKey(4), bh, s, d)
    out, bm = sata_attention(q, k_, v, q_block=32, k_block=32,
                             selection="chunked", topk_k=16, causal=True,
                             exact=False, interpret=True)
    _, bm_exact = sata_attention(q, k_, v, q_block=32, k_block=32,
                                 selection="chunked", topk_k=16,
                                 causal=True, exact=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(bm), np.asarray(bm_exact))
    adm = jnp.broadcast_to(causal_adm(s)[None], (bh, s, s))
    ref = ref_block_attention(q, k_, v, bm, mask=adm,
                              q_block=32, k_block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_ops_chunked_rejects_dense_schedule_and_missing_k():
    q, k_, v = rand_qkv(jax.random.PRNGKey(0), 1, 64, 32)
    with pytest.raises(ValueError, match="compact"):
        sata_attention(q, k_, v, selection="chunked", topk_k=8,
                       schedule="dense", q_block=32, k_block=32,
                       interpret=True)
    with pytest.raises(ValueError, match="topk_k"):
        sata_attention(q, k_, v, selection="chunked", q_block=32,
                       k_block=32, interpret=True)


def test_chunked_occupancy_restream_matches_fused():
    """occupancy_from_scores_chunked (pass-2 re-stream, used when the
    VJP hands precomputed thresholds in) == the fused pass-1 map."""
    bh, s, d = 2, 128, 32
    q, k_, _ = rand_qkv(jax.random.PRNGKey(8), bh, s, d)
    qp = jnp.arange(s, dtype=jnp.int32)
    thr, bm_fused = _select_chunked(q, k_, 24, q_pos=qp, k_pos=qp,
                                    causal=True, chunk=64,
                                    q_block=32, k_block=32)
    bm_re = occupancy_from_scores_chunked(q, k_, thr, q_block=32,
                                          k_block=32, causal=True,
                                          chunk=32)
    np.testing.assert_array_equal(np.asarray(bm_fused), np.asarray(bm_re))


# ---------------------------------------------------------------------------
# occupancy_bound / max_kv_blocks threading
# ---------------------------------------------------------------------------

def test_occupancy_bound_percentiles():
    counts = np.array([[1, 2, 3, 4], [4, 4, 8, 2]])
    assert occupancy_bound(counts) == 8                 # exact max
    assert occupancy_bound(counts, pct=50.0) == 4
    assert occupancy_bound(np.zeros((2, 3), np.int32)) == 1   # floor
    assert occupancy_bound(np.zeros((0,), np.int32)) == 1


def test_compact_plan_truncate_opt_in():
    """A sub-100-percentile occupancy_bound implies dropping tail
    blocks; on concrete maps that requires the explicit truncate=True
    (the default still raises), and counts come back clamped so each
    row keeps exactly its first pad_to occupied k-blocks."""
    bm = jnp.ones((1, 2, 4), dtype=bool)
    with pytest.raises(ValueError, match="truncate"):
        compact_kv_plan(bm, pad_to=2)
    idx, cnt = compact_kv_plan(bm, pad_to=2, truncate=True)
    assert idx.shape[-1] == 2
    np.testing.assert_array_equal(np.asarray(cnt), [[2, 2]])
    np.testing.assert_array_equal(np.asarray(idx), [[[0, 1], [0, 1]]])
    # empty-row padding after a truncated row must re-reference a tile
    # the truncated schedule still fetches, not a dropped one (fill is
    # derived from the clamped counts)
    bm2 = jnp.zeros((1, 2, 6), dtype=bool).at[0, 0, :].set(True)
    idx2, cnt2 = compact_kv_plan(bm2, pad_to=4, truncate=True)
    np.testing.assert_array_equal(np.asarray(cnt2), [[4, 0]])
    np.testing.assert_array_equal(np.asarray(idx2[0, 1]), [3, 3, 3, 3])


def test_occupancy_bound_rejects_tracer():
    with pytest.raises(TypeError, match="concrete"):
        jax.jit(lambda c: occupancy_bound(c))(jnp.ones((4,), jnp.int32))


def test_chunked_max_kv_blocks_threading():
    """A statically derived exact occupancy bound shrinks the plan's
    slot dim without changing the chunked route's output."""
    bh, s, d, k_sel = 2, 256, 32, 4
    q, k_, v = rand_qkv(jax.random.PRNGKey(11), bh, s, d)
    # locality-structured scores (queries select nearby keys) so each
    # q-block row's union of top-k sets concentrates in few k-blocks —
    # the regime where an occupancy bound actually shrinks the grid
    t = jnp.arange(s, dtype=jnp.float32)
    freqs = jnp.exp(-jnp.arange(d // 2) / 4.0) * 0.2
    feat = jnp.concatenate([jnp.sin(t[:, None] * freqs),
                            jnp.cos(t[:, None] * freqs)], axis=-1)
    q = 0.05 * q + 4.0 * feat[None]
    k_ = 0.05 * k_ + 4.0 * feat[None]
    out_full, bm = sata_attention(q, k_, v, q_block=32, k_block=32,
                                  selection="chunked", topk_k=k_sel,
                                  causal=True, interpret=True)
    _, counts = compact_kv_plan(bm)
    bound = occupancy_bound(counts)                     # concrete p100
    assert bound < bm.shape[-1]                         # grid does shrink
    out_b, _ = sata_attention(q, k_, v, q_block=32, k_block=32,
                              selection="chunked", topk_k=k_sel,
                              causal=True, interpret=True,
                              max_kv_blocks=bound)
    np.testing.assert_array_equal(np.asarray(out_full), np.asarray(out_b))


def test_resolve_sel_chunk():
    assert resolve_sel_chunk(None, 256, 32) == 32
    assert resolve_sel_chunk(1024, 256, 32) == 256
    assert resolve_sel_chunk(96, 256, 32) == 64   # 96→64: must divide 256
    assert resolve_sel_chunk(31, 256, 32) == 32


# ---------------------------------------------------------------------------
# Model-layer routing + training path
# ---------------------------------------------------------------------------

def _mk_cfg(**kw):
    from repro.models.config import ModelConfig
    base = dict(name="t", family="dense", n_layers=1, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                attention_variant="topk", topk_k=16, dtype="float32",
                sata_block=32, topk_impl="bisect")
    base.update(kw)
    return ModelConfig(**base)


def test_model_chunked_selection_parity_and_grads():
    """cfg.sata_selection='chunked' through the kernel route must match
    the _attend fallback (same bisect superset) in outputs AND grads —
    the chunked custom VJP recomputes from the threshold."""
    from repro.models.attention import attention_apply, attention_init
    cfg = _mk_cfg()
    params = attention_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 64), jnp.float32)
    import dataclasses
    ck = dataclasses.replace(cfg, use_sata_kernel=True,
                             sata_selection="chunked")
    base = attention_apply(params, cfg, x)
    kern = attention_apply(params, ck, x)
    np.testing.assert_allclose(np.asarray(base), np.asarray(kern),
                               rtol=1e-4, atol=1e-4)

    def loss(p, c):
        return (attention_apply(p, c, x) ** 2).sum()

    g_base = jax.grad(loss)(params, cfg)
    g_kern = jax.grad(loss)(params, ck)
    for name in g_base:
        np.testing.assert_allclose(np.asarray(g_base[name]),
                                   np.asarray(g_kern[name]),
                                   rtol=1e-3, atol=1e-4, err_msg=name)


def test_model_auto_selection_follows_bisect_decision():
    from repro.models.attention import _chunked_selection_on
    assert _chunked_selection_on(_mk_cfg(topk_impl="bisect"), 128)
    assert not _chunked_selection_on(_mk_cfg(topk_impl="sort"), 128)
    assert not _chunked_selection_on(_mk_cfg(topk_impl="auto"), 128)
    assert _chunked_selection_on(_mk_cfg(topk_impl="auto"), 8192)
    assert _chunked_selection_on(_mk_cfg(sata_selection="chunked",
                                         topk_impl="sort"), 128)
    assert not _chunked_selection_on(_mk_cfg(sata_selection="dense",
                                             topk_impl="bisect"), 128)
    # a requested dense-grid baseline must actually run the dense grid:
    # "auto" keeps dense selection, forced "chunked" is a config error
    assert not _chunked_selection_on(
        _mk_cfg(topk_impl="bisect", sata_schedule="dense"), 128)
    with pytest.raises(ValueError, match="compact"):
        _chunked_selection_on(_mk_cfg(sata_selection="chunked",
                                      sata_schedule="dense"), 128)


def test_truncating_max_kv_blocks_refuses_backward():
    """A truncating bound drops tiles only in the forward kernel; the
    reference recompute would differentiate the full selected set, so
    training through it must raise instead of silently biasing grads.
    Forward (the serving path) still works.  The loss-free "dense"
    overflow fallback (the default) is exempt: its forward never drops
    a selected tile, so value and gradient describe the same function
    and training through a bound is sound."""
    from repro.models.attention import attention_apply, attention_init
    cfg = _mk_cfg(use_sata_kernel=True, sata_selection="chunked",
                  sata_max_kv_blocks=2,          # < nkb = 128/32
                  sata_bound_fallback="truncate")
    params = attention_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 64), jnp.float32)
    assert jnp.isfinite(attention_apply(params, cfg, x)).all()
    with pytest.raises(NotImplementedError, match="truncating"):
        jax.grad(lambda p: (attention_apply(p, cfg, x) ** 2).sum())(params)
    cfg_d = _mk_cfg(use_sata_kernel=True, sata_selection="chunked",
                    sata_max_kv_blocks=2, sata_bound_fallback="dense")
    g = jax.grad(lambda p: (attention_apply(p, cfg_d, x) ** 2).sum())(params)
    assert all(bool(jnp.isfinite(leaf).all())
               for leaf in jax.tree_util.tree_leaves(g))


# ---------------------------------------------------------------------------
# The point of it all: no quadratic buffer in the traced computation
# ---------------------------------------------------------------------------

def _quad_pattern(s):
    return re.compile(QUAD.format(s=s))


@pytest.mark.parametrize("s", [2048])
def test_chunked_route_traces_no_quadratic_buffer(s):
    """Traced-HLO buffer inspection at S >= 2048: the chunked route's
    StableHLO contains NO (BH, S, S) tensor of any dtype; the dense
    route (same shapes) contains the fp32 score tensor — the quadratic
    HBM term this pipeline exists to kill."""
    bh, d = 1, 64

    def chunked(q, k_, v):
        return sata_attention(q, k_, v, q_block=128, k_block=128,
                              selection="chunked", topk_k=64, causal=True,
                              interpret=True, sel_chunk=128)[0]

    def dense(q, k_, v):
        return dense_bisect_route(q, k_, v, 64, q_block=128,
                                  k_block=128)[0]

    arg = jax.ShapeDtypeStruct((bh, s, d), jnp.float32)
    pat = _quad_pattern(s)
    assert not pat.search(jax.jit(chunked).lower(arg, arg, arg).as_text())
    assert pat.search(jax.jit(dense).lower(arg, arg, arg).as_text())


def test_chunked_training_path_traces_no_quadratic_buffer():
    """The backward graph too: the chunked VJP's residual is the O(S)
    threshold and the recompute is per-chunk checkpointed, so even
    jax.grad through the kernel route stays sub-quadratic at S=2048."""
    from repro.models.attention import (_sata_kernel_chunked_call,
                                        _select_chunked)
    bh, s, d, blk = 1, 2048, 64, 128
    arg = jax.ShapeDtypeStruct((bh, s, d), jnp.float32)

    def loss(qf, kf, vf):
        qp = jnp.arange(s, dtype=jnp.int32)
        thr, bm = _select_chunked(qf, kf, 64, q_pos=qp, k_pos=qp,
                                  causal=True, chunk=blk, q_block=blk,
                                  k_block=blk)
        out = _sata_kernel_chunked_call(qf, kf, vf, thr, bm, qp, qp,
                                        blk, True, blk, None)
        return (out ** 2).sum()

    txt = jax.jit(jax.grad(loss, argnums=(0, 1, 2))).lower(
        arg, arg, arg).as_text()
    assert not _quad_pattern(s).search(txt)
