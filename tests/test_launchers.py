"""End-to-end launcher tests: serving loop + dry-run cell on a local
mesh-sized problem (fast CPU versions of the production drivers)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import serve


def test_serve_completes_all_requests():
    out = serve("olmo-1b", smoke=True, n_requests=6, batch_slots=3,
                gen_len=5, max_len=32)
    assert len(out["outputs"]) == 6
    assert all(len(v) == 5 for v in out["outputs"].values())
    assert out["tokens_generated"] == 30


def test_serve_slot_reuse_beats_sequential():
    """Continuous-batching-lite: 6 requests on 3 slots finish within
    2×gen_len decode steps (slots are reclaimed)."""
    out = serve("olmo-1b", smoke=True, n_requests=6, batch_slots=3,
                gen_len=4, max_len=32)
    assert out["steps"] <= 2 * 4 + 1


def test_vlm_serving_with_context():
    out = serve("llama-3.2-vision-90b", smoke=True, n_requests=2,
                batch_slots=2, gen_len=3, max_len=16)
    assert all(len(v) == 3 for v in out["outputs"].values())
