"""Data-pipeline determinism + block-plan invariants (property tests)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.archs import SMOKE
from repro.core.blockmap import sata_block_plan
from repro.core.masks import SyntheticTrace, synthetic_masks
from repro.data.pipeline import SyntheticLM


def test_pipeline_deterministic_across_restart():
    cfg = SMOKE["olmo-1b"]
    p1 = SyntheticLM(cfg, batch=4, seq=16, seed=7)
    batches = [p1.next_batch() for _ in range(5)]
    state = p1.save_state()
    after = [p1.next_batch() for _ in range(3)]
    p2 = SyntheticLM(cfg, batch=4, seq=16, seed=7)
    p2.restore_state(state)
    resumed = [p2.next_batch() for _ in range(3)]
    for a, b in zip(after, resumed):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


def test_pipeline_labels_are_shifted_tokens():
    cfg = SMOKE["olmo-1b"]
    p = SyntheticLM(cfg, batch=2, seq=32, seed=0)
    b = p.next_batch()
    # labels[t] is the token following tokens[t] in the same stream:
    # tokens[1:] == labels[:-1]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6))
def test_property_block_plan_permutations(seed, clusters):
    """kv_order and q_order are valid permutations per head; the block
    map is exactly the occupancy of the doubly-permuted mask."""
    tr = SyntheticTrace(n_tokens=64, k=8, cluster_scale=3.0,
                        discrete_clusters=clusters, noise=0.4)
    masks = jnp.asarray(synthetic_masks(seed, tr, n_heads=2))
    kv, qo, bm = sata_block_plan(masks, 8, 8)
    for h in range(2):
        assert sorted(np.asarray(kv[h]).tolist()) == list(range(64))
        assert sorted(np.asarray(qo[h]).tolist()) == list(range(64))
    perm = jnp.take_along_axis(masks, kv[:, None, :], axis=2)
    perm = jnp.take_along_axis(perm, qo[:, :, None], axis=1)
    occ = perm.reshape(2, 8, 8, 8, 8).any(axis=(2, 4))
    np.testing.assert_array_equal(np.asarray(bm), np.asarray(occ))
