"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweep
against the pure-jnp oracle, block-skip semantics, SATA plan round-trip."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blockmap import block_skip_fraction, sata_block_plan
from repro.core.masks import SyntheticTrace, synthetic_masks, topk_mask
from repro.kernels.ops import sata_attention, sata_attention_reference
from repro.kernels.ref import ref_block_attention, ref_dense_attention
from repro.kernels.sata_attention import sata_block_attention

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def rand_qkv(key, bh, sq, sk, d, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (bh, sq, d), jnp.float32).astype(dtype)
    k_ = jax.random.normal(k2, (bh, sk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (bh, sk, d), jnp.float32).astype(dtype)
    return q, k_, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,sk,d,bq,bk", [
    (128, 128, 64, 32, 32),
    (256, 256, 64, 64, 64),
    (128, 256, 128, 32, 64),
    (256, 128, 64, 128, 32),
])
def test_kernel_matches_ref_dense_map(sq, sk, d, bq, bk, dtype):
    """All-ones block map == dense flash attention."""
    q, k_, v = rand_qkv(jax.random.PRNGKey(0), 3, sq, sk, d, dtype)
    bm = jnp.ones((3, sq // bq, sk // bk), dtype=bool)
    out = sata_block_attention(q, k_, v, bm, q_block=bq, k_block=bk,
                               interpret=True)
    ref = ref_dense_attention(q, k_, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("seed", [0, 1])
def test_kernel_matches_ref_sparse_map(dtype, seed):
    """Random block maps (incl. fully-empty query rows → zero output)."""
    bq = bk = 32
    sq = sk = 128
    q, k_, v = rand_qkv(jax.random.PRNGKey(seed), 2, sq, sk, 64, dtype)
    bm = jax.random.bernoulli(jax.random.PRNGKey(seed + 7),
                              0.5, (2, sq // bq, sk // bk))
    out = sata_block_attention(q, k_, v, bm, q_block=bq, k_block=bk,
                               interpret=True)
    ref = ref_block_attention(q, k_, v, bm, q_block=bq, k_block=bk)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_exact_mode_elementwise_mask(dtype):
    bq = bk = 32
    sq = sk = 128
    q, k_, v = rand_qkv(jax.random.PRNGKey(3), 2, sq, sk, 64, dtype)
    mask = jax.random.bernoulli(jax.random.PRNGKey(11), 0.3, (2, sq, sk))
    bm = mask.reshape(2, sq // bq, bq, sk // bk, bk).any(axis=(2, 4))
    out = sata_block_attention(q, k_, v, bm, mask=mask,
                               q_block=bq, k_block=bk, interpret=True)
    ref = ref_block_attention(q, k_, v, bm, mask=mask,
                              q_block=bq, k_block=bk)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_end_to_end_sata_equals_unsorted_topk():
    """The full pipeline (sort → permute → block-skip kernel → unpermute,
    exact mode) must be bit-comparable to plain top-k attention — SATA
    reorders execution, never the math (paper: 'without sacrificing
    model accuracy')."""
    bh, s, d = 3, 128, 64
    q, k_, v = rand_qkv(jax.random.PRNGKey(5), bh, s, s, jnp.float32, d) \
        if False else rand_qkv(jax.random.PRNGKey(5), bh, s, s, d, jnp.float32)
    scores = jnp.einsum("bqd,bkd->bqk", q, k_)
    mask = topk_mask(scores, 24)
    out, bm = sata_attention(q, k_, v, mask, q_block=16, k_block=16,
                             exact=True, interpret=True)
    ref = sata_attention_reference(q, k_, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_sata_sorting_increases_block_skip():
    """On locality-structured masks the SATA plan must skip strictly more
    blocks than the unsorted baseline (the paper's core claim, in MXU
    tile units)."""
    tr = SyntheticTrace(n_tokens=128, k=16, cluster_rank=2,
                        cluster_scale=2.5, noise=0.3)
    masks = jnp.asarray(synthetic_masks(0, tr, n_heads=4))
    _, _, bm_sata = sata_block_plan(masks, 16, 16)
    from repro.core.blockmap import identity_block_plan
    _, _, bm_id = identity_block_plan(masks, 16, 16)
    skip_sata = float(block_skip_fraction(bm_sata))
    skip_id = float(block_skip_fraction(bm_id))
    assert skip_sata > skip_id + 0.1, (skip_sata, skip_id)


def test_block_mode_covers_all_selected_pairs():
    """Block mode computes a superset of the selected pairs (never drops
    a selected (q, k) MAC)."""
    tr = SyntheticTrace(n_tokens=64, k=8, cluster_rank=2, cluster_scale=2.0,
                        noise=0.3)
    masks = jnp.asarray(synthetic_masks(1, tr, n_heads=2))
    kv_order, q_order, bm = sata_block_plan(masks, 8, 8)
    permuted = jnp.take_along_axis(masks, kv_order[:, None, :], axis=2)
    permuted = jnp.take_along_axis(permuted, q_order[:, :, None], axis=1)
    covered = jnp.repeat(jnp.repeat(bm, 8, axis=1), 8, axis=2)
    assert bool(jnp.all(~permuted | covered))


# ---------------------------------------------------------------------------
# Bisection top-k threshold (distributed-friendly decode path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,n", [(8, 1000), (64, 10000), (1, 128)])
def test_bisect_mask_selects_at_least_k(k, n):
    from repro.models.attention import topk_mask_bisect
    x = jnp.asarray(np.random.default_rng(0).standard_normal((3, 2, n)),
                    jnp.float32)
    m = topk_mask_bisect(x, k)
    counts = np.asarray(m.sum(-1))
    assert counts.min() >= k
    # fuzziness bounded: never more than ~1% + bf16-tie slack extra
    assert counts.max() <= k + max(8, n // 64)


def test_bisect_agrees_with_sort_on_clear_margins():
    """Where the k-th/k+1-th gap is large (> bf16 resolution), bisect and
    sort select identical sets."""
    from repro.models.attention import (kth_largest, topk_mask_bisect)
    rng = np.random.default_rng(1)
    x = np.sort(rng.standard_normal((2, 1, 512)).astype(np.float32))[..., ::-1]
    x[..., :16] += 10.0                    # clear top-16 margin
    x = jnp.asarray(np.ascontiguousarray(x))
    m = topk_mask_bisect(x, 16)
    ref = x >= kth_largest(x, 16)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(ref))


def test_bisect_respects_neg_inf_padding():
    from repro.models.attention import NEG_INF, topk_mask_bisect
    x = jnp.asarray(np.random.default_rng(2).standard_normal((1, 1, 256)),
                    jnp.float32)
    x = x.at[..., 200:].set(NEG_INF)       # masked tail (causal/invalid)
    m = topk_mask_bisect(x, 32)
    assert not bool(m[..., 200:].any())    # never selects masked keys
    assert int(m.sum()) >= 32
