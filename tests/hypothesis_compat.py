"""Import guard for the optional ``hypothesis`` dev dependency.

Test modules do ``from hypothesis_compat import given, settings, st``:
with hypothesis installed (``requirements-dev.txt`` / ``pip install
-e .[dev]``) the real decorators pass straight through; without it the
property-based tests are collected but *skipped* — the plain pytest
tests in the same files still run, and collection never errors.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Stub: strategy constructors become inert placeholders."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            def stub():
                pass
            stub.__name__ = getattr(fn, "__name__", "property_test")
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(stub)
        return deco
