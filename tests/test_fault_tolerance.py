"""Fault-tolerance integration tests: checkpoint/restart determinism,
failure injection + resume, elastic restore, async save atomicity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.launch.train import train


def _final_loss_curve(**kw):
    out = train("olmo-1b", smoke=True, steps=10, batch=4, seq=16,
                log_every=100, **kw)
    return out["losses"]


def test_restart_resumes_identically(tmp_path):
    """uninterrupted run == (run to failure → restart) bit-for-bit on the
    loss curve — checkpoint state + pipeline state both round-trip."""
    ref = _final_loss_curve(ckpt_dir=str(tmp_path / "ref"), ckpt_every=5)

    with pytest.raises(RuntimeError, match="injected failure"):
        _final_loss_curve(ckpt_dir=str(tmp_path / "ft"), ckpt_every=5,
                          fail_at=7)
    resumed = _final_loss_curve(ckpt_dir=str(tmp_path / "ft"), ckpt_every=5)
    # resumed run covers steps 5..9; compare the overlap
    np.testing.assert_allclose(ref[5:], resumed, rtol=1e-5)


def test_checkpoint_atomicity_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3, 3))}}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]           # keep-k GC
    # a stale tmp dir never shadows a finished checkpoint
    (tmp_path / "step_9.tmp").mkdir()
    assert mgr.latest_step() == 4


def test_async_save_immune_to_buffer_donation(tmp_path):
    """np.asarray of a CPU-backend jax array is a zero-copy view of the
    device buffer; the async save must snapshot an *owning* host copy
    before returning, or the train loop's next donated step overwrites
    the data mid-write (the timing-dependent restart-determinism flake:
    resumed runs read a corrupted checkpoint)."""
    mgr = CheckpointManager(str(tmp_path))
    x = jnp.arange(64.0)
    mgr.save(1, {"w": x}, blocking=False)
    # donate + overwrite the just-saved buffer while the write is in
    # flight — exactly what the train loop does on the next step
    jax.block_until_ready(
        jax.jit(lambda a: a * 0.0 - 1.0, donate_argnums=0)(x))
    mgr.wait()
    got = mgr.restore({"w": jnp.zeros((64,))})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(64.0))


def test_async_save_round_trip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state, blocking=False)
    mgr.wait()
    got = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(state["w"]))


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Save on a 1×1 mesh, restore with explicit shardings onto a 2-dev
    forced-host mesh (subprocess) — here we emulate by restoring with
    fresh NamedShardings on the same device set; leaf values must
    round-trip and shardings must apply."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    mgr.save(3, state)
    mesh = make_local_mesh()
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    got = mgr.restore(jax.tree.map(jnp.zeros_like, state), shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(state["w"]))
    assert got["w"].sharding == sh["w"]


def test_grad_compression_error_feedback():
    """int8 error-feedback compression: quantization error is carried,
    so the *sum* of dequantized grads over steps tracks the true sum."""
    from repro.optim.adamw import compress_int8
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((128,)), jnp.float32) * 1e-3
    err = jnp.zeros_like(g_true)
    total = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, err = compress_int8(g_true, err)
        total = total + deq
    np.testing.assert_allclose(np.asarray(total), np.asarray(g_true) * 50,
                               rtol=0.05, atol=1e-4)


def test_mid_serve_crash_restores_from_host_swap():
    """A crash_step fault mid-serve drops the device KV cache and the
    page allocator; every in-flight request full-swaps to host first
    and restores from its swap handle after the rebuild — final outputs
    bitwise equal to the fault-free run, with zero re-prefilled tokens
    and zero cold re-plans for the restored slots."""
    import dataclasses
    from repro.configs.archs import SMOKE
    from repro.launch.faults import FaultPlan
    from repro.launch.serve import serve
    cfg = dataclasses.replace(
        SMOKE["qwen3-4b"], topk_impl="bisect", sata_decode="on",
        sata_decode_block=8, sata_decode_replan=4,
        kv_cache_layout="paged", kv_pool_pages=8)
    kw = dict(n_requests=4, batch_slots=2, gen_len=12, max_len=32,
              prompt_len=6)
    base = serve("qwen3-4b", cfg=cfg, **kw)
    out = serve("qwen3-4b", cfg=cfg,
                faults=FaultPlan().crash_step(5), **kw)
    occ = out["page_occupancy"]
    assert occ["crashes"] == 1
    assert occ["host_swaps"] >= 1 and occ["swap_restores"] >= 1
    assert occ["re_prefill_tokens"] == 0
    assert occ["swap_cold_replans"] == 0
    assert occ["audits_run"] > 0
    assert out["outputs"] == base["outputs"]
    assert all(len(v) == 12 for v in out["outputs"].values())


def test_training_reduces_loss():
    """A 10-step curve's endpoint delta is noise-dominated (the old
    xfail); a 40-step run with 10-step head/tail averaging drops by
    ~0.1 nats on every seed tried — assert on the smoothed curve, and
    sanity-check the gradient signal the loop now reports is finite."""
    out = train("olmo-1b", smoke=True, steps=40, batch=4, seq=16,
                log_every=100)
    losses = np.asarray(out["losses"])
    gnorms = np.asarray(out["gnorms"])
    assert losses.shape == gnorms.shape == (40,)
    assert np.isfinite(gnorms).all() and (gnorms > 0).all()
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), (
        f"smoothed loss did not decrease: first10={np.mean(losses[:10]):.4f} "
        f"last10={np.mean(losses[-10:]):.4f}")
