"""Checkpointing: atomic, sharded, keep-last-k, async, elastic-restore.

Layout:  <dir>/step_<N>/ shard files (npz per leaf-group) + manifest.json
  * atomic: written to ``step_<N>.tmp`` then os.replace'd — a crash mid-
    save never corrupts the latest checkpoint.
  * keep-k GC after every successful save.
  * async: the device→host transfer happens synchronously (cheap), the
    file write runs on a background thread so the train loop continues.
  * elastic: checkpoints store the *logical* tree; ``restore`` accepts
    any target shardings and device_puts leaves onto the (possibly
    different-size) live mesh — restarts survive topology changes.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, extra: Optional[Dict] = None,
             blocking: bool = True,
             meta_blob: Optional[bytes] = None) -> None:
        """``meta_blob``: opaque host-side bytes (e.g. a pickled serve
        control-state) written atomically alongside the leaves as
        ``meta.bin`` — read back with :meth:`load_meta`."""
        self.wait()                                   # one in flight max
        leaves, treedef = _flatten(state)
        # device → host snapshot NOW, as an owning copy: np.asarray of a
        # CPU-backend jax array is a zero-copy view of the device buffer,
        # and the training loop donates those buffers to the next jitted
        # step — an async _write still holding views would serialize
        # whatever XLA reused them for (nondeterministic resume).
        host_leaves = [np.array(l) for l in leaves]
        tdef_repr = jax.tree_util.tree_structure(state)

        def _write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "leaves.npz",
                     **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
            manifest = {"step": step, "n_leaves": len(host_leaves),
                        "treedef": str(tdef_repr),
                        "extra": extra or {}}
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if meta_blob is not None:
                (tmp / "meta.bin").write_bytes(meta_blob)
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like``.  ``shardings`` (same
        tree) re-shards every leaf onto the live mesh — elastic restore."""
        self.wait()
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint found"
        path = self.dir / f"step_{step}"
        data = np.load(path / "leaves.npz")
        leaves, treedef = _flatten(like)
        assert len(leaves) == len(data.files), \
            f"leaf count mismatch: {len(leaves)} vs {len(data.files)}"
        new_leaves = []
        shard_leaves = (_flatten(shardings)[0] if shardings is not None
                        else [None] * len(leaves))
        for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
            arr = data[f"leaf_{i}"]
            arr = arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr
            if sh is not None:
                new_leaves.append(jax.device_put(arr, sh))
            else:
                new_leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    def manifest(self, step: Optional[int] = None) -> Dict:
        step = self.latest_step() if step is None else step
        return json.loads(
            (self.dir / f"step_{step}" / "manifest.json").read_text())

    def load_meta(self, step: Optional[int] = None) -> bytes:
        """The ``meta_blob`` bytes saved with this step (see
        :meth:`save`)."""
        self.wait()
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint found"
        return (self.dir / f"step_{step}" / "meta.bin").read_bytes()
