"""Training/serving step builders (pjit-ready pure functions)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import decode as dec
from repro.models import model as mdl
from repro.models.config import ModelConfig
from repro.optim.adamw import OptConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt: OptConfig,
                    micro_steps: int = 1):
    """→ train_step(state, batch) -> (state, metrics).

    ``micro_steps > 1`` scans gradient-accumulation microbatches; XLA
    overlaps each microbatch's gradient reduce-scatter with the next
    microbatch's compute (the standard comm/compute-overlap trick).
    """
    grad_fn = jax.value_and_grad(
        lambda p, b: mdl.loss_fn(p, cfg, b), has_aux=True)

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        params = state["params"]
        if micro_steps == 1:
            (loss, parts), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((micro_steps, x.shape[0] // micro_steps)
                                    + x.shape[1:]), batch)

            def acc(carry, mb):
                (loss_a, grads_a) = carry
                (l, _), g = grad_fn(params, mb)
                return (loss_a + l, jax.tree.map(jnp.add, grads_a, g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), zeros), micro)
            loss = loss / micro_steps
            grads = jax.tree.map(lambda g: g / micro_steps, grads)
            parts = {"nll": loss, "aux": jnp.zeros(())}

        err = state.get("err")
        new_params, opt_state, err, om = adamw_update(
            opt, params, grads, state["opt"], err)
        new_state = {"params": new_params, "opt": opt_state}
        if err is not None:
            new_state["err"] = err
        metrics = {"loss": loss, **parts, **om}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """Forward-only (inference-prefill shapes): logits for a full batch."""
    def prefill_step(params, batch):
        logits, _ = mdl.forward(params, cfg, batch)
        # return last-position logits only (what serving samples from)
        return logits[:, -1, :]
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One-token decode against a KV cache (decode_*/long_* shapes)."""
    def serve_step(params, cache, tokens, pos):
        return dec.serve_step(params, cfg, cache, tokens, pos)
    return serve_step


def init_train_state(key, cfg: ModelConfig, opt: OptConfig) -> Dict[str, Any]:
    from repro.optim.adamw import init_opt_state
    params = mdl.init_params(key, cfg)
    state = {"params": params, "opt": init_opt_state(params)}
    if opt.compress_grads:
        state["err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state
