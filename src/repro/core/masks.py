"""Top-K selective attention masks (the SATA workload).

The input to SATA is the TopK index set of Keys relevant to each Query
(paper Sec. III-A).  This module builds those masks — both from real
attention scores (``topk_mask``) and from synthetic, locality-structured
score generators used to reproduce the paper's workload traces
(``synthetic_scores``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def topk_mask(scores: jax.Array, k: int) -> jax.Array:
    """Boolean selection mask of the top-``k`` keys per query row.

    scores: (..., n_q, n_k) attention logits.  Returns bool (..., n_q, n_k)
    with exactly ``k`` True entries per row (ties broken by key index,
    matching ``jax.lax.top_k`` semantics).
    """
    n_k = scores.shape[-1]
    if k >= n_k:
        return jnp.ones(scores.shape, dtype=bool)
    _, idx = jax.lax.top_k(scores, k)                      # (..., n_q, k)
    mask = jnp.zeros(scores.shape, dtype=bool)
    mask = jnp.put_along_axis(mask, idx, True, axis=-1, inplace=False)
    return mask


def apply_selective_mask(scores: jax.Array, mask: jax.Array,
                         neg: float = -1e30) -> jax.Array:
    """Mask non-selected logits to ``neg`` (pre-softmax)."""
    return jnp.where(mask, scores, jnp.asarray(neg, scores.dtype))


@dataclasses.dataclass(frozen=True)
class SyntheticTrace:
    """Generator spec for locality-structured selective masks.

    Real selective-attention masks are not i.i.d.: queries cluster around
    shared salient keys (CLS-like tokens, local windows).  We model scores
    as ``low-rank cluster structure + distance band + noise`` and take
    top-k.  ``cluster_rank``/``band_width``/``noise`` steer how sortable
    the resulting mask is, calibrated per workload in configs/workloads.py
    to match the paper's Tab. I post-schedule statistics.
    """
    n_tokens: int
    k: int
    cluster_rank: int = 4
    cluster_scale: float = 1.0
    band_width: float = 0.0          # 0 disables the locality band
    band_scale: float = 1.0
    block_quant: int = 0             # >0: quantize positions to blocks
                                     # (window/group attention, DRSformer-like)
    discrete_clusters: int = 0       # >0: queries share per-cluster key
                                     # sets (object-region attention) —
                                     # raster order is uninformative, the
                                     # regime SATA sorting targets
    noise: float = 0.35
    causal: bool = False


def synthetic_scores(rng: np.ndarray | jax.Array, trace: SyntheticTrace,
                     n_heads: int) -> jax.Array:
    """(n_heads, N, N) synthetic attention scores for ``trace``."""
    n = trace.n_tokens
    k_q, k_k, k_n = jax.random.split(jnp.asarray(rng, dtype=jnp.uint32)
                                     if not isinstance(rng, jax.Array) else rng, 3)
    if trace.discrete_clusters > 0:
        c = trace.discrete_clusters
        q_cl = jax.random.randint(k_q, (n_heads, n), 0, c)     # query→cluster
        k_cl = jax.random.randint(k_k, (n_heads, n), 0, c)     # key→cluster
        same = (q_cl[:, :, None] == k_cl[:, None, :]).astype(jnp.float32)
        scores = trace.cluster_scale * same
    else:
        qf = jax.random.normal(k_q, (n_heads, n, trace.cluster_rank))
        kf = jax.random.normal(k_k, (n_heads, n, trace.cluster_rank))
        scores = trace.cluster_scale * jnp.einsum("hqr,hkr->hqk", qf, kf)
        scores = scores / np.sqrt(trace.cluster_rank)
    if trace.band_width > 0:
        pos = jnp.arange(n)
        if trace.block_quant > 0:
            pos = (pos // trace.block_quant) * trace.block_quant
        dist = jnp.abs(pos[:, None] - pos[None, :]).astype(jnp.float32)
        scores = scores + trace.band_scale * jnp.exp(
            -(dist / trace.band_width) ** 2)[None]
    scores = scores + trace.noise * jax.random.normal(k_n, (n_heads, n, n))
    if trace.causal:
        causal = jnp.tril(jnp.ones((n, n), bool))
        scores = jnp.where(causal[None], scores, -1e30)
    return scores


def synthetic_masks(seed: int, trace: SyntheticTrace, n_heads: int) -> np.ndarray:
    """(n_heads, N, N) boolean selective masks for a synthetic workload."""
    key = jax.random.PRNGKey(seed)
    scores = synthetic_scores(key, trace, n_heads)
    return np.asarray(topk_mask(scores, trace.k))
