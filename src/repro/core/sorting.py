"""Algo 1 — Intra-head mask sorting and query classification.

Given the binary selective mask ``QK ∈ {0,1}^{N_q × N_k}`` (rows = queries,
columns = keys), greedily order keys so columns with similar access
patterns become adjacent, then classify queries as HEAD / TAIL / GLOB
against a "heavy size" ``S_h``.

Two equivalent sorters are provided:

* ``sort_keys_direct``   — the textbook form of Algo 1 (Eq. 1): maintain a
  cumulative ``dummy`` vector (sum of sorted columns) and pick
  ``argmax(dummy · QK[:, i])`` among unsorted keys.
* ``sort_keys_psum``     — the paper's hardware form (Eq. 2): maintain
  per-key partial-sum registers incremented by the binary dot product
  with the most recently sorted column.  Identical output by construction
  (``Psum[i] == dummy·QK[:,i]`` telescopes); a property test asserts it.

Both reduce to a greedy traversal of the column Gram matrix
``G = QKᵀ·QK`` — precomputing G is the batched/JAX-friendly formulation
(``sort_keys_jax``) used in-graph for the block-sparse kernel planner.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class QType(enum.IntEnum):
    HEAD = 0
    TAIL = 1
    GLOB = 2


class HeadType(enum.IntEnum):
    HEAD = 0
    TAIL = 1
    GLOB = 2          # head failed to escape GLOB state


@dataclasses.dataclass(frozen=True)
class SortResult:
    kid: np.ndarray           # (N_k,) sorted key order (original key indices)
    qtypes: np.ndarray        # (N_q,) QType per query
    head_type: HeadType
    s_h: int                  # post-schedule heavy size
    n_decrements: int         # how many times S_h -= 1 fired (Tab. I stat)


# ---------------------------------------------------------------------------
# Sorting (Algo 1, lines 4-12)
# ---------------------------------------------------------------------------

def sort_keys_direct(mask: np.ndarray, seed: int = 0) -> np.ndarray:
    """Greedy key ordering via the cumulative ``dummy`` vector (Eq. 1)."""
    mask = np.asarray(mask, dtype=np.int64)
    n_k = mask.shape[1]
    order = np.empty(n_k, dtype=np.int64)
    sorted_set = np.zeros(n_k, dtype=bool)
    kid = seed % n_k
    dummy = mask[:, kid].copy()
    order[0] = kid
    sorted_set[kid] = True
    for step in range(1, n_k):
        dist = dummy @ mask                      # (N_k,) Eq. 1
        dist[sorted_set] = -1
        kid = int(np.argmax(dist))               # ties → lowest index
        order[step] = kid
        sorted_set[kid] = True
        dummy += mask[:, kid]
    return order


def sort_keys_psum(mask: np.ndarray, seed: int = 0) -> np.ndarray:
    """Greedy key ordering via Psum registers (Eq. 2) — hardware form."""
    mask = np.asarray(mask, dtype=np.int64)
    n_k = mask.shape[1]
    order = np.empty(n_k, dtype=np.int64)
    sorted_set = np.zeros(n_k, dtype=bool)
    psum = np.zeros(n_k, dtype=np.int64)
    kid = seed % n_k
    order[0] = kid
    sorted_set[kid] = True
    for step in range(1, n_k):
        # Psum-Reg[i] += QK[:, i]ᵀ · QK[:, kid]   for unsorted i (Eq. 2)
        psum += mask.T @ mask[:, kid]
        masked = np.where(sorted_set, -1, psum)
        kid = int(np.argmax(masked))
        order[step] = kid
        sorted_set[kid] = True
    return order


def sort_keys_jax(mask: jax.Array, seed: int = 0) -> jax.Array:
    """Batched in-graph sorter.  mask: (..., N_q, N_k) bool → (..., N_k) i32.

    Uses the Gram-matrix formulation: ``G = maskᵀ·mask`` then a scan whose
    carry is the Psum register file.  O(N²) per step after the one-off
    O(N_q·N_k²) Gram matmul (an MXU-friendly contraction).
    """
    m = mask.astype(jnp.float32)
    gram = jnp.einsum("...qi,...qj->...ij", m, m)          # (..., N_k, N_k)
    n_k = mask.shape[-1]
    batch_shape = mask.shape[:-2]
    gram2 = gram.reshape((-1, n_k, n_k))

    def one_head(g):
        def body(carry, _):
            psum, in_set, last = carry
            psum = psum + g[last]
            scores = jnp.where(in_set, -1.0, psum)
            nxt = jnp.argmax(scores).astype(jnp.int32)
            in_set = in_set.at[nxt].set(True)
            return (psum, in_set, nxt), nxt

        start = jnp.asarray(seed % n_k, jnp.int32)
        in0 = jnp.zeros((n_k,), bool).at[start].set(True)
        carry0 = (jnp.zeros((n_k,), jnp.float32), in0, start)
        _, rest = jax.lax.scan(body, carry0, None, length=n_k - 1)
        return jnp.concatenate([start[None], rest])

    order = jax.vmap(one_head)(gram2)
    return order.reshape(batch_shape + (n_k,))


# ---------------------------------------------------------------------------
# Query classification (Algo 1, lines 14-27)
# ---------------------------------------------------------------------------

def classify_queries(sorted_mask: np.ndarray, s_h: int) -> np.ndarray:
    """QType per query given a key-sorted mask and heavy size ``s_h``.

    * HEAD — touches none of the *last*  ``s_h`` sorted keys.
    * TAIL — touches none of the *first* ``s_h`` sorted keys.
    * GLOB — touches both ends.
    A query qualifying as both (touches neither end) is assigned HEAD,
    consistent with the paper's tie-to-HEAD rule.
    """
    n_k = sorted_mask.shape[1]
    s_h = int(min(s_h, n_k // 2))
    first = sorted_mask[:, :s_h].any(axis=1)
    last = sorted_mask[:, n_k - s_h:].any(axis=1)
    qt = np.full(sorted_mask.shape[0], QType.GLOB, dtype=np.int64)
    qt[~last] = QType.HEAD
    qt[last & ~first] = QType.TAIL
    return qt


def classify_with_escape(
    sorted_mask: np.ndarray,
    theta: Optional[int] = None,
    s_h0: Optional[int] = None,
) -> Tuple[np.ndarray, HeadType, int, int]:
    """The GLOB-escape loop (Algo 1 lines 14-27).

    Start at ``S_h = N/2`` and decrement while #GLOB queries exceeds θ
    (default N/2, the paper's setting).  Returns (qtypes, head_type,
    final s_h, n_decrements).
    """
    n_q, n_k = sorted_mask.shape
    s_h = n_k // 2 if s_h0 is None else int(s_h0)
    theta = n_q // 2 if theta is None else int(theta)
    n_dec = 0
    while True:
        qt = classify_queries(sorted_mask, s_h)
        n_glob = int((qt == QType.GLOB).sum())
        if n_glob > theta and s_h > 0:
            s_h -= 1
            n_dec += 1
            continue
        break
    if s_h == 0:
        # Degenerate: no locality exploitable — head stays GLOB.
        return qt, HeadType.GLOB, s_h, n_dec
    n_head = int((qt == QType.HEAD).sum())
    n_tail = int((qt == QType.TAIL).sum())
    ht = HeadType.HEAD if n_head >= n_tail else HeadType.TAIL   # tie → HEAD
    return qt, ht, s_h, n_dec


def sort_and_classify(mask: np.ndarray, seed: int = 0,
                      theta: Optional[int] = None,
                      use_psum: bool = True) -> SortResult:
    """Full Algo 1 for one head: sort keys, classify queries, escape GLOB."""
    mask = np.asarray(mask, dtype=bool)
    kid = (sort_keys_psum if use_psum else sort_keys_direct)(mask, seed)
    sorted_mask = mask[:, kid]
    qt, ht, s_h, n_dec = classify_with_escape(sorted_mask, theta)
    return SortResult(kid=kid, qtypes=qt, head_type=ht, s_h=s_h,
                      n_decrements=n_dec)


def locality_score(sorted_mask: np.ndarray) -> float:
    """Mean adjacent-column similarity — the quantity greedy sorting
    maximizes stepwise; used by tests to check sorted ≥ unsorted."""
    m = np.asarray(sorted_mask, dtype=np.float64)
    sims = (m[:, :-1] * m[:, 1:]).sum(axis=0)
    return float(sims.mean())
