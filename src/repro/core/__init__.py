"""SATA core — the paper's primary contribution.

Sorting (Algo 1), FSM scheduling (Algo 2), tiling + zero-skip
(Sec. III-D), the CIM estimation framework (Sec. IV), and the TPU-native
block-sparse execution planner derived from them.
"""
from repro.core.blockmap import (block_occupancy, block_skip_fraction,
                                 compact_kv_plan, identity_block_plan,
                                 sata_block_plan)
from repro.core.masks import (SyntheticTrace, apply_selective_mask,
                              synthetic_masks, synthetic_scores, topk_mask)
from repro.core.sata import SataPlan, SataStats, plan, stats_from_results
from repro.core.scheduling import (Schedule, Step, build_schedule,
                                   coverage_ok, schedule_heads)
from repro.core.simulator import (HwConfig, SimReport, scheduler_cost,
                                  simulate_dense, simulate_gated,
                                  simulate_schedule, simulate_tiled_sata)
from repro.core.sorting import (HeadType, QType, SortResult,
                                classify_queries, classify_with_escape,
                                locality_score, sort_and_classify,
                                sort_keys_direct, sort_keys_jax,
                                sort_keys_psum)
from repro.core.tiling import TiledPlan, Tile, plan_tiled, tiled_schedule
