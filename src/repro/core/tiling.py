"""Sec. III-D — tiling + zero-skip for long sequences.

The N×N selective mask is partitioned into ``S_f × S_f`` sub-blocks;
each non-empty tile is treated as a *sub-head*: all-zero rows/columns
inside the tile are skipped (zero-skip), the remaining local mask is
sorted/classified per Algo 1, and the resulting sub-heads enter the
Algo-2 FSM schedule.

Tile execution order is **Q-fold-major**: all tiles sharing a Q-fold run
consecutively, so the fold's queries are written into the stationary
array once and *stay resident* while the fold's K-tiles stream past
("Sorting would be conducted across Q-folds while fold-wise Ks are
reused", Sec. III-D — keys are re-streamed from the on-chip fold buffer,
queries are written once per fold).  The simulator charges query array
writes only on first touch within a fold group and key DRAM energy only
on the first stream of each key.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.scheduling import Schedule, build_schedule
from repro.core.sorting import SortResult, sort_and_classify


@dataclasses.dataclass(frozen=True)
class Tile:
    head: int                 # original head index
    q_fold: int
    k_fold: int
    q_idx: np.ndarray         # global query indices kept after zero-skip
    k_idx: np.ndarray         # global key indices kept after zero-skip
    mask: np.ndarray          # local (len(q_idx), len(k_idx)) mask
    result: SortResult        # Algo-1 result in local coordinates


@dataclasses.dataclass(frozen=True)
class TiledPlan:
    tiles: Tuple[Tile, ...]
    s_f: int
    n_tiles_total: int
    n_tiles_skipped: int      # all-zero tiles elided entirely
    n_rows_skipped: int       # zero-skipped query rows across kept tiles
    n_cols_skipped: int       # zero-skipped key columns across kept tiles

    @property
    def zero_skip_fraction(self) -> float:
        """Fraction of tile rows+cols elided by zero-skip + empty tiles."""
        total_rc = 2 * self.n_tiles_total * self.s_f
        skipped = (self.n_rows_skipped + self.n_cols_skipped
                   + 2 * self.n_tiles_skipped * self.s_f)
        return skipped / max(total_rc, 1)


def plan_tiled(masks: np.ndarray, s_f: int, seed: int = 0,
               theta_frac: float = 0.5) -> TiledPlan:
    """Tile every head's mask into S_f×S_f sub-heads (K-fold-major order).

    masks: (n_heads, N_q, N_k) bool.
    """
    masks = np.asarray(masks, dtype=bool)
    n_heads, n_q, n_k = masks.shape
    qf = -(-n_q // s_f)
    kf = -(-n_k // s_f)
    tiles: List[Tile] = []
    n_skipped = rows_skipped = cols_skipped = 0
    for h in range(n_heads):
        for q_fold in range(qf):              # Q-fold-major: queries resident
            for k_fold in range(kf):
                q0, q1 = q_fold * s_f, min((q_fold + 1) * s_f, n_q)
                k0, k1 = k_fold * s_f, min((k_fold + 1) * s_f, n_k)
                sub = masks[h, q0:q1, k0:k1]
                if not sub.any():
                    n_skipped += 1
                    continue
                keep_q = sub.any(axis=1)       # zero-skip rows
                keep_k = sub.any(axis=0)       # zero-skip cols
                rows_skipped += int((~keep_q).sum())
                cols_skipped += int((~keep_k).sum())
                local = sub[keep_q][:, keep_k]
                theta = max(1, int(theta_frac * local.shape[0]))
                res = sort_and_classify(local, seed=seed, theta=theta)
                tiles.append(Tile(
                    head=h, q_fold=q_fold, k_fold=k_fold,
                    q_idx=np.arange(q0, q1)[keep_q],
                    k_idx=np.arange(k0, k1)[keep_k],
                    mask=local, result=res))
    return TiledPlan(tiles=tuple(tiles), s_f=s_f,
                     n_tiles_total=n_heads * qf * kf,
                     n_tiles_skipped=n_skipped,
                     n_rows_skipped=rows_skipped,
                     n_cols_skipped=cols_skipped)


def tiled_schedule(plan: TiledPlan) -> Tuple[Schedule, List[np.ndarray]]:
    """Algo-2 FSM schedule over the sub-heads of a tiled plan.

    Returns the schedule plus the local masks (sub-head order) so that
    coverage invariants and the simulator can resolve operands.
    """
    results = [t.result for t in plan.tiles]
    local_masks = [t.mask for t in plan.tiles]
    sched = build_schedule(results, masks=local_masks, skip_empty_keys=False,
                           group_of=fold_group_ids(plan))
    return sched, local_masks


def fold_group_ids(plan: TiledPlan) -> np.ndarray:
    """(n_subheads,) group id — consecutive sub-heads sharing (head, q_fold).

    Queries loaded within one group stay resident in the stationary array
    until the group ends; re-loads inside the group are free.
    """
    ids, cur, last = [], -1, None
    for t in plan.tiles:
        key = (t.head, t.q_fold)
        if key != last:
            cur += 1
            last = key
        ids.append(cur)
    return np.asarray(ids, dtype=np.int64)
