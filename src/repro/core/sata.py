"""End-to-end SATA planner: mask → sort → classify → schedule → stats.

This is the paper's full pipeline for one attention layer, plus the
post-schedule statistics reported in Tab. I (GlobQ%, average heavy size,
average S_h-decrement count, GLOB-head fraction).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduling import Schedule, build_schedule, schedule_heads
from repro.core.sorting import HeadType, QType, SortResult
from repro.core.tiling import TiledPlan, plan_tiled


@dataclasses.dataclass(frozen=True)
class SataStats:
    """Tab.-I style post-schedule statistics."""
    glob_q_frac: float            # GlobQ%
    avg_s_h_frac: float           # avg S_h / N (or / S_f when tiled)
    avg_n_decrements: float       # avg #(S_h -= 1)
    glob_head_frac: float         # fraction of (sub)heads stuck GLOB
    n_heads: int
    n_tokens: int


def stats_from_results(results: Sequence[SortResult],
                       n_ref: Optional[int] = None) -> SataStats:
    if not results:
        return SataStats(0.0, 0.0, 0.0, 0.0, 0, 0)
    n_glob_q = sum(int((r.qtypes == QType.GLOB).sum()) for r in results)
    n_q = sum(len(r.qtypes) for r in results)
    # Tab. I reports S_h relative to the ORIGINAL sequence length N,
    # also for tiled workloads (e.g. 0.053N with S_f = 0.11N).
    fracs = [r.s_h / max(n_ref or len(r.kid), 1) for r in results]
    decs = [r.n_decrements for r in results]
    globs = sum(1 for r in results if r.head_type == HeadType.GLOB)
    return SataStats(
        glob_q_frac=n_glob_q / max(n_q, 1),
        avg_s_h_frac=float(np.mean(fracs)),
        avg_n_decrements=float(np.mean(decs)),
        glob_head_frac=globs / len(results),
        n_heads=len(results),
        n_tokens=len(results[0].kid))


@dataclasses.dataclass(frozen=True)
class SataPlan:
    """A complete executable plan for one multi-head selective layer."""
    schedule: Schedule
    results: Tuple[SortResult, ...]
    stats: SataStats
    tiled: Optional[TiledPlan] = None


def plan(masks: np.ndarray, s_f: Optional[int] = None, seed: int = 0,
         theta: Optional[int] = None) -> SataPlan:
    """Build the SATA plan for (n_heads, N, N) selective masks.

    ``s_f``: tile size; ``None`` or ``>= N`` disables tiling (TTST-style
    whole-head sorting).
    """
    masks = np.asarray(masks, dtype=bool)
    n = masks.shape[-1]
    if s_f is not None and s_f < n:
        tp = plan_tiled(masks, s_f, seed=seed)
        from repro.core.tiling import tiled_schedule
        sched, _ = tiled_schedule(tp)
        stats = stats_from_results([t.result for t in tp.tiles], n_ref=n)
        return SataPlan(schedule=sched,
                        results=tuple(t.result for t in tp.tiles),
                        stats=stats, tiled=tp)
    sched, results = schedule_heads(masks, seed=seed, theta=theta)
    return SataPlan(schedule=sched, results=tuple(results),
                    stats=stats_from_results(results), tiled=None)
