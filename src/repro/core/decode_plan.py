"""Decode-path SATA: incremental per-slot KV-block plan.

Prefill's chunked pipeline (``core/selection.py``) streams the full
``(Sq, Sk)`` score surface once; decode cannot afford even one row of it
per generated token — serving cost must scale with the *selected*
blocks, not the prefix.  This module maintains, per batch slot and KV
head, a persistent plan over the KV cache:

  k_min / k_max  (B, KV, nkb, D) — elementwise key bounds per k-block,
                 updated **incrementally** as the cache grows (a block's
                 bounds only ever absorb the tokens appended to it, and
                 completed blocks never change).  Two storage backends
                 (``summary=`` on init):

                 * ``"fp32"`` (default): exact bounds.  min/max is
                   associative, so the incrementally-maintained
                   summaries are *bit-identical* to recomputing them
                   from the cache — the property
                   ``summaries_from_cache`` pins.
                 * ``"int8"``: quantized codes plus per-block fp32
                   ``k_scale`` / ``k_zero`` (B, KV, nkb) — ~4× less
                   summary read traffic per ranking pass.  Rounding is
                   **conservative**: the dequantized bounds always
                   CONTAIN the exact fp32 bounds (absorb = dequantize ∪
                   new key, requantize outward — containment telescopes
                   by induction), so the Quest upper bound ranked from
                   them never under-estimates a block.  Quantized
                   summaries only *rank*; the exact token threshold
                   still runs over the planned blocks' full-precision
                   keys, and block selection stays a superset-safe
                   heuristic exactly as in the fp32 incremental path.
  kv_indices     (B, KV, P) int32 — ascending selected k-block indices
                 (``compact_kv_plan`` layout: the decode kernel's
                 scalar-prefetch schedule).
  kv_counts      (B, KV) int32   — live entries per row.
  step           (B,) int32      — per-slot decode steps since the slot
                 was (re)claimed (drives the periodic full re-plan;
                 per-slot so a drifting request re-plans without
                 dragging stable slots along).

Two plan refresh modes, blended by ``replan_interval``:

* **full re-plan** (every ``replan_interval``-th step): score the slot's
  query rows against *all* cached keys, bisect the per-row top-k
  threshold with the SAME predicate the prefill path counts with
  (``core.blockmap.bisect_select``), and keep every block holding a
  selected token.  ``replan_interval=1`` makes every step exact: the
  kernel output equals dense top-k (bisect) decode bitwise.  With
  ``replan_mode="sketch"`` the periodic re-plan runs ``sketch_replan``
  instead: coarse super-block sketches (unions of F adjacent block
  summaries) rank candidate regions first, and the exact threshold
  bisection reads only the surviving ``ceil(P/F)·F`` candidate blocks'
  keys — re-plan traffic sub-linear in cached K bytes, approximate by
  design (opt-in; the exact threshold still applies over whatever the
  sketch admits).
* **incremental** (in between): rank blocks by the Quest-style upper
  bound ``sum_d max(q_d·k_min_d, q_d·k_max_d)`` from the summaries —
  O(nkb·D) instead of O(S·D) — keep the top ``P`` (new blocks *enter*,
  cold blocks *retire* as their bound falls out of the top set), then
  gather only the planned blocks' keys to bisect the exact token
  threshold *within* the plan.  Selection work and K fetch both scale
  with ``P·k_block``, not the prefix.

The re-plan trigger is either a fixed integer interval
(``plan["step"] % interval == 0`` — bit-compatible with PR 3) or
**churn-adaptive** (``churn_budget`` set): each incremental step
measures plan churn — blocks entering + retiring per (slot, kv head) —
and a full re-plan fires once the accumulated churn reaches
``churn_budget · P``.  A stable plan then re-plans rarely (selection
traffic stays O(P·k_block)); a drifting one re-plans early (exactness
recovers before the summary ranking strays far).  Both triggers are
**per slot** (``step``/``churn``/``replans`` are (B,)): one drifting
request's full re-plan no longer rewrites every stable slot's plan —
when a step mixes triggered and untriggered slots, both branches
evaluate and each slot keeps its own (the all-full / all-incremental
fast paths still run one branch).

**Paged cache**: every planner works identically over the paged
serving layout (``core/paging.py``) — block summaries and plan indices
are *logical* (block == page), so only key gathers change: pass the
per-slot ``page_table`` and hand ``k_cache`` as the physical pool
``(n_pages, page, KV, D)``.  The full re-plan streams the gathered
logical view (it reads all cached K either way); the incremental
gather dereferences pages per planned block, staying O(P·page).

**Prefill→decode handoff**: ``plan_from_prefill`` seeds a claimed
slot's state from prefill outputs — summaries recomputed from the
written keys (bit-identical to incremental maintenance by the
associativity argument above) and the plan rows from the prompt tail's
selected blocks — with ``step`` already *off* the re-plan beat, so the
first decode steps run the planned incremental path instead of a cold
full re-plan (or, worse, a dense step).

**Per-slot QoS vectors** (``init_decode_plan(..., qos=True)``): the
state additionally carries ``budget``/``interval`` (B,) int32 and
``quant``/``sketch`` (B,) bool — the degradation-ladder knobs the
serving loop's QoS controller mutates *as values* between steps (the
pytree structure never changes, so stepping a slot down a rung never
re-traces the jitted step).  ``budget`` caps the blocks a re-plan may
keep (ranked by best block score, the token threshold then recomputed
over the survivors — still an exact top-k *within* the planned
blocks); ``interval`` is the slot's own re-plan beat;
``quant`` routes the slot's summary *ranking* through an int8
quantize→dequantize round trip (conservative — containment as in the
int8 backend); ``sketch`` swaps the slot's periodic re-plan for the
hierarchical ``sketch_replan``.  QoS steps always run the per-slot
``lax.map`` path so each slot's arithmetic depends only on its own
knobs — an undegraded slot's output is bitwise identical to a run
where no slot ever degraded.

All functions are jittable; the state is a plain dict pytree so it
stacks across layers and rides the serving scan next to the KV cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockmap import bisect_select, compact_kv_plan
from repro.core.paging import logical_kv_view
from repro.core.selection import NEG_INF, kth_largest_bisect

PlanState = Dict[str, jax.Array]

SUMMARY_BACKENDS = ("fp32", "int8")

# int8 code range: block range endpoints land on ±126 so the ±1
# conservative-rounding margin below never clips anti-conservatively
_INT8_LEVELS = 252.0


def summary_bytes(nkb: int, d: int, summary: str = "fp32") -> int:
    """Block-summary bytes per (slot, kv head) — what one incremental
    ranking pass reads.  fp32: 2·nkb·D·4.  int8: 2·nkb·D codes plus the
    per-block fp32 (scale, zero) pairs."""
    assert summary in SUMMARY_BACKENDS, summary
    if summary == "int8":
        return 2 * nkb * d + nkb * 2 * 4
    return 2 * nkb * d * 4


def quantize_summaries(k_min: jax.Array, k_max: jax.Array
                       ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                  jax.Array]:
    """fp32 per-block bounds (..., D) → int8 codes plus per-block fp32
    (scale, zero) (...,).  CONSERVATIVE: ``dequantize_summaries`` of
    the result always contains the inputs elementwise (quantized lo ≤
    lo, quantized hi ≥ hi) — floor−1 / ceil+1 rounding leaves a whole
    quantization step of margin, which dominates every fp32 rounding
    error in the round trip (the scale floor keeps that step above a
    few ulps of ``zero`` even for near-constant blocks).  Empty blocks
    (±inf bounds, the init state) get the ``scale = -1`` sentinel and
    dequantize back to ±inf."""
    empty = ~jnp.isfinite(k_min[..., 0])
    lo = jnp.where(empty[..., None], 0.0, k_min.astype(jnp.float32))
    hi = jnp.where(empty[..., None], 0.0, k_max.astype(jnp.float32))
    rlo = lo.min(axis=-1)
    rhi = hi.max(axis=-1)
    zero = 0.5 * (rlo + rhi)
    rng = jnp.maximum(rhi - rlo,
                      jnp.maximum(1e-30, 1e-4 * jnp.abs(zero)))
    scale = rng / _INT8_LEVELS
    q_lo = jnp.clip(jnp.floor((lo - zero[..., None]) / scale[..., None])
                    - 1, -128, 127).astype(jnp.int8)
    q_hi = jnp.clip(jnp.ceil((hi - zero[..., None]) / scale[..., None])
                    + 1, -128, 127).astype(jnp.int8)
    return (q_lo, q_hi,
            jnp.where(empty, -1.0, scale).astype(jnp.float32),
            jnp.where(empty, 0.0, zero).astype(jnp.float32))


def dequantize_summaries(q_lo: jax.Array, q_hi: jax.Array,
                         scale: jax.Array, zero: jax.Array
                         ) -> Tuple[jax.Array, jax.Array]:
    """Inverse of ``quantize_summaries``: int8 codes (..., D) + fp32
    (scale, zero) (...,) → fp32 bounds.  ``scale < 0`` marks empty
    blocks, which come back as the ±inf init state."""
    lo = zero[..., None] + q_lo.astype(jnp.float32) * scale[..., None]
    hi = zero[..., None] + q_hi.astype(jnp.float32) * scale[..., None]
    valid = (scale >= 0.0)[..., None]
    return (jnp.where(valid, lo, jnp.inf),
            jnp.where(valid, hi, -jnp.inf))


def plan_summary_bounds(plan: PlanState) -> Tuple[jax.Array, jax.Array]:
    """The plan's block bounds as fp32 (±inf marks empty blocks),
    whatever backend stores them.  The backend is carried by the state
    itself (``k_scale`` present ⇔ int8), so jitted consumers stay
    signature-stable across backends."""
    if "k_scale" in plan:
        return dequantize_summaries(plan["k_min"], plan["k_max"],
                                    plan["k_scale"], plan["k_zero"])
    return plan["k_min"], plan["k_max"]


def degraded_summary_bounds(plan: PlanState,
                            quant: Optional[jax.Array]
                            ) -> Tuple[jax.Array, jax.Array]:
    """``plan_summary_bounds`` with the per-slot ``quant`` QoS rung
    applied: flagged slots rank from an int8 quantize→dequantize round
    trip of their fp32 bounds (the same conservative rounding as the
    int8 backend, so containment — and with it the superset-safe
    ranking property — holds).  Unflagged slots pass through bitwise
    untouched (a ``jnp.where`` of the exact values).  No-op when the
    backend already stores int8 codes."""
    k_min, k_max = plan_summary_bounds(plan)
    if quant is None or "k_scale" in plan:
        return k_min, k_max
    d_lo, d_hi = dequantize_summaries(*quantize_summaries(k_min, k_max))
    m = quant[:, None, None, None]
    return jnp.where(m, d_lo, k_min), jnp.where(m, d_hi, k_max)


def clamp_plan_budget(occ: jax.Array, blk_score: jax.Array,
                      budget: jax.Array) -> jax.Array:
    """Cap selected blocks per (slot, kv head) at the slot's QoS
    ``budget``: rank the selected blocks by their best token score and
    keep the top-``budget``.  When a slot's budget covers its whole
    selection the bisect threshold converges below every finite score
    and the occupancy passes through unchanged.  occ: (B, KV, nkb)
    bool; blk_score: (B, KV, nkb) fp32 (finite on selected blocks);
    budget: (B,) int32."""
    s = jnp.where(occ, blk_score, NEG_INF)
    thr = kth_largest_bisect(s, budget[:, None, None])        # (B, KV, 1)
    return occ & bisect_select(s, thr)


def init_decode_plan(batch: int, n_kv_heads: int, max_len: int, d: int,
                     k_block: int, plan_blocks: Optional[int] = None,
                     summary: str = "fp32", *, qos: bool = False,
                     replan_interval: int = 1,
                     retire: bool = False) -> PlanState:
    """Empty plan over a ``max_len`` cache.  ``plan_blocks`` (P) is the
    static plan width; ``None`` keeps the full ``nkb`` (exact — no block
    a re-plan selects is ever dropped).  ``summary`` picks the bounds
    storage backend (module docstring).  ``qos=True`` adds the per-slot
    degradation-ladder knob vectors (initialized to full quality:
    budget = P, interval = ``replan_interval``, fp32 exact re-plans) —
    see the module docstring's QoS section.  ``retire=True`` adds the
    cascade-retirement state (``sata_retire``): ``imp`` (B, KV, nkb)
    fp32 accumulated block importance (exponentially decayed membership
    of each step's planned set — it rides the planners' existing score
    pass, zero extra cache reads) and ``live_blk`` (B, nkb) bool, the
    retired-block mask every planner ANDs into its validity predicate
    so retired blocks leave the ranking set entirely.  ``retire=False``
    leaves the pytree — and with it every jitted consumer — bitwise
    identical to the pre-retirement state."""
    assert max_len % k_block == 0, (max_len, k_block)
    assert summary in SUMMARY_BACKENDS, summary
    nkb = max_len // k_block
    p = nkb if plan_blocks is None else min(int(plan_blocks), nkb)
    assert p >= 1, p
    if summary == "int8":
        bounds = {
            "k_min": jnp.zeros((batch, n_kv_heads, nkb, d), jnp.int8),
            "k_max": jnp.zeros((batch, n_kv_heads, nkb, d), jnp.int8),
            "k_scale": jnp.full((batch, n_kv_heads, nkb), -1.0,
                                jnp.float32),
            "k_zero": jnp.zeros((batch, n_kv_heads, nkb), jnp.float32),
        }
    else:
        bounds = {
            "k_min": jnp.full((batch, n_kv_heads, nkb, d), jnp.inf,
                              jnp.float32),
            "k_max": jnp.full((batch, n_kv_heads, nkb, d), -jnp.inf,
                              jnp.float32),
        }
    qos_state = {}
    if qos:
        qos_state = {
            "budget": jnp.full((batch,), p, jnp.int32),
            "interval": jnp.full((batch,), max(int(replan_interval), 1),
                                 jnp.int32),
            "quant": jnp.zeros((batch,), bool),
            "sketch": jnp.zeros((batch,), bool),
        }
    retire_state = {}
    if retire:
        retire_state = {
            "imp": jnp.zeros((batch, n_kv_heads, nkb), jnp.float32),
            "live_blk": jnp.ones((batch, nkb), bool),
        }
    return {
        **bounds,
        **qos_state,
        **retire_state,
        "kv_indices": jnp.zeros((batch, n_kv_heads, p), jnp.int32),
        "kv_counts": jnp.zeros((batch, n_kv_heads), jnp.int32),
        "step": jnp.zeros((batch,), jnp.int32),
        # churn-adaptive trigger state + re-plan counter (serving reads
        # the counter for true plan-side traffic accounting); both stay
        # untouched on the fixed-interval path, so integer intervals are
        # bit-compatible with the pre-churn state machine.  ``replans``
        # is cumulative over the slot's whole pool lifetime (NOT reset
        # on claim): serving accounts traffic by its monotone delta.
        "churn": jnp.zeros((batch,), jnp.float32),
        "replans": jnp.zeros((batch,), jnp.int32),
        # liveness: only active slots age (``step``), fire re-plan
        # beats, and count re-plans — a serving slot whose request
        # completed must not keep forcing full re-plans (and inflating
        # the traffic accounting) on a beat nobody is listening to.
        # Defaults True so non-serving callers are unaffected; serving
        # releases on completion (``release_plan_slot``) and
        # re-activates on claim (``reset_plan_slot``).
        "active": jnp.ones((batch,), bool),
    }


def reset_plan_slot(plan: PlanState, slot, *, batch_axis: int = 0
                    ) -> PlanState:
    """Reset one batch slot's plan to the init state (claimed serving
    slots must not inherit the previous request's summaries).  Works on
    layer-stacked states: ``batch_axis`` names the batch dimension.
    The slot's ``step``/``churn`` restart too (a cold slot's first
    update must run the full re-plan); ``replans`` stays — it is the
    cumulative traffic counter serving reads by delta."""
    ix = (slice(None),) * batch_axis + (slot,)
    if "k_scale" in plan:            # int8 backend: sentinel = empty
        bounds = {
            "k_min": plan["k_min"].at[ix].set(0),
            "k_max": plan["k_max"].at[ix].set(0),
            "k_scale": plan["k_scale"].at[ix].set(-1.0),
            "k_zero": plan["k_zero"].at[ix].set(0.0),
        }
    else:
        bounds = {
            "k_min": plan["k_min"].at[ix].set(jnp.inf),
            "k_max": plan["k_max"].at[ix].set(-jnp.inf),
        }
    out = {
        **plan,                      # replans is cumulative accounting
        **bounds,
        "kv_indices": plan["kv_indices"].at[ix].set(0),
        "kv_counts": plan["kv_counts"].at[ix].set(0),
        "step": plan["step"].at[ix].set(0),
        "churn": plan["churn"].at[ix].set(0.0),
        "active": plan["active"].at[ix].set(True),
    }
    if "imp" in plan:                # retirement state restarts with the
        out["imp"] = plan["imp"].at[ix].set(0.0)       # new occupant
        out["live_blk"] = plan["live_blk"].at[ix].set(True)
    return out


def release_plan_slot(plan: PlanState, slot, *, batch_axis: int = 0
                      ) -> PlanState:
    """Mark one batch slot's plan inactive — its request completed (or
    was preempted), so the slot stops aging, never fires a re-plan
    beat, and contributes nothing to the re-plan accounting until a
    new claim re-activates it (``reset_plan_slot``)."""
    ix = (slice(None),) * batch_axis + (slot,)
    return {**plan, "active": plan["active"].at[ix].set(False)}


# every per-slot plan field, in one place: host-swap preemption must
# move the COMPLETE per-slot state (summaries whatever the backend,
# selected blocks, beat phase, churn trigger, cumulative re-plan
# counter, liveness) or the restored slot's decode diverges from the
# never-preempted run.  The QoS knob vectors (budget/interval/quant/
# sketch) are deliberately NOT here: a rung is a property of the
# serving SLOT under load, owned by the serve loop's QoS controller —
# it re-pushes the knob vectors on every admission and rung change, so
# swapping a request must not drag a rung to a different slot.  The
# retirement state (``imp``/``live_blk``) IS here: a swapped-out
# request's accumulated importance and retired-block mask belong to the
# request, and restoring them is what keeps a restored slot's plan from
# resurrecting blocks whose pages were already reclaimed.
PLAN_SLOT_FIELDS = ("k_min", "k_max", "k_scale", "k_zero", "kv_indices",
                    "kv_counts", "step", "churn", "replans", "active",
                    "imp", "live_blk")


def capture_plan_slot(plan: PlanState, slot, *, batch_axis: int = 0
                      ) -> Dict[str, np.ndarray]:
    """Host (numpy) snapshot of one slot's complete plan state, for
    host-swap preemption.  Works on layer-stacked states like
    ``reset_plan_slot``; the dict round-trips bitwise through
    ``install_plan_slot`` (fp32/int8/int32/bool all copy exactly)."""
    ix = (slice(None),) * batch_axis + (slot,)
    return {name: np.asarray(plan[name][ix])
            for name in PLAN_SLOT_FIELDS if name in plan}


def install_plan_slot(plan: PlanState, slot, saved: Dict[str, np.ndarray],
                      *, batch_axis: int = 0) -> PlanState:
    """Reset-free reinstall of a captured slot snapshot: every saved
    field lands bitwise at ``slot``, including ``step`` (the re-plan
    beat phase — restoring it is what makes the first post-restore
    step incremental instead of a cold full re-plan) and ``active``
    (captured live, so the slot resumes aging immediately)."""
    ix = (slice(None),) * batch_axis + (slot,)
    out = dict(plan)
    for name, val in saved.items():
        out[name] = plan[name].at[ix].set(
            jnp.asarray(val, plan[name].dtype))
    return out


def update_block_summaries(plan: PlanState, k_new: jax.Array,
                           pos: jax.Array, *, k_block: int) -> PlanState:
    """Absorb one appended key per slot into its block's min/max bounds.

    k_new: (B, 1, KV, D) — the value actually written to the cache (same
    dtype cast), so the incremental summaries match a from-scratch
    recompute over cache contents exactly; pos: (B,) int32 write
    positions.
    """
    kn = k_new[:, 0].astype(jnp.float32)                     # (B, KV, D)
    b = kn.shape[0]
    blk = (pos // k_block).astype(jnp.int32)                 # (B,)
    bi = jnp.arange(b)[:, None]
    ki = jnp.arange(kn.shape[1])[None, :]
    bx = blk[:, None]
    if "k_scale" not in plan:
        return {
            **plan,
            "k_min": plan["k_min"].at[bi, ki, bx].min(kn),
            "k_max": plan["k_max"].at[bi, ki, bx].max(kn),
        }
    # int8 backend: dequantize only the touched block's bounds, absorb
    # the key, requantize outward.  The carried codes already contain
    # the block's true bounds, so the union contains (true ∪ new) and
    # conservative requantization keeps it that way — containment
    # telescopes across any append sequence.
    lo, hi = dequantize_summaries(plan["k_min"][bi, ki, bx],
                                  plan["k_max"][bi, ki, bx],
                                  plan["k_scale"][bi, ki, bx],
                                  plan["k_zero"][bi, ki, bx])
    q_lo, q_hi, sc, zp = quantize_summaries(jnp.minimum(lo, kn),
                                            jnp.maximum(hi, kn))
    return {
        **plan,
        "k_min": plan["k_min"].at[bi, ki, bx].set(q_lo),
        "k_max": plan["k_max"].at[bi, ki, bx].set(q_hi),
        "k_scale": plan["k_scale"].at[bi, ki, bx].set(sc),
        "k_zero": plan["k_zero"].at[bi, ki, bx].set(zp),
    }


def summaries_from_cache(k_cache: jax.Array, pos: jax.Array, *,
                         k_block: int) -> Tuple[jax.Array, jax.Array]:
    """From-scratch reference for the incremental summaries: per-block
    elementwise min/max over the cached keys at positions ``<= pos``
    (empty blocks keep the ±inf init).  k_cache: (B, S, KV, D);
    pos: (B,).  Returns (k_min, k_max) shaped (B, KV, nkb, D)."""
    b, s, kv, d = k_cache.shape
    nkb = s // k_block
    kf = k_cache.astype(jnp.float32).transpose(0, 2, 1, 3)   # (B, KV, S, D)
    valid = (jnp.arange(s) <= pos[:, None])[:, None, :, None]
    lo = jnp.where(valid, kf, jnp.inf).reshape(b, kv, nkb, k_block, d)
    hi = jnp.where(valid, kf, -jnp.inf).reshape(b, kv, nkb, k_block, d)
    return lo.min(axis=3), hi.max(axis=3)


def _compact_rows(occ: jax.Array, pad_to: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """(B, KV, nkb) bool occupancy → ascending selected-block lists in
    ``compact_kv_plan``'s padded layout, clamped to ``pad_to`` slots."""
    b, kv, nkb = occ.shape
    idx, cnt = compact_kv_plan(occ.reshape(b * kv, 1, nkb),
                               pad_to=min(pad_to, nkb), truncate=True)
    return (idx.reshape(b, kv, -1).astype(jnp.int32),
            cnt.reshape(b, kv).astype(jnp.int32))


def block_upper_bounds(q: jax.Array, k_min: jax.Array, k_max: jax.Array,
                       *, sm_scale: float) -> jax.Array:
    """Quest-style score upper bound per (slot, kv head, q row, block):
    ``sum_d max(q_d·k_min_d, q_d·k_max_d)`` — an upper bound on any
    token score inside the block, so ranking blocks by it never
    underestimates a block holding a high-scoring key.
    q: (B, KV, G, D); k_min/k_max: (B, KV, nkb, D) (±inf entries must be
    pre-masked by the caller).  Returns (B, KV, G, nkb) fp32.

    The elementwise max must happen per dimension BEFORE summing —
    ``max(q·k_min, q·k_max)`` of the two full dot products is NOT a
    bound for mixed-sign q — which distributes to one dot against each
    bound: positive q components can at most hit ``k_max``, negative
    ones ``k_min``."""
    lo = jnp.einsum("bkgd,bknd->bkgn", jnp.minimum(q, 0.0), k_min)
    hi = jnp.einsum("bkgd,bknd->bkgn", jnp.maximum(q, 0.0), k_max)
    return (lo + hi) * sm_scale


def full_replan(q: jax.Array, k_cache: jax.Array, pos: jax.Array, *,
                topk_k: int, k_block: int, plan_blocks: int,
                budget: Optional[jax.Array] = None,
                live_blk: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Exact per-step plan: score all cached keys, bisect each query
    row's top-k threshold, keep every block with a selected token.

    q: (B, KV, G, D); k_cache: (B, S, KV, D); pos: (B,).
    Returns (kv_indices (B, KV, P), kv_counts (B, KV),
    thresholds (B, KV, G, 1) fp32).

    ``budget`` (B,) int32 (QoS ladder) caps the kept blocks per (slot,
    head) at the slot's degraded width: selected blocks ranked by best
    token score, top-``budget`` survive, and the token threshold is
    re-bisected over the survivors only — the plan stays an exact
    top-k *within* the (narrowed) planned blocks.

    ``live_blk`` (B, nkb) bool (cascade retirement) masks retired
    blocks' tokens out of the score multiset entirely: their pages are
    already freed, so neither the threshold nor the selection may name
    them — the plan is an exact top-k over the *surviving* tokens.
    """
    b, s, kv, d = k_cache.shape
    nkb = s // k_block
    sm_scale = 1.0 / np.sqrt(d)
    sc = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32),
                    k_cache.astype(jnp.float32),
                    preferred_element_type=jnp.float32) * sm_scale
    valid = (jnp.arange(s) <= pos[:, None])[:, None, None, :]  # (B,1,1,S)
    if live_blk is not None:
        live_tok = jnp.repeat(live_blk, k_block, axis=-1)      # (B, S)
        valid = valid & live_tok[:, None, None, :]
    sc = jnp.where(valid, sc, NEG_INF)
    thr = kth_largest_bisect(sc, topk_k)                     # (B, KV, G, 1)
    sel = bisect_select(jnp.where(valid, sc, -jnp.inf), thr) & valid
    occ = sel.reshape(b, kv, -1, nkb, k_block).any(axis=(2, 4))
    if budget is not None:
        blk_score = sc.max(axis=2).reshape(b, kv, nkb, k_block).max(-1)
        occ = clamp_plan_budget(occ, blk_score, budget)
        keep = jnp.repeat(occ, k_block, axis=-1)             # (B, KV, S)
        thr = kth_largest_bisect(
            jnp.where(keep[:, :, None, :], sc, NEG_INF), topk_k)
    kv_indices, kv_counts = _compact_rows(occ, plan_blocks)
    return kv_indices, kv_counts, thr


def gather_planned_keys(k_cache: jax.Array, kv_indices: jax.Array, *,
                        k_block: int,
                        page_table: Optional[jax.Array] = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """Fetch only the planned blocks' keys: (B, KV, P·k_block, D) plus
    the gathered (logical) token positions (B, KV, P·k_block).  This is
    the O(P·k_block) selection-side fetch the incremental path banks on.

    Contiguous layout: k_cache (B, S, KV, D).  Paged layout
    (``page_table`` (B, max_pages) given): k_cache is the physical pool
    (n_pages, page, KV, D) with page == k_block — each planned logical
    block dereferences the table to its physical page, so the fetch
    still touches only P pages per (slot, head)."""
    tok = (kv_indices[..., None] * k_block +
           jnp.arange(k_block)[None, None, None, :])          # (B,KV,P,kb)
    if page_table is None:
        b, s, kv, d = k_cache.shape
        tok = tok.reshape(b, kv, -1)                          # (B,KV,P·kb)
        kg = jnp.take_along_axis(
            k_cache, tok.transpose(0, 2, 1)[..., None], axis=1)
        return kg.transpose(0, 2, 1, 3), tok                  # (B,KV,P·kb,D)
    b, kv, p = kv_indices.shape
    phys = jnp.take_along_axis(page_table,
                               kv_indices.reshape(b, -1),
                               axis=1).reshape(b, kv, p)      # (B,KV,P)
    # pool → (KV, n_pages, page, D), then per-head physical-page gather
    kp = jnp.moveaxis(k_cache, 2, 0)
    kg = jax.vmap(lambda heads, ph: heads[ph],
                  in_axes=(0, 1), out_axes=1)(kp, phys)       # (B,KV,P,pg,D)
    return (kg.reshape(b, kv, p * k_block, k_cache.shape[-1]),
            tok.reshape(b, kv, -1))


def incremental_plan(q: jax.Array, k_cache: jax.Array, plan: PlanState,
                     pos: jax.Array, *, topk_k: int, k_block: int,
                     page_table: Optional[jax.Array] = None,
                     budget: Optional[jax.Array] = None,
                     quant: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Approximate per-step plan from the incrementally-maintained block
    summaries: rank all valid blocks by their upper-bound score (new
    blocks enter here the step their first token lands; a planned block
    retires when its bound drops out of the top-P), then bisect the
    exact token threshold over the planned blocks only.

    Shapes as ``full_replan``; with ``page_table`` set, ``k_cache`` is
    the physical page pool and the planned-block gather walks the table
    (see ``gather_planned_keys``).  Cost: O(nkb·D) ranking +
    O(P·k_block·D) threshold — independent of the prefix length.

    QoS ladder: ``budget`` (B,) int32 ranks top-``budget`` blocks
    instead of top-P (the plan layout stays padded to the static P);
    ``quant`` (B,) bool routes flagged slots' summary ranking through
    the conservative int8 round trip (``degraded_summary_bounds``).

    Cascade retirement: a plan carrying ``live_blk`` ranks only live
    blocks — a retired block never re-enters the plan (its summary is
    the empty sentinel too, but the mask is the contract).
    """
    b, kv, _, d = q.shape
    nkb = plan["k_min"].shape[2]
    p = plan["kv_indices"].shape[-1]
    sm_scale = 1.0 / np.sqrt(d)
    valid_blk = (jnp.arange(nkb) * k_block <= pos[:, None])   # (B, nkb)
    if "live_blk" in plan:
        valid_blk = valid_blk & plan["live_blk"]
    vb = valid_blk[:, None, :, None]
    k_min, k_max = degraded_summary_bounds(plan, quant)  # fp32 either way
    ub = block_upper_bounds(q.astype(jnp.float32),
                            jnp.where(vb, k_min, 0.0),
                            jnp.where(vb, k_max, 0.0),
                            sm_scale=sm_scale)                # (B,KV,G,nkb)
    ub_row = jnp.where(valid_blk[:, None, :], ub.max(axis=2), NEG_INF)
    # top-P blocks per (slot, kv head) — the same bisect predicate as the
    # token-level threshold, applied at block granularity (a QoS budget
    # narrows the rank per slot; k broadcasts through the bisect)
    p_row = p if budget is None else budget[:, None, None]
    thr_b = kth_largest_bisect(ub_row, p_row)                 # (B, KV, 1)
    occ = bisect_select(ub_row, thr_b) & valid_blk[:, None, :]
    kv_indices, kv_counts = _compact_rows(occ, p)
    # exact token threshold, restricted to the planned blocks
    kg, tok = gather_planned_keys(k_cache, kv_indices, k_block=k_block,
                                  page_table=page_table)
    sc = jnp.einsum("bkgd,bktd->bkgt", q.astype(jnp.float32),
                    kg.astype(jnp.float32),
                    preferred_element_type=jnp.float32) * sm_scale
    slot = jnp.arange(p * k_block) // k_block                 # (P·kb,)
    live = slot[None, None, :] < kv_counts[..., None]         # no dup pads
    live = live & (tok <= pos[:, None, None])
    sc = jnp.where(live[:, :, None, :], sc, NEG_INF)
    thr = kth_largest_bisect(sc, topk_k)                      # (B, KV, G, 1)
    return kv_indices, kv_counts, thr


def sketch_geometry(nkb: int, plan_blocks: int, sketch_factor: int
                    ) -> Tuple[int, int, int, int]:
    """Static shape arithmetic shared by ``sketch_replan`` and the
    plan-traffic accounting (``kernels.ops.decode_fetch_stats``).
    Returns ``(F, nsb, C, C·F)``: the super-block factor F (largest
    divisor of ``nkb`` ≤ ``sketch_factor``), the super-block count,
    the surviving super-block budget ``C = ceil(P / F)`` and the
    candidate block count the exact threshold pass then reads."""
    f = max(1, min(int(sketch_factor), nkb))
    while nkb % f:
        f -= 1
    nsb = nkb // f
    c = min(max(1, -(-int(plan_blocks) // f)), nsb)
    return f, nsb, c, c * f


def sketch_replan(q: jax.Array, k_cache: jax.Array, plan: PlanState,
                  pos: jax.Array, *, topk_k: int, k_block: int,
                  sketch_factor: int = 4,
                  page_table: Optional[jax.Array] = None,
                  budget: Optional[jax.Array] = None,
                  quant: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Hierarchical two-level re-plan: the sub-linear replacement for
    ``full_replan``'s all-cached-K stream.

    Level 1 unions each run of F adjacent block summaries into a
    super-block sketch and ranks the sketches by the same Quest upper
    bound the incremental path uses (a super-block's bound is a bound
    on every key inside it, so the ranking never under-estimates a
    region — sketches only *rank*).  The top ``C = ceil(P/F)``
    super-blocks survive.  Level 2 gathers only the survivors'
    ``C·F`` candidate blocks and bisects the exact per-row token
    threshold over them, keeping every candidate block holding a
    selected token — exactly ``full_replan``'s tail, restricted to the
    candidate set.  Re-plan reads drop from O(S·D) to
    O(nkb·D + C·F·k_block·D).

    Approximate by design (a high-scoring key inside a region whose
    *sketch* ranks below the top C is missed until a later re-plan) —
    opt-in via ``replan_mode="sketch"``.  When ``C·F ≥ nkb`` every
    valid block is a candidate and the result equals ``full_replan``
    bitwise (the bisection threshold depends only on the live score
    multiset).  Shapes as ``full_replan``; with ``page_table`` set,
    ``k_cache`` is the physical page pool.  QoS ladder: ``budget``
    (B,) int32 narrows both levels per slot (``ceil(budget/F)``
    surviving super-blocks, then the block cap as in ``full_replan``);
    ``quant`` (B,) bool quantizes flagged slots' sketch ranking."""
    b, kv, gq, d = q.shape
    k_min, k_max = degraded_summary_bounds(plan, quant)
    nkb = k_min.shape[2]
    p = plan["kv_indices"].shape[-1]
    f, nsb, c, _ = sketch_geometry(nkb, p, sketch_factor)
    sm_scale = 1.0 / np.sqrt(d)
    valid_blk = (jnp.arange(nkb) * k_block <= pos[:, None])   # (B, nkb)
    if "live_blk" in plan:            # retired blocks leave the ranking
        valid_blk = valid_blk & plan["live_blk"]
    vb = valid_blk[:, None, :, None]
    lo = jnp.where(vb, k_min, 0.0)
    hi = jnp.where(vb, k_max, 0.0)
    slo = lo.reshape(b, kv, nsb, f, d).min(axis=3)            # sketch =
    shi = hi.reshape(b, kv, nsb, f, d).max(axis=3)            # bound union
    ub = block_upper_bounds(q.astype(jnp.float32), slo, shi,
                            sm_scale=sm_scale)                # (B,KV,G,nsb)
    valid_sb = valid_blk.reshape(b, nsb, f).any(axis=-1)
    ub_row = jnp.where(valid_sb[:, None, :], ub.max(axis=2), NEG_INF)
    # QoS budget narrows the surviving super-block count per slot
    c_row = c if budget is None else \
        jnp.clip((budget[:, None, None] + f - 1) // f, 1, c)
    thr_sb = kth_largest_bisect(ub_row, c_row)                # (B, KV, 1)
    occ_sb = bisect_select(ub_row, thr_sb) & valid_sb[:, None, :]
    sb_idx, sb_cnt = _compact_rows(occ_sb, c)                 # (B, KV, C)
    cand = (sb_idx[..., None] * f +
            jnp.arange(f)[None, None, None, :]).reshape(b, kv, c * f)
    # exact token threshold, restricted to the candidate blocks
    kg, tok = gather_planned_keys(k_cache, cand, k_block=k_block,
                                  page_table=page_table)
    sc = jnp.einsum("bkgd,bktd->bkgt", q.astype(jnp.float32),
                    kg.astype(jnp.float32),
                    preferred_element_type=jnp.float32) * sm_scale
    sb_slot = jnp.arange(c * f * k_block) // (f * k_block)    # (C·F·kb,)
    live = sb_slot[None, None, :] < sb_cnt[..., None]         # no dup pads
    live = live & (tok <= pos[:, None, None])
    if "live_blk" in plan:
        # a surviving super-block may straddle retired blocks whose
        # pages are already freed — their gathered rows are garbage and
        # must never reach the threshold multiset
        lv = jax.vmap(lambda m, c_: m[c_])(plan["live_blk"], cand)
        live = live & jnp.repeat(lv, k_block, axis=-1)
    sc = jnp.where(live[:, :, None, :], sc, NEG_INF)
    thr = kth_largest_bisect(sc, topk_k)                      # (B, KV, G, 1)
    sel = bisect_select(jnp.where(live[:, :, None, :], sc, -jnp.inf),
                        thr) & live[:, :, None, :]
    sel_blk = sel.reshape(b, kv, gq, c * f, k_block).any(axis=(2, 4))
    if budget is not None:
        cand_score = sc.max(axis=2).reshape(b, kv, c * f, k_block).max(-1)
        sel_blk = clamp_plan_budget(sel_blk, cand_score, budget)
        keep = jnp.repeat(sel_blk, k_block, axis=-1)          # (B,KV,C·F·kb)
        thr = kth_largest_bisect(
            jnp.where(keep[:, :, None, :], sc, NEG_INF), topk_k)
    occ = jnp.zeros((b, kv, nkb), bool).at[
        jnp.arange(b)[:, None, None],
        jnp.arange(kv)[None, :, None], cand].max(sel_blk)
    kv_indices, kv_counts = _compact_rows(occ, p)
    return kv_indices, kv_counts, thr


def _plan_occupancy(kv_indices: jax.Array, kv_counts: jax.Array,
                    nkb: int) -> jax.Array:
    """(B, KV, P) padded index lists → (B, KV, nkb) bool occupancy
    (padding slots past the count are ignored)."""
    hit = kv_indices[..., None] == jnp.arange(nkb)            # (B,KV,P,nkb)
    live = (jnp.arange(kv_indices.shape[-1]) <
            kv_counts[..., None])[..., None]
    return (hit & live).any(axis=-2)


def plan_churn(plan: PlanState, kv_indices: jax.Array,
               kv_counts: jax.Array) -> jax.Array:
    """Blocks entering + retiring between the carried plan and this
    step's, per slot (mean over kv heads) — the drift signal the
    churn-adaptive trigger integrates.  Per-slot (B,), not a batch
    reduction: each serving slot accumulates only its own drift, so
    one churning request re-plans alone and an idle slot's frozen plan
    neither dilutes nor inflates anyone's budget."""
    nkb = plan["k_min"].shape[2]
    o_old = _plan_occupancy(plan["kv_indices"], plan["kv_counts"], nkb)
    o_new = _plan_occupancy(kv_indices, kv_counts, nkb)
    return (o_old ^ o_new).sum(-1).astype(jnp.float32).mean(-1)


def decode_plan_update(plan: PlanState, q: jax.Array, k_cache: jax.Array,
                       pos: jax.Array, *, topk_k: int, k_block: int,
                       replan_interval: int = 1,
                       churn_budget: Optional[float] = None,
                       page_table: Optional[jax.Array] = None,
                       replan_mode: str = "exact",
                       sketch_factor: int = 4,
                       retire_decay: float = 0.9
                       ) -> Tuple[PlanState, jax.Array]:
    """One decode step of plan maintenance (summaries must already hold
    the step's appended key — call ``update_block_summaries`` first).
    Returns the updated state and the per-row thresholds for the decode
    kernel.

    Re-plan trigger (per slot — ``step``/``churn`` are (B,)): with
    ``churn_budget`` set (``sata_decode_replan="auto"``) a slot's full
    re-plan fires when the churn IT accumulated over incremental steps
    reaches ``churn_budget · P`` (and always at its step 0 — a cold
    plan has nothing to rank from); otherwise every
    ``replan_interval``-th step of the slot re-plans and intermediate
    steps use the incremental summary-ranked plan, bit-compatible with
    the fixed-interval state machine (``replan_interval=1`` = exact
    top-k every step).  ``replan_mode="sketch"`` swaps the periodic
    re-plan for the two-level ``sketch_replan`` (traffic sub-linear in
    cached K; approximate — see its docstring).

    A step mixing triggered and untriggered slots runs the **partial
    re-plan**: ``lax.map`` over slots with a real ``lax.cond`` per
    slot, so only the triggering slots' caches are streamed — plan
    traffic proportional to the triggering subset, not the batch
    (steps where the whole batch agrees keep the batched
    single-branch fast path).  With ``page_table`` set, ``k_cache`` is
    the physical page pool of the paged serving layout.

    **QoS ladder** (state carries the knob vectors — ``budget`` in
    ``plan``): the trigger reads each slot's own ``interval``, every
    step runs the per-slot ``lax.map`` path (knobs differ per slot, so
    there is no batched fast path — and per-slot isolation is what
    makes an undegraded slot bitwise independent of its degraded
    neighbors), re-plans honor the slot's ``budget``/``quant`` and a
    flagged ``sketch`` slot re-plans hierarchically.  Incompatible
    with the churn-adaptive trigger (the controller owns the beat).

    **Cascade retirement** (state carries ``imp``/``live_blk``): every
    planner ANDs ``live_blk`` into its block-validity predicate, and
    after the plan lands the accumulated importance decays and absorbs
    this step's planned membership — ``imp ← retire_decay·imp + sel``
    per (slot, kv head, block), a SpAtten-style cumulative attention
    importance proxied by the score pass's own selection output, so it
    costs zero extra cache reads.  Inactive slots' importance is
    frozen.  A retirement-free plan skips all of this bitwise."""
    assert replan_mode in ("exact", "sketch"), replan_mode
    p = plan["kv_indices"].shape[-1]
    qos = "budget" in plan
    assert not (qos and churn_budget is not None), \
        "QoS ladder owns the re-plan beat; use an integer interval"

    def _full(_):
        if replan_mode == "sketch":
            return sketch_replan(q, k_cache, plan, pos, topk_k=topk_k,
                                 k_block=k_block,
                                 sketch_factor=sketch_factor,
                                 page_table=page_table)
        kc = k_cache if page_table is None else \
            logical_kv_view(k_cache, page_table)
        return full_replan(q, kc, pos, topk_k=topk_k,
                           k_block=k_block, plan_blocks=p,
                           live_blk=plan.get("live_blk"))

    def _incr(_):
        return incremental_plan(q, k_cache, plan, pos, topk_k=topk_k,
                                k_block=k_block, page_table=page_table)

    active = plan["active"]
    churn = plan["churn"]
    if qos:
        # each slot's own beat (step 0 lands on every beat, so a cold
        # slot still re-plans first)
        do_full = ((plan["step"] % jnp.maximum(plan["interval"], 1)) == 0) \
            & active
    elif churn_budget is not None:
        do_full = ((plan["step"] == 0) | (churn >= churn_budget * p)) \
            & active
    elif replan_interval <= 1:
        do_full = active
    else:
        do_full = (plan["step"] % replan_interval == 0) & active

    if qos:
        # always the per-slot map: knobs differ per slot, and per-slot
        # isolation keeps undegraded slots bitwise independent of
        # their degraded neighbors
        sub = {k: plan[k] for k in
               ("k_min", "k_max", "k_scale", "k_zero", "kv_indices",
                "live_blk")
               if k in plan}
        xs = (do_full, q, pos, sub,
              k_cache if page_table is None else page_table,
              plan["budget"], plan["quant"], plan["sketch"])

        def _one_qos(args):
            do_f, qb, posb, subb, kb, bud, qnt, skt = args
            qb, posb = qb[None], posb[None]
            bud, qnt = bud[None], qnt[None]
            subb = {k: v[None] for k, v in subb.items()}
            kc = kb[None] if page_table is None else k_cache
            tb = None if page_table is None else kb[None]

            def _sketch_one(_):
                return sketch_replan(qb, kc, subb, posb, topk_k=topk_k,
                                     k_block=k_block,
                                     sketch_factor=sketch_factor,
                                     page_table=tb, budget=bud,
                                     quant=qnt)

            def _exact_one(_):
                kf = kc if tb is None else logical_kv_view(kc, tb)
                return full_replan(qb, kf, posb, topk_k=topk_k,
                                   k_block=k_block, plan_blocks=p,
                                   budget=bud,
                                   live_blk=subb.get("live_blk"))

            def _full_one(_):
                if replan_mode == "sketch":
                    return _sketch_one(None)
                return jax.lax.cond(skt, _sketch_one, _exact_one, None)

            def _incr_one(_):
                return incremental_plan(qb, kc, subb, posb,
                                        topk_k=topk_k, k_block=k_block,
                                        page_table=tb, budget=bud,
                                        quant=qnt)

            fi, fc, ft = jax.lax.cond(do_f, _full_one, _incr_one, None)
            return fi[0], fc[0], ft[0]

        kv_indices, kv_counts, thr = jax.lax.map(_one_qos, xs)
    elif replan_interval <= 1 and churn_budget is None:
        # exact mode computes the full re-plan unconditionally (idle
        # slots ride the batched einsum for free); ``do_full`` above
        # still scopes the accounting to active slots
        kv_indices, kv_counts, thr = _full(None)
    else:
        def _mixed(_):
            # partial re-plan: per-slot cond under a sequential map —
            # a genuine runtime branch (NOT a batched select of both),
            # so untriggered slots never stream their cache
            sub = {k: plan[k] for k in
                   ("k_min", "k_max", "k_scale", "k_zero", "kv_indices",
                    "live_blk")
                   if k in plan}
            xs = (do_full, q, pos, sub,
                  k_cache if page_table is None else page_table)

            def _one(args):
                do_f, qb, posb, subb, kb = args
                qb, posb = qb[None], posb[None]
                subb = {k: v[None] for k, v in subb.items()}
                kc = kb[None] if page_table is None else k_cache
                tb = None if page_table is None else kb[None]

                def _full_one(_):
                    if replan_mode == "sketch":
                        return sketch_replan(
                            qb, kc, subb, posb, topk_k=topk_k,
                            k_block=k_block, sketch_factor=sketch_factor,
                            page_table=tb)
                    kf = kc if tb is None else logical_kv_view(kc, tb)
                    return full_replan(qb, kf, posb, topk_k=topk_k,
                                       k_block=k_block, plan_blocks=p,
                                       live_blk=subb.get("live_blk"))

                def _incr_one(_):
                    return incremental_plan(
                        qb, kc, subb, posb, topk_k=topk_k,
                        k_block=k_block, page_table=tb)

                fi, fc, ft = jax.lax.cond(do_f, _full_one, _incr_one,
                                          None)
                return fi[0], fc[0], ft[0]

            return jax.lax.map(_one, xs)

        branch = jnp.where(do_full.all(), 2,
                           jnp.where(do_full.any(), 1, 0))
        kv_indices, kv_counts, thr = jax.lax.switch(
            branch, [_incr, _mixed, _full], None)
    if churn_budget is not None:
        churn = jnp.where(do_full, 0.0,
                          churn + plan_churn(plan, kv_indices, kv_counts))
    new_plan = {**plan, "kv_indices": kv_indices, "kv_counts": kv_counts,
                "step": plan["step"] + active.astype(jnp.int32),
                "churn": churn,
                "replans": plan["replans"] + do_full.astype(jnp.int32)}
    if "imp" in plan:
        # SpAtten-style cumulative importance: decay, then absorb this
        # step's planned-set membership — derived from the score pass's
        # own output, so no extra cache reads.  Idle slots freeze.
        nkb = plan["imp"].shape[-1]
        sel = _plan_occupancy(kv_indices, kv_counts, nkb)
        imp = plan["imp"] * retire_decay + sel.astype(jnp.float32)
        new_plan["imp"] = jnp.where(active[:, None, None], imp,
                                    plan["imp"])
    return new_plan, thr


def retire_plan_blocks(plan: PlanState, slot, blocks, *,
                       batch_axis: int = 0) -> PlanState:
    """Plan-state repair after a retirement pass freed one slot's cold
    blocks' pages (host-invoked between steps, like
    ``install_plan_slot``): mark the blocks dead in ``live_blk``, reset
    their summaries to the empty sentinel (so even a stale ranking can
    never resurrect them — the conservative-bounds contract holds
    vacuously for a block with no tokens), zero their accumulated
    importance, and re-absorb ``kv_indices``/``kv_counts`` over the
    survivors (occupancy → compact round-trips the untouched entries
    bitwise).  Positions stay logical throughout — survivors keep their
    token positions, so causality masks and RoPE are untouched.  Works
    on layer-stacked states via ``batch_axis``."""
    assert "live_blk" in plan, "plan was not initialized with retire=True"
    ix = (slice(None),) * batch_axis + (slot,)
    nkb = plan["live_blk"].shape[-1]
    p = plan["kv_indices"].shape[-1]
    m = jnp.zeros((nkb,), bool).at[jnp.asarray(blocks, jnp.int32)].set(True)
    out = dict(plan)
    out["live_blk"] = plan["live_blk"].at[ix].set(
        plan["live_blk"][ix] & ~m)
    out["imp"] = plan["imp"].at[ix].set(
        jnp.where(m, 0.0, plan["imp"][ix]))
    if "k_scale" in plan:            # int8 backend: sentinel = empty
        out["k_min"] = plan["k_min"].at[ix].set(
            jnp.where(m[:, None], 0, plan["k_min"][ix]))
        out["k_max"] = plan["k_max"].at[ix].set(
            jnp.where(m[:, None], 0, plan["k_max"][ix]))
        out["k_scale"] = plan["k_scale"].at[ix].set(
            jnp.where(m, -1.0, plan["k_scale"][ix]))
        out["k_zero"] = plan["k_zero"].at[ix].set(
            jnp.where(m, 0.0, plan["k_zero"][ix]))
    else:
        out["k_min"] = plan["k_min"].at[ix].set(
            jnp.where(m[:, None], jnp.inf, plan["k_min"][ix]))
        out["k_max"] = plan["k_max"].at[ix].set(
            jnp.where(m[:, None], -jnp.inf, plan["k_max"][ix]))
    # recompact the slot's planned rows over the survivors
    idx, cnt = plan["kv_indices"][ix], plan["kv_counts"][ix]
    lead = idx.shape[:-2]                       # () or (L,) layer-stacked
    occ = _plan_occupancy(idx.reshape((-1,) + idx.shape[-2:]),
                          cnt.reshape((-1,) + cnt.shape[-1:]), nkb)
    ni, nc = _compact_rows(occ & ~m, p)
    out["kv_indices"] = plan["kv_indices"].at[ix].set(
        ni.reshape(lead + ni.shape[-2:]).astype(idx.dtype))
    out["kv_counts"] = plan["kv_counts"].at[ix].set(
        nc.reshape(lead + nc.shape[-1:]).astype(cnt.dtype))
    return out


def plan_from_prefill(k_cache: jax.Array, q_tail: jax.Array,
                      pos: jax.Array, *, topk_k: int, k_block: int,
                      plan_blocks: Optional[int] = None,
                      summary: str = "fp32") -> PlanState:
    """Seed a decode-plan state from prefill outputs — the prefill→
    decode handoff.  Instead of claiming a slot cold (empty summaries,
    forcing the first decode step through a full re-plan that streams
    the whole prefix), seed:

      * summaries from the keys prefill wrote (``summaries_from_cache``
        — bit-identical to what incremental maintenance would have
        accumulated, by min/max associativity);
      * the plan rows from the prompt *tail's* selected blocks: the
        prefill block map's last row already knows which k-blocks the
        final positions touch, and the next decode query sits adjacent
        to them, so its selection lands in (nearly) the same block set
        — ``full_replan`` with the tail queries IS that row of the map
        at exact single-row cost, amortized into prefill (which just
        streamed all K anyway);
      * ``step = 1`` — deliberately OFF the re-plan beat, so decode
        step 0 runs the planned incremental path, not a cold dense
        re-plan.

    k_cache: (B, S, KV, D) the slot's written cache in LOGICAL layout
    (paged callers pass ``logical_kv_view``); q_tail: (B, KV, G, D) the
    last prompt position's grouped queries; pos: (B,) last written
    positions.  Returns a fresh PlanState for these B slots."""
    b, s, kv, d = k_cache.shape
    plan = init_decode_plan(b, kv, s, d, k_block, plan_blocks,
                            summary=summary)
    k_min, k_max = summaries_from_cache(k_cache, pos, k_block=k_block)
    p = plan["kv_indices"].shape[-1]
    kv_indices, kv_counts, _ = full_replan(q_tail, k_cache, pos,
                                           topk_k=topk_k, k_block=k_block,
                                           plan_blocks=p)
    out = {**plan, "kv_indices": kv_indices, "kv_counts": kv_counts,
           "step": jnp.ones((b,), jnp.int32)}
    if summary == "int8":
        # one-shot quantization of the from-scratch bounds: any future
        # install of the same pages quantizes the same fp32 input, so
        # copying cached page-summary rows stays bit-identical to
        # recomputation (the prefix-cache seeding contract)
        q_lo, q_hi, sc, zp = quantize_summaries(k_min, k_max)
        out.update(k_min=q_lo, k_max=q_hi, k_scale=sc, k_zero=zp)
    else:
        out.update(k_min=k_min, k_max=k_max)
    return out
