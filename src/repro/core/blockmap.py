"""SATA → block-sparse tile maps (the TPU-native execution plan).

The MXU consumes 128×128 (or block-shaped) dense tiles — element-level
sparsity buys nothing.  SATA's key sorting concentrates each query's
selected keys into a contiguous range of the sorted order, so after
permuting K/V by ``kid`` and grouping queries by class, whole
(q_block × k_block) tiles of the score matrix become empty and can be
skipped.  This module derives that plan *in-graph* (pure jnp, jittable,
vmappable over heads) for consumption by ``kernels/sata_attention``.

Outputs per head:
  kv_order  (N,)  int32   — SATA sorted key permutation (Gram-greedy)
  q_order   (N,)  int32   — queries grouped HEAD | GLOB | TAIL
  block_map (nqb, nkb) bool — tile occupancy after both permutations
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.sorting import sort_keys_jax


def query_order_from_sorted(sorted_mask: jax.Array, s_h: int) -> jax.Array:
    """Order queries (HEAD | GLOB | TAIL) and, *within* each class, by the
    centroid of their selected keys in sorted-key space.

    The class bands are the paper's classification; the centroid refine-
    ment is a beyond-paper extension: two HEAD queries whose key sets sit
    at sorted positions ~10 vs ~120 land in different q-blocks, so their
    (q_block × k_block) tiles empty out — at MXU granularity the 3-class
    ordering alone leaves blocks occupied (§Perf documents the delta).
    sorted_mask: (..., N_q, N_k) bool, already column-permuted by kid."""
    n_k = sorted_mask.shape[-1]
    s_h = min(int(s_h), n_k // 2)
    first = sorted_mask[..., :s_h].any(axis=-1)
    last = sorted_mask[..., n_k - s_h:].any(axis=-1)
    # class rank: HEAD=0 (no tail access), GLOB=1 (both), TAIL=2
    rank = jnp.where(~last, 0, jnp.where(first, 1, 2)).astype(jnp.float32)
    m = sorted_mask.astype(jnp.float32)
    pos = jnp.arange(n_k, dtype=jnp.float32)
    centroid = (m * pos).sum(-1) / jnp.clip(m.sum(-1), 1.0)   # (..., N_q)
    key = rank * (2.0 * n_k) + centroid
    return jnp.argsort(key, axis=-1, stable=True).astype(jnp.int32)


def block_occupancy(mask: jax.Array, q_block: int, k_block: int) -> jax.Array:
    """(..., N_q/qb, N_k/kb) bool — any selected pair inside each tile."""
    *b, n_q, n_k = mask.shape
    nqb, nkb = n_q // q_block, n_k // k_block
    m = mask.reshape(*b, nqb, q_block, nkb, k_block)
    return m.any(axis=(-3, -1))


def sata_block_plan(mask: jax.Array, q_block: int, k_block: int,
                    s_h_frac: float = 0.5, seed: int = 0
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full in-graph SATA plan: (kv_order, q_order, block_map).

    mask: (..., N_q, N_k) bool top-k selection mask.
    """
    n_k = mask.shape[-1]
    kv_order = sort_keys_jax(mask, seed=seed)                      # (..., N_k)
    sorted_mask = jnp.take_along_axis(mask, kv_order[..., None, :], axis=-1)
    s_h = max(1, int(s_h_frac * n_k))
    q_order = query_order_from_sorted(sorted_mask, s_h)            # (..., N_q)
    permuted = jnp.take_along_axis(sorted_mask, q_order[..., :, None], axis=-2)
    block_map = block_occupancy(permuted, q_block, k_block)
    return kv_order, q_order, block_map


def block_skip_fraction(block_map: jax.Array) -> jax.Array:
    """Fraction of (q_block × k_block) tiles with zero work."""
    return 1.0 - block_map.mean()


def identity_block_plan(mask: jax.Array, q_block: int, k_block: int
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Unsorted baseline: identity permutations + raw occupancy."""
    *b, n_q, n_k = mask.shape
    kv_order = jnp.broadcast_to(jnp.arange(n_k, dtype=jnp.int32), (*b, n_k))
    q_order = jnp.broadcast_to(jnp.arange(n_q, dtype=jnp.int32), (*b, n_q))
    return kv_order, q_order, block_occupancy(mask, q_block, k_block)
