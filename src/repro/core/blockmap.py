"""SATA → block-sparse tile maps (the TPU-native execution plan).

The MXU consumes 128×128 (or block-shaped) dense tiles — element-level
sparsity buys nothing.  SATA's key sorting concentrates each query's
selected keys into a contiguous range of the sorted order, so after
permuting K/V by ``kid`` and grouping queries by class, whole
(q_block × k_block) tiles of the score matrix become empty and can be
skipped.  This module derives that plan *in-graph* (pure jnp, jittable,
vmappable over heads) for consumption by ``kernels/sata_attention``.

Outputs per head:
  kv_order  (N,)  int32   — SATA sorted key permutation (Gram-greedy)
  q_order   (N,)  int32   — queries grouped HEAD | GLOB | TAIL
  block_map (nqb, nkb) bool — tile occupancy after both permutations

``compact_kv_plan`` turns the boolean map into the *scheduled* form the
compacted-grid kernel consumes: per (bh, q_block) a padded ascending
list of occupied k-block indices plus a count, so the Pallas grid walks
only occupied slots and the BlockSpec index maps never point the DMA
engine at an empty tile.

The plan-from-chunks constructors (``occupancy_from_score_chunk``,
``occupancy_from_scores_chunked``, ``compact_plan_from_chunks``) build
the same schedule from *streamed* ``q_chunk × Sk`` score tiles and a
per-row top-k threshold, so neither the (BH, Sq, Sk) score tensor nor
the boolean mask is ever materialized — the selection state that
persists is O(Sq) thresholds plus the block-granular plan.
``occupancy_bound`` turns concrete plan statistics into the static
``max_kv_blocks`` bound jitted serving paths need for a compact grid.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sorting import sort_keys_jax


def query_order_from_sorted(sorted_mask: jax.Array, s_h: int) -> jax.Array:
    """Order queries (HEAD | GLOB | TAIL) and, *within* each class, by the
    centroid of their selected keys in sorted-key space.

    The class bands are the paper's classification; the centroid refine-
    ment is a beyond-paper extension: two HEAD queries whose key sets sit
    at sorted positions ~10 vs ~120 land in different q-blocks, so their
    (q_block × k_block) tiles empty out — at MXU granularity the 3-class
    ordering alone leaves blocks occupied (§Perf documents the delta).
    sorted_mask: (..., N_q, N_k) bool, already column-permuted by kid."""
    n_k = sorted_mask.shape[-1]
    s_h = min(int(s_h), n_k // 2)
    first = sorted_mask[..., :s_h].any(axis=-1)
    last = sorted_mask[..., n_k - s_h:].any(axis=-1)
    # class rank: HEAD=0 (no tail access), GLOB=1 (both), TAIL=2
    rank = jnp.where(~last, 0, jnp.where(first, 1, 2)).astype(jnp.float32)
    m = sorted_mask.astype(jnp.float32)
    pos = jnp.arange(n_k, dtype=jnp.float32)
    centroid = (m * pos).sum(-1) / jnp.clip(m.sum(-1), 1.0)   # (..., N_q)
    key = rank * (2.0 * n_k) + centroid
    return jnp.argsort(key, axis=-1, stable=True).astype(jnp.int32)


def block_occupancy(mask: jax.Array, q_block: int, k_block: int) -> jax.Array:
    """(..., N_q/qb, N_k/kb) bool — any selected pair inside each tile."""
    *b, n_q, n_k = mask.shape
    nqb, nkb = n_q // q_block, n_k // k_block
    m = mask.reshape(*b, nqb, q_block, nkb, k_block)
    return m.any(axis=(-3, -1))


def sata_block_plan(mask: jax.Array, q_block: int, k_block: int,
                    s_h_frac: float = 0.5, seed: int = 0
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full in-graph SATA plan: (kv_order, q_order, block_map).

    mask: (..., N_q, N_k) bool top-k selection mask.
    """
    n_k = mask.shape[-1]
    kv_order = sort_keys_jax(mask, seed=seed)                      # (..., N_k)
    sorted_mask = jnp.take_along_axis(mask, kv_order[..., None, :], axis=-1)
    s_h = max(1, int(s_h_frac * n_k))
    q_order = query_order_from_sorted(sorted_mask, s_h)            # (..., N_q)
    permuted = jnp.take_along_axis(sorted_mask, q_order[..., :, None], axis=-2)
    block_map = block_occupancy(permuted, q_block, k_block)
    return kv_order, q_order, block_map


def compact_kv_plan(block_map: jax.Array, pad_to: int | None = None,
                    truncate: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Compact each (…, q_block) row of ``block_map`` to the list of
    occupied k-block indices — the scalar-prefetch schedule for the
    compacted-grid kernel.

    block_map: (..., nqb, nkb) bool/int tile occupancy.
    Returns ``(kv_indices (..., nqb, P) int32, kv_counts (..., nqb) int32)``
    with ``P = pad_to or nkb``.

    Slot ``j < count`` holds the j-th occupied k-block index (ascending).
    Padding slots are chosen so the kernel's K/V index map never points
    the DMA at a tile that is not already part of the fetched set:

      * rows with ≥1 occupied tile repeat their *last* occupied index —
        consecutive grid steps then map to the block already resident in
        VMEM and the Pallas pipeline issues no new fetch;
      * fully-empty rows inherit the last occupied index of the nearest
        preceding non-empty row, so the row-boundary transition is a
        no-op re-reference rather than a fetch of an unoccupied tile;
      * *leading* empty rows (no preceding non-empty row) take the
        **first** occupied index of the first non-empty row — the grid's
        unavoidable first-step fetch then lands exactly on the tile that
        row will need, so it costs nothing extra.

    A batch entry whose map is entirely empty (no occupied tile at all)
    falls back to index 0 — some tile must back the very first grid step.

    ``pad_to`` statically narrows the slot dimension (and hence the
    kernel grid): callers that know ``counts.max()`` concretely (eager
    benchmarks, a host-side planner) pass it so grid size scales with the
    occupied-tile count instead of ``nkb``.  It must be ≥ the true max
    count or occupied tiles would be dropped — validated here whenever
    the map is concrete; under jit the caller must pass a static
    over-estimate (the safe default ``None`` keeps the full ``nkb``).
    ``truncate=True`` opts into dropping instead: each row keeps its
    first ``pad_to`` occupied k-blocks (ascending) and counts are
    clamped — the explicit approximation a sub-100-percentile
    ``occupancy_bound`` implies.
    """
    bm = block_map.astype(bool)
    *_, nqb, nkb = bm.shape
    counts = bm.sum(-1).astype(jnp.int32)                       # (..., nqb)
    if pad_to is not None:
        if not truncate and not isinstance(counts, jax.core.Tracer) \
                and pad_to < int(counts.max(initial=0)):
            raise ValueError(
                f"pad_to={pad_to} < max occupancy "
                f"{int(counts.max(initial=0))}: occupied tiles would be "
                f"silently dropped (pass truncate=True to opt in)")
        # clamp BEFORE deriving the padding fill: `last`/`fill` must
        # reference a tile the truncated schedule actually fetches, or
        # empty-row padding would DMA a tile no slot computes on.
        counts = jnp.minimum(counts, pad_to)
    # stable sort of (not occupied) → occupied indices first, ascending
    order = jnp.argsort(~bm, axis=-1, stable=True).astype(jnp.int32)
    last = jnp.take_along_axis(
        order, jnp.maximum(counts - 1, 0)[..., None], axis=-1)[..., 0]
    # forward-fill `last` across q rows for empty rows; leading empties
    # borrow from the first non-empty row.
    valid = counts > 0
    rowid = jnp.where(valid, jnp.arange(nqb, dtype=jnp.int32), -1)
    prev_valid = jax.lax.cummax(rowid, axis=rowid.ndim - 1)     # (..., nqb)
    first_valid = jnp.argmax(valid, axis=-1)[..., None]
    fill_fwd = jnp.take_along_axis(last, jnp.maximum(prev_valid, 0), axis=-1)
    first_occ = order[..., 0]                   # first occupied per row
    fill_bwd = jnp.take_along_axis(first_occ, first_valid, axis=-1)
    fill = jnp.where(prev_valid >= 0, fill_fwd, fill_bwd)       # (..., nqb)
    fill = jnp.where(valid.any(-1, keepdims=True), fill, 0)
    slot = jnp.arange(nkb, dtype=jnp.int32)
    kv_indices = jnp.where(slot < counts[..., None], order, fill[..., None])
    if pad_to is not None:
        kv_indices = kv_indices[..., :pad_to]
    return kv_indices, counts


# ---------------------------------------------------------------------------
# Plan-from-chunks: selection → occupancy → compact plan without ever
# materializing the (BH, Sq, Sk) score tensor or boolean mask
# ---------------------------------------------------------------------------

def bisect_select(scores: jax.Array, threshold: jax.Array) -> jax.Array:
    """THE selection predicate: ``bf16(score) >= bf16(threshold)`` — the
    exact compare ``kth_largest_bisect``'s counting pass runs, so its
    ``count >= k`` loop invariant transfers to whoever applies it.
    Every consumer (the bisect itself, mask construction, occupancy
    reduction, the threshold-mode kernel, the chunked differentiation
    rule) MUST call this one helper: a drifted reimplementation would
    let the occupancy map and the kernel disagree about which tiles
    hold work, silently dropping selected keys."""
    return scores.astype(jnp.bfloat16) >= threshold.astype(jnp.bfloat16)


def occupancy_from_score_chunk(scores_chunk: jax.Array, thr_chunk: jax.Array,
                               admissible: jax.Array, q_block: int,
                               k_block: int) -> jax.Array:
    """Tile-level occupancy reduction for one streamed score chunk.

    scores_chunk: (BH, C, Sk) fp32 *raw* (unmasked) scaled scores;
    thr_chunk:    (BH, C, 1) fp32 per-row top-k threshold
                  (``kth_largest_bisect`` output);
    admissible:   (BH|1, C, Sk) bool causal/validity mask.
    Returns (BH, C/q_block, Sk/k_block) bool tile occupancy.

    The compare is the bisect-consistent bf16 one (see
    ``kth_largest_bisect``): an admissible entry is selected iff
    ``bf16(score) >= bf16(thr)`` — the exact predicate the threshold-mode
    kernel re-evaluates per tile, so the occupancy map and the kernel
    agree on which tiles hold work.
    """
    bh, c, sk = scores_chunk.shape
    sel = bisect_select(scores_chunk, thr_chunk) & admissible
    return sel.reshape(bh, c // q_block, q_block,
                       sk // k_block, k_block).any(axis=(2, 4))


def resolve_sel_chunk(chunk: Optional[int], s: int, q_block: int) -> int:
    """Largest multiple of ``q_block`` that is <= ``chunk`` (default
    ``q_block``) and divides ``s`` — the streaming granularity of the
    chunked selection passes.  Requires ``s % q_block == 0``."""
    assert s % q_block == 0, (s, q_block)
    c = min(chunk or q_block, s)
    c = max(q_block, (c // q_block) * q_block)
    while s % c:
        c -= q_block
    return c


def stream_score_chunks(q: jax.Array, k: jax.Array, fn, *, chunk: int,
                        sm_scale: Optional[float] = None,
                        causal: bool = True,
                        q_pos: Optional[jax.Array] = None,
                        k_pos: Optional[jax.Array] = None,
                        extras: Tuple[jax.Array, ...] = (),
                        remat: bool = False):
    """The one streaming loop every chunked-selection consumer shares:
    materialize one (BH, chunk, Sk) scaled score tile + its causal
    admissibility mask at a time and apply
    ``fn(scores_chunk, admissible, *extra_chunks)``.

    ``extras`` are (BH, Sq, …) arrays chunked alongside ``q`` (e.g. the
    per-row thresholds on a re-stream).  ``remat=True`` wraps each chunk
    in ``jax.checkpoint`` so a differentiated caller recomputes the tile
    in backward instead of saving it.  Returns ``fn``'s outputs stacked
    on a leading (Sq/chunk) axis.

    Centralized on purpose: the bisect-consistency contract (score
    scaling, NEG_INF admissibility, one tile live at a time) must stay
    identical between threshold pass, occupancy re-stream, and the
    chunked differentiation rule — one loop means they cannot drift.
    """
    bh, s, d = q.shape
    sk = k.shape[1]
    assert s % chunk == 0, (s, chunk)
    scale = float(sm_scale if sm_scale is not None else 1.0 / np.sqrt(d))
    n = s // chunk
    q_pos = (jnp.arange(s, dtype=jnp.int32) if q_pos is None
             else q_pos.astype(jnp.int32))
    kp = (jnp.arange(sk, dtype=jnp.int32) if k_pos is None
          else k_pos.astype(jnp.int32))
    qs = jnp.moveaxis(q.reshape(bh, n, chunk, d), 1, 0)
    ps = q_pos.reshape(n, chunk)
    exs = tuple(jnp.moveaxis(e.reshape(bh, n, chunk, *e.shape[2:]), 1, 0)
                for e in extras)

    def one(args):
        q_c, p_c, *e_c = args
        sc = jnp.einsum("bqd,bkd->bqk", q_c, k,
                        preferred_element_type=jnp.float32) * scale
        if causal:
            adm = (kp[None, :] <= p_c[:, None])[None]
        else:
            adm = jnp.ones((1, chunk, sk), dtype=bool)
        return fn(sc, adm, *e_c)

    if remat:
        one = jax.checkpoint(one)
    return jax.lax.map(one, (qs, ps) + exs)


def occupancy_from_scores_chunked(
    q: jax.Array, k: jax.Array, thresholds: jax.Array, *,
    q_block: int, k_block: int, sm_scale: Optional[float] = None,
    causal: bool = True, q_pos: Optional[jax.Array] = None,
    k_pos: Optional[jax.Array] = None, chunk: Optional[int] = None,
) -> jax.Array:
    """Re-stream ``q_chunk × Sk`` score tiles against precomputed per-row
    thresholds and emit the (BH, nqb, nkb) tile occupancy map directly
    from tile-level reductions — the boolean (BH, Sq, Sk) mask is never
    built.  Peak live selection state is one (BH, chunk, Sk) tile.

    q: (BH, Sq, D); k: (BH, Sk, D); thresholds: (BH, Sq, 1) fp32.
    """
    bh, sq, _ = q.shape
    sk = k.shape[1]
    assert sk % k_block == 0, (sk, k_block)
    chunk = resolve_sel_chunk(chunk, sq, q_block)
    occ = stream_score_chunks(
        q, k,
        lambda sc, adm, t_c: occupancy_from_score_chunk(sc, t_c, adm,
                                                        q_block, k_block),
        chunk=chunk, sm_scale=sm_scale, causal=causal, q_pos=q_pos,
        k_pos=k_pos, extras=(thresholds,))          # (n, BH, chunk/qb, nkb)
    return jnp.moveaxis(occ, 0, 1).reshape(bh, sq // q_block, sk // k_block)


def compact_plan_from_chunks(
    q: jax.Array, k: jax.Array, thresholds: jax.Array, *,
    q_block: int, k_block: int, sm_scale: Optional[float] = None,
    causal: bool = True, q_pos: Optional[jax.Array] = None,
    k_pos: Optional[jax.Array] = None, chunk: Optional[int] = None,
    pad_to: Optional[int] = None, truncate: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Selection → compact schedule in one call, mask-free: streamed
    occupancy (``occupancy_from_scores_chunked``) followed by
    ``compact_kv_plan``.  Returns (block_map, kv_indices, kv_counts)."""
    bm = occupancy_from_scores_chunked(
        q, k, thresholds, q_block=q_block, k_block=k_block,
        sm_scale=sm_scale, causal=causal, q_pos=q_pos, k_pos=k_pos,
        chunk=chunk)
    kv_indices, kv_counts = compact_kv_plan(bm, pad_to=pad_to,
                                            truncate=truncate)
    return bm, kv_indices, kv_counts


def occupancy_bound(kv_counts, pct: float = 100.0) -> int:
    """Static per-row occupancy bound from concrete plan statistics.

    ``kv_counts``: (…, nqb) int occupied-k-block counts from a
    calibration run (``compact_kv_plan`` / ``compact_plan_from_chunks``).
    Returns ``ceil(pct-th percentile)`` as a plain int, floored at 1 —
    the value to pass as ``max_kv_blocks`` so *jitted* serving paths get
    a compact grid without a concrete mask in hand.

    ``pct=100`` is exact (no tile ever dropped).  Lower percentiles
    trade tail rows for a smaller grid: a row whose occupancy exceeds
    the bound keeps its first ``bound`` occupied k-blocks (ascending)
    and drops the rest — pass ``truncate=True`` to ``compact_kv_plan``
    to opt into that approximation on concrete maps (under jit the
    validation cannot run and truncation is implicit).
    Host-side by design: raises on tracers (derive the bound offline,
    then bake it in as a static argument).
    """
    if isinstance(kv_counts, jax.core.Tracer):
        raise TypeError(
            "occupancy_bound needs concrete counts — run the planner on "
            "calibration data outside jit, then pass the result as the "
            "static max_kv_blocks")
    counts = np.asarray(kv_counts).reshape(-1)
    if counts.size == 0:
        return 1
    return max(1, int(np.ceil(np.percentile(counts, pct))))


def block_skip_fraction(block_map: jax.Array) -> jax.Array:
    """Fraction of (q_block × k_block) tiles with zero work."""
    return 1.0 - block_map.mean()


def fixed_occupancy_map(rng, bh: int, nqb: int, nkb: int, occ: int):
    """Host-side (numpy) random block map with exactly ``occ`` occupied
    k-blocks per (bh, q_row) — the concentrated regime SATA's key sort
    produces, and the shape benchmarks/roofline use so the padded compact
    grid (`P = occ`) actually shrinks (a Bernoulli map almost surely has
    one fully-occupied row pinning P at ``nkb``)."""
    bm = np.zeros((bh, nqb, nkb), dtype=bool)
    for b in range(bh):
        for i in range(nqb):
            bm[b, i, rng.choice(nkb, size=occ, replace=False)] = True
    return bm


def identity_block_plan(mask: jax.Array, q_block: int, k_block: int
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Unsorted baseline: identity permutations + raw occupancy."""
    *b, n_q, n_k = mask.shape
    kv_order = jnp.broadcast_to(jnp.arange(n_k, dtype=jnp.int32), (*b, n_k))
    q_order = jnp.broadcast_to(jnp.arange(n_q, dtype=jnp.int32), (*b, n_q))
    return kv_order, q_order, block_occupancy(mask, q_block, k_block)
