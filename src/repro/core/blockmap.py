"""SATA → block-sparse tile maps (the TPU-native execution plan).

The MXU consumes 128×128 (or block-shaped) dense tiles — element-level
sparsity buys nothing.  SATA's key sorting concentrates each query's
selected keys into a contiguous range of the sorted order, so after
permuting K/V by ``kid`` and grouping queries by class, whole
(q_block × k_block) tiles of the score matrix become empty and can be
skipped.  This module derives that plan *in-graph* (pure jnp, jittable,
vmappable over heads) for consumption by ``kernels/sata_attention``.

Outputs per head:
  kv_order  (N,)  int32   — SATA sorted key permutation (Gram-greedy)
  q_order   (N,)  int32   — queries grouped HEAD | GLOB | TAIL
  block_map (nqb, nkb) bool — tile occupancy after both permutations

``compact_kv_plan`` turns the boolean map into the *scheduled* form the
compacted-grid kernel consumes: per (bh, q_block) a padded ascending
list of occupied k-block indices plus a count, so the Pallas grid walks
only occupied slots and the BlockSpec index maps never point the DMA
engine at an empty tile.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.sorting import sort_keys_jax


def query_order_from_sorted(sorted_mask: jax.Array, s_h: int) -> jax.Array:
    """Order queries (HEAD | GLOB | TAIL) and, *within* each class, by the
    centroid of their selected keys in sorted-key space.

    The class bands are the paper's classification; the centroid refine-
    ment is a beyond-paper extension: two HEAD queries whose key sets sit
    at sorted positions ~10 vs ~120 land in different q-blocks, so their
    (q_block × k_block) tiles empty out — at MXU granularity the 3-class
    ordering alone leaves blocks occupied (§Perf documents the delta).
    sorted_mask: (..., N_q, N_k) bool, already column-permuted by kid."""
    n_k = sorted_mask.shape[-1]
    s_h = min(int(s_h), n_k // 2)
    first = sorted_mask[..., :s_h].any(axis=-1)
    last = sorted_mask[..., n_k - s_h:].any(axis=-1)
    # class rank: HEAD=0 (no tail access), GLOB=1 (both), TAIL=2
    rank = jnp.where(~last, 0, jnp.where(first, 1, 2)).astype(jnp.float32)
    m = sorted_mask.astype(jnp.float32)
    pos = jnp.arange(n_k, dtype=jnp.float32)
    centroid = (m * pos).sum(-1) / jnp.clip(m.sum(-1), 1.0)   # (..., N_q)
    key = rank * (2.0 * n_k) + centroid
    return jnp.argsort(key, axis=-1, stable=True).astype(jnp.int32)


def block_occupancy(mask: jax.Array, q_block: int, k_block: int) -> jax.Array:
    """(..., N_q/qb, N_k/kb) bool — any selected pair inside each tile."""
    *b, n_q, n_k = mask.shape
    nqb, nkb = n_q // q_block, n_k // k_block
    m = mask.reshape(*b, nqb, q_block, nkb, k_block)
    return m.any(axis=(-3, -1))


def sata_block_plan(mask: jax.Array, q_block: int, k_block: int,
                    s_h_frac: float = 0.5, seed: int = 0
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full in-graph SATA plan: (kv_order, q_order, block_map).

    mask: (..., N_q, N_k) bool top-k selection mask.
    """
    n_k = mask.shape[-1]
    kv_order = sort_keys_jax(mask, seed=seed)                      # (..., N_k)
    sorted_mask = jnp.take_along_axis(mask, kv_order[..., None, :], axis=-1)
    s_h = max(1, int(s_h_frac * n_k))
    q_order = query_order_from_sorted(sorted_mask, s_h)            # (..., N_q)
    permuted = jnp.take_along_axis(sorted_mask, q_order[..., :, None], axis=-2)
    block_map = block_occupancy(permuted, q_block, k_block)
    return kv_order, q_order, block_map


def compact_kv_plan(block_map: jax.Array, pad_to: int | None = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Compact each (…, q_block) row of ``block_map`` to the list of
    occupied k-block indices — the scalar-prefetch schedule for the
    compacted-grid kernel.

    block_map: (..., nqb, nkb) bool/int tile occupancy.
    Returns ``(kv_indices (..., nqb, P) int32, kv_counts (..., nqb) int32)``
    with ``P = pad_to or nkb``.

    Slot ``j < count`` holds the j-th occupied k-block index (ascending).
    Padding slots are chosen so the kernel's K/V index map never points
    the DMA at a tile that is not already part of the fetched set:

      * rows with ≥1 occupied tile repeat their *last* occupied index —
        consecutive grid steps then map to the block already resident in
        VMEM and the Pallas pipeline issues no new fetch;
      * fully-empty rows inherit the last occupied index of the nearest
        preceding non-empty row, so the row-boundary transition is a
        no-op re-reference rather than a fetch of an unoccupied tile;
      * *leading* empty rows (no preceding non-empty row) take the
        **first** occupied index of the first non-empty row — the grid's
        unavoidable first-step fetch then lands exactly on the tile that
        row will need, so it costs nothing extra.

    A batch entry whose map is entirely empty (no occupied tile at all)
    falls back to index 0 — some tile must back the very first grid step.

    ``pad_to`` statically narrows the slot dimension (and hence the
    kernel grid): callers that know ``counts.max()`` concretely (eager
    benchmarks, a host-side planner) pass it so grid size scales with the
    occupied-tile count instead of ``nkb``.  It must be ≥ the true max
    count or occupied tiles would be dropped — validated here whenever
    the map is concrete; under jit the caller must pass a static
    over-estimate (the safe default ``None`` keeps the full ``nkb``).
    """
    bm = block_map.astype(bool)
    *_, nqb, nkb = bm.shape
    counts = bm.sum(-1).astype(jnp.int32)                       # (..., nqb)
    # stable sort of (not occupied) → occupied indices first, ascending
    order = jnp.argsort(~bm, axis=-1, stable=True).astype(jnp.int32)
    last = jnp.take_along_axis(
        order, jnp.maximum(counts - 1, 0)[..., None], axis=-1)[..., 0]
    # forward-fill `last` across q rows for empty rows; leading empties
    # borrow from the first non-empty row.
    valid = counts > 0
    rowid = jnp.where(valid, jnp.arange(nqb, dtype=jnp.int32), -1)
    prev_valid = jax.lax.cummax(rowid, axis=rowid.ndim - 1)     # (..., nqb)
    first_valid = jnp.argmax(valid, axis=-1)[..., None]
    fill_fwd = jnp.take_along_axis(last, jnp.maximum(prev_valid, 0), axis=-1)
    first_occ = order[..., 0]                   # first occupied per row
    fill_bwd = jnp.take_along_axis(first_occ, first_valid, axis=-1)
    fill = jnp.where(prev_valid >= 0, fill_fwd, fill_bwd)       # (..., nqb)
    fill = jnp.where(valid.any(-1, keepdims=True), fill, 0)
    slot = jnp.arange(nkb, dtype=jnp.int32)
    kv_indices = jnp.where(slot < counts[..., None], order, fill[..., None])
    if pad_to is not None:
        if not isinstance(counts, jax.core.Tracer) \
                and pad_to < int(counts.max(initial=0)):
            raise ValueError(
                f"pad_to={pad_to} < max occupancy "
                f"{int(counts.max(initial=0))}: occupied tiles would be "
                f"silently dropped")
        kv_indices = kv_indices[..., :pad_to]
    return kv_indices, counts


def block_skip_fraction(block_map: jax.Array) -> jax.Array:
    """Fraction of (q_block × k_block) tiles with zero work."""
    return 1.0 - block_map.mean()


def fixed_occupancy_map(rng, bh: int, nqb: int, nkb: int, occ: int):
    """Host-side (numpy) random block map with exactly ``occ`` occupied
    k-blocks per (bh, q_row) — the concentrated regime SATA's key sort
    produces, and the shape benchmarks/roofline use so the padded compact
    grid (`P = occ`) actually shrinks (a Bernoulli map almost surely has
    one fully-occupied row pinning P at ``nkb``)."""
    import numpy as np
    bm = np.zeros((bh, nqb, nkb), dtype=bool)
    for b in range(bh):
        for i in range(nqb):
            bm[b, i, rng.choice(nkb, size=occ, replace=False)] = True
    return bm


def identity_block_plan(mask: jax.Array, q_block: int, k_block: int
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Unsorted baseline: identity permutations + raw occupancy."""
    *b, n_q, n_k = mask.shape
    kv_order = jnp.broadcast_to(jnp.arange(n_k, dtype=jnp.int32), (*b, n_k))
    q_order = jnp.broadcast_to(jnp.arange(n_q, dtype=jnp.int32), (*b, n_q))
    return kv_order, q_order, block_occupancy(mask, q_block, k_block)
