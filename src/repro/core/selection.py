"""Top-k selection primitives — shared by the model layer and the
kernel planner (keeping ``kernels/`` free of ``models/`` imports).

``kth_largest_bisect`` is the distributed/streaming-friendly top-k
threshold; ``select_thresholds_chunked`` is pass 1 of the chunked
selection pipeline (fused with the tile-occupancy reduction of pass 2):
it streams ``chunk × Sk`` score tiles through
``core.blockmap.stream_score_chunks`` so the dense (BH, Sq, Sk) score
tensor is never materialized — only (BH, Sq, 1) thresholds and the
block-granular occupancy map persist.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.blockmap import (bisect_select,  # noqa: F401  (re-export)
                                 occupancy_from_score_chunk,
                                 resolve_sel_chunk, stream_score_chunks)

NEG_INF = -2.0 ** 30


def kth_largest_bisect(scores: jax.Array, k: int, iters: int = 16
                       ) -> jax.Array:
    """Distributed-friendly top-k threshold: fixed-iteration bisection on
    the score range, converging to the k-th largest value.

    Every iteration is an elementwise compare + a tiny row reduction —
    fully shardable along the key dim (a sequence-sharded KV cache needs
    only (B,KV,G,1)-sized all-reduces per step instead of resharding the
    whole score tensor for a sort), and fully *chunkable* along the query
    dim (every reduction is row-local, so the chunked selection pass
    gets bit-identical thresholds).  Counting runs on a bf16 copy (half
    the bandwidth of the dominant pass; selection boundaries are already
    fuzzy at bf16 score precision) and 16 iterations resolve the
    threshold to range/2^16.  Returns a threshold t with
    count(scores >= t) >= k (ties may admit a few extra keys — the same
    superset semantics as the sort threshold).

    ``k`` may also be an array broadcasting against the row-count shape
    ``scores.shape[:-1] + (1,)`` — each row then converges to ITS OWN
    k-th largest value (``cnt >= k`` is elementwise).  The decode QoS
    ladder leans on this: per-slot degraded plan budgets are just a
    (B, 1, 1) ``k``, no re-trace, no second kernel."""
    valid = scores > NEG_INF / 2
    sc = jnp.where(valid, scores, jnp.inf)
    lo = jnp.minimum(jnp.min(sc, axis=-1, keepdims=True), 0.0) - 1.0
    hi = jnp.max(jnp.where(valid, scores, -jnp.inf), axis=-1, keepdims=True)
    cnt_src = jnp.where(valid, scores, -jnp.inf).astype(jnp.bfloat16)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum(bisect_select(cnt_src, mid).astype(jnp.int32),
                      axis=-1, keepdims=True)
        take = cnt >= k                    # threshold lies at or above mid
        return (jnp.where(take, mid, lo), jnp.where(take, hi, mid))

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    # Loop invariant: count(cnt_src >= bf16(lo)) >= k.  The caller must
    # apply the mask with the SAME bf16 comparison or the invariant
    # breaks (fp32 compare against a bf16-counted threshold undershoots).
    return jax.lax.stop_gradient(lo)


def topk_mask_bisect(scores: jax.Array, k: int) -> jax.Array:
    """Boolean top-k mask via bisection, compare-consistent with the
    bf16 counting pass (guarantees >= k selected per row)."""
    lo = kth_largest_bisect(scores, k)
    valid = scores > NEG_INF / 2
    return bisect_select(jnp.where(valid, scores, -jnp.inf), lo)


def select_thresholds_chunked(q: jax.Array, k: jax.Array, k_sel: int, *,
                              q_pos: Optional[jax.Array] = None,
                              k_pos: Optional[jax.Array] = None,
                              causal: bool = True,
                              sm_scale: Optional[float] = None,
                              chunk: Optional[int] = None,
                              q_block: int = 128, k_block: int = 128
                              ) -> Tuple[jax.Array, jax.Array]:
    """Chunked selection, passes 1+2 fused in one stream: per resident
    ``chunk × Sk`` score tile, bisect each row's top-k threshold
    (row-local ⇒ bit-identical to the full-matrix bisect) and reduce
    the same tile to block occupancy — the compare the occupancy uses
    is the exact bf16 predicate the threshold-mode kernel re-evaluates.

    q: (BH, Sq, D); k: (BH, Sk, D).
    Returns ``(thresholds (BH, Sq, 1) fp32, block_map (BH, nqb, nkb))``.
    """
    bh, s, d = q.shape
    sk = k.shape[1]
    assert sk % k_block == 0, (sk, k_block)
    chunk = resolve_sel_chunk(chunk, s, q_block)

    def _fn(sc, adm):
        thr_c = kth_largest_bisect(jnp.where(adm, sc, NEG_INF), k_sel)
        occ_c = occupancy_from_score_chunk(sc, thr_c, adm, q_block, k_block)
        return thr_c, occ_c

    thr, occ = stream_score_chunks(q, k, _fn, chunk=chunk,
                                   sm_scale=sm_scale, causal=causal,
                                   q_pos=q_pos, k_pos=k_pos)
    thr = jnp.moveaxis(thr, 0, 1).reshape(bh, s, 1)
    bm = jnp.moveaxis(occ, 0, 1).reshape(bh, s // q_block, sk // k_block)
    return thr, bm
