"""Paged serving KV cache: global page pool + per-slot page tables.

Contiguous serving reserves one ``(max_len, KV, D)`` region per batch
slot, so ``max_len`` is a static worst-case bound and HBM sits reserved
for prefixes that never materialize.  The paged layout replaces it with

  k_pages / v_pages  (n_pages, page, KV, D) — one global physical pool
                     per layer; a page holds ``page`` consecutive token
                     rows of ONE slot's cache;
  page_table         (B, max_pages) int32 — per-slot logical→physical
                     page map.  Logical page ``pos // page`` of slot
                     ``b`` lives at physical page ``page_table[b, lp]``.

Pages are allocated on append (the first write into a logical page maps
a physical one) and freed when the slot's request completes, so a slot
only ever holds ``ceil((pos+1)/page)`` pages and pool exhaustion turns
into *backpressure on the claim loop* (the serving driver defers new
requests, or stalls a slot one step at a page boundary) instead of a
shape error.

Physical page 0 is the reserved **overflow page**: unmapped table
entries point at it, so a write from a stalled slot (its next page
could not be allocated this step) lands there harmlessly — overflow
contents are never read as valid data because every read path masks
key positions ``<= pos`` and a stall can only happen at a page boundary
(positions inside an already-written page always have their page
mapped).  The stalled token is simply re-fed once a page frees; the
incremental plan summaries tolerate the replay because min/max
absorption of an identical key row is idempotent.

The allocator is deliberately **host-side** (plain numpy): allocation
is a serving-control decision made between jitted steps, exactly like
slot claiming.  Device code only ever consumes the resulting table.

SATA decode composes with near-zero kernel change: the decode plan
(``core/decode_plan.py``) keeps block summaries per *logical* page and
emits logical page indices; only the kernel's K/V BlockSpec index maps
dereference the page table (one extra scalar-prefetch operand — grid
and flash inner loop untouched).  This requires the decode k-block edge
to equal the page size (plan blocks ARE pages).
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

OVERFLOW_PAGE = 0


def logical_kv_view(pages: jnp.ndarray, page_table: jnp.ndarray
                    ) -> jnp.ndarray:
    """Gather the pool back into the contiguous logical layout:
    pages (n_pages, page, KV, D) + table (B, max_pages)
    → (B, max_pages·page, KV, D).  Unmapped entries resolve to the
    overflow page — whatever lives there is masked by position on every
    read path.  This materializes the full logical cache, so it backs
    only the paths that already stream all cached K (the dense decode
    fallback and the exact full re-plan)."""
    b, mp = page_table.shape
    g = jnp.take(pages, page_table, axis=0)       # (B, mp, page, KV, D)
    return g.reshape(b, mp * g.shape[2], *pages.shape[2:])


class PageAllocator:
    """Host-side free-list allocator for the paged pool.

    Positions advance sequentially from 0 within a slot, so logical
    pages map strictly in order; ``n_mapped[slot]`` is both the mapped
    count and the next logical page to map.  ``table`` mirrors the
    device page table (unmapped = OVERFLOW_PAGE)."""

    def __init__(self, n_pages: int, batch_slots: int, max_pages: int,
                 page: int):
        assert n_pages >= 2, "pool needs >= 1 usable page + overflow"
        self.n_pages = int(n_pages)
        self.page = int(page)
        self.max_pages = int(max_pages)
        # LIFO free list keeps recently-freed (cache-warm) pages hot
        self.free: List[int] = list(range(n_pages - 1, OVERFLOW_PAGE, -1))
        self.table = np.full((batch_slots, max_pages), OVERFLOW_PAGE,
                             np.int32)
        self.n_mapped = np.zeros(batch_slots, np.int32)
        self.pages_in_use_peak = 0

    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def pages_in_use(self) -> int:
        return (self.n_pages - 1) - len(self.free)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache rows."""
        return -(-max(int(n_tokens), 0) // self.page)

    def can_admit(self, n_new_pages: int = 1) -> bool:
        """Admission control for the claim loop: only claim a slot when
        the pool can back its first pages — exhaustion defers the
        request instead of landing it on the overflow page."""
        return len(self.free) >= n_new_pages

    def ensure(self, slot: int, pos: int) -> bool:
        """Map physical pages for ``slot`` covering position ``pos``.
        Returns False (slot must stall this step) on pool exhaustion;
        any pages mapped before running dry stay mapped."""
        need = pos // self.page + 1
        while self.n_mapped[slot] < need:
            if not self.free:
                return False
            phys = self.free.pop()
            self.table[slot, self.n_mapped[slot]] = phys
            self.n_mapped[slot] += 1
        self.pages_in_use_peak = max(self.pages_in_use_peak,
                                     self.pages_in_use)
        return True

    def free_slot(self, slot: int) -> int:
        """Release all of a finished slot's pages back to the pool.
        Stale table entries are reset to the overflow page (reads are
        position-masked anyway, but a recycled physical page must not
        stay visible through an old slot's table row)."""
        n = int(self.n_mapped[slot])
        for lp in range(n):
            self.free.append(int(self.table[slot, lp]))
        self.table[slot, :] = OVERFLOW_PAGE
        self.n_mapped[slot] = 0
        return n

    def stats(self, *, row_bytes: int, layers: int = 1) -> Dict[str, int]:
        """Pool occupancy in bytes.  ``row_bytes`` = bytes of ONE token
        row of K+V for one layer (2 · KV · D · itemsize); ``layers``
        scales to the stacked cache."""
        page_bytes = self.page * row_bytes * layers
        return {
            "n_pages": self.n_pages,
            "page_size": self.page,
            "pages_in_use": self.pages_in_use,
            "pages_in_use_peak": self.pages_in_use_peak,
            "hbm_reserved_bytes": self.n_pages * page_bytes,
            "hbm_used_peak_bytes": self.pages_in_use_peak * page_bytes,
        }
