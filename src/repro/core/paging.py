"""Paged serving KV cache: global page pool + per-slot page tables.

Contiguous serving reserves one ``(max_len, KV, D)`` region per batch
slot, so ``max_len`` is a static worst-case bound and HBM sits reserved
for prefixes that never materialize.  The paged layout replaces it with

  k_pages / v_pages  (n_pages, page, KV, D) — one global physical pool
                     per layer; a page holds ``page`` consecutive token
                     rows of ONE slot's cache;
  page_table         (B, max_pages) int32 — per-slot logical→physical
                     page map.  Logical page ``pos // page`` of slot
                     ``b`` lives at physical page ``page_table[b, lp]``.

Pages are allocated on append (the first write into a logical page maps
a physical one) and freed when the slot's request completes, so a slot
only ever holds ``ceil((pos+1)/page)`` pages and pool exhaustion turns
into *backpressure on the claim loop* (the serving driver defers new
requests, or stalls a slot one step at a page boundary) instead of a
shape error.

Physical page 0 is the reserved **overflow page**: unmapped table
entries point at it, so a write from a stalled slot (its next page
could not be allocated this step) lands there harmlessly — overflow
contents are never read as valid data because every read path masks
key positions ``<= pos`` and a stall can only happen at a page boundary
(positions inside an already-written page always have their page
mapped).  The stalled token is simply re-fed once a page frees; the
incremental plan summaries tolerate the replay because min/max
absorption of an identical key row is idempotent.

The allocator is deliberately **host-side** (plain numpy): allocation
is a serving-control decision made between jitted steps, exactly like
slot claiming.  Device code only ever consumes the resulting table.

**Shared-prefix page cache** (PR 5): physical pages carry a
**refcount**, so one page can back the same logical block of several
slots at once.  ``PrefixCache`` keeps a prompt-prefix trie keyed on
page-aligned token-hash chains: each node is one physical page worth
of prompt tokens, children extend the chain, and a claim first walks
the trie (``match``) to map the longest cached prefix into the new
slot's page table — refcount bump, zero copy, and the prefill pass
runs only over the unmatched tail.  Shared pages are **immutable while
``refcount > 1``**: any append that would land in one goes through
copy-on-write (``ensure_writable``: allocate a fresh page, have the
driver copy the rows device-side, remap the slot's table entry,
decrement the old page) — in particular a prompt whose final page is
partial gets that page registered in the trie at install, so the
owner's own first decode append CoWs it and the trie keeps the
pristine prompt-only page.  ``free_slot`` decrements instead of
recycling, so completing (or preempting) a request never frees a page
the trie or another slot still references.

SATA decode composes with near-zero kernel change: the decode plan
(``core/decode_plan.py``) keeps block summaries per *logical* page and
emits logical page indices; only the kernel's K/V BlockSpec index maps
dereference the page table (one extra scalar-prefetch operand — grid
and flash inner loop untouched).  This requires the decode k-block edge
to equal the page size (plan blocks ARE pages).
"""
from __future__ import annotations

import hashlib
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

OVERFLOW_PAGE = 0


class PageIntegrityError(RuntimeError):
    """A host-swap payload failed its checksum at restore time — the
    handle's pages were corrupted while parked in host memory.  The
    serving driver quarantines the handle (``discard_handle`` +
    ``PrefixCache.invalidate_pages``) and recovers the victim request
    by re-prefill; corrupted KV is never scattered back to the pool."""


def _payload_checksums(payload: Any) -> Dict[str, int]:
    """crc32 per payload array (the ``gather_phys_pages`` dict layout;
    a bare array checks under the empty key).  crc32 detects every
    single-byte flip, which is the failure model ``corrupt_page``
    injects — and any burst under 32 bits."""
    if isinstance(payload, dict):
        return {k: zlib.crc32(np.ascontiguousarray(v).tobytes())
                for k, v in payload.items()}
    return {"": zlib.crc32(np.ascontiguousarray(payload).tobytes())}

# Host-swap payload gather/scatter callbacks: the allocator decides
# WHICH physical pages move (host-side policy), the serving driver owns
# HOW their device rows move (``models.decode.gather_phys_pages`` /
# ``scatter_phys_pages``).  Payloads are opaque to the allocator.
GatherFn = Callable[[List[int]], Any]
ScatterFn = Callable[[List[int], Any], None]


def logical_kv_view(pages: jnp.ndarray, page_table: jnp.ndarray
                    ) -> jnp.ndarray:
    """Gather the pool back into the contiguous logical layout:
    pages (n_pages, page, KV, D) + table (B, max_pages)
    → (B, max_pages·page, KV, D).  Unmapped entries resolve to the
    overflow page — whatever lives there is masked by position on every
    read path.  This materializes the full logical cache, so it backs
    only the paths that already stream all cached K (the dense decode
    fallback and the exact full re-plan)."""
    b, mp = page_table.shape
    g = jnp.take(pages, page_table, axis=0)       # (B, mp, page, KV, D)
    return g.reshape(b, mp * g.shape[2], *pages.shape[2:])


# --- per-physical-page SATA block-summary cache -------------------------
# Shared-prefix installs copy a cached page's summary row instead of
# recomputing it from the page's keys (PR 5).  The rows mirror the plan
# state's block-summary backend (``core/decode_plan.py``): fp32 stores
# exact elementwise bounds; int8 stores conservative quantized codes
# plus per-page fp32 (scale, zero).  Since a given page's summary row
# is always produced by quantizing the SAME from-scratch fp32 bounds,
# copying a cached row is bit-identical to recomputation under either
# backend.

def page_summary_fields(summary: str = "fp32") -> Tuple[str, ...]:
    """Cache-dict field names of the page-summary arrays — the rows
    ``copy_phys_pages`` must move together on copy-on-write (a CoW'd
    page starts as an exact copy, so its summary row does too)."""
    if summary == "int8":
        return ("page_k_min", "page_k_max", "page_k_scale", "page_k_zero")
    return ("page_k_min", "page_k_max")


def init_page_summaries(n_pages: int, n_kv_heads: int, d: int,
                        summary: str = "fp32") -> Dict[str, jnp.ndarray]:
    """Empty per-physical-page summary arrays for the serving cache
    dict: bounds are (n_pages, KV, D); the int8 backend adds
    (n_pages, KV) scale/zero with the ``scale = -1`` empty sentinel
    (matches ``decode_plan.dequantize_summaries``)."""
    if summary == "int8":
        return {
            "page_k_min": jnp.zeros((n_pages, n_kv_heads, d), jnp.int8),
            "page_k_max": jnp.zeros((n_pages, n_kv_heads, d), jnp.int8),
            "page_k_scale": jnp.full((n_pages, n_kv_heads), -1.0,
                                     jnp.float32),
            "page_k_zero": jnp.zeros((n_pages, n_kv_heads), jnp.float32),
        }
    return {
        "page_k_min": jnp.full((n_pages, n_kv_heads, d), jnp.inf,
                               jnp.float32),
        "page_k_max": jnp.full((n_pages, n_kv_heads, d), -jnp.inf,
                               jnp.float32),
    }


class PageAllocator:
    """Host-side free-list allocator for the paged pool.

    Positions advance sequentially from 0 within a slot, so logical
    pages map strictly in order; ``n_mapped[slot]`` is both the mapped
    count and the next logical page to map.  ``table`` mirrors the
    device page table (unmapped = OVERFLOW_PAGE)."""

    def __init__(self, n_pages: int, batch_slots: int, max_pages: int,
                 page: int, audit: bool = False):
        assert n_pages >= 2, "pool needs >= 1 usable page + overflow"
        self.n_pages = int(n_pages)
        self.page = int(page)
        self.max_pages = int(max_pages)
        # LIFO free list keeps recently-freed (cache-warm) pages hot
        self.free: List[int] = list(range(n_pages - 1, OVERFLOW_PAGE, -1))
        self.table = np.full((batch_slots, max_pages), OVERFLOW_PAGE,
                             np.int32)
        self.n_mapped = np.zeros(batch_slots, np.int32)
        self.pages_in_use_peak = 0
        # per-physical-page reference count: slot table entries + (for
        # prefix-cached pages) the trie's retention each count one.  A
        # page recycles only at ref == 0; ref > 1 marks it SHARED and
        # therefore immutable (writes must CoW first).
        self.ref = np.zeros(n_pages, np.int64)
        self.shared_pages_peak = 0
        # pages withheld by injected external pressure (fault
        # injection's ``pool_squeeze``) — out of the free list but
        # referenced by nobody
        self.squeezed: List[int] = []
        # cascade retirement (``retire_compact``): per-slot set of
        # logical pages whose physical page was retired mid-stream.
        # Retired logical pages are HOLES below ``n_mapped``: their
        # table entries point at the overflow page (position masking in
        # the plan keeps them unread — the plan stops naming retired
        # blocks), ``ensure`` never remaps them (it only maps at
        # ``n_mapped`` and beyond), and ``free_slot``/``swap_out`` skip
        # them.  Cleared with the slot.
        self.retired: List[set] = [set() for _ in range(batch_slots)]
        self.pages_retired = 0
        # lazy copy-on-write (``cfg.kv_lazy_cow``): phys page → slot
        # holding a write lease on it.  A lease lets the SOLE mapping
        # slot append in place into a trie-retained partial page
        # (appends land past the rows the trie node covers, so the
        # cached prefix stays pristine); it is live only while exactly
        # {holder's table entry, trie retention} reference the page —
        # any third reference re-protects the page and the holder falls
        # back to the eager CoW copy on its next append.
        self.lazy_cow = False
        self.cow_leases: Dict[int, int] = {}
        self.lazy_cow_skips = 0
        # outstanding host-swap handles: each resident (shared) page a
        # handle pins holds one reference until ``swap_in`` releases it
        self.swapped: List[Dict[str, Any]] = []
        # invariant audit (``check_invariants``) after every mutation —
        # the debug flag tests and serve-smoke keep on by default.
        # ``audit="light"`` samples: the full O(pages·slots) audit runs
        # every ``audit_period``-th mutation, every other mutation runs
        # the O(pages) vectorized refcount-sum check — fault/property
        # workloads keep continuous auditing without the quadratic cost
        # on every hot-path mutation.
        self.audit = audit if audit == "light" else bool(audit)
        self.audit_period = 16
        self.audit_trie: Optional["PrefixCache"] = None
        self.audits_run = 0
        self.light_audits_run = 0
        self._mutations = 0

    def _audit(self) -> None:
        if not self.audit:
            return
        self._mutations += 1
        if self.audit == "light" and self._mutations % self.audit_period:
            self._light_audit()
            self.light_audits_run += 1
            return
        self.check_invariants()
        self.audits_run += 1

    def _light_audit(self) -> None:
        """Cheap sampled-mode check: total refcounts must equal the
        nameable reference count (table mappings + handle pins + trie
        nodes), the overflow page must stay unreferenced, and the
        idle-page count must match the free+squeezed lists.  Catches
        leaked/double references in O(pages) without walking tables."""
        expect = int(self.n_mapped.sum())
        expect -= sum(len(r) for r in self.retired)   # holes map nothing
        expect += sum(int((h["resident"] >= 0).sum())
                      for h in self.swapped)
        if self.audit_trie is not None:
            expect += self.audit_trie.node_count
        total = int(self.ref.sum())
        assert total == expect, (
            f"refcount sum {total} != nameable references {expect}")
        assert self.ref[OVERFLOW_PAGE] == 0, \
            "overflow page acquired a reference"
        idle = int((self.ref == 0).sum()) - 1       # minus overflow
        assert idle == len(self.free) + len(self.squeezed), (
            f"{idle} idle pages vs {len(self.free)} free + "
            f"{len(self.squeezed)} squeezed")

    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def pages_in_use(self) -> int:
        return (self.n_pages - 1) - len(self.free) - len(self.squeezed)

    @property
    def shared_pages(self) -> int:
        """Physical pages currently referenced more than once."""
        return int((self.ref > 1).sum())

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache rows."""
        return -(-max(int(n_tokens), 0) // self.page)

    def can_admit(self, n_new_pages: int = 1) -> bool:
        """Admission control for the claim loop: only claim a slot when
        the pool can back its first pages — exhaustion defers the
        request instead of landing it on the overflow page."""
        return len(self.free) >= n_new_pages

    def ensure(self, slot: int, pos: int) -> bool:
        """Map physical pages for ``slot`` covering position ``pos``.
        Returns False (slot must stall this step) on pool exhaustion;
        any pages mapped before running dry stay mapped."""
        need = pos // self.page + 1
        while self.n_mapped[slot] < need:
            if not self.free:
                self._audit()
                return False
            phys = self.free.pop()
            self.ref[phys] = 1
            self.table[slot, self.n_mapped[slot]] = phys
            self.n_mapped[slot] += 1
        self.pages_in_use_peak = max(self.pages_in_use_peak,
                                     self.pages_in_use)
        self._audit()
        return True

    def map_shared(self, slot: int, phys_pages: List[int]) -> None:
        """Map already-populated physical pages (a matched cached
        prefix) as the slot's first logical pages: refcount bump, zero
        copy.  Must precede any ``ensure`` for the slot (logical pages
        map strictly in order)."""
        assert self.n_mapped[slot] == 0, "shared prefix maps first"
        for lp, phys in enumerate(phys_pages):
            assert phys != OVERFLOW_PAGE
            self.table[slot, lp] = int(phys)
            self.ref[phys] += 1
        self.n_mapped[slot] = len(phys_pages)
        self.shared_pages_peak = max(self.shared_pages_peak,
                                     self.shared_pages)
        self._audit()

    def _deref(self, phys: int) -> None:
        """Reference drop without the audit hook — for multi-page
        mutations (``free_slot``, ``swap_out``) whose intermediate
        states are legitimately inconsistent; they audit once at the
        end."""
        assert phys != OVERFLOW_PAGE and self.ref[phys] > 0, phys
        self.ref[phys] -= 1
        if self.ref[phys] == 0:
            self.free.append(int(phys))

    def deref(self, phys: int) -> None:
        """Drop one reference; the page recycles at zero."""
        self._deref(phys)
        self._audit()

    def ensure_writable(self, slot: int, pos: int
                        ) -> Tuple[bool, Optional[Tuple[int, int]]]:
        """Copy-on-write gate: the page holding ``pos`` must be
        exclusively owned before the slot may write a row into it.
        Returns ``(ok, copy)`` — ``copy = (src, dst)`` when a shared
        page was remapped and the caller must copy the K/V rows
        device-side (``models.decode.copy_phys_pages``) before the
        write lands; ``(False, None)`` when the pool cannot back the
        copy (the slot stalls this step, exactly like ``ensure``).

        **Lazy CoW** (``lazy_cow=True``): when the only other reference
        to the shared page is the prefix trie's retention (``ref ==
        2``) AND the write row sits past every row a trie node covers
        (``PrefixCache.covered_rows``), the copy is skipped and the
        slot takes a *write lease* instead — such appends can never
        corrupt the cached prefix.  A partial matcher whose tail starts
        INSIDE the covered rows always eager-copies.  The driver must push ``writable_ref_view()`` (not
        ``ref``) so the device write-protect honors the lease; the
        lease self-invalidates the moment a third reference appears,
        and the next append then takes the eager copy path (copying
        the holder's own in-place rows — correct contents either
        way)."""
        lp = pos // self.page
        if lp >= self.n_mapped[slot]:
            return True, None                    # unmapped: ensure() maps
        src = int(self.table[slot, lp])
        if self.ref[src] <= 1:
            self.cow_leases.pop(src, None)       # lease served its term
            return True, None                    # exclusive: write away
        if self.lazy_cow and self.ref[src] == 2:
            if self.cow_leases.get(src) == slot:
                return True, None                # live lease
            if (src not in self.cow_leases and self._trie_retains(src)
                    and pos % self.page >=
                    self.audit_trie.covered_rows(src)):
                # the write row is PAST every row a trie node covers
                # (the owner appending after registering its prompt) —
                # in place is safe.  A partial matcher diverging INSIDE
                # the covered range never qualifies: it must eager-copy
                # or it would overwrite cached prefix rows.
                self.cow_leases[src] = slot
                self.lazy_cow_skips += 1
                return True, None
        if not self.free:
            return False, None                   # CoW needs a page: stall
        dst = self.free.pop()
        self.ref[dst] = 1
        self.table[slot, lp] = dst
        self.ref[src] -= 1                       # shared pages never hit 0
        self.cow_leases.pop(src, None)           # holder went private
        self.pages_in_use_peak = max(self.pages_in_use_peak,
                                     self.pages_in_use)
        self._audit()
        return True, (src, dst)

    def _trie_retains(self, phys: int) -> bool:
        """Is one of ``phys``'s references the prefix trie's retention?
        (Lease eligibility: at ``ref == 2`` with trie retention the
        only sharer is the trie, whose node never covers the rows an
        append writes.)"""
        return self.audit_trie is not None and \
            phys in self.audit_trie.retained_pages()

    def writable_ref_view(self) -> np.ndarray:
        """The refcounts the driver pushes device-side for the paged
        write protect.  Identical to ``ref`` except that a *live* lazy-
        CoW lease's page reports 1, so the holder's in-place appends
        pass the protect.  Liveness is re-derived from scratch on every
        push — a lease whose page gained a third reference (or whose
        holder no longer maps it) is dropped here and the true refcount
        re-protects the page."""
        if not self.cow_leases:
            return self.ref
        view = self.ref.copy()
        for phys in list(self.cow_leases):
            slot = self.cow_leases[phys]
            held = phys in self.table[slot, :self.n_mapped[slot]]
            if self.ref[phys] == 2 and held:
                view[phys] = 1
            elif self.ref[phys] != 2 or not held:
                del self.cow_leases[phys]
        return view

    def drop_leases(self, slot: int) -> None:
        """Release every lazy-CoW lease ``slot`` holds (slot freed,
        swapped, or preempted — the next occupant must not inherit a
        write grant on a page it never mapped)."""
        self.cow_leases = {p: s for p, s in self.cow_leases.items()
                           if s != slot}

    def retire_compact(self, slot: int, lps: List[int]
                       ) -> Tuple[List[int], List[int]]:
        """Cascade retirement: free the physical pages behind ``slot``'s
        cold logical pages ``lps`` and return them to the global pool
        mid-stream.  Returns ``(freed_phys, skipped_lps)``.

        Pinned pages are **never** retired: a page referenced by anyone
        else — the prefix trie's retention, another slot's mapping, or
        a swap handle's resident pin (``ref > 1`` covers all three, and
        a swapped-out request has no table row to name pages through in
        the first place) — is skipped and reported back, not freed.

        A retired logical page becomes a HOLE: its table entry resets
        to the overflow page while ``n_mapped`` stands, so ``ensure``
        never remaps it and the slot's surviving pages keep their
        logical positions (causality masks untouched — the decode plan
        simply stops naming the retired blocks).  The caller owns the
        policy of never retiring the block holding the current write
        position."""
        freed: List[int] = []
        skipped: List[int] = []
        for lp in sorted({int(x) for x in lps}):
            assert 0 <= lp < self.n_mapped[slot], \
                f"retire of unmapped logical page {lp} (slot {slot})"
            assert lp not in self.retired[slot], \
                f"logical page {lp} already retired (slot {slot})"
            phys = int(self.table[slot, lp])
            assert phys != OVERFLOW_PAGE, (slot, lp)
            if self.ref[phys] > 1:               # pinned: trie / slot /
                skipped.append(lp)               # swap-handle reference
                continue
            self.table[slot, lp] = OVERFLOW_PAGE
            self.retired[slot].add(lp)
            self.cow_leases.pop(phys, None)
            self._deref(phys)
            freed.append(phys)
        self.pages_retired += len(freed)
        self._audit()
        return freed, skipped

    def free_slot(self, slot: int) -> int:
        """Release a finished slot's references.  Pages drop back to
        the free list only when nothing else references them — a page
        shared with the prefix trie or another slot survives (this is
        what makes preemption safe under sharing).  Stale table entries
        reset to the overflow page (reads are position-masked anyway,
        but a recycled physical page must not stay visible through an
        old slot's table row).  Retired holes hold no reference and are
        simply forgotten with the slot."""
        n = int(self.n_mapped[slot])
        phys = [int(self.table[slot, lp]) for lp in range(n)
                if lp not in self.retired[slot]]
        self.table[slot, :] = OVERFLOW_PAGE
        self.n_mapped[slot] = 0
        self.retired[slot] = set()
        self.drop_leases(slot)
        for p in phys:
            self._deref(p)
        self._audit()
        return n

    # --- fault injection: external pool pressure ----------------------

    def squeeze(self, n: int) -> int:
        """Withhold up to ``n`` free pages (injected external memory
        pressure): they leave the free list unreferenced, so the pool
        looks that much smaller to admission, CoW, and append until
        ``unsqueeze`` returns them.  Returns pages actually taken."""
        taken = 0
        while taken < n and self.free:
            self.squeezed.append(self.free.pop())
            taken += 1
        self._audit()
        return taken

    def unsqueeze(self, n: Optional[int] = None) -> int:
        """Return squeezed pages to the free list (all by default)."""
        back = 0
        while self.squeezed and (n is None or back < n):
            self.free.append(self.squeezed.pop())
            back += 1
        self._audit()
        return back

    # --- host-swap preemption -----------------------------------------

    def swap_out(self, slot: int, gather: GatherFn) -> Dict[str, Any]:
        """Detach ``slot``'s pages for host-swap preemption and return
        the swap handle that ``swap_in`` re-admits from.

        Private pages (``ref == 1``) have their device rows gathered to
        host through ``gather(phys_list)`` (the payload is opaque to
        the allocator) and drop back to the free pool; **shared pages
        are not swapped** — the trie's or other slots' refcounts keep
        them resident, and the handle pins one reference per shared
        page so eviction can never recycle a page a swapped request
        still needs.  The slot's table row resets; re-admission is
        ``swap_in``."""
        n = int(self.n_mapped[slot])
        assert n > 0, "swap_out on a slot with no mapped pages"
        retired = sorted(self.retired[slot])
        phys = [int(self.table[slot, lp]) for lp in range(n)]
        resident = np.full(n, -1, np.int64)
        priv_lp: List[int] = []
        priv_phys: List[int] = []
        for lp, p in enumerate(phys):
            if lp in self.retired[slot]:
                continue             # retired hole: nothing to move
            if self.ref[p] > 1:
                resident[lp] = p     # slot's ref transfers to the handle
            else:
                priv_lp.append(lp)
                priv_phys.append(p)
        chunks = [(priv_lp, gather(priv_phys))] if priv_phys else []
        self.table[slot, :] = OVERFLOW_PAGE
        self.n_mapped[slot] = 0
        self.retired[slot] = set()
        self.drop_leases(slot)
        for p in priv_phys:
            self._deref(p)
        handle = {"n_pages": n, "resident": resident, "chunks": chunks,
                  # retired holes restore as holes (``swap_in`` re-marks
                  # them), so the logical layout round-trips exactly
                  "retired": retired,
                  # integrity: one checksum set per chunk, verified
                  # before any swap_in mutation (bit-rot in host memory
                  # must never scatter back into the pool)
                  "sums": [_payload_checksums(pl) for _, pl in chunks]}
        self.swapped.append(handle)
        self._audit()
        return handle

    def swap_to_full(self, handle: Dict[str, Any], gather: GatherFn
                     ) -> None:
        """Convert a handle's resident (shared) pages into host payload
        too — the crash path: the device pool is about to be lost, so
        refcount residency can no longer keep those pages alive.  After
        this the handle restores entirely from host memory (``swap_in``
        against a fresh allocator)."""
        resident = handle["resident"]
        res_lp = [lp for lp in range(handle["n_pages"]) if resident[lp] >= 0]
        if not res_lp:
            return
        res_phys = [int(resident[lp]) for lp in res_lp]
        payload = gather(res_phys)
        handle["chunks"].append((res_lp, payload))
        handle["sums"].append(_payload_checksums(payload))
        resident[:] = -1
        for p in res_phys:
            self._deref(p)
        self._audit()

    def swap_pages_needed(self, handle: Dict[str, Any]) -> int:
        """Free pages ``swap_in`` must allocate for this handle (its
        payload-backed logical pages; resident pages just remap)."""
        return sum(len(lps) for lps, _ in handle["chunks"])

    def verify_handle(self, handle: Dict[str, Any]) -> None:
        """Re-checksum every payload chunk against the sums recorded at
        swap-out; raises :class:`PageIntegrityError` naming the first
        mismatching chunk/array.  ``swap_in`` runs this before touching
        any allocator state, so a corrupted handle leaves the pool
        untouched (the driver quarantines it via ``discard_handle``)."""
        for ci, ((lps, payload), sums) in enumerate(
                zip(handle["chunks"], handle.get("sums", []))):
            fresh = _payload_checksums(payload)
            for key, want in sums.items():
                got = fresh.get(key)
                if got != want:
                    raise PageIntegrityError(
                        f"swap payload checksum mismatch: chunk {ci} "
                        f"(logical pages {list(lps)}) array {key!r}: "
                        f"crc {got:#010x} != recorded {want:#010x}")

    def discard_handle(self, handle: Dict[str, Any]) -> List[int]:
        """Quarantine a swap handle: drop it from the outstanding list
        and release its resident pins (those pages' CONTENTS are fine —
        they never left the device — but nothing references them for
        this request anymore; host-side payload is simply abandoned).
        Returns the formerly resident physical pages so the driver can
        invalidate any trie entries built over them."""
        assert any(h is handle for h in self.swapped), \
            "unknown or already-restored handle"
        resident = handle["resident"]
        res = [int(p) for p in resident if p >= 0]
        for p in res:
            self._deref(p)
        resident[:] = -1
        self.swapped = [h for h in self.swapped if h is not handle]
        self._audit()
        return res

    def swap_in(self, slot: int, handle: Dict[str, Any],
                scatter: ScatterFn) -> bool:
        """Re-admit a swapped request into (empty) ``slot``: resident
        shared pages remap at their logical positions (the handle's
        pinned reference transfers back to the slot's table), payload
        pages land in freshly allocated physical pages via
        ``scatter(new_phys, payload)``.  Returns False — nothing
        mutated — when the pool cannot back the payload pages yet (the
        driver defers re-admission, exactly like a deferred claim)."""
        assert any(h is handle for h in self.swapped), \
            "unknown or already-restored handle"
        self.verify_handle(handle)      # before ANY mutation
        if len(self.free) < self.swap_pages_needed(handle):
            return False
        assert self.n_mapped[slot] == 0, "swap_in needs an empty slot"
        resident = handle["resident"]
        for lp in range(handle["n_pages"]):
            if resident[lp] >= 0:
                self.table[slot, lp] = int(resident[lp])
        for lps, payload in handle["chunks"]:
            fresh = []
            for lp in lps:
                q = self.free.pop()
                self.ref[q] = 1
                self.table[slot, lp] = q
                fresh.append(q)
            scatter(fresh, payload)
        self.n_mapped[slot] = handle["n_pages"]
        self.retired[slot] = set(handle.get("retired", ()))
        self.swapped = [h for h in self.swapped if h is not handle]
        self.pages_in_use_peak = max(self.pages_in_use_peak,
                                     self.pages_in_use)
        self._audit()
        return True

    # --- invariant audit ----------------------------------------------

    def check_invariants(self, trie: Optional["PrefixCache"] = None
                         ) -> None:
        """Allocator-state audit — raises ``AssertionError`` on the
        first violated invariant:

        * the overflow page is never referenced, never free, never
          squeezed, and never appears in a mapped table region;
        * free / squeezed lists are duplicate-free and disjoint, and a
          page sits on one of them iff its refcount is zero;
        * every page's refcount equals exactly the references the
          bookkeeping can name: slot table entries in mapped regions
          + swap handles' resident pins + the prefix trie's retention
          (in particular no writable ``ref == 1`` page can be mapped
          by two slots — a double mapping forces ``ref >= 2``, i.e.
          shared and write-protected, or fails here);
        * table entries beyond ``n_mapped`` are exactly the overflow
          page (no stale mapping survives a free/swap);
        * retired logical pages are holes strictly below ``n_mapped``
          whose table entries are exactly the overflow page (a retired
          page maps nothing and references nothing);
        * every lazy-CoW lease names a non-overflow page with a live
          reference (lease *liveness* — ref == 2 + holder mapping — is
          re-derived on every ``writable_ref_view`` push instead);
        * every trie node's page is live (``ref > 0``).

        ``trie`` defaults to ``audit_trie`` (auto-wired by
        ``PrefixCache``)."""
        trie = trie if trie is not None else self.audit_trie
        assert self.ref[OVERFLOW_PAGE] == 0, \
            "overflow page acquired a reference"
        free_set = set(self.free)
        assert len(free_set) == len(self.free), "free list has duplicates"
        sq_set = set(self.squeezed)
        assert len(sq_set) == len(self.squeezed), \
            "squeezed list has duplicates"
        assert not (free_set & sq_set), "page both free and squeezed"
        assert OVERFLOW_PAGE not in free_set | sq_set, \
            "overflow page entered the free/squeezed lists"
        expected = np.zeros(self.n_pages, np.int64)
        for slot in range(self.table.shape[0]):
            m = int(self.n_mapped[slot])
            assert all(0 <= lp < m for lp in self.retired[slot]), (
                f"slot {slot}: retired pages {sorted(self.retired[slot])} "
                f"outside the mapped region [0, {m})")
            for lp in range(self.max_pages):
                p = int(self.table[slot, lp])
                if lp < m and lp in self.retired[slot]:
                    assert p == OVERFLOW_PAGE, (
                        f"slot {slot}: retired logical page {lp} still "
                        f"maps physical page {p}")
                elif lp < m:
                    assert p != OVERFLOW_PAGE, \
                        f"slot {slot} maps overflow at logical page {lp}"
                    expected[p] += 1
                else:
                    assert p == OVERFLOW_PAGE, \
                        f"stale table entry {p} at slot {slot} lp {lp}"
        for phys, slot in self.cow_leases.items():
            assert phys != OVERFLOW_PAGE, "lease on the overflow page"
            assert self.ref[phys] >= 1, \
                f"lazy-CoW lease on dead page {phys} (slot {slot})"
        for h in self.swapped:
            for p in h["resident"]:
                if p >= 0:
                    expected[int(p)] += 1
        if trie is not None:
            for p in trie.retained_pages():
                assert self.ref[p] > 0, f"trie retains dead page {p}"
                expected[p] += 1
        bad = np.nonzero(expected != self.ref)[0]
        assert bad.size == 0, (
            f"refcount mismatch at pages {bad.tolist()}: counted "
            f"{expected[bad].tolist()} references, ref say "
            f"{self.ref[bad].tolist()}")
        for p in range(1, self.n_pages):
            idle = self.ref[p] == 0
            assert (p in free_set or p in sq_set) == idle, (
                f"page {p}: ref {int(self.ref[p])} but "
                f"{'on' if not idle else 'missing from'} the "
                f"free/squeezed lists")

    def stats(self, *, row_bytes: int, layers: int = 1) -> Dict[str, int]:
        """Pool occupancy in bytes.  ``row_bytes`` = bytes of ONE token
        row of K+V for one layer (2 · KV · D · itemsize); ``layers``
        scales to the stacked cache."""
        page_bytes = self.page * row_bytes * layers
        return {
            "n_pages": self.n_pages,
            "page_size": self.page,
            "pages_in_use": self.pages_in_use,
            "pages_in_use_peak": self.pages_in_use_peak,
            "shared_pages": self.shared_pages,
            "shared_pages_peak": self.shared_pages_peak,
            "private_pages": self.pages_in_use - self.shared_pages,
            "hbm_reserved_bytes": self.n_pages * page_bytes,
            "hbm_used_peak_bytes": self.pages_in_use_peak * page_bytes,
            "pages_retired": self.pages_retired,
            "lazy_cow_skips": self.lazy_cow_skips,
        }


# ---------------------------------------------------------------------------
# Shared-prefix page cache
# ---------------------------------------------------------------------------

class _TrieNode:
    """One physical page worth of prompt tokens.  ``ntok == page``
    (full) nodes key the chain walk by token-hash and may have
    children; partial nodes (``ntok < page``) terminate a chain and
    match by token-prefix comparison only."""

    __slots__ = ("phys", "tokens", "digest", "children", "partials",
                 "parent", "stamp")

    def __init__(self, phys: int, tokens: Tuple[int, ...], digest: bytes,
                 parent: Optional["_TrieNode"]):
        self.phys = int(phys)
        self.tokens = tokens
        self.digest = digest
        self.children: Dict[bytes, "_TrieNode"] = {}
        self.partials: List["_TrieNode"] = []
        self.parent = parent
        self.stamp = 0

    @property
    def evictable(self) -> bool:
        return not self.children and not self.partials


def _chain_digest(parent_digest: bytes, tokens: np.ndarray) -> bytes:
    """Position-dependent page key: hashing the parent digest chains
    the pages, so identical page contents at different prefix depths
    never collide.  Token equality is still verified on lookup — the
    digest only routes."""
    return hashlib.sha1(
        parent_digest + np.ascontiguousarray(tokens, np.int64).tobytes()
    ).digest()


class PrefixCache:
    """Prompt-prefix trie over the page pool.

    ``match(tokens)`` walks full-page children by chained token hash
    (verifying the stored tokens — the digest only routes) and finishes
    with the longest token-prefix match against the stop node's
    children, so a prompt sharing only half a cached page still maps
    that page (the tail prefill CoWs it before writing).  ``register``
    inserts a freshly prefilled prompt's pages — full pages as chain
    nodes, the final partial page as a terminal node — bumping each
    page's refcount by one for the trie's own retention.  ``evict``
    releases least-recently-used leaf pages no slot references when the
    pool runs dry; interior nodes free once their subtree is gone.

    Everything is host-side bookkeeping, like the allocator it feeds.
    """

    def __init__(self, alloc: PageAllocator):
        self.alloc = alloc
        self.page = alloc.page
        self.root = _TrieNode(OVERFLOW_PAGE, (), b"root", None)
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.tokens_saved = 0
        self.evictions = 0
        # live node count (== len(retained_pages())), maintained so the
        # allocator's light audit can price trie retention in O(1)
        self.node_count = 0
        self.invalidated = 0
        # the allocator's invariant audit counts trie retention —
        # wire this cache in so every audit sees the full refcount story
        alloc.audit_trie = self

    @property
    def cached_pages(self) -> int:
        return len(self.retained_pages())

    def retained_pages(self) -> List[int]:
        """Physical pages the trie holds one retention reference on —
        one entry per node (a page can back several nodes only if it
        was registered at different chain depths, which the chained
        digest prevents; each node pinned exactly one ref)."""
        out: List[int] = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            if node is not self.root:
                out.append(node.phys)
            stack.extend(node.children.values())
            stack.extend(node.partials)
        return out

    def covered_rows(self, phys: int) -> int:
        """Rows of ``phys`` a live trie node covers (its ``ntok``; 0
        when no node is backed by ``phys``).  The lazy-CoW lease gate:
        in-place writes are safe only at rows PAST this — a write
        inside the covered range would corrupt the cached prefix for
        every future matcher."""
        best = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            if node is not self.root and node.phys == int(phys):
                best = max(best, len(node.tokens))
            stack.extend(node.children.values())
            stack.extend(node.partials)
        return best

    def _touch(self, node: _TrieNode) -> None:
        self._clock += 1
        while node is not None and node is not self.root:
            node.stamp = self._clock
            node = node.parent

    def match(self, tokens: np.ndarray
              ) -> Tuple[int, List[int], Optional[int]]:
        """Longest cached prefix of ``tokens``: returns
        ``(matched_tokens, phys_pages, partial_rows)`` where
        ``phys_pages`` are the ascending physical pages to map
        (``map_shared``) and ``partial_rows`` is the number of valid
        rows in the last mapped page when the match ends mid-page
        (``None`` for a page-aligned match).  Callers wanting the
        prefill to always produce last-token logits should match
        ``tokens[:-1]``.  Pure lookup (plus LRU touch) — the driver
        records hit statistics with ``note`` once a claim actually
        lands, so a deferred admission never double-counts."""
        toks = np.asarray(tokens, np.int64).reshape(-1)
        node, phys, m = self.root, [], 0
        while len(toks) - m >= self.page:
            page_toks = toks[m:m + self.page]
            child = node.children.get(_chain_digest(node.digest, page_toks))
            if child is None or child.tokens != tuple(page_toks.tolist()):
                break
            node, m = child, m + self.page
            phys.append(child.phys)
        # longest common token prefix among the stop node's children
        # (full AND partial): a shared page is useful even half-used —
        # the tail prefill CoWs it and overwrites from the divergence
        best, best_len = None, 0
        rest = tuple(toks[m:].tolist())
        for cand in list(node.children.values()) + node.partials:
            lcp = 0
            for a, b in zip(rest, cand.tokens):
                if a != b:
                    break
                lcp += 1
            if lcp > best_len:
                best, best_len = cand, lcp
        if best is not None:
            phys.append(best.phys)
            m += best_len
            self._touch(best)
        elif phys:
            self._touch(node)
        return m, phys, (best_len if best is not None else None)

    def note(self, matched_tokens: int) -> None:
        """Record one admitted request's hit statistics."""
        if matched_tokens:
            self.hits += 1
            self.tokens_saved += matched_tokens
        else:
            self.misses += 1

    def register(self, tokens: np.ndarray, table_row: np.ndarray) -> int:
        """Insert a prompt's pages (the slot's current mapping
        ``table_row``) into the trie; each newly retained page's
        refcount bumps by one for the trie.  Already-cached chain nodes
        are skipped (the match that preceded this register mapped
        them); a partial page is skipped when an existing sibling
        already covers its tokens.  Returns pages newly retained."""
        toks = np.asarray(tokens, np.int64).reshape(-1)
        node, m, added = self.root, 0, 0
        while len(toks) - m >= self.page:
            page_toks = toks[m:m + self.page]
            digest = _chain_digest(node.digest, page_toks)
            child = node.children.get(digest)
            if child is None or child.tokens != tuple(page_toks.tolist()):
                phys = int(table_row[m // self.page])
                child = _TrieNode(phys, tuple(page_toks.tolist()), digest,
                                  node)
                node.children[digest] = child
                self.alloc.ref[phys] += 1
                added += 1
            node, m = child, m + self.page
        rest = tuple(toks[m:].tolist())
        if rest:
            covered = any(
                len(cand.tokens) >= len(rest)
                and cand.tokens[:len(rest)] == rest
                for cand in list(node.children.values()) + node.partials)
            if not covered:
                phys = int(table_row[m // self.page])
                part = _TrieNode(phys, rest,
                                 _chain_digest(node.digest,
                                               np.asarray(rest)), node)
                node.partials.append(part)
                self.alloc.ref[phys] += 1
                added += 1
                node = part
        self._touch(node)
        self.node_count += added
        self.alloc.shared_pages_peak = max(self.alloc.shared_pages_peak,
                                           self.alloc.shared_pages)
        self.alloc._audit()
        return added

    def evict(self, need_pages: int) -> int:
        """Free least-recently-used evictable leaves until ``need_pages``
        pages sit on the free list (or nothing more can go).  Only
        leaves no slot references (``ref == 1`` — the trie's own
        retention is the last one) are touched: evicting a leaf some
        running slot still maps would free nothing now and destroy a
        warm entry for nothing.  An interior node whose subtree
        evicted becomes a leaf itself and goes on a later round."""
        freed = 0
        while len(self.alloc.free) < need_pages:
            victims = []
            stack = [self.root]
            while stack:
                n = stack.pop()
                stack.extend(n.children.values())
                stack.extend(n.partials)
                if n is not self.root and n.evictable \
                        and self.alloc.ref[n.phys] == 1:
                    victims.append(n)
            pick = min(victims, key=lambda n: n.stamp, default=None)
            if pick is None:
                break
            parent = pick.parent
            if pick in parent.partials:
                parent.partials.remove(pick)
            else:
                parent.children.pop(pick.digest, None)
            self.alloc.deref(pick.phys)
            self.node_count -= 1
            freed += 1
            self.evictions += 1
        return freed

    def invalidate_pages(self, pages: List[int]) -> int:
        """Quarantine: drop every trie node whose physical page is in
        ``pages``, together with its whole subtree (a chain walk cannot
        cross a removed node, so orphaned descendants would be
        unreachable dead weight), releasing one retention reference per
        removed node.  Used when a corrupted swap handle is discarded —
        any prefix entry built over the victim's shared pages must stop
        being matchable.  Returns nodes removed."""
        bad = {int(p) for p in pages}
        removed = 0

        def _drop_subtree(node: _TrieNode) -> int:
            n = 0
            stack = [node]
            while stack:
                x = stack.pop()
                stack.extend(x.children.values())
                stack.extend(x.partials)
                self.alloc._deref(x.phys)
                n += 1
            return n

        def _scrub(node: _TrieNode) -> None:
            nonlocal removed
            for key in list(node.children):
                child = node.children[key]
                if child.phys in bad:
                    removed += _drop_subtree(child)
                    del node.children[key]
                else:
                    _scrub(child)
            keep = []
            for part in node.partials:
                if part.phys in bad:
                    removed += _drop_subtree(part)
                else:
                    keep.append(part)
            node.partials = keep

        _scrub(self.root)
        self.node_count -= removed
        self.invalidated += removed
        self.alloc._audit()
        return removed

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "requests": total,
            "hits": self.hits,
            "hit_rate": self.hits / max(total, 1),
            "prefill_tokens_saved": self.tokens_saved,
            "cached_pages": self.cached_pages,
            "evictions": self.evictions,
            "invalidated": self.invalidated,
        }


class SharedPrefixIndex:
    """Cross-replica prompt-prefix index: the distributed counterpart
    of :class:`PrefixCache`.

    Each serving replica owns its page pool and trie, but registers its
    prompt-prefix pages here — keyed by the SAME chained page digest
    the trie routes on — together with the pages' host-side payload
    (K/V rows plus summary rows, the ``gather_phys_pages`` dict).  A
    replica whose local trie misses walks the chain here instead; a hit
    published by ANOTHER replica is a *migration*: the caller copies
    the matched payload into freshly allocated local pages
    (``scatter_phys_pages``), registers them in its local trie, and
    from then on serves them with ordinary refcount/CoW semantics —
    the index stays a pure copy source, never a shared owner, so no
    cross-replica refcount protocol is needed.

    Host-side and process-local by construction (the N-replica harness
    runs replicas in one process); the digest-chain key is what a real
    multi-host index service would shard on.
    """

    def __init__(self):
        self.page: Optional[int] = None
        # chain digest -> (replica_id, page tokens, per-page payload)
        self._pages: Dict[bytes, Tuple[int, Tuple[int, ...],
                                       Dict[str, np.ndarray]]] = {}
        self.publishes = 0
        self.pages_published = 0
        self.lookups = 0
        self.remote_hits = 0

    def publish(self, replica_id: int, tokens: np.ndarray, page: int,
                payload: Dict[str, np.ndarray]) -> int:
        """Register a prompt's FULL pages (payload page axis must cover
        ``len(tokens) // page`` pages, in prefix order).  Already-known
        digests are skipped — first publisher wins, so a page's payload
        is immutable once indexed (prefix pages are append-frozen by
        the trie's own CoW protection).  Returns pages newly indexed."""
        if self.page is None:
            self.page = int(page)
        assert self.page == int(page), "replicas must agree on page size"
        toks = np.asarray(tokens, np.int64).reshape(-1)
        digest, added = b"root", 0
        for p in range(len(toks) // self.page):
            page_toks = toks[p * self.page:(p + 1) * self.page]
            digest = _chain_digest(digest, page_toks)
            if digest not in self._pages:
                self._pages[digest] = (
                    int(replica_id), tuple(page_toks.tolist()),
                    {k: np.asarray(v[:, p:p + 1])
                     for k, v in payload.items()})
                added += 1
        self.publishes += 1
        self.pages_published += added
        return added

    def lookup(self, replica_id: int, tokens: np.ndarray
               ) -> Optional[Tuple[int, Dict[str, np.ndarray], int]]:
        """Longest indexed full-page prefix of ``tokens``: returns
        ``(matched_tokens, stacked_payload, remote_pages)`` — payload
        page axis in prefix order, ready for ``scatter_phys_pages``
        into ``matched_tokens // page`` fresh pages — or ``None`` when
        no page matches.  ``remote_pages`` counts matched pages whose
        publisher is not ``replica_id`` (the migration, vs re-reading
        what this replica itself published)."""
        self.lookups += 1
        if self.page is None:
            return None
        toks = np.asarray(tokens, np.int64).reshape(-1)
        digest, chain = b"root", []
        for p in range(len(toks) // self.page):
            page_toks = toks[p * self.page:(p + 1) * self.page]
            digest = _chain_digest(digest, page_toks)
            hit = self._pages.get(digest)
            if hit is None or hit[1] != tuple(page_toks.tolist()):
                break
            chain.append(hit)
        if not chain:
            return None
        remote = sum(1 for rid, _, _ in chain if rid != int(replica_id))
        payload = {k: np.concatenate([c[2][k] for c in chain], axis=1)
                   for k in chain[0][2]}
        return len(chain) * self.page, payload, remote

    def stats(self) -> Dict[str, float]:
        return {
            "pages_indexed": len(self._pages),
            "publishes": self.publishes,
            "pages_published": self.pages_published,
            "lookups": self.lookups,
            "remote_hits": self.remote_hits,
        }
