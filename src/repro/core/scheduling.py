"""Algo 2 — Sparsity-aware inter-head FSM scheduling.

Queries are the *stationary* operand (each query has exactly K key-MACs;
keys have variable fan-in).  Keys stream through the compute array in
SATA-sorted order.  The FSM overlaps loading the next group of queries
with MAC-ing keys the currently-retiring group does not need:

  init      load major Qs of head 0 (pipeline fill)
  intoHD(h) MAC streamed keys [0 : S_h)        | load minor Qs of head h
  midstHD(h)MAC streamed keys [S_h : N - S_h)  | (all Qs resident)
  outtaHD(h)MAC streamed keys [N - S_h : N)    | load major Qs of head h+1
            (dominant Qs of head h retire — they never touch these keys)
  wrapGLOB  conventional load-then-MAC for heads stuck in GLOB state

"major" = dominant-type ∪ GLOB queries, "minor" = the opposite type.
For a TAIL-type head the key stream order is *reversed* so that the
first-streamed S_h keys are exactly the ones its major queries own —
this is the symmetric reading of the paper's init/intoHD descriptions
(Sec. III-C) and is asserted correct by the coverage property test.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sorting import HeadType, QType, SortResult, sort_and_classify


@dataclasses.dataclass(frozen=True)
class Step:
    """One FSM state occupancy: MAC ``k_mac`` keys while loading ``q_load``."""
    phase: str                     # init|intoHD|midstHD|outtaHD|globLoad|globMAC
    k_head: int                    # head owning the MAC'd keys (-1: none)
    q_head: int                    # head owning the loaded queries (-1: none)
    k_mac: Tuple[int, ...]         # original key indices MAC'd this step
    q_load: Tuple[int, ...]        # original query indices loaded this step
    n_active_q: int                # resident queries participating in MACs
    resident: Tuple[Tuple[int, int], ...]  # resident (head, q) pairs


@dataclasses.dataclass(frozen=True)
class Schedule:
    steps: Tuple[Step, ...]
    n_tokens: int
    n_heads: int
    peak_residency: int

    @property
    def q_seq(self) -> List[Tuple[int, int]]:
        return [(s.q_head, q) for s in self.steps for q in s.q_load]

    @property
    def k_seq(self) -> List[Tuple[int, int]]:
        return [(s.k_head, k) for s in self.steps for k in s.k_mac]


def _split_queries(res: SortResult) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    qt = res.qtypes
    dom = QType.HEAD if res.head_type == HeadType.HEAD else QType.TAIL
    mnr = QType.TAIL if res.head_type == HeadType.HEAD else QType.HEAD
    dominant = np.flatnonzero(qt == dom)
    minor = np.flatnonzero(qt == mnr)
    glob = np.flatnonzero(qt == QType.GLOB)
    return dominant, minor, glob


def _stream_order(res: SortResult) -> np.ndarray:
    """Key stream order: sorted order for HEAD heads, reversed for TAIL."""
    kid = np.asarray(res.kid)
    return kid if res.head_type == HeadType.HEAD else kid[::-1]


def build_schedule(results: Sequence[SortResult],
                   masks: Optional[Sequence[np.ndarray]] = None,
                   skip_empty_keys: bool = False,
                   group_of: Optional[Sequence[int]] = None) -> Schedule:
    """Build the full inter-head schedule from per-head Algo-1 results.

    ``masks`` (original, unsorted) are only needed when
    ``skip_empty_keys`` is set — all-zero key columns are then elided
    from the stream (zero-skip, Sec. III-D).

    ``group_of`` assigns each (sub-)head to a Q-fold residency group
    (tiled path).  GLOB sub-heads then run at the end of *their group*
    — their fold's queries are still resident — instead of the paper's
    untiled behaviour of wrapping all GLOB heads up at the very end.
    """
    if group_of is None:
        local = [i for i, r in enumerate(results)
                 if r.head_type != HeadType.GLOB]
        globs = [i for i, r in enumerate(results)
                 if r.head_type == HeadType.GLOB]
        sequence = [("local", i) for i in local] + [("glob", i) for i in globs]
    else:
        order: List[int] = []
        seen: set = set()
        for g in group_of:
            if g not in seen:
                seen.add(g)
                order.append(g)
        sequence = []
        local = []
        for g in order:
            members = [i for i in range(len(results)) if group_of[i] == g]
            loc = [i for i in members if results[i].head_type != HeadType.GLOB]
            glb = [i for i in members if results[i].head_type == HeadType.GLOB]
            sequence += [("local", i) for i in loc]
            sequence += [("glob", i) for i in glb]
            local += loc

    n_tokens = len(results[0].kid) if results else 0
    steps: List[Step] = []
    resident: List[Tuple[int, int]] = []   # (head, q) pairs currently resident
    peak = 0

    def _filter(i: int, seg: np.ndarray) -> np.ndarray:
        """Zero-skip: drop keys no query selects (Sec. III-D) — applied
        per segment so the S_h boundaries keep their sorted positions."""
        if skip_empty_keys and masks is not None and len(seg):
            nonzero = np.asarray(masks[i]).any(axis=0)
            seg = seg[nonzero[seg]]
        return seg

    def key_segments(i: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        stream = _stream_order(results[i])
        n = len(stream)
        s_h = min(results[i].s_h, n // 2)
        return (_filter(i, stream[:s_h]),
                _filter(i, stream[s_h:n - s_h]),
                _filter(i, stream[n - s_h:]))

    def emit(phase, k_head, q_head, k_mac, q_load, n_active):
        nonlocal peak
        steps.append(Step(phase=phase, k_head=k_head, q_head=q_head,
                          k_mac=tuple(int(k) for k in k_mac),
                          q_load=tuple(int(q) for q in q_load),
                          n_active_q=int(n_active),
                          resident=tuple(resident)))
        peak = max(peak, len(resident))

    pos = -1                       # index into the local chain
    for kind, i in sequence:
        res = results[i]
        if kind == "glob":
            # wrapGLOB: conventional load-then-MAC flow.
            stream = np.concatenate(key_segments(i))
            all_q = np.arange(len(res.qtypes))
            resident.extend((i, int(q)) for q in all_q)
            emit("globLoad", -1, i, (), all_q, 0)
            emit("globMAC", i, -1, stream, (), n_active=len(all_q))
            for q in all_q.tolist():
                resident.remove((i, int(q)))
            continue

        pos += 1
        dominant, minor, glob = _split_queries(res)
        first_seg, mid_seg, last_seg = key_segments(i)

        if pos == 0:
            # Pipeline fill: load major queries of the first head.
            resident.extend((i, int(q)) for q in np.concatenate([dominant, glob]))
            emit("init", -1, i, (), np.concatenate([dominant, glob]), 0)

        # intoHD — first-streamed s_h keys (minor queries don't need them).
        emit("intoHD", i, i, first_seg, minor,
             n_active=len(dominant) + len(glob))
        resident.extend((i, int(q)) for q in minor)

        # midstHD — middle keys vs every resident query of this head.
        if len(mid_seg) > 0:
            emit("midstHD", i, -1, mid_seg, (),
                 n_active=len(dominant) + len(minor) + len(glob))

        # outtaHD — last-streamed s_h keys; dominant queries retire, next
        # head's major queries stream into the freed slots.
        for q in dominant.tolist():
            resident.remove((i, int(q)))
        if pos + 1 < len(local):
            nxt = results[local[pos + 1]]
            ndom, _, nglob = _split_queries(nxt)
            incoming = np.concatenate([ndom, nglob])
            q_head = local[pos + 1]
        else:
            incoming, q_head = np.asarray([], dtype=np.int64), -1
        resident.extend((q_head, int(q)) for q in incoming)
        emit("outtaHD", i, q_head, last_seg, incoming,
             n_active=len(minor) + len(glob))
        for q in minor.tolist() + glob.tolist():
            resident.remove((i, int(q)))

    return Schedule(steps=tuple(steps), n_tokens=n_tokens,
                    n_heads=len(results), peak_residency=peak)


def schedule_heads(masks: np.ndarray, seed: int = 0,
                   theta: Optional[int] = None,
                   skip_empty_keys: bool = False) -> Tuple[Schedule, List[SortResult]]:
    """Convenience: Algo 1 per head + Algo 2 across heads.

    masks: (n_heads, N_q, N_k) boolean selective masks.
    """
    results = [sort_and_classify(masks[h], seed=seed, theta=theta)
               for h in range(masks.shape[0])]
    sched = build_schedule(results, masks=list(masks),
                           skip_empty_keys=skip_empty_keys)
    return sched, results


def coverage_ok(schedule: Schedule, masks: np.ndarray) -> bool:
    """Invariant: every selected (q, k) pair is computable — when key k of
    head h is MAC'd, query q is resident; and each key streams exactly once."""
    masks = np.asarray(masks, dtype=bool)
    seen_keys = {h: [] for h in range(masks.shape[0])}
    for s in schedule.steps:
        if s.k_head < 0:
            continue
        res = set(s.resident)
        for k in s.k_mac:
            seen_keys[s.k_head].append(k)
            needed = {(s.k_head, int(q))
                      for q in np.flatnonzero(masks[s.k_head][:, k])}
            if not needed <= res:
                return False
    for h in range(masks.shape[0]):
        nonzero_cols = set(np.flatnonzero(masks[h].any(axis=0)).tolist())
        ks = seen_keys[h]
        if len(ks) != len(set(ks)):
            return False                      # a key streamed twice
        if not nonzero_cols <= set(ks):
            return False                      # a needed key never streamed
    return True
