"""SATA estimation framework — the paper's Sec. IV evaluation plane.

Models a multi-level CIM-centric system (Fig. 3c): DRAM → on-chip operand
buffers → stationary compute array (32×32 sub-arrays).  Queries are the
stationary operand; keys stream.  An array pass holds at most ``cap_q``
queries, so work wider than ``cap_q`` re-streams keys once per query
fold — the quadratic traffic term SATA's sorting/tiling/zero-skip
attacks.

* Throughput (Eq. 3): a scheduled step that MACs ``x`` keys while loading
  ``y`` queries costs
      τ_i = min(τ_RD_DT·x, τ_WR_ARR·y) + min(τ_RD_COMP·x, τ_WR_DT·y)
  implemented verbatim (``overlap="paper"``); a conservative
  pipeline-max variant (``overlap="max"``) is provided for sensitivity.
* Energy: first touch of an operand vector is a DRAM transfer, re-touches
  hit the operand buffer; array writes are charged per load; MACs run
  dense *within the resident-query subset* (keys bypass the freed
  HEAD/TAIL queries); the scheduler is charged via the binary-sort cost
  model of Sec. III-E / IV-D.

Absolute constants are calibration (NeuroSim is not available in this
container); every reported number is a *ratio* against baselines under
identical constants — which is what Fig. 4 reports.  e_mac8 includes the
ADC/peripheral cost of an analog CIM MAC, the dominant CIM energy term.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduling import Schedule
from repro.core.tiling import TiledPlan, tiled_schedule


@dataclasses.dataclass(frozen=True)
class HwConfig:
    """CIM-centric system constants (65nm, 1 GHz, 32×32 sub-arrays)."""
    cap_q: int = 32               # stationary query slots per array pass
    bus_bits: int = 256
    # --- latency (cycles per operand vector, × ceil(d_k·8/bus)) ---
    rd_dram_cyc: float = 3.0      # K vector DRAM→buffer transfer / beat
    rd_dt_cyc: float = 1.0        # K vector fold-buffer→array / beat
    wr_arr_cyc: float = 1.5       # Q vector write into CIM rows / beat
    rd_comp_cyc: float = 0.5      # MAC pass of one K vector / beat
    wr_dt_cyc: float = 1.0        # Q vector DRAM/buffer→staging / beat
    # --- energy (pJ) ---
    e_dram_bit: float = 2.0       # off-chip transfer per bit (first touch)
    e_buf_bit: float = 0.08       # operand-buffer hit per bit
    e_wr_bit: float = 0.5         # CIM array write per bit
    e_mac8: float = 1.0           # one 8-bit CIM MAC incl. ADC/peripherals
    e_bin_op: float = 0.04        # scheduler binary op incl. reg traffic
    e_reg_bit: float = 0.002      # scheduler Psum/FIFO register write
    p_static: float = 150.0       # system leakage+clock power, pJ/cycle
                                  # (65nm: a large share of total power;
                                  # makes energy track runtime, as in any
                                  # post-PNR power report)


def _beats(d_k: int, hw: HwConfig) -> float:
    return max(1.0, math.ceil(d_k * 8 / hw.bus_bits))


@dataclasses.dataclass(frozen=True)
class SimReport:
    latency_cycles: float
    energy_pj: float
    macs: float                   # actual 8-bit MACs performed
    k_fetches: int                # key vector touches (DRAM + buffer)
    q_loads: int                  # query vector array writes
    dram_bits: float              # off-chip traffic
    scheduler_energy_pj: float
    scheduler_cycles: float
    stall_fraction: float         # compute-idle fraction of total cycles

    @property
    def edp(self) -> float:
        return self.latency_cycles * self.energy_pj

    def throughput_gain(self, base: "SimReport") -> float:
        return base.latency_cycles / self.latency_cycles

    def energy_eff_gain(self, base: "SimReport") -> float:
        """ops/J gain at iso-useful-work (the QK workload is identical)."""
        return base.energy_pj / self.energy_pj


# ---------------------------------------------------------------------------
# Scheduler overhead model (Sec. III-E / IV-D)
# ---------------------------------------------------------------------------

def scheduler_cost(n: int, d_k: int, n_heads: int, hw: HwConfig
                   ) -> Tuple[float, float]:
    """(cycles, pJ) for sorting+classifying ``n_heads`` masks of size n×n.

    Psum form (Eq. 2): each of the n sort steps updates ≤n registers with
    an n-bit binary AND+popcount.  The dot-product engine is a
    cap_q×cap_q binary MAC array (trivial silicon next to the CIM macro),
    so one step takes ⌈n²/cap_q²⌉ cycles plus one priority-encode cycle
    (combinational log-depth tree).  The Psum register array grows
    quadratically with tile size and the encoder tree logarithmically —
    the scalings the paper reports in Sec. IV-D.
    """
    par = hw.cap_q * hw.cap_q              # binary MAC lanes
    bin_ops = float(n) ** 3 * n_heads
    cycles = n_heads * n * (math.ceil(n * n / par) + 1.0)
    reg_bits = n * (math.ceil(math.log2(max(n, 2))) + 4)
    energy = (bin_ops * hw.e_bin_op
              + n_heads * n * reg_bits * hw.e_reg_bit)
    return cycles, energy


# ---------------------------------------------------------------------------
# Scheduled (SATA) simulation
# ---------------------------------------------------------------------------

def simulate_schedule(schedule: Schedule, d_k: int, hw: HwConfig,
                      overlap: str = "phase_max",
                      orig_head: Optional[Sequence[int]] = None,
                      k_globals: Optional[Sequence[np.ndarray]] = None,
                      q_globals: Optional[Sequence[np.ndarray]] = None,
                      q_groups: Optional[np.ndarray] = None,
                      include_scheduler: bool = True,
                      n_sort: Optional[int] = None) -> SimReport:
    """Run the Eq.-3 step model over an Algo-2 schedule.

    For tiled plans, ``orig_head``/``k_globals``/``q_globals`` map each
    sub-head's local operand indices back to (head, global index) so
    first-touch DRAM vs. buffer-hit accounting is exact, and ``q_groups``
    (per-subhead Q-fold-group ids) marks runs of sub-heads whose queries
    stay resident — re-loads inside a group cost nothing.  Untiled
    schedules default to identity mappings / one group per head.
    """
    beats = _beats(d_k, hw)
    bits = d_k * 8
    lat = comp = energy = macs = dram_bits = 0.0
    k_fetches = q_loads = 0
    seen_k: set = set()
    seen_q: set = set()
    resident_q: dict = {}          # group id → set of resident (head, q)

    def _head(i: int) -> int:
        return int(orig_head[i]) if orig_head is not None else i

    def _kg(i: int, k: int) -> int:
        return int(k_globals[i][k]) if k_globals is not None else k

    def _qg(i: int, q: int) -> int:
        return int(q_globals[i][q]) if q_globals is not None else q

    def _group(i: int):
        return int(q_groups[i]) if q_groups is not None else i

    for s in schedule.steps:
        # Queries already resident in their fold group load for free.
        fresh_q = []
        if s.q_head >= 0 and len(s.q_load):
            res_set = resident_q.setdefault(_group(s.q_head), set())
            for q in s.q_load:
                ident = (_head(s.q_head), _qg(s.q_head, q))
                if ident not in res_set:
                    res_set.add(ident)
                    fresh_q.append(ident)
        x, y = len(s.k_mac), len(fresh_q)
        mult = max(1, -(-s.n_active_q // hw.cap_q))   # key restreams/fold
        # First-touch keys stream from DRAM; re-touches hit the fold buffer.
        x_first = 0
        if s.k_head >= 0:
            h = _head(s.k_head)
            x_first = sum(1 for k in s.k_mac
                          if (h, _kg(s.k_head, k)) not in seen_k)
        t_rd_dt = (hw.rd_dram_cyc * x_first
                   + hw.rd_dt_cyc * (x * mult - x_first)) * beats
        t_wr_arr = hw.wr_arr_cyc * beats * y
        t_rd_comp = hw.rd_comp_cyc * beats * x * mult
        t_wr_dt = hw.wr_dt_cyc * beats * y
        if overlap == "phase_max":
            # Physical reading of Eq. 3: two overlap phases, each bounded
            # by its slower engine (K-stream ∥ Q-array-write, then
            # K-compute ∥ Q-staging).  Work-conserving; the default.
            tau = max(t_rd_dt, t_wr_arr) + max(t_rd_comp, t_wr_dt)
        elif overlap == "paper":                      # Eq. 3, verbatim min()
            if x == 0 or y == 0:                      # degenerate: serial
                tau = t_rd_dt + t_rd_comp + t_wr_arr + t_wr_dt
            else:
                tau = min(t_rd_dt, t_wr_arr) + min(t_rd_comp, t_wr_dt)
        elif overlap == "max":                        # decoupled pipelines
            tau = max(t_rd_dt + t_rd_comp, t_wr_arr + t_wr_dt)
        else:
            raise ValueError(overlap)
        lat += tau
        comp += t_rd_comp

        # --- energy: first touch DRAM, re-touch buffer ---
        if s.k_head >= 0:
            h = _head(s.k_head)
            for k in s.k_mac:
                ident = (h, _kg(s.k_head, k))
                if ident in seen_k:
                    energy += bits * hw.e_buf_bit * mult
                else:
                    seen_k.add(ident)
                    energy += bits * (hw.e_dram_bit + (mult - 1) * hw.e_buf_bit)
                    dram_bits += bits
        for ident in fresh_q:
            if ident in seen_q:
                energy += bits * (hw.e_buf_bit + hw.e_wr_bit)
            else:
                seen_q.add(ident)
                energy += bits * (hw.e_dram_bit + hw.e_wr_bit)
                dram_bits += bits
        energy += x * s.n_active_q * d_k * hw.e_mac8
        macs += x * s.n_active_q * d_k
        k_fetches += x * mult
        q_loads += y

    sch_cyc, sch_pj = (0.0, 0.0)
    if include_scheduler:
        n = n_sort if n_sort is not None else schedule.n_tokens
        sch_cyc, sch_pj = scheduler_cost(n, d_k, schedule.n_heads, hw)
        energy += sch_pj
        # Scheduling latency hides behind the QK MatMul via pipelining
        # (Sec. IV-A); only the excess beyond compute is exposed.
        lat += max(0.0, sch_cyc - lat)
    energy += lat * hw.p_static
    stall = 1.0 - comp / max(lat, 1e-9)
    return SimReport(latency_cycles=lat, energy_pj=energy, macs=macs,
                     k_fetches=k_fetches, q_loads=q_loads,
                     dram_bits=dram_bits,
                     scheduler_energy_pj=sch_pj, scheduler_cycles=sch_cyc,
                     stall_fraction=stall)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def _folded_baseline(masks: np.ndarray, d_k: int, hw: HwConfig,
                     mac_selected_only: bool) -> SimReport:
    masks = np.asarray(masks, dtype=bool)
    n_heads, n_q, n_k = masks.shape
    beats = _beats(d_k, hw)
    bits = d_k * 8
    n_folds = -(-n_q // hw.cap_q)
    # Queries: DRAM once, array-write once.  Keys: DRAM on first stream,
    # buffer on each of the (n_folds-1) restreams.  Serial flow: all
    # loads of a fold complete before its key stream (no overlap).
    lat = n_heads * (n_q * (hw.wr_dt_cyc + hw.wr_arr_cyc) * beats
                     + n_folds * n_k * (hw.rd_dram_cyc + hw.rd_comp_cyc) * beats)
    comp = n_heads * n_folds * n_k * hw.rd_comp_cyc * beats
    macs = (float(masks.sum()) if mac_selected_only
            else float(n_heads * n_q * n_k)) * d_k
    energy = n_heads * (
        n_q * bits * (hw.e_dram_bit + hw.e_wr_bit)
        + n_folds * n_k * bits * hw.e_dram_bit      # DRAM restream per fold
    ) + macs * hw.e_mac8
    energy += lat * hw.p_static
    return SimReport(latency_cycles=lat, energy_pj=energy, macs=macs,
                     k_fetches=n_heads * n_folds * n_k,
                     q_loads=n_heads * n_q,
                     dram_bits=n_heads * (n_q + n_folds * n_k) * bits,
                     scheduler_energy_pj=0.0, scheduler_cycles=0.0,
                     stall_fraction=1.0 - comp / lat)


def simulate_dense(masks: np.ndarray, d_k: int, hw: HwConfig) -> SimReport:
    """Dense CIM baseline (NeuroSim original flow): all N×N MACs, keys
    restream once per query fold, no load/compute overlap."""
    return _folded_baseline(masks, d_k, hw, mac_selected_only=False)


def simulate_gated(masks: np.ndarray, d_k: int, hw: HwConfig) -> SimReport:
    """Pruned-but-unscheduled baseline: selective gating without SATA —
    MAC energy only on selected pairs, but dense-shaped timing/traffic
    ("halting the functional unit" leaves the stream's bubbles in place)."""
    return _folded_baseline(masks, d_k, hw, mac_selected_only=True)


def simulate_tiled_sata(plan: TiledPlan, d_k: int, hw: HwConfig,
                        overlap: str = "phase_max") -> SimReport:
    """SATA with tiling + zero-skip (long-sequence path, Sec. III-D)."""
    from repro.core.tiling import fold_group_ids
    sched, _ = tiled_schedule(plan)
    return simulate_schedule(
        sched, d_k, hw, overlap=overlap,
        orig_head=[t.head for t in plan.tiles],
        k_globals=[t.k_idx for t in plan.tiles],
        q_globals=[t.q_idx for t in plan.tiles],
        q_groups=fold_group_ids(plan),
        n_sort=plan.s_f)
