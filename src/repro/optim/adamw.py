"""AdamW with fully-sharded fp32 moments (ZeRO-style: moments inherit
their parameter's sharding, which already spans both mesh axes), global
gradient clipping, cosine LR schedule, and optional int8 error-feedback
gradient compression (applied around the data-axis gradient reduction).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False   # int8 error-feedback compression


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr \
        * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {"m": jax.tree.map(zeros, params),
             "v": jax.tree.map(zeros, params),
             "step": jnp.zeros((), jnp.int32)}
    return state


def _global_norm(tree: Any) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def compress_int8(g: jax.Array, err: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 quantization: returns (dequantized g, new err).

    The quantized representation is what would cross the wire in the
    data-axis all-reduce; the residual feeds back next step so the
    compression is unbiased over time (1-bit Adam-style)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def adamw_update(opt: OptConfig, params: Any, grads: Any,
                 state: Dict[str, Any],
                 err: Optional[Any] = None
                 ) -> Tuple[Any, Dict[str, Any], Optional[Any], Dict]:
    """One AdamW step. Returns (new_params, new_state, new_err, metrics)."""
    step = state["step"] + 1
    if opt.compress_grads and err is not None:
        pairs = jax.tree.map(compress_int8, grads, err)
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        err = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / (gnorm + 1e-9))
    lr = lr_at(opt, step)
    b1c = 1 - opt.b1 ** step.astype(jnp.float32)
    b2c = 1 - opt.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = opt.b1 * m + (1 - opt.b1) * g
        v = opt.b2 * v + (1 - opt.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + opt.eps) \
            + opt.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, err, metrics
