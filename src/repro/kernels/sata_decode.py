"""SATA decode gather kernel — scalar-prefetch selective fetch over the
KV cache for single-token decode.

Prefill's compacted grid walks ``(BH, nqb, P)``; at decode there is one
query *token* per slot, so the natural tile is the **GQA group**: the
``G = H // KV`` query heads that share a KV head attend the same cached
K/V blocks, giving a ``(G, D)`` q tile per ``(batch, kv_head)`` row and
a grid of ``(B·KV, P)`` — one slot per *selected* k-block, exactly the
incremental plan (``core/decode_plan.py``) maintains.

Scalar-prefetch operands (available to the BlockSpec index maps before
the body runs, so the DMA engine only ever touches planned tiles):

  kv_indices (B·KV, P) int32 — ascending selected k-block indices
                              (``compact_kv_plan`` padding: slots past
                              the count re-reference the resident block
                              — no fetch, and the body is skipped);
  kv_counts  (B·KV,)   int32 — live slots per row;
  pos        (B,)      int32 — per-slot decode positions: keys at
                              ``token > pos[b]`` are masked in-body, so
                              ragged slot lengths and freshly-claimed
                              (reset) slots never read stale cache.

K/V stay in the serving cache layout ``(B, S, KV, D)`` — the index maps
slice ``(b, block, kv_head)`` tiles directly, so no head-expanded or
transposed copy of the cache is ever materialized.

**Paged cache** (``sata_decode_attention_paged_kernel``): the serving
cache may instead live in a global page pool ``(n_pages, page, KV, D)``
with a per-slot page table (``core/paging.py``).  Because the plan's
block edge equals the page size, the ONLY change is one more scalar-
prefetch operand — the page table — and a K/V index map that
dereferences it: ``physical = table[slot, kv_indices[row, j]]``.  The
grid, the flash inner loop, and the in-body masks are byte-for-byte the
same kernel (positions stay *logical*: ``kv_indices`` holds logical
page ids, so causality masking never sees physical placement).

Selection inside a fetched tile is threshold mode only: the element
mask is re-derived as ``bf16(score) >= bf16(thr)`` (the bisect predicate
shared with prefill) AND ``token <= pos``.  With a full re-plan every
step the output is bitwise equal to dense top-k (bisect) decode: a tile
whose every entry is masked contributes ``p = 0`` and leaves the online
softmax state untouched, so skipping it is exact.

The kernel is **summary-backend agnostic**: it consumes only the plan's
``kv_indices``/``kv_counts``/thresholds, never the block summaries, so
the fp32 and int8 summary backends (and the exact vs sketch re-plan
modes) change which blocks get planned — not how a planned block is
attended.  The plan-side traffic those backends save is accounted in
``kernels.ops.decode_fetch_stats`` (dtype- and mode-aware), not here.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.blockmap import bisect_select
from repro.kernels.sata_attention import (_acc_init, _finalize_out,
                                          _flash_update_tile, _vmem)


def _decode_kernel(idx_ref, cnt_ref, pos_ref, q_ref, k_ref, v_ref,
                   thr_ref, o_ref, acc_ref, m_ref, l_ref, *,
                   sm_scale: float, n_slots: int, k_block: int,
                   n_kv: int):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        _acc_init(acc_ref, m_ref, l_ref)

    @pl.when(j < cnt_ref[i])
    def _update():
        q = q_ref[0, 0]                            # (G, D)
        k = k_ref[0, :, 0, :]                      # (k_block, D)
        v = v_ref[0, :, 0, :]
        # global key positions of the resident tile gate validity: the
        # plan may include the partially-written tail block, and padded
        # slots of *shorter* ragged rows must not see future tokens.
        kpos = idx_ref[i, j] * k_block + \
            jax.lax.broadcasted_iota(jnp.int32, (1, k_block), 1)
        admissible = kpos <= pos_ref[i // n_kv]              # (1, k_block)
        _flash_update_tile(q, k, v, acc_ref, m_ref, l_ref,
                           sm_scale=sm_scale, threshold=thr_ref[0, 0],
                           admissible=admissible)

    @pl.when(j == n_slots - 1)
    def _finalize():
        o_ref[0, 0] = _finalize_out(acc_ref, l_ref).astype(o_ref.dtype)


def sata_decode_attention_kernel(
    q: jax.Array, k: jax.Array, v: jax.Array,
    kv_indices: jax.Array, kv_counts: jax.Array,
    thresholds: jax.Array, pos: jax.Array,
    *, k_block: int = 128, sm_scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, KV, G, D) grouped query rows; k/v: (B, S, KV, D) cache;
    kv_indices: (B, KV, P) int32; kv_counts: (B, KV) int32;
    thresholds: (B, KV, G, 1) fp32 per-row top-k thresholds;
    pos: (B,) int32 per-slot positions.  Returns (B, KV, G, D)."""
    from jax.experimental.pallas import tpu as pltpu

    b, n_kv, g, d = q.shape
    s = k.shape[1]
    assert k.shape == (b, s, n_kv, d), (k.shape, q.shape)
    assert s % k_block == 0, (s, k_block)
    p = kv_indices.shape[-1]
    assert kv_indices.shape == (b, n_kv, p), kv_indices.shape
    assert kv_counts.shape == (b, n_kv), kv_counts.shape
    assert thresholds.shape == (b, n_kv, g, 1), thresholds.shape
    assert pos.shape == (b,), pos.shape
    if p == 0:
        return jnp.zeros((b, n_kv, g, d), q.dtype)
    sm_scale = float(sm_scale if sm_scale is not None else 1.0 / np.sqrt(d))

    def q_map(i, j, idx_ref, cnt_ref, pos_ref):
        return (i // n_kv, i % n_kv, 0, 0)

    def kv_map(i, j, idx_ref, cnt_ref, pos_ref):
        return (i // n_kv, idx_ref[i, j], i % n_kv, 0)

    def thr_map(i, j, idx_ref, cnt_ref, pos_ref):
        return (i // n_kv, i % n_kv, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b * n_kv, p),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), q_map),
            pl.BlockSpec((1, k_block, 1, d), kv_map),
            pl.BlockSpec((1, k_block, 1, d), kv_map),
            pl.BlockSpec((1, 1, g, 1), thr_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), q_map),
        scratch_shapes=[
            _vmem((g, d), jnp.float32),             # acc
            _vmem((g, 1), jnp.float32),             # running max m
            _vmem((g, 1), jnp.float32),             # running sum l
        ],
    )
    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale,
                               n_slots=p, k_block=k_block, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, d), q.dtype),
        interpret=interpret,
    )(kv_indices.reshape(b * n_kv, p).astype(jnp.int32),
      kv_counts.reshape(b * n_kv).astype(jnp.int32),
      pos.astype(jnp.int32),
      q, k, v, thresholds.astype(jnp.float32))


def _paged_decode_kernel(idx_ref, cnt_ref, pos_ref, tbl_ref, *args, **kw):
    """Paged body == contiguous body: the page table is consumed only by
    the BlockSpec index maps, never inside the kernel."""
    del tbl_ref
    _decode_kernel(idx_ref, cnt_ref, pos_ref, *args, **kw)


def sata_decode_attention_paged_kernel(
    q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
    page_table: jax.Array, kv_indices: jax.Array, kv_counts: jax.Array,
    thresholds: jax.Array, pos: jax.Array,
    *, sm_scale: Optional[float] = None, interpret: bool = False,
) -> jax.Array:
    """Decode gather kernel over the paged pool: q (B, KV, G, D);
    k_pages/v_pages (n_pages, page, KV, D); page_table (B, max_pages)
    int32 (logical→physical); kv_indices (B, KV, P) int32 *logical*
    page ids; kv_counts (B, KV); thresholds (B, KV, G, 1) fp32;
    pos (B,).  Returns (B, KV, G, D).  The k-block edge IS the page
    size."""
    from jax.experimental.pallas import tpu as pltpu

    b, n_kv, g, d = q.shape
    n_pages, page, kvh, dk = k_pages.shape
    assert (kvh, dk) == (n_kv, d), (k_pages.shape, q.shape)
    assert v_pages.shape == k_pages.shape
    p = kv_indices.shape[-1]
    assert kv_indices.shape == (b, n_kv, p), kv_indices.shape
    assert kv_counts.shape == (b, n_kv), kv_counts.shape
    assert thresholds.shape == (b, n_kv, g, 1), thresholds.shape
    assert page_table.shape[0] == b, (page_table.shape, b)
    assert pos.shape == (b,), pos.shape
    if p == 0:
        return jnp.zeros((b, n_kv, g, d), q.dtype)
    sm_scale = float(sm_scale if sm_scale is not None else 1.0 / np.sqrt(d))

    def q_map(i, j, idx_ref, cnt_ref, pos_ref, tbl_ref):
        return (i // n_kv, i % n_kv, 0, 0)

    def kv_map(i, j, idx_ref, cnt_ref, pos_ref, tbl_ref):
        # the one paged-vs-contiguous difference: logical plan entry →
        # physical page through the slot's table row
        return (tbl_ref[i // n_kv, idx_ref[i, j]], 0, i % n_kv, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b * n_kv, p),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), q_map),
            pl.BlockSpec((1, page, 1, d), kv_map),
            pl.BlockSpec((1, page, 1, d), kv_map),
            pl.BlockSpec((1, 1, g, 1), q_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), q_map),
        scratch_shapes=[
            _vmem((g, d), jnp.float32),             # acc
            _vmem((g, 1), jnp.float32),             # running max m
            _vmem((g, 1), jnp.float32),             # running sum l
        ],
    )
    kernel = functools.partial(_paged_decode_kernel, sm_scale=sm_scale,
                               n_slots=p, k_block=page, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, d), q.dtype),
        interpret=interpret,
    )(kv_indices.reshape(b * n_kv, p).astype(jnp.int32),
      kv_counts.reshape(b * n_kv).astype(jnp.int32),
      pos.astype(jnp.int32),
      page_table.astype(jnp.int32),
      q, k_pages, v_pages, thresholds.astype(jnp.float32))
