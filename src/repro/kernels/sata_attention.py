"""SATA block-sparse flash attention — Pallas TPU kernel.

TPU-native embodiment of the paper's insight: SATA's key sorting
concentrates each query's selected keys into contiguous runs, so after
permuting K/V by ``kv_order`` and grouping queries by HEAD/GLOB/TAIL
class, whole (q_block × k_block) tiles of the score matrix are empty.
The kernel walks the (bh, q_block, k_block) grid with flash-style online
softmax and **skips all compute for empty tiles** (``@pl.when`` on the
prefetched block map) — the MXU analogue of gating whole CIM sub-array
passes, at the granularity the MXU actually exploits (128×128 tiles).

Two execution modes:
  * block mode  (``mask=None``)   — dense math inside occupied tiles,
    exactly the paper's energy model ("MACs are dense, albeit in a
    subset of tiles").
  * exact mode  (``mask`` given)  — additionally applies the element-
    level top-k mask inside each tile; bit-exact selective attention.

Grid: (B·H, n_q_blocks, n_k_blocks), k innermost so the VMEM scratch
accumulators (acc, m, l) carry across the k sweep.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -2.0 ** 30


def _kernel(bm_ref, q_ref, k_ref, v_ref, mask_ref, o_ref,
            acc_ref, m_ref, l_ref, *, sm_scale: float, n_kb: int,
            exact: bool):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    occupied = bm_ref[0, 0, 0] != 0

    @pl.when(occupied)
    def _update():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (bq, bk)
        if exact:
            s = jnp.where(mask_ref[0], s, NEG_INF)
        m_prev = m_ref[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                         # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_kb - 1)
    def _finalize():
        l = l_ref[...]
        out = jnp.where(l > 0, acc_ref[...] / jnp.where(l > 0, l, 1.0), 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def sata_block_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, block_map: jax.Array,
    mask: Optional[jax.Array] = None,
    *, q_block: int = 128, k_block: int = 128,
    sm_scale: Optional[float] = None, interpret: bool = False,
) -> jax.Array:
    """q: (BH, Sq, D); k/v: (BH, Sk, D) in SATA-sorted key order;
    block_map: (BH, Sq/q_block, Sk/k_block) bool/int;
    mask: optional (BH, Sq, Sk) element-level selection mask."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % q_block == 0 and sk % k_block == 0, (sq, sk)
    nqb, nkb = sq // q_block, sk // k_block
    sm_scale = float(sm_scale if sm_scale is not None else 1.0 / np.sqrt(d))
    exact = mask is not None
    if mask is None:
        mask = jnp.ones((bh, 1, 1), dtype=jnp.int8)    # dummy, never read

    grid = (bh, nqb, nkb)
    kernel = functools.partial(_kernel, sm_scale=sm_scale, n_kb=nkb,
                               exact=exact)
    mask_spec = (pl.BlockSpec((1, q_block, k_block),
                              lambda b, i, j: (b, i, j)) if exact
                 else pl.BlockSpec((1, 1, 1), lambda b, i, j: (b, 0, 0)))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1), lambda b, i, j: (b, i, j)),      # map
            pl.BlockSpec((1, q_block, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, k_block, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, k_block, d), lambda b, i, j: (b, j, 0)),
            mask_spec,
        ],
        out_specs=pl.BlockSpec((1, q_block, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            _vmem((q_block, d), jnp.float32),       # acc
            _vmem((q_block, 1), jnp.float32),       # running max m
            _vmem((q_block, 1), jnp.float32),       # running sum l
        ],
        interpret=interpret,
    )(block_map.astype(jnp.int32), q, k, v, mask)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
