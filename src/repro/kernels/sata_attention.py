"""SATA block-sparse flash attention — Pallas TPU kernel.

TPU-native embodiment of the paper's insight: SATA's key sorting
concentrates each query's selected keys into contiguous runs, so after
permuting K/V by ``kv_order`` and grouping queries by HEAD/GLOB/TAIL
class, whole (q_block × k_block) tiles of the score matrix are empty.
The kernel walks the (bh, q_block, k_block) grid with flash-style online
softmax and **skips all compute for empty tiles** (``@pl.when`` on the
prefetched block map) — the MXU analogue of gating whole CIM sub-array
passes, at the granularity the MXU actually exploits (128×128 tiles).

Three execution modes:
  * block mode     (no selection operand) — dense math inside occupied
    tiles, exactly the paper's energy model ("MACs are dense, albeit in
    a subset of tiles").  A ``causal=True`` request is still honored:
    the compacted grid gates future keys with the position operands.
  * exact mode     (``mask`` given) — additionally applies the element-
    level top-k mask inside each tile; bit-exact selective attention.
    The mask is a (BH, Sq, Sk) resident — the quadratic operand the
    threshold mode exists to avoid.
  * threshold mode (``thresholds`` given; compacted grid only) — the
    element mask is *re-derived per tile* from a (BH, Sq, 1) per-row
    top-k threshold: ``bf16(score) >= bf16(thr)``, the exact compare the
    bisect selection (``models.attention.kth_largest_bisect``) counted
    with, AND-ed with causality from ``q_pos``/``k_pos`` operands that
    ride through the same prefetched index maps as K/V (so they survive
    any key permutation).  Selection state entering the kernel is O(S):
    this is the chunked selection pipeline's back end — pass 1 streams
    ``q_chunk × Sk`` score tiles to bisect per-row thresholds, pass 2
    reduces the same tiles to the block occupancy map
    (``core.blockmap.compact_plan_from_chunks``), and no (BH, Sq, Sk)
    score tensor or boolean mask ever exists.

Scheduling: dense grid vs compacted grid
----------------------------------------
``sata_block_attention`` (dense grid) walks the full
``(BH, n_q_blocks, n_k_blocks)`` grid and gates *compute* on the
prefetched block map — but the BlockSpec pipeline still streams every
K/V tile through VMEM, so HBM traffic stays quadratic and wall-clock
barely tracks the block-skip fraction.  It is kept as the baseline the
benchmarks measure against.

``sata_block_attention_compact`` is the SATA scheduler proper: the
planner (``core.blockmap.compact_kv_plan``) compresses each
``(bh, q_block)`` row of the occupancy map into an ascending list of
occupied k-block indices (``kv_indices (BH, nqb, P)``) plus a count
(``kv_counts (BH, nqb)``).  Both ride in as *scalar prefetch* operands
(``pltpu.PrefetchScalarGridSpec``), available to the BlockSpec index
maps **before** the kernel body runs, so the K/V (and exact-mode mask)
index maps dereference ``kv_indices[b, i, j]`` and the DMA engine only
ever fetches occupied tiles.  The grid shrinks to ``(BH, nqb, P)`` where
``P`` is the padded max occupancy — work scheduled, fetched, and
computed all scale with the occupied-tile count, not ``nqb·nkb``.
Padding slots repeat an already-resident index (see ``compact_kv_plan``)
— the Pallas pipeline skips the DMA when consecutive grid steps map to
the same block, so padding costs neither fetch nor compute (the body is
``pl.when``-gated on ``j < kv_counts[b, i]``).

Grid: k-slot innermost so the VMEM scratch accumulators (acc, m, l)
carry across the k sweep of one query block.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.blockmap import bisect_select

NEG_INF = -2.0 ** 30


def _acc_init(acc_ref, m_ref, l_ref):
    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)


def _finalize_out(acc_ref, l_ref):
    """Normalized output tile; rows with no admissible key (l == 0)
    emit zeros.  Shared by the prefill and decode kernels — their out
    refs differ only in leading block layout."""
    l = l_ref[...]
    return jnp.where(l > 0, acc_ref[...] / jnp.where(l > 0, l, 1.0), 0.0)


def _acc_finalize(o_ref, acc_ref, l_ref):
    o_ref[0] = _finalize_out(acc_ref, l_ref).astype(o_ref.dtype)


def _kernel(bm_ref, q_ref, k_ref, v_ref, mask_ref, o_ref,
            acc_ref, m_ref, l_ref, *, sm_scale: float, n_kb: int,
            exact: bool):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        _acc_init(acc_ref, m_ref, l_ref)

    occupied = bm_ref[0, 0, 0] != 0

    @pl.when(occupied)
    def _update():
        _flash_update(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                      sm_scale=sm_scale,
                      tile_mask=mask_ref[0] if exact else None)

    @pl.when(kj == n_kb - 1)
    def _finalize():
        _acc_finalize(o_ref, acc_ref, l_ref)


def sata_block_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, block_map: jax.Array,
    mask: Optional[jax.Array] = None,
    *, q_block: int = 128, k_block: int = 128,
    sm_scale: Optional[float] = None, interpret: bool = False,
) -> jax.Array:
    """q: (BH, Sq, D); k/v: (BH, Sk, D) in SATA-sorted key order;
    block_map: (BH, Sq/q_block, Sk/k_block) bool/int;
    mask: optional (BH, Sq, Sk) element-level selection mask."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % q_block == 0 and sk % k_block == 0, (sq, sk)
    nqb, nkb = sq // q_block, sk // k_block
    sm_scale = float(sm_scale if sm_scale is not None else 1.0 / np.sqrt(d))
    exact = mask is not None
    if mask is None:
        mask = jnp.ones((bh, 1, 1), dtype=jnp.int8)    # dummy, never read

    grid = (bh, nqb, nkb)
    kernel = functools.partial(_kernel, sm_scale=sm_scale, n_kb=nkb,
                               exact=exact)
    mask_spec = (pl.BlockSpec((1, q_block, k_block),
                              lambda b, i, j: (b, i, j)) if exact
                 else pl.BlockSpec((1, 1, 1), lambda b, i, j: (b, 0, 0)))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1), lambda b, i, j: (b, i, j)),      # map
            pl.BlockSpec((1, q_block, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, k_block, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, k_block, d), lambda b, i, j: (b, j, 0)),
            mask_spec,
        ],
        out_specs=pl.BlockSpec((1, q_block, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            _vmem((q_block, d), jnp.float32),       # acc
            _vmem((q_block, 1), jnp.float32),       # running max m
            _vmem((q_block, 1), jnp.float32),       # running sum l
        ],
        interpret=interpret,
    )(block_map.astype(jnp.int32), q, k, v, mask)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


# ---------------------------------------------------------------------------
# Compacted grid: scalar-prefetch scheduling (skips fetch, not just compute)
# ---------------------------------------------------------------------------

def _flash_update(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, tile_mask=None, threshold=None,
                  admissible=None):
    """One online-softmax accumulation step over the resident K/V tile.

    Selection is one of: ``tile_mask`` (precomputed element mask, exact
    mode), ``threshold`` (a (bq, 1) per-row top-k threshold — the tile
    mask is re-derived *in-kernel* with the bisect-consistent bf16
    compare, optionally AND-ed with ``admissible``), or neither (block
    mode: dense math inside the tile).
    """
    _flash_update_tile(q_ref[0], k_ref[0], v_ref[0], acc_ref, m_ref,
                       l_ref, sm_scale=sm_scale, tile_mask=tile_mask,
                       threshold=threshold, admissible=admissible)


def _flash_update_tile(q, k, v, acc_ref, m_ref, l_ref, *,
                       sm_scale: float, tile_mask=None, threshold=None,
                       admissible=None):
    """Array-level core of ``_flash_update`` — shared with the decode
    kernel, whose block shapes carry a different leading layout.
    q: (bq, d); k/v: (bk, d); accumulators are VMEM refs."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale       # (bq, bk)
    if threshold is not None:
        assert tile_mask is None
        tile_mask = bisect_select(s, threshold)              # (bq, bk)
        if admissible is not None:
            tile_mask = tile_mask & admissible
    if tile_mask is not None:
        s = jnp.where(tile_mask, s, NEG_INF)
    m_prev = m_ref[...]                            # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                         # (bq, bk)
    if tile_mask is not None:
        # a row fully masked so far has s == m_new == NEG_INF, where the
        # finite sentinel gives exp(0) = 1, not 0 — zero masked entries
        # explicitly so such rows keep l == 0 and finalize to zeros.
        p = jnp.where(tile_mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _compact_kernel(idx_ref, cnt_ref, q_ref, k_ref, v_ref, mask_ref,
                    thr_ref, qpos_ref, kpos_ref, o_ref,
                    acc_ref, m_ref, l_ref, *, sm_scale: float, n_slots: int,
                    select: str, causal: bool):
    b, qi, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _acc_init(acc_ref, m_ref, l_ref)

    # Slots past the row's occupancy count are padding: their index maps
    # re-reference an already-resident tile (no fetch) and the body is
    # skipped entirely (no compute).
    @pl.when(j < cnt_ref[b, qi])
    def _update():
        threshold = admissible = tile_mask = None
        if causal and select != "mask":
            # k_pos rides in per K-tile through the same prefetched
            # index map as K itself, so causality survives any key
            # permutation.  (Exact mode bakes causality into the mask.)
            qp = qpos_ref[0]                       # (bq, 1) int32
            kp = kpos_ref[0]                       # (bk, 1) int32
            admissible = jnp.transpose(kp) <= qp   # (bq, bk)
        if select == "mask":
            tile_mask = mask_ref[0]
        elif select == "threshold":
            threshold = thr_ref[0]                 # (bq, 1)
        else:
            # block mode: dense math inside the tile, but a causal
            # request must still gate future keys.
            tile_mask = admissible
            admissible = None
        _flash_update(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                      sm_scale=sm_scale, tile_mask=tile_mask,
                      threshold=threshold, admissible=admissible)

    @pl.when(j == n_slots - 1)
    def _finalize():
        _acc_finalize(o_ref, acc_ref, l_ref)


def sata_block_attention_compact(
    q: jax.Array, k: jax.Array, v: jax.Array,
    kv_indices: jax.Array, kv_counts: jax.Array,
    mask: Optional[jax.Array] = None,
    thresholds: Optional[jax.Array] = None,
    q_pos: Optional[jax.Array] = None,
    k_pos: Optional[jax.Array] = None,
    *, causal: bool = False, q_block: int = 128, k_block: int = 128,
    sm_scale: Optional[float] = None, interpret: bool = False,
) -> jax.Array:
    """Compacted-grid SATA attention (see module docstring).

    q: (BH, Sq, D); k/v: (BH, Sk, D) in SATA-sorted key order;
    kv_indices: (BH, Sq/q_block, P) int32 occupied k-block indices,
    padded per ``core.blockmap.compact_kv_plan``;
    kv_counts:  (BH, Sq/q_block) int32 occupancy per q-block row.

    Selection — exactly one of:
      * ``mask``       (BH, Sq, Sk) element-level mask (exact mode; the
        quadratic operand the chunked pipeline exists to avoid);
      * ``thresholds`` (BH, Sq, 1) fp32 per-row top-k thresholds
        (threshold mode): the tile mask is recomputed in-kernel as
        ``bf16(score) >= bf16(thr)``; with ``causal=True``, ``q_pos``
        (BH, Sq, 1) / ``k_pos`` (BH, Sk, 1) int32 token positions (in
        the kernel's K layout order) gate it so only admissible keys
        count.  Only O(S) selection state ever reaches the kernel.
      * neither — block mode (dense math inside occupied tiles); with
        ``causal=True`` the position operands still gate future keys,
        so a causal request never leaks across the diagonal tiles.
    """
    from jax.experimental.pallas import tpu as pltpu

    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % q_block == 0 and sk % k_block == 0, (sq, sk)
    nqb = sq // q_block
    n_slots = kv_indices.shape[-1]
    assert kv_indices.shape[:2] == (bh, nqb), (kv_indices.shape, bh, nqb)
    assert kv_counts.shape == (bh, nqb), (kv_counts.shape, bh, nqb)
    assert mask is None or thresholds is None, \
        "mask and thresholds are mutually exclusive selection modes"
    if n_slots == 0:
        # entirely-empty plan (pad_to=0): a zero-extent grid dim would
        # never run the kernel, leaving o_ref unwritten — the attention
        # of a row with no admissible key is zeros by definition.
        return jnp.zeros((bh, sq, d), q.dtype)
    sm_scale = float(sm_scale if sm_scale is not None else 1.0 / np.sqrt(d))
    select = ("mask" if mask is not None
              else "threshold" if thresholds is not None else "none")
    # exact mode bakes causality into the mask; threshold AND block mode
    # both need positions to honor a causal request in-kernel
    use_pos = causal and select != "mask"
    if use_pos:
        assert q_pos is not None and k_pos is not None, \
            "causal threshold/block mode needs q_pos/k_pos"
        assert q_pos.shape == (bh, sq, 1), q_pos.shape
        assert k_pos.shape == (bh, sk, 1), k_pos.shape
    dummy3 = jnp.zeros((1, 1, 1), jnp.int8)
    if mask is None:
        mask = dummy3                                  # never read
    if thresholds is None:
        thresholds = jnp.zeros((1, 1, 1), jnp.float32)
    if not use_pos:
        q_pos = k_pos = jnp.zeros((1, 1, 1), jnp.int32)
    if thresholds.shape != (1, 1, 1):
        assert thresholds.shape == (bh, sq, 1), thresholds.shape

    # index maps receive (grid ids..., *scalar-prefetch refs)
    def kv_map(b, i, j, idx_ref, cnt_ref):
        return (b, idx_ref[b, i, j], 0)

    def q_row_map(b, i, j, idx_ref, cnt_ref):
        return (b, i, 0)

    def _dummy_map(b, i, j, idx_ref, cnt_ref):
        return (0, 0, 0)

    dummy_spec = pl.BlockSpec((1, 1, 1), _dummy_map)
    mask_spec = (
        pl.BlockSpec((1, q_block, k_block),
                     lambda b, i, j, idx_ref, cnt_ref:
                     (b, i, idx_ref[b, i, j])) if select == "mask"
        else dummy_spec)
    thr_spec = (pl.BlockSpec((1, q_block, 1), q_row_map)
                if select == "threshold" else dummy_spec)
    qpos_spec = (pl.BlockSpec((1, q_block, 1), q_row_map)
                 if use_pos else dummy_spec)
    kpos_spec = (pl.BlockSpec((1, k_block, 1), kv_map)
                 if use_pos else dummy_spec)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bh, nqb, n_slots),
        in_specs=[
            pl.BlockSpec((1, q_block, d), q_row_map),
            pl.BlockSpec((1, k_block, d), kv_map),
            pl.BlockSpec((1, k_block, d), kv_map),
            mask_spec,
            thr_spec,
            qpos_spec,
            kpos_spec,
        ],
        out_specs=pl.BlockSpec((1, q_block, d), q_row_map),
        scratch_shapes=[
            _vmem((q_block, d), jnp.float32),       # acc
            _vmem((q_block, 1), jnp.float32),       # running max m
            _vmem((q_block, 1), jnp.float32),       # running sum l
        ],
    )
    kernel = functools.partial(_compact_kernel, sm_scale=sm_scale,
                               n_slots=n_slots, select=select,
                               causal=use_pos)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(kv_indices.astype(jnp.int32), kv_counts.astype(jnp.int32),
      q, k, v, mask, thresholds.astype(jnp.float32),
      q_pos.astype(jnp.int32), k_pos.astype(jnp.int32))
