"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0 ** 30


def ref_block_attention(q, k, v, block_map,
                        mask: Optional[jax.Array] = None,
                        *, q_block: int = 128, k_block: int = 128,
                        sm_scale: Optional[float] = None) -> jax.Array:
    """Reference for ``sata_block_attention``: masked softmax attention
    where a (q_block × k_block) tile participates iff its block_map entry
    is set; optional element-level mask on top (exact mode).  Rows with
    no admissible key return zeros (matching the kernel's l==0 guard)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    sm_scale = float(sm_scale if sm_scale is not None else 1.0 / np.sqrt(d))
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    bm = jnp.repeat(jnp.repeat(block_map.astype(bool), q_block, axis=1),
                    k_block, axis=2)
    keep = bm if mask is None else (bm & mask.astype(bool))
    s = jnp.where(keep, s, NEG_INF)
    any_key = keep.any(axis=-1, keepdims=True)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(any_key, p, 0.0)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_dense_attention(q, k, v, *, sm_scale=None) -> jax.Array:
    bh, sq, d = q.shape
    bm = jnp.ones((bh, 1, 1), dtype=bool)
    return ref_block_attention(q, k, v, bm, q_block=sq, k_block=k.shape[1],
                               sm_scale=sm_scale)
