"""Jit'd public wrappers: SATA planning (sort → permute → block map) +
the Pallas kernel, end to end.

``schedule`` selects the kernel's execution plan:
  * ``"compact"`` (default) — scalar-prefetch compacted grid: the K/V
    BlockSpec index maps walk ``compact_kv_plan``'s occupied-tile lists,
    so empty tiles are never fetched *or* visited.
  * ``"dense"``  — full ``(BH, nqb, nkb)`` grid with compute-only
    skipping (``@pl.when`` on the block map); kept as the measured
    baseline.

``interpret=None`` auto-detects the backend: compiled Mosaic on TPU,
interpret mode elsewhere (CPU CI).  Pass an explicit bool to override.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockmap import (compact_kv_plan, identity_block_plan,
                                 sata_block_plan)
from repro.kernels.ref import ref_block_attention
from repro.kernels.sata_attention import (sata_block_attention,
                                          sata_block_attention_compact)


def default_interpret() -> bool:
    """Interpret Pallas kernels only when no TPU backend is present."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("q_block", "k_block",
                                             "use_sata", "interpret",
                                             "exact", "schedule",
                                             "max_kv_blocks"))
def sata_attention(q: jax.Array, k_: jax.Array, v: jax.Array,
                   scores_mask: jax.Array, *, q_block: int = 128,
                   k_block: int = 128, use_sata: bool = True,
                   exact: bool = True, interpret: Optional[bool] = None,
                   schedule: str = "compact",
                   max_kv_blocks: Optional[int] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Top-k selective attention through the SATA plan + Pallas kernel.

    q/k_/v: (BH, S, D); scores_mask: (BH, Sq, Sk) bool top-k selection.
    Returns (output in ORIGINAL query order, block_map) — block skip
    fraction is ``1 - block_map.mean()``.

    ``max_kv_blocks`` (compact schedule only) statically bounds the
    occupied k-blocks per q-row, shrinking the kernel grid's innermost
    dimension from ``nkb`` to that bound.  Callers with a concrete block
    map get it from ``int(kv_counts.max())`` (``compact_kv_plan`` raises
    on a concrete under-estimate); inside jit it must be a static
    over-estimate — an under-estimate cannot be detected there and drops
    occupied tiles (the default ``None`` keeps the safe full ``nkb``).
    """
    if schedule not in ("compact", "dense"):
        raise ValueError(f"unknown schedule {schedule!r}")
    if interpret is None:
        interpret = default_interpret()
    plan_fn = sata_block_plan if use_sata else identity_block_plan
    if use_sata:
        kv_order, q_order, block_map = plan_fn(scores_mask, q_block, k_block)
    else:
        kv_order, q_order, block_map = identity_block_plan(
            scores_mask, q_block, k_block)
    kp = jnp.take_along_axis(k_, kv_order[:, :, None], axis=1)
    vp = jnp.take_along_axis(v, kv_order[:, :, None], axis=1)
    qp = jnp.take_along_axis(q, q_order[:, :, None], axis=1)
    # block mode needs no dense (BH, Sq, Sk) mask — only exact mode
    # permutes and ships it.
    mask_p = None
    if exact:
        mask_p = jnp.take_along_axis(
            jnp.take_along_axis(scores_mask, kv_order[:, None, :], axis=2),
            q_order[:, :, None], axis=1)
    if schedule == "compact":
        kv_indices, kv_counts = compact_kv_plan(block_map,
                                                pad_to=max_kv_blocks)
        out_p = sata_block_attention_compact(
            qp, kp, vp, kv_indices, kv_counts, mask=mask_p,
            q_block=q_block, k_block=k_block, interpret=interpret)
    else:
        out_p = sata_block_attention(qp, kp, vp, block_map, mask=mask_p,
                                     q_block=q_block, k_block=k_block,
                                     interpret=interpret)
    # scatter back to original query order
    inv = jnp.argsort(q_order, axis=-1)
    out = jnp.take_along_axis(out_p, inv[:, :, None], axis=1)
    return out, block_map


def sata_attention_reference(q, k_, v, scores_mask) -> jax.Array:
    """Oracle: exact top-k selective attention, no planning/permutation."""
    bh, sq, _ = q.shape
    bm = jnp.ones((bh, 1, 1), dtype=bool)
    return ref_block_attention(q, k_, v, bm, mask=scores_mask,
                               q_block=sq, k_block=k_.shape[1])


def kernel_fetch_stats(block_map, *, q_block: int, k_block: int, d: int,
                       dtype_bytes: int = 4,
                       max_kv_blocks: Optional[int] = None) -> Dict:
    """Tile-visit and K/V fetch-byte accounting, dense vs compacted grid.

    The dense grid visits — and its BlockSpec pipeline *fetches* — every
    ``(bh, q_row, k_block)`` tile regardless of occupancy.  The compacted
    grid visits ``nqb × P`` slots (P = the padded slot count) and fetches
    at most one K+V tile per *occupied* slot: padding slots re-reference
    the resident block, which the Pallas pipeline does not re-fetch.
    Counts are exact for the scheduled index sequence (boundary reuse
    between consecutive rows can only lower the compact fetch count).

    ``max_kv_blocks`` defaults to the same value as ``sata_attention``'s
    (the full ``nkb``), so default-args accounting describes the grid the
    default kernel call actually runs; pass the concrete occupancy bound
    to model a ``max_kv_blocks``-narrowed launch.
    """
    bm = np.asarray(block_map).astype(bool)
    bh, nqb, nkb = bm.shape
    counts = bm.sum(-1)                                   # (bh, nqb)
    p = int(max_kv_blocks) if max_kv_blocks is not None else nkb
    tile_bytes = 2 * k_block * d * dtype_bytes            # one K + one V tile
    dense_visits = bh * nqb * nkb
    compact_visits = bh * nqb * p
    dense_fetch_tiles = bh * nqb * nkb
    compact_fetch_tiles = int(counts.sum())
    return {
        "grid_dense": [bh, nqb, nkb],
        "grid_compact": [bh, nqb, p],
        "tile_visits_dense": dense_visits,
        "tile_visits_compact": compact_visits,
        "kv_fetch_tiles_dense": dense_fetch_tiles,
        "kv_fetch_tiles_compact": compact_fetch_tiles,
        "kv_fetch_bytes_dense": dense_fetch_tiles * tile_bytes,
        "kv_fetch_bytes_compact": compact_fetch_tiles * tile_bytes,
        "visit_reduction": dense_visits / max(compact_visits, 1),
        "fetch_reduction": dense_fetch_tiles / max(compact_fetch_tiles, 1),
        "block_skip_fraction": float(1.0 - bm.mean()),
    }
