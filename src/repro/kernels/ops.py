"""Jit'd public wrappers: SATA planning (sort → permute → block map) +
the Pallas kernel, end to end."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.blockmap import identity_block_plan, sata_block_plan
from repro.kernels.ref import ref_block_attention
from repro.kernels.sata_attention import sata_block_attention


@functools.partial(jax.jit, static_argnames=("q_block", "k_block", "k",
                                             "use_sata", "interpret",
                                             "exact"))
def sata_attention(q: jax.Array, k_: jax.Array, v: jax.Array,
                   scores_mask: jax.Array, *, q_block: int = 128,
                   k_block: int = 128, k: int = 64, use_sata: bool = True,
                   exact: bool = True, interpret: bool = True
                   ) -> Tuple[jax.Array, jax.Array]:
    """Top-k selective attention through the SATA plan + Pallas kernel.

    q/k_/v: (BH, S, D); scores_mask: (BH, Sq, Sk) bool top-k selection.
    Returns (output in ORIGINAL query order, block_map) — block skip
    fraction is ``1 - block_map.mean()``.
    """
    plan_fn = sata_block_plan if use_sata else identity_block_plan
    if use_sata:
        kv_order, q_order, block_map = plan_fn(scores_mask, q_block, k_block)
    else:
        kv_order, q_order, block_map = identity_block_plan(
            scores_mask, q_block, k_block)
    kp = jnp.take_along_axis(k_, kv_order[:, :, None], axis=1)
    vp = jnp.take_along_axis(v, kv_order[:, :, None], axis=1)
    qp = jnp.take_along_axis(q, q_order[:, :, None], axis=1)
    mask_p = jnp.take_along_axis(
        jnp.take_along_axis(scores_mask, kv_order[:, None, :], axis=2),
        q_order[:, :, None], axis=1)
    out_p = sata_block_attention(qp, kp, vp, block_map,
                                 mask=mask_p if exact else None,
                                 q_block=q_block, k_block=k_block,
                                 interpret=interpret)
    # scatter back to original query order
    inv = jnp.argsort(q_order, axis=-1)
    out = jnp.take_along_axis(out_p, inv[:, :, None], axis=1)
    return out, block_map


def sata_attention_reference(q, k_, v, scores_mask) -> jax.Array:
    """Oracle: exact top-k selective attention, no planning/permutation."""
    bh, sq, _ = q.shape
    bm = jnp.ones((bh, 1, 1), dtype=bool)
    return ref_block_attention(q, k_, v, bm, mask=scores_mask,
                               q_block=sq, k_block=k_.shape[1])
