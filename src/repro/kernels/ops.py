"""Jit'd public wrappers: SATA planning (sort → permute → block map) +
the Pallas kernel, end to end.

``schedule`` selects the kernel's execution plan:
  * ``"compact"`` (default) — scalar-prefetch compacted grid: the K/V
    BlockSpec index maps walk ``compact_kv_plan``'s occupied-tile lists,
    so empty tiles are never fetched *or* visited.
  * ``"dense"``  — full ``(BH, nqb, nkb)`` grid with compute-only
    skipping (``@pl.when`` on the block map); kept as the measured
    baseline.

``selection`` picks how the top-k set is produced and shipped:
``"dense"`` takes a caller-materialized (BH, Sq, Sk) mask through the
full SATA plan; ``"chunked"`` streams score tiles to a per-row bisect
threshold and block-level plan (``core.blockmap``) and lets the kernel
re-derive the mask per tile — nothing quadratic is ever live.

``interpret=None`` auto-detects the backend: compiled Mosaic on TPU,
interpret mode elsewhere (CPU CI).  Pass an explicit bool to override.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockmap import (compact_kv_plan, compact_plan_from_chunks,  # noqa: F401  (re-export)
                                 identity_block_plan, occupancy_bound,  # noqa: F401  (re-export)
                                 occupancy_from_scores_chunked,
                                 resolve_sel_chunk, sata_block_plan)
from repro.core.selection import select_thresholds_chunked
from repro.kernels.ref import ref_block_attention
from repro.kernels.sata_attention import (sata_block_attention,
                                          sata_block_attention_compact)


def default_interpret() -> bool:
    """Interpret Pallas kernels only when no TPU backend is present."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("q_block", "k_block",
                                             "use_sata", "interpret",
                                             "exact", "schedule",
                                             "max_kv_blocks", "selection",
                                             "topk_k", "causal",
                                             "sel_chunk", "on_exceed"))
def sata_attention(q: jax.Array, k_: jax.Array, v: jax.Array,
                   scores_mask: Optional[jax.Array] = None, *,
                   q_block: int = 128,
                   k_block: int = 128, use_sata: bool = True,
                   exact: bool = True, interpret: Optional[bool] = None,
                   schedule: str = "compact",
                   max_kv_blocks: Optional[int] = None,
                   selection: str = "dense",
                   topk_k: Optional[int] = None,
                   causal: bool = False,
                   sel_chunk: Optional[int] = None,
                   thresholds: Optional[jax.Array] = None,
                   block_map: Optional[jax.Array] = None,
                   q_pos: Optional[jax.Array] = None,
                   k_pos: Optional[jax.Array] = None,
                   on_exceed: str = "truncate",
                   ) -> Tuple[jax.Array, jax.Array]:
    """Top-k selective attention through the SATA plan + Pallas kernel.

    q/k_/v: (BH, S, D).  Returns (output in ORIGINAL query order,
    block_map) — block skip fraction is ``1 - block_map.mean()``.

    ``selection`` picks how the top-k set reaches the kernel:
      * ``"dense"``   — the caller hands in ``scores_mask``
        (BH, Sq, Sk) bool; the full SATA plan (sort → permute → block
        map) runs on it.  Simple, but the mask (and whatever score
        tensor produced it) is a quadratic HBM resident.
      * ``"chunked"`` — mask-free: pass 1 streams ``sel_chunk × Sk``
        score tiles to bisect the per-row top-k threshold
        (``topk_k`` keys per query, O(Sq) thresholds persist), pass 2
        re-streams tiles to emit the block occupancy map and compact
        plan (``core.blockmap.compact_plan_from_chunks``), and the
        kernel re-derives the element mask per tile from the threshold.
        Nothing quadratic is ever materialized.  Keys stay in their
        original order regardless of ``use_sata`` (the token-level SATA
        sort needs the dense mask — its Gram matrix is itself (Sk, Sk)
        — so the chunked route trades sort concentration for O(S)
        selection memory and ``use_sata`` has no effect here).  Compact
        schedule only; ``causal`` gates admissibility; precomputed
        ``thresholds`` (BH, Sq, 1) and/or ``block_map`` skip the
        corresponding pass (the model layer's VJP reuses pass-1/2
        outputs this way).

    ``max_kv_blocks`` (compact schedule only) statically bounds the
    occupied k-blocks per q-row, shrinking the kernel grid's innermost
    dimension from ``nkb`` to that bound.  Callers with a concrete block
    map get it from ``int(kv_counts.max())`` (``compact_kv_plan`` raises
    on a concrete under-estimate); inside jit it must be a static
    over-estimate — derive it from calibration traffic with
    ``core.blockmap.occupancy_bound`` (the default ``None`` keeps the
    safe full ``nkb``).

    ``on_exceed`` (chunked selection only) decides what happens when a
    row's true occupancy exceeds ``max_kv_blocks``: ``"truncate"``
    keeps each row's first ``bound`` occupied k-blocks (the PR-2
    approximation — an in-graph under-estimate is otherwise
    undetectable), ``"dense"`` detects the overflow in-graph and
    re-routes the whole batch through the full-width (dense-grid-cost)
    schedule instead — the loss-free escape hatch that makes
    sub-100-percentile ``occupancy_bound`` values safe to serve.
    """
    if schedule not in ("compact", "dense"):
        raise ValueError(f"unknown schedule {schedule!r}")
    if selection not in ("dense", "chunked"):
        raise ValueError(f"unknown selection {selection!r}")
    if interpret is None:
        interpret = default_interpret()
    if on_exceed not in ("truncate", "dense"):
        raise ValueError(f"unknown on_exceed {on_exceed!r}")
    if selection == "chunked":
        if schedule != "compact":
            raise ValueError("chunked selection requires the compact "
                             "schedule (the dense grid has no threshold "
                             "mode)")
        return _sata_attention_chunked(
            q, k_, v, topk_k=topk_k, q_block=q_block, k_block=k_block,
            exact=exact, causal=causal, interpret=interpret,
            max_kv_blocks=max_kv_blocks, sel_chunk=sel_chunk,
            thresholds=thresholds, block_map=block_map,
            q_pos=q_pos, k_pos=k_pos, on_exceed=on_exceed)
    if scores_mask is None:
        raise ValueError("selection='dense' needs scores_mask")
    if causal or any(a is not None for a in
                     (topk_k, thresholds, block_map, q_pos, k_pos,
                      sel_chunk)):
        # reject rather than silently ignore: on this path the mask IS
        # the selection — causality included — so a caller passing
        # causal=True (or any chunked-only operand) is holding the API
        # wrong and would otherwise get a quiet causality leak.
        raise ValueError(
            "selection='dense' takes its selection (causality included) "
            "entirely from scores_mask; causal/topk_k/thresholds/"
            "block_map/q_pos/k_pos/sel_chunk are chunked-only arguments")
    plan_fn = sata_block_plan if use_sata else identity_block_plan
    if use_sata:
        kv_order, q_order, block_map = plan_fn(scores_mask, q_block, k_block)
    else:
        kv_order, q_order, block_map = identity_block_plan(
            scores_mask, q_block, k_block)
    kp = jnp.take_along_axis(k_, kv_order[:, :, None], axis=1)
    vp = jnp.take_along_axis(v, kv_order[:, :, None], axis=1)
    qp = jnp.take_along_axis(q, q_order[:, :, None], axis=1)
    # block mode needs no dense (BH, Sq, Sk) mask — only exact mode
    # permutes and ships it.
    mask_p = None
    if exact:
        mask_p = jnp.take_along_axis(
            jnp.take_along_axis(scores_mask, kv_order[:, None, :], axis=2),
            q_order[:, :, None], axis=1)
    if schedule == "compact":
        kv_indices, kv_counts = compact_kv_plan(block_map,
                                                pad_to=max_kv_blocks)
        out_p = sata_block_attention_compact(
            qp, kp, vp, kv_indices, kv_counts, mask=mask_p,
            q_block=q_block, k_block=k_block, interpret=interpret)
    else:
        out_p = sata_block_attention(qp, kp, vp, block_map, mask=mask_p,
                                     q_block=q_block, k_block=k_block,
                                     interpret=interpret)
    # scatter back to original query order
    inv = jnp.argsort(q_order, axis=-1)
    out = jnp.take_along_axis(out_p, inv[:, :, None], axis=1)
    return out, block_map


def _sata_attention_chunked(q, k_, v, *, topk_k, q_block, k_block, exact,
                            causal, interpret, max_kv_blocks, sel_chunk,
                            thresholds, block_map, q_pos, k_pos,
                            on_exceed="truncate"):
    """Mask-free selection → plan → threshold-mode kernel (see
    ``sata_attention``).  Keys keep their original order, so no
    permutation or scatter-back is needed."""
    bh, sq, d = q.shape
    sk = k_.shape[1]
    if sq % q_block or sk % k_block:
        raise ValueError(f"S must tile by the block edge: {(sq, sk)} "
                         f"vs {(q_block, k_block)}")
    sm_scale = 1.0 / np.sqrt(d)
    chunk = resolve_sel_chunk(sel_chunk, sq, q_block)
    q_pos = (jnp.arange(sq, dtype=jnp.int32) if q_pos is None
             else q_pos.astype(jnp.int32))
    k_pos = (jnp.arange(sk, dtype=jnp.int32) if k_pos is None
             else k_pos.astype(jnp.int32))
    if thresholds is None:
        if topk_k is None:
            raise ValueError("selection='chunked' needs topk_k (or "
                             "precomputed thresholds)")
        thresholds, bm = select_thresholds_chunked(
            q, k_, topk_k, q_pos=q_pos, k_pos=k_pos, causal=causal,
            sm_scale=sm_scale, chunk=chunk, q_block=q_block,
            k_block=k_block)
        if block_map is None:
            block_map = bm
    if block_map is None:
        block_map = occupancy_from_scores_chunked(
            q, k_, thresholds, q_block=q_block, k_block=k_block,
            sm_scale=sm_scale, causal=causal, q_pos=q_pos, k_pos=k_pos,
            chunk=chunk)
    pos_q = jnp.broadcast_to(q_pos[None, :, None], (bh, sq, 1))
    pos_k = jnp.broadcast_to(k_pos[None, :, None], (bh, sk, 1))

    def _run(kv_indices, kv_counts):
        return sata_block_attention_compact(
            q, k_, v, kv_indices, kv_counts,
            thresholds=thresholds if exact else None,
            q_pos=pos_q, k_pos=pos_k, causal=causal,
            q_block=q_block, k_block=k_block, interpret=interpret)

    nkb = sk // k_block
    bounded = max_kv_blocks is not None and max_kv_blocks < nkb
    if bounded and on_exceed == "dense":
        # loss-free escape hatch: a row whose occupancy exceeds the
        # calibrated bound would silently lose selected tiles under
        # truncation; detect the overflow in-graph and re-route the
        # batch through the full-width schedule (dense-grid cost, exact
        # result).  Both plans are cheap; only one kernel launch runs.
        idx_t, cnt_t = compact_kv_plan(block_map, pad_to=max_kv_blocks,
                                       truncate=True)
        idx_f, cnt_f = compact_kv_plan(block_map)
        out = jax.lax.cond(
            (cnt_f > max_kv_blocks).any(),
            lambda _: _run(idx_f, cnt_f),
            lambda _: _run(idx_t, cnt_t), None)
    else:
        kv_indices, kv_counts = compact_kv_plan(block_map,
                                                pad_to=max_kv_blocks)
        out = _run(kv_indices, kv_counts)
    return out, block_map


@functools.partial(jax.jit, static_argnames=("k_block", "interpret"))
def sata_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                          kv_indices: jax.Array, kv_counts: jax.Array,
                          thresholds: jax.Array, pos: jax.Array, *,
                          k_block: int = 128,
                          page_table: Optional[jax.Array] = None,
                          interpret: Optional[bool] = None) -> jax.Array:
    """Decode-path selective attention: fetch only the planned k-blocks
    of the KV cache for one generated token per slot.

    q: (B, KV, G, D) — the G = H//KV query heads grouped per KV head
    (they share fetched K/V tiles); k/v: (B, S, KV, D) serving cache
    (original layout — no head-expanded copy); kv_indices/kv_counts:
    the per-slot plan from ``core.decode_plan``; thresholds:
    (B, KV, G, 1) fp32 per-row top-k thresholds (bisect predicate);
    pos: (B,) int32 per-slot positions.  Returns (B, KV, G, D).

    With ``page_table`` (B, max_pages) given, k/v are the paged pool
    ``(n_pages, page, KV, D)`` (``core/paging.py``; page == k_block)
    and the kernel's K/V index maps dereference the table — same grid,
    same inner loop, one extra prefetch operand.

    Grid is ``(B·KV, P)`` — scheduled work and K/V fetch both scale
    with the *selected* block count, not the prefix length
    (``decode_fetch_stats`` accounts for it).
    """
    from repro.kernels.sata_decode import (
        sata_decode_attention_kernel, sata_decode_attention_paged_kernel)
    if interpret is None:
        interpret = default_interpret()
    if page_table is not None:
        # the plan's logical block edge must BE the page size, or the
        # kernel would dereference block-granular indices as page ids
        assert k.shape[1] == k_block, (
            f"paged decode needs k_block == page size "
            f"({k_block} != {k.shape[1]})")
        return sata_decode_attention_paged_kernel(
            q, k, v, page_table, kv_indices, kv_counts, thresholds, pos,
            interpret=interpret)
    return sata_decode_attention_kernel(
        q, k, v, kv_indices, kv_counts, thresholds, pos,
        k_block=k_block, interpret=interpret)


def decode_fetch_stats(kv_counts, pos, *, k_block: int, d: int,
                       n_kv_heads: Optional[int] = None,
                       dtype_bytes: int = 4,
                       replan=None,
                       nkb: Optional[int] = None,
                       summary: str = "fp32",
                       replan_mode: str = "exact",
                       sketch_factor: int = 4,
                       plan_blocks=None,
                       quant=None,
                       sketch=None,
                       live_blocks=None) -> Dict:
    """Per-step K/V fetch accounting for the decode route.  kv_counts:
    (B, KV) [or (L, B, KV) — any (..., B, KV)] int; pos: (B,) int
    per-slot positions.

    Kernel side (always reported): dense decode streams every valid
    block of the prefix per (slot, kv head); the planned kernel fetches
    ``kv_counts`` tiles.

    Plan side (``replan`` given — the fraction of this step's layer
    plans that ran the full re-plan; a plain bool works, and a (B,)
    vector charges each slot its own fraction — the partial re-plan's
    gather-based branch streams only the triggering slots' caches, and
    linearity makes a broadcast scalar reproduce the blended total
    exactly): the selection machinery reads keys too, and pretending
    otherwise overstates the win.  An exact full re-plan streams ALL
    valid cached K (one K-only pass — so at ``sata_decode_replan=1``
    selection traffic still scales with the prefix); a *sketch*
    re-plan (``replan_mode="sketch"``) reads the summaries plus only
    the ``ceil(P/F)·F`` surviving candidate blocks' keys
    (``decode_plan.sketch_geometry`` — pass ``plan_blocks`` for P); an
    incremental step reads the block summaries (``summary`` sizes them
    — fp32 bounds or int8 codes + per-block scale/zero, see
    ``decode_plan.summary_bytes``; ``nkb`` — pass it, it is a property
    of the cache, not of the counts) plus the planned blocks' keys for
    the in-plan threshold.  ``step_bytes_plan_route`` then totals
    kernel + plan traffic for the step, the honest number to compare
    against ``step_bytes_dense_route`` (dense decode plans nothing).

    **Degraded budgets** (QoS ladder): ``plan_blocks`` also accepts a
    (B,) per-slot vector — a degraded slot's sketch re-plan prices at
    ITS narrowed candidate geometry, not the admission-time P.
    ``quant``/``sketch`` (B,) bool mark slots on the int8-ranking /
    sketch-re-plan rungs: a quantized slot's summary reads price at
    the int8 code size (the modeled traffic of the rung's backend
    switch) and a sketched slot's periodic re-plan prices
    hierarchically even when the global ``replan_mode`` is exact.
    Scalar arguments keep the pre-ladder accounting bit-for-bit.

    **Retirement** (``live_blocks`` — (B,) int, the per-slot count of
    LIVE blocks after cascade retirement): retired blocks leave the
    ranking set entirely — their pages are freed, so a full re-plan
    can only stream the surviving blocks' keys and an incremental step
    only reads summaries the plan still maintains.  Summary reads
    price at the live count instead of ``nkb``, and the exact/sketch
    re-plan's key stream at ``min(valid_blocks, live_blocks)``.
    ``None`` (or retire off) keeps every prior pricing bit-for-bit.
    """
    from repro.core.decode_plan import sketch_geometry, summary_bytes
    cnt = np.asarray(kv_counts)
    pos = np.asarray(pos).reshape(-1)
    b = pos.shape[0]
    kv = n_kv_heads if n_kv_heads is not None else cnt.shape[-1]
    valid_blocks = (pos + 1 + k_block - 1) // k_block          # (B,)
    dense_tiles = int(valid_blocks.sum()) * kv * (cnt.size // (b * kv))
    plan_tiles = int(cnt.sum())
    tile_bytes = 2 * k_block * d * dtype_bytes                 # K + V tile
    out = {
        "kv_fetch_tiles_dense": dense_tiles,
        "kv_fetch_tiles_plan": plan_tiles,
        "kv_fetch_bytes_dense": dense_tiles * tile_bytes,
        "kv_fetch_bytes_plan": plan_tiles * tile_bytes,
        "fetch_reduction": dense_tiles / max(plan_tiles, 1),
    }
    if replan is not None:
        k_tile_bytes = k_block * d * dtype_bytes               # K only
        layers = cnt.size // (b * kv)
        # retirement: a slot's ranking set shrinks to its live blocks
        live = None
        if live_blocks is not None:
            live = np.asarray(live_blocks, np.int64).reshape(-1)
            assert live.size == b, (live.size, b)
        # per-slot summary pricing: the quant rung models the int8
        # backend's code reads for flagged slots
        if nkb is None:
            sum_head_slot = np.zeros(b, np.int64)
        elif live is None:
            s_base = summary_bytes(nkb, d, summary)
            sum_head_slot = np.full(b, s_base, np.int64)
            if quant is not None:
                qn = np.asarray(quant, bool).reshape(-1)
                assert qn.size == b, (qn.size, b)
                sum_head_slot = np.where(
                    qn, summary_bytes(nkb, d, "int8"), s_base)
        else:
            sum_head_slot = np.array(
                [summary_bytes(int(n), d, summary) for n in live],
                np.int64)
            if quant is not None:
                qn = np.asarray(quant, bool).reshape(-1)
                assert qn.size == b, (qn.size, b)
                sum_head_slot = np.where(qn, np.array(
                    [summary_bytes(int(n), d, "int8") for n in live],
                    np.int64), sum_head_slot)
        summaries_b = int(sum_head_slot.sum()) * kv * layers
        # per-slot plan width: a (B,) vector prices each slot's sketch
        # geometry at its own (possibly degraded) budget
        pb_arr = None if plan_blocks is None else \
            np.asarray(plan_blocks).reshape(-1)
        skt = None if sketch is None else \
            np.asarray(sketch, bool).reshape(-1)
        vb = valid_blocks if live is None else \
            np.minimum(live, valid_blocks)
        exact_slot = vb * kv * layers * k_tile_bytes
        if nkb is not None and (replan_mode == "sketch"
                                or skt is not None):
            pb_slot = np.full(b, nkb, np.int64)
            if pb_arr is not None:
                assert pb_arr.size in (1, b), (pb_arr.size, b)
                pb_slot = np.minimum(
                    np.broadcast_to(pb_arr, (b,)).astype(np.int64), nkb)
            cand_slot = np.array(
                [min(int(vb[i]),
                     sketch_geometry(nkb, int(pb_slot[i]),
                                     sketch_factor)[3])
                 for i in range(b)], np.int64)
            sketch_slot = (cand_slot * kv * layers * k_tile_bytes
                           + sum_head_slot * kv * layers)
            if replan_mode == "sketch":
                full_slot = sketch_slot
            else:
                assert skt.size == b, (skt.size, b)
                full_slot = np.where(skt, sketch_slot, exact_slot)
        else:
            full_slot = exact_slot
        full_b = int(full_slot.sum())
        incr_b = summaries_b + plan_tiles * k_tile_bytes
        rep = np.asarray(replan, np.float64).reshape(-1)
        if rep.size == 1:
            step_b = int(round(float(rep[0]) * full_b
                               + (1.0 - float(rep[0])) * incr_b))
        else:
            assert rep.size == b, (rep.size, b)
            cnt_slot = cnt.reshape(-1, b, kv).sum(axis=(0, 2))  # (B,)
            incr_slot = (sum_head_slot * kv * layers
                         + cnt_slot * k_tile_bytes)
            step_b = int(round(float(
                (rep * full_slot + (1.0 - rep) * incr_slot).sum())))
        out["plan_fetch_bytes_full"] = full_b
        out["plan_fetch_bytes_incremental"] = incr_b
        out["plan_fetch_bytes_step"] = step_b
        out["step_bytes_plan_route"] = (out["kv_fetch_bytes_plan"]
                                        + out["plan_fetch_bytes_step"])
        out["step_bytes_dense_route"] = out["kv_fetch_bytes_dense"]
    return out


def sata_attention_reference(q, k_, v, scores_mask) -> jax.Array:
    """Oracle: exact top-k selective attention, no planning/permutation."""
    bh, sq, _ = q.shape
    bm = jnp.ones((bh, 1, 1), dtype=bool)
    return ref_block_attention(q, k_, v, bm, mask=scores_mask,
                               q_block=sq, k_block=k_.shape[1])


def kernel_fetch_stats(block_map, *, q_block: int, k_block: int, d: int,
                       dtype_bytes: int = 4,
                       max_kv_blocks: Optional[int] = None) -> Dict:
    """Tile-visit and K/V fetch-byte accounting, dense vs compacted grid.

    The dense grid visits — and its BlockSpec pipeline *fetches* — every
    ``(bh, q_row, k_block)`` tile regardless of occupancy.  The compacted
    grid visits ``nqb × P`` slots (P = the padded slot count) and fetches
    at most one K+V tile per *occupied* slot: padding slots re-reference
    the resident block, which the Pallas pipeline does not re-fetch.
    Counts are exact for the scheduled index sequence (boundary reuse
    between consecutive rows can only lower the compact fetch count).

    ``max_kv_blocks`` defaults to the same value as ``sata_attention``'s
    (the full ``nkb``), so default-args accounting describes the grid the
    default kernel call actually runs; pass the concrete occupancy bound
    to model a ``max_kv_blocks``-narrowed launch.
    """
    bm = np.asarray(block_map).astype(bool)
    bh, nqb, nkb = bm.shape
    counts = bm.sum(-1)                                   # (bh, nqb)
    p = int(max_kv_blocks) if max_kv_blocks is not None else nkb
    tile_bytes = 2 * k_block * d * dtype_bytes            # one K + one V tile
    dense_visits = bh * nqb * nkb
    compact_visits = bh * nqb * p
    dense_fetch_tiles = bh * nqb * nkb
    compact_fetch_tiles = int(counts.sum())
    return {
        "grid_dense": [bh, nqb, nkb],
        "grid_compact": [bh, nqb, p],
        "tile_visits_dense": dense_visits,
        "tile_visits_compact": compact_visits,
        "kv_fetch_tiles_dense": dense_fetch_tiles,
        "kv_fetch_tiles_compact": compact_fetch_tiles,
        "kv_fetch_bytes_dense": dense_fetch_tiles * tile_bytes,
        "kv_fetch_bytes_compact": compact_fetch_tiles * tile_bytes,
        "visit_reduction": dense_visits / max(compact_visits, 1),
        "fetch_reduction": dense_fetch_tiles / max(compact_fetch_tiles, 1),
        "block_skip_fraction": float(1.0 - bm.mean()),
    }
