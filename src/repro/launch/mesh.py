"""Production mesh builders + the SATA scale-out shard_map wrappers.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).

Scale-out (``XLA_FLAGS=--xla_force_host_platform_device_count=8`` gives
CI a simulated multi-device CPU mesh):

* **Sequence-sharded selection** (``sequence_sharded_attention``):
  queries shard along Sq; every selection reduction in
  ``select_thresholds_chunked`` is row-local, so each shard's
  thresholds/occupancy are *bitwise* the corresponding rows of the
  single-device run.  Each shard builds its own ``compact_kv_plan`` and
  halo-exchanges only the K/V tiles that plan selects — the compact
  per-shard tile buffers (and the fetch accounting) are
  plan-proportional.  The transport primitive here is an ``all_gather``
  standing in for the tile-granular RDMA a real interconnect issues
  (see the ring-collective pattern in the Pallas guide); what the
  epilogue *touches* is only the planned tiles.
* **Tensor-parallel decode** (``tensor_parallel_decode_step``): the
  decode plan state, KV cache and gather kernel are all per-(slot,
  KV-head) independent, so sharding over KV heads needs no collectives
  at all — ``plan_pspecs`` maps every plan leaf to its PartitionSpec
  and the kernel runs unchanged inside ``shard_map``.

These wrappers are EXPLICIT: they never install a global device
context, so ``attention.sata_decode_on``'s conservative
``mesh_installed()`` fallback (for paths that have no SPMD rule) is
not tripped by them.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.blockmap import bisect_select, compact_kv_plan
from repro.core.selection import NEG_INF, select_thresholds_chunked


def _mesh_kwargs(n_axes: int) -> dict:
    """``axis_types`` only where the explicit-axes API exists (jax>=0.5);
    older jax (0.4.x) meshes are implicitly Auto."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16×16 (256 chips) single-pod, or 2×16×16 multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes over which the batch (and FSDP weight dims) shard."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_local_mesh():
    """Single-device mesh for CPU tests/examples."""
    return jax.make_mesh((1, 1), ("data", "model"), **_mesh_kwargs(2))


def activate_mesh(mesh):
    """Context manager installing ``mesh`` for jit/sharding-constraint
    resolution: ``jax.set_mesh`` where it exists (jax>=0.6), else the
    classic ``Mesh.__enter__`` global-mesh context (jax 0.4.x)."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


# ---------------------------------------------------------------------------
# SATA scale-out: explicit shard_map wrappers (module docstring)
# ---------------------------------------------------------------------------

def make_shard_mesh(n_shards: int, axis: str = "shard"):
    """1-D mesh over the first ``n_shards`` local devices — the unit the
    scale-out wrappers (and the forced-host-device CI mesh) run on."""
    devs = jax.devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"need {n_shards} devices, have {len(devs)} — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N before "
            f"jax initializes to simulate a CPU mesh")
    return jax.sharding.Mesh(np.array(devs[:n_shards]), (axis,))


def _selection_plan_local(q, k, q_pos, *, k_sel: int, q_block: int,
                          k_block: int, sm_scale, causal: bool):
    """One shard's selection + plan: row-local thresholds/occupancy
    (bitwise the global rows) and the full-width compact schedule.
    ``pad_to`` stays ``None`` (P = nkb) so the sharded and single-device
    tile buffers have identical padded layout — the epilogue's masked
    reductions then add identically-placed exact zeros and parity is
    bitwise, not approximate."""
    thr, bm = select_thresholds_chunked(
        q, k, k_sel, q_pos=q_pos, causal=causal, sm_scale=sm_scale,
        q_block=q_block, k_block=k_block)
    kv_indices, kv_counts = compact_kv_plan(bm)
    return thr, bm, kv_indices, kv_counts


def _gather_plan_tiles(x, kv_indices, *, k_block: int):
    """Fetch only the planned tiles: x (BH, Sk, D) + indices
    (BH, nqb, P) → compact (BH, nqb, P·k_block, D) buffers plus the
    gathered token positions (BH, nqb, P·k_block)."""
    bh, sk, d = x.shape
    nkb = sk // k_block
    _, nqb, p = kv_indices.shape
    xt = x.reshape(bh, nkb, k_block, d)
    tiles = jax.vmap(lambda t, ix: t[ix])(xt, kv_indices)
    tok = (kv_indices[..., None] * k_block +
           jnp.arange(k_block)[None, None, None, :])
    return (tiles.reshape(bh, nqb, p * k_block, d),
            tok.reshape(bh, nqb, p * k_block))


def planned_tile_attention(q, k_tiles, v_tiles, tok, thr, kv_counts, *,
                           q_block: int, k_block: int, q_pos,
                           sm_scale=None):
    """Threshold-mode attention over the compact planned-tile buffers —
    the one epilogue BOTH the sharded path and the single-device
    reference run, so identical plans give bitwise-identical outputs.

    q: (BH, Sq, D); k_tiles/v_tiles: (BH, nqb, P·kb, D); tok:
    (BH, nqb, P·kb) gathered token positions; thr: (BH, Sq, 1);
    kv_counts: (BH, nqb); q_pos: (Sq,) global query positions.

    A token participates iff its slot is live (padding slots repeat
    real tiles — without the count mask they would double-count), it is
    causally admissible, and it passes the bisect-consistent selection
    predicate against its row's threshold.  All reductions are
    row-local.
    """
    bh, s, d = q.shape
    nqb = s // q_block
    t = k_tiles.shape[2]
    scale = float(sm_scale if sm_scale is not None else 1.0 / np.sqrt(d))
    qb = q.reshape(bh, nqb, q_block, d).astype(jnp.float32)
    sc = jnp.einsum("bnqd,bntd->bnqt", qb,
                    k_tiles.astype(jnp.float32),
                    preferred_element_type=jnp.float32) * scale
    slot = jnp.arange(t) // k_block                           # (P·kb,)
    live = slot[None, None, :] < kv_counts[..., None]         # (BH,nqb,P·kb)
    posr = q_pos.astype(jnp.int32).reshape(nqb, q_block)
    adm = live[:, :, None, :] & \
        (tok[:, :, None, :] <= posr[None, :, :, None])
    thr_r = thr.reshape(bh, nqb, q_block, 1)
    sel = bisect_select(sc, thr_r) & adm
    sc = jnp.where(sel, sc, NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    w = jnp.where(sel, jnp.exp(sc - m), 0.0)
    out = jnp.einsum("bnqt,bntd->bnqd", w, v_tiles.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(w.sum(-1, keepdims=True), 1e-30)
    return out.reshape(bh, s, d)


def sequence_local_attention(q, k, v, *, k_sel: int, q_block: int = 128,
                             k_block: int = 128, causal: bool = True,
                             sm_scale=None):
    """Single-device reference for ``sequence_sharded_attention``: the
    same selection → plan → tile-gather → epilogue pipeline with no
    mesh.  Returns (out, stats)."""
    s = q.shape[1]
    q_pos = jnp.arange(s, dtype=jnp.int32)
    thr, bm, idx, cnt = _selection_plan_local(
        q, k, q_pos, k_sel=k_sel, q_block=q_block, k_block=k_block,
        sm_scale=sm_scale, causal=causal)
    k_tiles, tok = _gather_plan_tiles(k, idx, k_block=k_block)
    v_tiles, _ = _gather_plan_tiles(v, idx, k_block=k_block)
    out = planned_tile_attention(q, k_tiles, v_tiles, tok, thr, cnt,
                                 q_block=q_block, k_block=k_block,
                                 q_pos=q_pos, sm_scale=sm_scale)
    return out, {"thresholds": thr, "block_map": bm, "kv_counts": cnt,
                 "fetched_tiles": cnt.sum()}


def sequence_sharded_attention(mesh, q, k, v, *, k_sel: int,
                               q_block: int = 128, k_block: int = 128,
                               causal: bool = True, sm_scale=None,
                               axis: Optional[str] = None):
    """Sequence-parallel selective attention on ``mesh``: q shards along
    Sq, K/V along Sk; each shard bisects its rows' thresholds
    (row-local ⇒ bitwise the global rows), builds its own compact plan,
    halo-exchanges only the planned K/V tiles into compact buffers, and
    runs the shared epilogue.  Output is bitwise equal to
    ``sequence_local_attention`` on one device.

    q: (BH, Sq, D); k/v: (BH, Sk, D).  Sq must tile by
    ``n_shards·q_block`` and Sk by ``n_shards·k_block``.  Returns
    ``(out, stats)`` with ``stats["fetched_tiles_per_shard"]`` the
    plan-proportional per-shard fetch the halo exchange materializes.
    """
    ax = axis or mesh.axis_names[0]
    n = mesh.shape[ax]
    bh, s, d = q.shape
    sk = k.shape[1]
    assert s % (n * q_block) == 0, (s, n, q_block)
    assert sk % (n * k_block) == 0, (sk, n, k_block)

    def local(q_l, pos_l, k_l, v_l):
        # score-pass stream: selection is exact over ALL keys, so each
        # shard streams the full K once (same score traffic as the
        # single-device chunked pass, now split across n query shards)
        k_full = jax.lax.all_gather(k_l, ax, axis=1, tiled=True)
        thr, bm, idx, cnt = _selection_plan_local(
            q_l, k_full, pos_l, k_sel=k_sel, q_block=q_block,
            k_block=k_block, sm_scale=sm_scale, causal=causal)
        # halo exchange: the all_gather is the simulated interconnect;
        # the compact buffers (and the accounting) keep only the tiles
        # this shard's plan selects
        v_full = jax.lax.all_gather(v_l, ax, axis=1, tiled=True)
        k_tiles, tok = _gather_plan_tiles(k_full, idx, k_block=k_block)
        v_tiles, _ = _gather_plan_tiles(v_full, idx, k_block=k_block)
        out = planned_tile_attention(q_l, k_tiles, v_tiles, tok, thr,
                                     cnt, q_block=q_block,
                                     k_block=k_block, q_pos=pos_l,
                                     sm_scale=sm_scale)
        fetched = cnt.sum().reshape(1)
        return out, thr, bm, cnt, fetched

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(None, ax, None), P(ax),
                             P(None, ax, None), P(None, ax, None)),
                   out_specs=(P(None, ax, None), P(None, ax, None),
                              P(None, ax, None), P(None, ax), P(ax)),
                   check_rep=False)
    q_pos = jnp.arange(s, dtype=jnp.int32)
    out, thr, bm, cnt, fetched = fn(q, q_pos, k, v)
    return out, {"thresholds": thr, "block_map": bm, "kv_counts": cnt,
                 "fetched_tiles_per_shard": fetched}


# every plan leaf keyed to where its KV-head axis sits (None = no KV
# axis → replicated).  ``live_blk`` (B, nkb) and the (B,) QoS/trigger
# vectors are slot state shared by all heads.
_PLAN_KV_AXIS: Dict[str, Optional[int]] = {
    "k_min": 1, "k_max": 1, "k_scale": 1, "k_zero": 1,
    "kv_indices": 1, "kv_counts": 1, "imp": 1,
    "live_blk": None, "step": None, "churn": None, "replans": None,
    "active": None, "budget": None, "interval": None, "quant": None,
    "sketch": None,
}


def plan_pspecs(plan: Dict, axis: str) -> Dict:
    """PartitionSpec per decode-plan leaf for KV-head tensor
    parallelism: summary bounds, plan rows and importance shard on
    their KV axis (dim 1); per-slot vectors replicate.  The result is
    shard_map in/out-spec ready — the plan pytree is a plain dict, so
    the spec dict mirrors it leaf for leaf."""
    specs = {}
    for name, val in plan.items():
        kv_dim = _PLAN_KV_AXIS[name]
        if kv_dim is None:
            specs[name] = P(*((None,) * val.ndim))
        else:
            spec = [None] * val.ndim
            spec[kv_dim] = axis
            specs[name] = P(*spec)
    return specs


def tensor_parallel_decode_step(mesh, qg, k, v, k_new, pos, plan, *,
                                topk_k: int, k_block: int,
                                replan_interval: int = 1,
                                page_table=None,
                                replan_mode: str = "exact",
                                sketch_factor: int = 4,
                                axis: Optional[str] = None):
    """One SATA decode step (summary absorb → plan update → gather
    kernel) with the plan state, KV cache and kernel sharded over KV
    heads.  Per-(slot, KV-head) independence means NO collectives: each
    shard maintains its heads' summaries, re-plans its heads' rows and
    gathers its heads' planned tiles — output and plan are bitwise the
    single-device step (``replan_interval=1`` fp32 = exact top-k).

    qg: (B, KV, G, D) grouped queries; k/v: (B, S, KV, D) contiguous
    cache or the (n_pages, page, KV, D) pool with ``page_table``
    (B, max_pages) given; k_new: (B, 1, KV, D); pos: (B,).  KV must
    tile by the mesh axis size.  The churn-adaptive trigger
    (``replan="auto"``) is per-slot-mean over *local* heads and would
    diverge across shards — integer intervals only.

    Returns ``(out (B, KV, G, D), plan')`` with ``plan'`` sharded the
    same way (pass it straight back next step).
    """
    from repro.core.decode_plan import (decode_plan_update,
                                        update_block_summaries)
    from repro.kernels.ops import sata_decode_attention
    ax = axis or mesh.axis_names[0]
    n = mesh.shape[ax]
    kv = qg.shape[1]
    assert kv % n == 0, (kv, n)
    paged = page_table is not None
    pspec = plan_pspecs(plan, ax)
    cache_spec = P(None, None, ax, None)      # KV at dim 2 both layouts

    def local(qg_l, k_l, v_l, kn_l, pos_r, plan_l, tbl):
        plan_l = update_block_summaries(plan_l, kn_l, pos_r,
                                        k_block=k_block)
        plan_l, thr = decode_plan_update(
            plan_l, qg_l, k_l, pos_r, topk_k=topk_k, k_block=k_block,
            replan_interval=replan_interval, page_table=tbl,
            replan_mode=replan_mode, sketch_factor=sketch_factor)
        out = sata_decode_attention(qg_l, k_l, v_l, plan_l["kv_indices"],
                                    plan_l["kv_counts"], thr, pos_r,
                                    k_block=k_block, page_table=tbl)
        return out, plan_l

    tbl_spec = P(None, None) if paged else P(None)
    tbl_arg = page_table if paged else jnp.zeros((1,), jnp.int32)
    if not paged:
        # shard_map needs a concrete leaf; the kernel sees None
        def local_nt(qg_l, k_l, v_l, kn_l, pos_r, plan_l, _):
            return local(qg_l, k_l, v_l, kn_l, pos_r, plan_l, None)
        body = local_nt
    else:
        body = local
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, ax, None, None), cache_spec,
                             cache_spec, cache_spec, P(None), pspec,
                             tbl_spec),
                   out_specs=(P(None, ax, None, None), pspec),
                   check_rep=False)
    return fn(qg, k, v, k_new, pos, plan, tbl_arg)
