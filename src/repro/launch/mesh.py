"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16×16 (256 chips) single-pod, or 2×16×16 multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes over which the batch (and FSDP weight dims) shard."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_local_mesh():
    """Single-device mesh for CPU tests/examples."""
    auto = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=auto)
