"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

from typing import Tuple

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """``axis_types`` only where the explicit-axes API exists (jax>=0.5);
    older jax (0.4.x) meshes are implicitly Auto."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16×16 (256 chips) single-pod, or 2×16×16 multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes over which the batch (and FSDP weight dims) shard."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_local_mesh():
    """Single-device mesh for CPU tests/examples."""
    return jax.make_mesh((1, 1), ("data", "model"), **_mesh_kwargs(2))


def activate_mesh(mesh):
    """Context manager installing ``mesh`` for jit/sharding-constraint
    resolution: ``jax.set_mesh`` where it exists (jax>=0.6), else the
    classic ``Mesh.__enter__`` global-mesh context (jax 0.4.x)."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh
