"""Deterministic fault injection for the paged serving loop.

A ``FaultPlan`` is a seeded, fully explicit schedule of adverse events
keyed on the serving loop's step counter, so every backpressure branch
in ``launch.serve`` — boundary stall, CoW stall, admission deferral,
preemption (host-swap or requeue), mid-serve crash + restart from
swapped host state — is *drivable from tests* instead of hoped-for
emergent behavior.  The events:

  pool_squeeze(step, pages)   withhold free pages (external memory
                              pressure) — ``PageAllocator.squeeze``
  pool_restore(step, pages)   return squeezed pages (None = all)
  preempt(step, slot)         force-preempt a slot (None = the loop's
                              own victim policy picks)
  defer_admission(step)       skip the claim loop for one iteration
  crash_step(step)            drop the device cache + allocator; the
                              loop swaps all live state to host first
                              and restores from the swap handles
  load_spike(step, severity)  sustained overload signal: with the QoS
                              ladder on, every active slot steps down
                              ``severity`` rungs; ladder off, the loop
                              preempts ``severity`` victims (the PR 7
                              requeue/swap baseline behavior)
  slow_step(step)             step-deadline miss signal: one pressure
                              tick into the QoS controller (no-op
                              beyond a counter when the ladder is off)
  corrupt_page(step, nth)     flip one byte in the ``nth`` outstanding
                              host swap handle (bit-rot injection);
                              integrity checksums must catch it at
                              swap-in, quarantine the pages, and
                              recover the victim by re-prefill

Determinism is the point: the schedule is data, the serving loop
replays it identically every run, and the headline property — serve
outputs bitwise equal to the fault-free run — is assertable.
``FaultPlan.seeded`` derives a schedule from a PRNG seed for
property-style coverage; the schedule it builds is still fully
deterministic given the seed.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

Event = Tuple[str, Optional[int]]


class FaultPlan:
    """Builder for a step-keyed fault schedule.  All mutators return
    ``self`` so schedules chain:

        FaultPlan().pool_squeeze(4, pages=6).pool_restore(12).crash_step(20)
    """

    def __init__(self) -> None:
        self._events: Dict[int, List[Event]] = {}

    def _add(self, step: int, kind: str, arg: Optional[int]) -> "FaultPlan":
        assert step >= 0, step
        self._events.setdefault(int(step), []).append((kind, arg))
        return self

    def pool_squeeze(self, step: int, pages: int) -> "FaultPlan":
        return self._add(step, "pool_squeeze", int(pages))

    def pool_restore(self, step: int,
                     pages: Optional[int] = None) -> "FaultPlan":
        return self._add(step, "pool_restore",
                         None if pages is None else int(pages))

    def preempt(self, step: int, slot: Optional[int] = None) -> "FaultPlan":
        return self._add(step, "preempt",
                         None if slot is None else int(slot))

    def defer_admission(self, step: int) -> "FaultPlan":
        return self._add(step, "defer_admission", None)

    def crash_step(self, step: int) -> "FaultPlan":
        return self._add(step, "crash_step", None)

    def load_spike(self, step: int, severity: int = 1) -> "FaultPlan":
        return self._add(step, "load_spike", int(severity))

    def slow_step(self, step: int) -> "FaultPlan":
        return self._add(step, "slow_step", None)

    def corrupt_page(self, step: int, nth: int = 0) -> "FaultPlan":
        return self._add(step, "corrupt_page", int(nth))

    def at(self, step: int) -> List[Event]:
        """Events scheduled for this loop step (empty list if none)."""
        return self._events.get(int(step), [])

    @property
    def empty(self) -> bool:
        return not self._events

    @property
    def has_crash(self) -> bool:
        return any(kind == "crash_step"
                   for evs in self._events.values() for kind, _ in evs)

    @property
    def last_step(self) -> int:
        return max(self._events, default=-1)

    def describe(self) -> str:
        lines = []
        for step in sorted(self._events):
            for kind, arg in self._events[step]:
                lines.append(f"step {step:4d}: {kind}"
                             + (f"({arg})" if arg is not None else ""))
        return "\n".join(lines) if lines else "(no faults)"

    @classmethod
    def seeded(cls, seed: int, *, steps: int, n_events: int = 6,
               max_squeeze: int = 8, slots: Optional[int] = None,
               allow_crash: bool = False) -> "FaultPlan":
        """Random-but-reproducible schedule over ``steps`` loop steps:
        squeeze/restore pairs, forced preemptions, admission deferrals,
        and (``allow_crash``) at most one crash.  Every draw comes from
        the seeded generator, so the same seed always yields the same
        schedule — suitable for property tests and the seeded
        serve-smoke."""
        rng = np.random.default_rng(seed)
        plan = cls()
        crash_used = False
        kinds = ["squeeze", "preempt", "defer"]
        for _ in range(n_events):
            kind = kinds[int(rng.integers(len(kinds)))]
            step = int(rng.integers(1, max(steps - 2, 2)))
            if kind == "squeeze":
                pages = int(rng.integers(1, max_squeeze + 1))
                plan.pool_squeeze(step, pages)
                plan.pool_restore(min(step + int(rng.integers(2, 8)),
                                      steps - 1))
            elif kind == "preempt":
                slot = (None if slots is None
                        else int(rng.integers(slots)))
                plan.preempt(step, slot)
            else:
                plan.defer_admission(step)
        if allow_crash and not crash_used:
            plan.crash_step(int(rng.integers(2, max(steps - 2, 3))))
        return plan

    @classmethod
    def seeded_overload(cls, seed: int, *, steps: int,
                        n_spikes: int = 2, max_severity: int = 2,
                        n_corrupt: int = 1,
                        n_slow: int = 2) -> "FaultPlan":
        """Overload-flavored seeded schedule: load spikes with paired
        slow-step pressure ticks (each spike is a sustained episode,
        not a blip) and host-handle corruption events.  Independent of
        :meth:`seeded` — its draw sequence stays frozen so existing
        committed schedules never shift."""
        rng = np.random.default_rng(seed)
        plan = cls()
        lo, hi = 2, max(steps - 4, 3)
        for _ in range(n_spikes):
            step = int(rng.integers(lo, hi))
            sev = int(rng.integers(1, max_severity + 1))
            plan.load_spike(step, sev)
            for _ in range(int(rng.integers(1, n_slow + 1))):
                plan.slow_step(min(step + 1 + int(rng.integers(0, 3)),
                                   steps - 1))
        for _ in range(n_corrupt):
            plan.corrupt_page(int(rng.integers(lo, hi)),
                              int(rng.integers(0, 2)))
        return plan
