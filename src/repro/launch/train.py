"""Training driver — mesh setup, sharded state, fault-tolerant loop.

Production loop features (all exercised by tests/examples on CPU):
  * checkpoint/restart (atomic, keep-k, async save cadence)
  * step-time watchdog → straggler logging + simulated hot-spare swap
  * failure injection (``--fail-at``) → process "dies", restart resumes
    from the latest checkpoint with identical training state
  * elastic restore onto a different mesh (``--elastic-from``)
  * gradient compression + grad-accumulation flags

Usage (CPU example, reduced arch):
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 20 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.archs import ARCHS, SMOKE
from repro.data.pipeline import SyntheticLM
from repro.distributed import ctx as dctx
from repro.distributed.sharding import (batch_specs, param_specs,
                                        to_shardings)
from repro.launch.mesh import (activate_mesh, make_local_mesh,
                               make_production_mesh)
from repro.optim.adamw import OptConfig
from repro.train.step import init_train_state, make_train_step


class Watchdog:
    """Step-time straggler detector: flags steps slower than
    ``factor``× the running median; on a real cluster this triggers the
    hot-spare pod swap — here it logs and counts."""

    def __init__(self, factor: float = 3.0):
        self.times, self.factor, self.flagged = [], factor, 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) < 5:
            return False
        med = float(np.median(self.times[-50:]))
        if dt > self.factor * med:
            self.flagged += 1
            print(f"[watchdog] straggler step: {dt:.3f}s vs median "
                  f"{med:.3f}s → would swap in hot-spare slice", flush=True)
            return True
        return False


def train(arch: str, smoke: bool = True, steps: int = 20,
          batch: int = 8, seq: int = 32, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 10, fail_at: Optional[int] = None,
          micro_steps: int = 1, compress_grads: bool = False,
          mesh=None, log_every: int = 5, seed: int = 0) -> Dict[str, Any]:
    cfg = (SMOKE if smoke else ARCHS)[arch]
    opt = OptConfig(warmup_steps=max(2, steps // 10), decay_steps=steps,
                    compress_grads=compress_grads)
    mesh = mesh or make_local_mesh()
    dctx.set_activation_shardings(
        dctx.make_activation_shardings(mesh, cfg), mesh=mesh)

    state = init_train_state(jax.random.PRNGKey(seed), cfg, opt)
    st_spec = {"params": param_specs(state["params"], cfg, mesh),
               "opt": {"m": param_specs(state["opt"]["m"], cfg, mesh),
                       "v": param_specs(state["opt"]["v"], cfg, mesh),
                       "step": jax.sharding.PartitionSpec()}}
    if "err" in state:
        st_spec["err"] = param_specs(state["err"], cfg, mesh)
    st_sh = to_shardings(st_spec, mesh)
    state = jax.device_put(state, st_sh)

    pipe = SyntheticLM(cfg, batch, seq, seed=seed)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if mgr is not None and mgr.latest_step() is not None:
        state = mgr.restore(state, shardings=st_sh)
        man = mgr.manifest()
        pipe.restore_state(man["extra"]["pipeline"])
        start_step = man["step"]
        print(f"[train] resumed from step {start_step}", flush=True)

    step_fn = make_train_step(cfg, opt, micro_steps=micro_steps)
    b0 = pipe.next_batch() if start_step == 0 else None
    if b0 is not None:
        pipe.restore_state({"seed": seed, "step": 0})  # don't skip batch 0
    b_spec = batch_specs(jax.eval_shape(lambda: pipe.next_batch()), mesh)
    pipe.restore_state({"seed": seed, "step": start_step})
    b_sh = to_shardings(b_spec, mesh)
    jitted = jax.jit(step_fn, in_shardings=(st_sh, b_sh), donate_argnums=(0,))

    wd = Watchdog()
    losses = []
    gnorms = []
    with activate_mesh(mesh):
        for step in range(start_step, steps):
            if fail_at is not None and step == fail_at:
                if mgr is not None:
                    # the simulated crash kills the *compute* process;
                    # an async save already in flight still lands (the
                    # writer is logically a separate service).  Without
                    # this join, a restart could race the write and
                    # silently resume from scratch.
                    mgr.wait()
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.time()
            hb = pipe.next_batch()
            db = jax.device_put(hb, b_sh)
            state, metrics = jitted(state, db)
            loss = float(metrics["loss"])
            losses.append(loss)
            gnorms.append(float(metrics["grad_norm"]))
            wd.observe(time.time() - t0)
            if step % log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, state,
                         extra={"pipeline": pipe.save_state()},
                         blocking=False)
    if mgr is not None:
        mgr.save(steps, state, extra={"pipeline": pipe.save_state()})
        mgr.wait()
    dctx.clear()
    return {"losses": losses, "gnorms": gnorms, "final_state": state,
            "stragglers": wd.flagged}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--micro-steps", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, fail_at=args.fail_at,
                micro_steps=args.micro_steps,
                compress_grads=args.compress_grads)
    print(f"[train] done: first loss {out['losses'][0]:.4f} → "
          f"last {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
