import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell: ``jax.jit(step, in_shardings, out_shardings).lower(...)
.compile()`` against ShapeDtypeStruct inputs on the production mesh
(16×16 single pod / 2×16×16 multi-pod), then record
``memory_analysis()`` / ``cost_analysis()`` / collective bytes parsed
from the partitioned HLO into ``results/dryrun/<cell>.json``.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCHS, SHAPES, all_cells, cell_enabled
from repro.distributed import ctx as dctx
from repro.distributed.sharding import (batch_specs, cache_specs,
                                        param_specs, to_shardings)
from repro.launch.inputs import (batch_specs_for, decode_specs_for,
                                 state_specs_for)
from repro.launch.mesh import activate_mesh, make_production_mesh
from repro.optim.adamw import OptConfig
from repro.train.step import make_prefill_step, make_serve_step, \
    make_train_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

COLLECTIVE_RE = re.compile(
    r"=\s+(\S+?)\[([\d,]*)\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}


def collective_bytes(hlo_text: str):
    """Sum result-operand sizes of every collective op in partitioned HLO.

    all-gather result = bytes received per device; all-reduce/
    reduce-scatter/all-to-all/collective-permute result ≈ bytes moved per
    device (ring all-reduce moves 2× — applied as a factor)."""
    per_kind = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        factor = 2.0 if kind == "all-reduce" else 1.0
        per_kind[kind] = per_kind.get(kind, 0.0) + nbytes * factor
    return per_kind, float(sum(per_kind.values()))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, verbose: bool = True, cfg=None,
             tag_suffix: str = "", cp: bool = True):
    cfg = cfg if cfg is not None else ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tag = (f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
           f"{tag_suffix}")
    t0 = time.time()

    seq_shard = shape.kind in ("train", "prefill")
    dctx.set_activation_shardings(
        dctx.make_activation_shardings(mesh, cfg, seq_shard=seq_shard),
        mesh=mesh)
    dctx.set_context_parallel(cp and seq_shard)
    with activate_mesh(mesh):
        if shape.kind == "train":
            state_sds = state_specs_for(cfg, OptConfig())
            batch_sds = batch_specs_for(cfg, shape)
            st_spec = {
                "params": param_specs(state_sds["params"], cfg, mesh),
                "opt": {"m": param_specs(state_sds["opt"]["m"], cfg, mesh),
                        "v": param_specs(state_sds["opt"]["v"], cfg, mesh),
                        "step": jax.sharding.PartitionSpec()},
            }
            b_spec = batch_specs(batch_sds, mesh)
            dp_size = 32 if multi_pod else 16
            micro = max(1, min(cfg.micro_steps,
                               shape.global_batch // dp_size))
            step = make_train_step(cfg, OptConfig(), micro_steps=micro)
            jitted = jax.jit(step,
                             in_shardings=(to_shardings(st_spec, mesh),
                                           to_shardings(b_spec, mesh)),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            state_sds = state_specs_for(cfg, OptConfig())
            params_sds = state_sds["params"]
            batch_sds = batch_specs_for(cfg, shape)
            p_spec = param_specs(params_sds, cfg, mesh)
            b_spec = batch_specs(batch_sds, mesh)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(
                to_shardings(p_spec, mesh), to_shardings(b_spec, mesh)))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            state_sds = state_specs_for(cfg, OptConfig())
            params_sds = state_sds["params"]
            cache_sds, tok_sds, pos_sds = decode_specs_for(cfg, shape)
            # TP-only weights for decode when weights+cache fit per
            # device — FSDP weight all-gathers dominate decode
            # collectives.  Budget: bf16 weights/16 + KV cache/256 +
            # ~1 GiB transients against 16 GiB HBM (deepseek-67B: 8.4+6.4
            # → TP; llama-90B: 11+7 → falls back to FSDP).
            n_model = mesh.shape["model"]
            n_dev = n_model * (mesh.shape["data"]
                               * mesh.shape.get("pod", 1))
            expert_params = (cfg.n_layers * cfg.n_experts * 3
                             * cfg.d_model * cfg.d_ff if cfg.moe else 0)
            dense_params = cfg.param_count() - expert_params
            # infer_tp: dense weights /model; experts /(model×data)
            tp_w = dense_params * 2 / n_model + expert_params * 2 / n_dev
            cache_b = sum(
                int(np.prod(l.shape)) * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(cache_sds)
            ) / n_dev
            # infer_tp = TP dense weights + train-sharded experts
            # (§Perf iterations 3/6/7).
            mode = "infer_tp" if tp_w + cache_b <= 15e9 else "train"
            p_spec = param_specs(params_sds, cfg, mesh, mode=mode)
            c_spec = cache_specs(cache_sds, cfg, mesh)
            step = make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(to_shardings(p_spec, mesh),
                              to_shardings(c_spec, mesh),
                              to_shardings(batch_specs(tok_sds, mesh), mesh),
                              None),
                donate_argnums=(1,))
            lowered = jitted.lower(params_sds, cache_sds, tok_sds,
                                   jax.ShapeDtypeStruct((), jnp.int32))

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # --- analyses ---
    result = {"cell": tag, "arch": arch, "shape": shape_name,
              "multi_pod": multi_pod, "ok": True,
              "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1)}
    try:
        mem = compiled.memory_analysis()
        result["memory"] = {
            k: getattr(mem, k) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:                                    # CPU backend gaps
        result["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        result["cost"] = {k: float(v) for k, v in ca.items()
                          if isinstance(v, (int, float))}
    except Exception as e:
        result["cost"] = {"error": str(e)}
    try:
        hlo = compiled.as_text()
        per_kind, total = collective_bytes(hlo)
        result["collectives"] = {"per_kind": per_kind, "total_bytes": total}
        result["hlo_bytes"] = len(hlo)
    except Exception as e:
        result["collectives"] = {"error": str(e)}

    if verbose:
        mem_gb = result.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30
        flops = result.get("cost", {}).get("flops", 0)
        coll = result.get("collectives", {}).get("total_bytes", 0)
        print(f"[dryrun] {tag}: OK lower={t_lower:.0f}s "
              f"compile={t_compile:.0f}s temp={mem_gb:.2f}GiB/dev "
              f"flops={flops:.3g} coll={coll:.3g}B", flush=True)
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        (RESULTS / f"{tag}.json").write_text(json.dumps(result, indent=1))
    dctx.set_context_parallel(False)
    dctx.clear()
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        if not cell_enabled(args.arch, args.shape):
            print(f"[dryrun] {args.arch}×{args.shape}: skipped "
                  f"(long_500k needs sub-quadratic attention)")
            return
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
            if args.skip_done and (RESULTS / f"{tag}.json").exists():
                prev = json.loads((RESULTS / f"{tag}.json").read_text())
                if prev.get("ok"):
                    print(f"[dryrun] {tag}: cached OK", flush=True)
                    continue
            try:
                run_cell(arch, shape, mp)
            except Exception as e:
                failures.append(tag)
                RESULTS.mkdir(parents=True, exist_ok=True)
                (RESULTS / f"{tag}.json").write_text(json.dumps(
                    {"cell": tag, "ok": False, "error": str(e),
                     "traceback": traceback.format_exc()[-4000:]}, indent=1))
                print(f"[dryrun] {tag}: FAIL {e}", flush=True)
    if failures:
        print(f"[dryrun] {len(failures)} failures: {failures}", flush=True)
        sys.exit(1)
    print("[dryrun] all cells OK", flush=True)


if __name__ == "__main__":
    main()
