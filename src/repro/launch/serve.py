"""Serving driver: batched request decoding with top-k selective
attention over a KV cache (continuous-batching-lite: fixed batch slots,
**per-slot positions**, new requests claim finished slots).

Each slot owns its decode position; claiming a slot resets its
per-request state (``models.decode.reset_slot``), so a request never
inherits the previous occupant's KV contents — and requests of
different lengths decode concurrently at their own offsets.  Latency is
reported per request (claim → last token), not just aggregate tok/s.

**Cache layout** (``cfg.kv_cache_layout``):

* ``"contiguous"`` — one (max_len, KV, D) region per slot per layer:
  simple, but every slot reserves worst-case HBM for its whole life.
* ``"paged"`` — a global page pool + per-slot page table
  (``core/paging.py``).  The driver owns the host-side allocator:
  pages map on append (a slot holds only ``ceil(pos/page)`` pages),
  free when its request completes, and pool exhaustion becomes
  *backpressure* — the claim loop defers new requests (admission
  control), and a mid-flight slot that cannot map its next page at a
  page boundary stalls for a step (its token is re-fed once a page
  frees; the overflow page swallows the discarded write).  The run
  report includes page occupancy: HBM reserved vs actually used, and
  the reserved-bytes ratio vs the contiguous layout.

**Prefill→decode handoff** (``prompt_len > 1``, dense/moe): prompts
prefill in one full-sequence pass (``models.decode.prefill_prompt``)
whose K/V rows and *seeded decode plan* install into the claimed slot —
the first decode step starts planned (summaries + the prompt tail's
selected blocks) instead of running a cold full re-plan.

**Shared-prefix page cache** (``cfg.kv_prefix_cache``, paged only): a
claim first walks the prompt-prefix trie (``core.paging.PrefixCache``)
and maps the longest cached prefix's pages straight into the new
slot's table — refcount bump, zero copy, and prefill runs only over
the unmatched tail (its queries attend over the gathered prefix K/V,
so the math is the full prefill's, minus the matched positions'
FLOPs).  Shared pages are immutable: any append that would land in
one (in particular the owner's first append into its registered
partial prompt page) goes through copy-on-write — allocate, copy
device-side, remap — or stalls the step when the pool is dry, and the
driver evicts least-recently-used trie pages under pressure.
Completion/preemption decrement refcounts, never freeing a page the
trie or another slot still holds.  The run report adds hit-rate,
prefill tokens saved, CoW copies, and shared-vs-private occupancy.

With ``cfg.sata_decode`` routing on, every step fetches only the
planned KV blocks (``core/decode_plan.py`` + the decode gather kernel)
and the driver accumulates both kernel-side and *plan-side* traffic
(full re-plans stream all cached K; the plan state's ``replans``
counter makes the split exact even under ``sata_decode_replan="auto"``).

**Fault tolerance** (paged): preemption prefers **host-swap** over
requeue on the dense/moe families — the victim's private pages (K/V
rows + per-page summary rows), page-table row, position, and complete
decode-plan state move to host numpy (``PageAllocator.swap_out`` +
``models.decode.gather_phys_pages`` / ``capture_plan_state``); shared
trie pages stay resident under their refcounts.  Re-admission scatters
the payload back into fresh pages and reinstalls the plan reset-free,
so decode resumes at the exact position — **zero re-prefill, zero cold
re-plans, bitwise equal to a never-preempted run** (the plan indexes
*logical* blocks and carries its beat phase, so physical page identity
never enters the math).  ``host_swap_bytes`` bounds the host-side
budget (``0`` disables swap; a dry budget falls back to today's
requeue-and-regenerate).  A ``FaultPlan`` (``launch/faults.py``)
passed as ``serve(faults=...)`` drives every backpressure branch
deterministically: pool squeezes/restores, forced preemptions,
admission deferrals, and a mid-serve ``crash_step`` that swaps ALL
live state to host, drops the device cache + allocator, and restores
every in-flight request from its swap handle.
``max_steps_per_request`` retires runaway slots gracefully as
``timed_out``; a request preempted ``preempt_retry_limit`` times
re-admits under a reserved-page guarantee (and is excluded from victim
selection), so repeated-victim livelock is impossible.
``audit_pages`` (default on) runs ``PageAllocator.check_invariants``
after every allocator mutation.

Usage (CPU, reduced arch):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --requests 8 --gen-len 16
"""
from __future__ import annotations

import argparse
import dataclasses
import pickle
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCHS, SMOKE
from repro.core.paging import (PageAllocator, PageIntegrityError,
                               PrefixCache, SharedPrefixIndex)
from repro.launch.faults import FaultPlan
from repro.launch.mesh import make_local_mesh
from repro.models import attention as attn
from repro.models import decode as dec
from repro.models import model as mdl


class ServeKilled(RuntimeError):
    """Raised by ``serve(kill_at_step=N)`` — a deterministic stand-in
    for a process crash, injected AFTER the checkpoint block so a
    resumed run replays from the last saved state."""


class QoSController:
    """SLO degradation ladder: deterministic per-slot rung counters
    mapping overload pressure to decode-plan quality knobs.

    Rungs apply cumulatively::

        0  full quality        budget=P, interval=iv, fp32, exact
        1  half plan budget    budget = max(1, P // 2)
        2  slow re-plan beat   interval = iv * 4
        3  int8 rank bounds    quantized (conservative) block ranking
        4  sketch re-plans     hierarchical candidate pre-filter

    ``press(active, severity)`` steps every active slot DOWN
    ``severity`` rungs — within a pressure episode quality is monotone
    non-increasing.  ``tick(active, pressure)`` is the hysteresis
    clock: a pressure-free tick increments a per-slot clear counter,
    and only after ``clear_steps`` consecutive clear ticks does a slot
    recover ONE rung (the counter then resets, so two recoveries are
    always >= ``clear_steps`` apart — no flapping); any pressure
    zeroes every counter.  ``reset(i)`` (new admission) returns the
    slot to full quality immediately: a rung is a property of the
    slot's CURRENT occupant's episode, not of the hardware."""

    MAX_RUNG = 4

    def __init__(self, n_slots: int, p0: int, iv0: int,
                 clear_steps: int = 4):
        self.n_slots = int(n_slots)
        self.p0 = max(1, int(p0))
        self.iv0 = max(1, int(iv0))
        self.clear_steps = max(1, int(clear_steps))
        self.rung = [0] * self.n_slots
        self.clear = [0] * self.n_slots
        self.rung_downs = 0
        self.rung_ups = 0

    def knobs(self, i: int) -> Tuple[int, int, bool, bool]:
        """(budget, interval, quant, sketch) for slot ``i``'s rung."""
        r = self.rung[i]
        return (self.p0 if r < 1 else max(1, self.p0 // 2),
                self.iv0 if r < 2 else self.iv0 * 4,
                r >= 3, r >= 4)

    def vectors(self):
        """Per-slot knob vectors for ``models.decode.set_qos_knobs``."""
        ks = [self.knobs(i) for i in range(self.n_slots)]
        return (np.asarray([k[0] for k in ks], np.int32),
                np.asarray([k[1] for k in ks], np.int32),
                np.asarray([k[2] for k in ks], bool),
                np.asarray([k[3] for k in ks], bool))

    def press(self, active: List[int], severity: int = 1) -> List[int]:
        """Overload signal: degrade every active slot ``severity``
        rungs (clamped at the bottom).  Returns the changed slots."""
        changed = []
        for i in active:
            new = min(self.rung[i] + max(1, int(severity)), self.MAX_RUNG)
            if new != self.rung[i]:
                self.rung[i] = new
                self.rung_downs += 1
                changed.append(i)
            self.clear[i] = 0
        return changed

    def tick(self, active: List[int], pressure: bool) -> List[int]:
        """Per-step hysteresis clock (call once per loop step, after
        this step's pressure is known).  Returns slots that recovered
        one rung."""
        if pressure:
            self.clear = [0] * self.n_slots
            return []
        changed = []
        for i in active:
            if self.rung[i] == 0:
                continue
            self.clear[i] += 1
            if self.clear[i] >= self.clear_steps:
                self.rung[i] -= 1
                self.clear[i] = 0
                self.rung_ups += 1
                changed.append(i)
        return changed

    def reset(self, i: int) -> bool:
        """New admission into slot ``i`` starts at full quality."""
        changed = self.rung[i] != 0
        self.rung[i] = 0
        self.clear[i] = 0
        return changed


def _plan_field(cache: Dict, field: str) -> Optional[np.ndarray]:
    """One field of the SATA decode-plan state, if routing is on
    (hybrid keeps its attention cache under ``shared_kv``)."""
    for name in ("kv", "shared_kv"):
        kvc = cache.get(name)
        if isinstance(kvc, dict) and "plan" in kvc:
            return np.asarray(kvc["plan"][field])
    return None


def _plan_counts(cache: Dict) -> Optional[np.ndarray]:
    """Layer-stacked (L, B, KV) plan occupancy."""
    cnt = _plan_field(cache, "kv_counts")
    return None if cnt is None else cnt.reshape(-1, *cnt.shape[-2:])


def _plan_replans(cache: Dict) -> Optional[np.ndarray]:
    """Cumulative per-(layer, slot) full-re-plan counters, layer axes
    flattened to (L, B) — both the churn-adaptive trigger and the
    per-slot beat fire independently, so the caller attributes deltas
    to the slots that actually hold live requests."""
    r = _plan_field(cache, "replans")
    return None if r is None else \
        r.astype(np.float64).reshape(-1, r.shape[-1])


def _pick_victim(stalled: List[int], slots: List[Optional[int]],
                 outputs: Dict[int, List[int]], admit_seq: Dict[int, int],
                 protected=()) -> int:
    """Preemption victim policy: the stalled slot with the least
    decoded progress loses the least salvageable work; ties break by
    admission order — the YOUNGEST admission goes first (explicit,
    where ``min`` over insertion order used to decide silently).
    Slots holding protected requests (at the preemption retry limit)
    are skipped unless every candidate is protected."""
    cands = [i for i in stalled if slots[i] not in protected]
    if not cands:
        cands = list(stalled)
    return min(cands, key=lambda i: (len(outputs[slots[i]]),
                                     -admit_seq[slots[i]]))


@dataclasses.dataclass(frozen=True)
class ServeOptions:
    """Workload shape for one :func:`serve` run."""
    n_requests: int = 8
    batch_slots: int = 4
    gen_len: int = 16
    max_len: int = 64
    prompt_len: int = 1
    shared_prefix_len: int = 0        # prompts share their first N
                                      # tokens (a common system prompt)
                                      # — the workload the prefix cache
                                      # exists for


@dataclasses.dataclass(frozen=True)
class ResilienceOptions:
    """Fault-tolerance / overload knobs for :func:`serve` (see the
    module docstring for the failure model each one drives)."""
    host_swap_bytes: Optional[int] = None   # host-swap payload budget
                                            # (None unbounded, 0 =
                                            # requeue-only)
    max_steps_per_request: Optional[int] = None  # deadline watchdog
    preempt_retry_limit: int = 3            # reserved-page guarantee
                                            # past this many preemptions
    audit_pages: Union[bool, str] = True    # allocator invariant audit
                                            # (True | False | "light")
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    resume: bool = False
    kill_at_step: Optional[int] = None      # deterministic crash after
                                            # the checkpoint block


_LEGACY_SERVE_KW = {
    # legacy flat kwarg -> (options group, field)
    "n_requests": ("options", "n_requests"),
    "batch_slots": ("options", "batch_slots"),
    "gen_len": ("options", "gen_len"),
    "max_len": ("options", "max_len"),
    "prompt_len": ("options", "prompt_len"),
    "shared_prefix_len": ("options", "shared_prefix_len"),
    "host_swap_bytes": ("resilience", "host_swap_bytes"),
    "max_steps_per_request": ("resilience", "max_steps_per_request"),
    "preempt_retry_limit": ("resilience", "preempt_retry_limit"),
    "audit_pages": ("resilience", "audit_pages"),
    "checkpoint_dir": ("resilience", "checkpoint_dir"),
    "checkpoint_every": ("resilience", "checkpoint_every"),
    "resume": ("resilience", "resume"),
    "kill_at_step": ("resilience", "kill_at_step"),
}

_warned_serve_legacy = False


def _fold_serve_legacy(options: Optional[ServeOptions],
                       resilience: Optional[ResilienceOptions],
                       legacy: Dict[str, Any]
                       ) -> Tuple[ServeOptions, ResilienceOptions]:
    """Map legacy flat ``serve()`` kwargs onto the options dataclasses
    (explicit flat values override group values).  One
    DeprecationWarning per process, naming every legacy kwarg seen."""
    opt = options or ServeOptions()
    res = resilience or ResilienceOptions()
    if legacy:
        unknown = [k for k in legacy if k not in _LEGACY_SERVE_KW]
        if unknown:
            raise TypeError(f"serve() got unexpected keyword argument(s) "
                            f"{unknown}")
        global _warned_serve_legacy
        if not _warned_serve_legacy:
            _warned_serve_legacy = True
            warnings.warn(
                f"flat serve() kwargs {sorted(legacy)} are deprecated; "
                f"pass serve(options=ServeOptions(...), "
                f"resilience=ResilienceOptions(...))",
                DeprecationWarning, stacklevel=3)
        by_group: Dict[str, Dict[str, Any]] = {"options": {},
                                               "resilience": {}}
        for k, v in legacy.items():
            group, field = _LEGACY_SERVE_KW[k]
            by_group[group][field] = v
        if by_group["options"]:
            opt = dataclasses.replace(opt, **by_group["options"])
        if by_group["resilience"]:
            res = dataclasses.replace(res, **by_group["resilience"])
    return opt, res


def serve(arch: str, smoke: bool = True, *,
          seed: int = 0, mesh=None, params=None, cfg=None,
          options: Optional[ServeOptions] = None,
          faults: Optional[FaultPlan] = None,
          resilience: Optional[ResilienceOptions] = None,
          prefix_index: Optional[SharedPrefixIndex] = None,
          replica_id: int = 0,
          **legacy) -> Dict[str, Any]:
    """Serve ``options.n_requests`` requests through ``batch_slots``
    decode slots.  The workload shape lives in :class:`ServeOptions`,
    fault injection in ``faults`` (a :class:`FaultPlan`), and the
    recovery/watchdog knobs in :class:`ResilienceOptions`; the legacy
    flat kwargs (``n_requests=...``, ``checkpoint_dir=...``) still work
    through a deprecation shim.

    Fault-tolerance knobs (see the module docstring): ``faults`` is a
    deterministic ``FaultPlan`` keyed on the loop-step counter;
    ``host_swap_bytes`` caps host-swap payload bytes held at once
    (``None`` = unbounded, ``0`` = requeue-only); a request is retired
    as ``timed_out`` after holding a slot ``max_steps_per_request``
    steps; ``preempt_retry_limit`` preemptions of one request trigger
    the reserved-page re-admission guarantee; ``audit_pages`` keeps
    the allocator's invariant audit on (``"light"`` samples the full
    invariant audit every 16th mutation and runs a cheap vectorized
    refcount-sum check otherwise).

    Cross-replica serving: with a :class:`SharedPrefixIndex` passed as
    ``prefix_index`` (plus ``kv_prefix_cache=True``), this replica
    publishes its prompt-prefix pages to the index and, on a local trie
    miss, *migrates* a prefix another replica published — the matched
    pages are copied into freshly allocated local pages, registered in
    the local trie, and served under ordinary refcount/CoW semantics.
    See :func:`serve_replicated` for the N-replica harness.

    Overload resilience (``cfg.sata_qos_ladder``): ``load_spike`` /
    ``slow_step`` faults and organic pool pressure (deferrals, stalls)
    step every active slot down the :class:`QoSController` rung ladder
    instead of preempting — the per-slot plan budget/interval/summary
    knobs degrade in place (no re-trace, no requeue) and recover with
    hysteresis once pressure clears.  Without the ladder, a
    ``load_spike`` sheds load the old way: one preemption per severity
    unit.  Every request's report entry records its degradation
    timeline (``out["degradation"]``).

    Checkpoint/resume: with ``checkpoint_dir`` + ``checkpoint_every``,
    the loop atomically saves the device cache and EVERY host-side
    control structure (allocator, trie, swap handles, queue, admission
    order, QoS rungs, counters) at the top of each N-th step;
    ``kill_at_step`` raises :class:`ServeKilled` right after the
    checkpoint block, and a fresh process calling with ``resume=True``
    replays from the last save — outputs bitwise equal to an
    uninterrupted run."""
    opt, res = _fold_serve_legacy(options, resilience, legacy)
    n_requests, batch_slots = opt.n_requests, opt.batch_slots
    gen_len, max_len = opt.gen_len, opt.max_len
    prompt_len, shared_prefix_len = opt.prompt_len, opt.shared_prefix_len
    host_swap_bytes = res.host_swap_bytes
    max_steps_per_request = res.max_steps_per_request
    preempt_retry_limit = res.preempt_retry_limit
    audit_pages = res.audit_pages
    checkpoint_dir, checkpoint_every = res.checkpoint_dir, \
        res.checkpoint_every
    resume, kill_at_step = res.resume, res.kill_at_step
    cfg = cfg or (SMOKE if smoke else ARCHS)[arch]
    mesh = mesh or make_local_mesh()
    if params is None:
        params = mdl.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)

    cache = dec.init_cache(cfg, batch_slots, max_len)
    if cfg.family in ("vlm", "audio"):
        batch_ctx = {}
        if cfg.family == "vlm":
            batch_ctx["image_embeds"] = jnp.asarray(rng.standard_normal(
                (batch_slots, cfg.n_image_tokens, cfg.d_model)), jnp.float32)
        else:
            batch_ctx["audio_embeds"] = jnp.asarray(rng.standard_normal(
                (batch_slots, cfg.encoder_len, cfg.d_model)), jnp.float32)
        cache = dec.prefill_context(params, cfg, cache, batch_ctx)

    step = jax.jit(lambda p, c, t, pos: dec.serve_step(p, cfg, c, t, pos))

    # --- paged-pool allocator (host-side; device consumes the table)
    alloc: Optional[PageAllocator] = None
    from repro.models.layers import _dtype
    if attn.paged_kv_on(cfg):
        page = attn.kv_page_size(cfg, max_len)
        pool = cache.get("kv", cache.get("shared_kv"))
        n_pages = int(pool["k_pages"].shape[1])
        alloc = PageAllocator(n_pages, batch_slots, max_len // page, page,
                              audit=audit_pages)
        alloc.lazy_cow = bool(cfg.kv.lazy_cow)
        cache = dec.set_page_table(cfg, cache, alloc.table)
        # backpressure only helps when at least ONE request's worst-case
        # working set fits: otherwise the livelock handler preempts the
        # sole active slot forever and the run silently truncates
        need_rows = min(max_len, max(1, prompt_len) + gen_len - 1)
        need = alloc.pages_for(need_rows)
        if need > alloc.free_pages:
            raise ValueError(
                f"kv_pool_pages={n_pages} ({alloc.free_pages} usable) "
                f"cannot hold one request's worst-case working set "
                f"({need} pages of {page} tokens) — no schedule can make "
                f"progress; grow the pool or shorten gen_len/max_len")

    # host-swap preemption covers the families whose complete per-slot
    # decode state is pages + plan (dense/moe); recurrent families
    # (hybrid/ssm) carry per-slot mamba/rwkv state the page swap does
    # not capture, so they keep the requeue-and-regenerate path
    can_swap = (alloc is not None and cfg.family in ("dense", "moe")
                and (host_swap_bytes is None or host_swap_bytes > 0))
    if alloc is not None:
        n_layers_kv = int(pool["k_pages"].shape[0])
        swap_page_bytes = (2 * cfg.n_kv_heads * cfg.hd
                           * jnp.dtype(_dtype(cfg)).itemsize
                           * page * n_layers_kv)   # budget estimate/page
    if faults is not None and not faults.empty:
        if alloc is None:
            raise ValueError(
                "fault injection drives the paged allocator — set "
                "kv_cache_layout='paged'")
        if faults.has_crash and not (cfg.family in ("dense", "moe")):
            raise ValueError(
                "crash_step recovery restores every live slot from host "
                "swap, which needs the dense/moe paged serving path")

    # --- prompt prefill (handoff) — dense/moe full-sequence path
    prompt_len = max(1, int(prompt_len))
    use_prefill = prompt_len > 1 and cfg.family in ("dense", "moe")
    if prompt_len > 1 and not use_prefill:
        raise NotImplementedError(
            f"prompt_len > 1 needs the dense/moe prefill path "
            f"(family {cfg.family!r})")
    prefill = (jax.jit(lambda p, t: dec.prefill_prompt(p, cfg, t, max_len))
               if use_prefill else None)
    prefill_tail = (jax.jit(lambda p, t, pk: dec.prefill_prompt(
        p, cfg, t, max_len, prefix_kv=pk)) if use_prefill else None)

    # --- shared-prefix page cache (prompt-prefix trie over the pool)
    pcache: Optional[PrefixCache] = None
    if attn.prefix_cache_on(cfg):
        assert alloc is not None
        pcache = PrefixCache(alloc)
    cow_copies = 0
    page = alloc.page if alloc is not None else max_len
    # --- cross-replica prefix index (see SharedPrefixIndex): publishes
    # ride the local trie register; a local miss consults the index and
    # migrates a remote replica's pages into the local pool
    if prefix_index is not None and pcache is None:
        raise ValueError(
            "prefix_index needs the local shared-prefix cache on "
            "(kv_prefix_cache=True, paged layout) — migration lands "
            "remote pages in the local trie")
    cross_replica_hits = migrated_pages = migrated_tokens = 0
    index_publishes = 0

    def _push_tables():
        nonlocal cache
        # writable_ref_view == ref when lazy CoW is off (bit-identical
        # push); with leases, a live lease's page reports refcount 1 so
        # its holder's in-place appends pass the device write-protect
        cache = dec.set_page_table(
            cfg, cache, alloc.table,
            page_ref=alloc.writable_ref_view() if pcache is not None
            else None)

    def _step_writable(i: int) -> bool:
        """Pool-side gate before slot ``i`` appends at pos_h[i]: CoW
        the target page if it is shared, map it if it is new — evicting
        trie-retained pages first when the pool is dry (cached prefixes
        are a use of SPARE pages, never a reason to stall a live
        request).  False = stall this step."""
        nonlocal cache, cow_copies
        ok, cp = alloc.ensure_writable(i, int(pos_h[i]))
        if not ok and pcache is not None and pcache.evict(1):
            ok, cp = alloc.ensure_writable(i, int(pos_h[i]))
        if not ok:
            return False
        if cp is not None:
            cache = dec.copy_phys_pages(cache, [cp])
            cow_copies += 1
        if alloc.ensure(i, int(pos_h[i])):
            return True
        if pcache is not None and pcache.evict(1):
            return alloc.ensure(i, int(pos_h[i]))
        return False

    # deterministic prompt tokens per request: a request's output
    # depends only on its own prompt, never on which slot served it
    prompts = rng.integers(0, cfg.vocab_size, (n_requests, prompt_len))
    if shared_prefix_len:
        assert shared_prefix_len < prompt_len, "tail must be non-empty"
        prompts[:, :shared_prefix_len] = prompts[0, :shared_prefix_len]
    queue: List[int] = list(range(n_requests))
    outputs: Dict[int, List[int]] = {}
    latency: Dict[int, float] = {}
    t_claim: Dict[int, float] = {}
    slots: List[Optional[int]] = [None] * batch_slots
    pos_h = np.zeros(batch_slots, np.int32)       # per-slot positions
    tokens_h = np.zeros((batch_slots, 1), np.int32)
    produced = 0
    steps = 0
    deferred_claims = stalled_steps = preemptions = 0
    fetch_tiles_plan = fetch_tiles_dense = 0
    plan_bytes = kernel_bytes_plan = kernel_bytes_dense = 0
    noted: set = set()               # requests whose hit/miss is counted
    # --- fault-tolerance state
    swapped_recs: Dict[int, Dict[str, Any]] = {}  # request → swap record
    preempt_count: Dict[int, int] = {}
    admit_seq: Dict[int, int] = {}                # request → claim order
    admit_clock = 0
    req_steps: Dict[int, int] = {}                # watchdog: steps held
    timed_out: set = set()
    host_swaps = swap_restores = requeue_preemptions = 0
    tokens_salvaged = requeue_tokens_discarded = re_prefill_tokens = 0
    swap_cold_replans = crashes = protected_admissions = 0
    host_swap_bytes_now = host_swap_bytes_peak = 0
    restore_wall = 0.0
    rep_offset = 0.0              # re-plan count carried across crashes
    # --- overload / integrity state
    corrupt_pages_injected = corrupt_pages_detected = 0
    quarantined_pages = trie_nodes_invalidated = 0
    load_spikes_seen = slow_steps_seen = 0
    degraded_steps = 0
    deferred_retries_skipped = 0
    defer_until: Dict[int, int] = {}      # request → earliest retry step
    defer_backoff: Dict[int, int] = {}    # request → current backoff
    degrade_log: Dict[int, List] = {}     # request → [(step, rung), ...]
    qos_dirty = False
    # --- cascade retirement state
    retire_events = pages_reclaimed = retired_tokens = 0
    retire_log: Dict[int, List] = {}      # request → [(step, pages_freed)]

    def _clear_backoff() -> None:
        """Pool capacity (may have) grown — deferred claims re-check
        immediately (backoff answers a FULL pool, it is not a fixed
        penalty)."""
        defer_until.clear()
        defer_backoff.clear()

    def _log_rungs(changed: List[int]) -> None:
        """Record rung transitions on the occupying requests' timelines
        and mark the device knob vectors stale."""
        nonlocal qos_dirty
        for i in changed:
            qos_dirty = True
            r = slots[i]
            if r is not None:
                degrade_log.setdefault(r, []).append(
                    (steps, qosctl.rung[i]))

    def _gather_pages(phys):
        return dec.gather_phys_pages(cache, phys)

    def _scatter_pages(fresh, payload):
        nonlocal cache
        cache = dec.scatter_phys_pages(cache, fresh, payload)

    def _payload_bytes(rec) -> int:
        b = sum(a.nbytes for _, payload in rec["handle"]["chunks"]
                for a in payload.values())
        return b + sum(np.asarray(v).nbytes
                       for snap in rec["plan"].values()
                       for v in snap.values())

    def _protected() -> set:
        return {r for r, c in preempt_count.items()
                if c >= preempt_retry_limit}

    def _reserve_need(exclude: Optional[int] = None) -> int:
        """Pages admission must hold back for queued PROTECTED requests
        (at the retry limit): their next re-admission is guaranteed, so
        ordinary claims may not consume the last pages they need."""
        n = 0
        for r in queue:
            if r == exclude or preempt_count.get(r, 0) < preempt_retry_limit:
                continue
            if r in swapped_recs:
                n += alloc.swap_pages_needed(swapped_recs[r]["handle"])
            else:
                n += alloc.pages_for(max(prompt_len, 1))
        return n

    def _swap_out(victim: int) -> None:
        """Host-swap the victim: plan snapshot first (the slot is still
        live), then pages (gather-before-free inside ``swap_out``), then
        release.  Decoded output and position are KEPT — restore
        resumes, it does not regenerate."""
        nonlocal cache, host_swaps, tokens_salvaged
        nonlocal host_swap_bytes_now, host_swap_bytes_peak
        r = slots[victim]
        plan = dec.capture_plan_state(cfg, cache, victim)
        handle = alloc.swap_out(victim, _gather_pages)
        rec = {"handle": handle, "plan": plan,
               "pos": int(pos_h[victim]), "token": int(tokens_h[victim, 0])}
        rec["bytes"] = _payload_bytes(rec)
        swapped_recs[r] = rec
        tokens_salvaged += len(outputs[r])
        queue.insert(0, r)
        slots[victim] = None
        cache = dec.release_slot(cfg, cache, victim)
        host_swaps += 1
        host_swap_bytes_now += rec["bytes"]
        host_swap_bytes_peak = max(host_swap_bytes_peak,
                                   host_swap_bytes_now)

    def _preempt(victim: int) -> None:
        """Evict the victim slot — host-swap when the family supports
        it and the host budget holds the estimated payload, else the
        requeue-and-regenerate fallback (deterministic regeneration
        keeps the final outputs unchanged either way; swap just keeps
        the progress)."""
        nonlocal cache, produced, preemptions, requeue_preemptions
        nonlocal requeue_tokens_discarded
        r = slots[victim]
        preempt_count[r] = preempt_count.get(r, 0) + 1
        est = int(alloc.n_mapped[victim]) * swap_page_bytes
        fits = (host_swap_bytes is None
                or host_swap_bytes_now + est <= host_swap_bytes)
        if can_swap and fits and alloc.n_mapped[victim] > 0:
            _swap_out(victim)
        else:
            produced -= len(outputs[r])       # discarded, not served
            requeue_tokens_discarded += len(outputs[r])
            outputs[r] = []
            queue.insert(0, r)
            slots[victim] = None
            cache = dec.release_slot(cfg, cache, victim)
            alloc.free_slot(victim)
            requeue_preemptions += 1
        preemptions += 1
        _clear_backoff()                  # the victim's pages freed

    def _crash_restore() -> None:
        """Mid-serve crash: every byte the device holds is about to be
        lost, so (1) outstanding swap handles convert their resident
        shared pages to host payload, (2) every live slot full-swaps to
        host, then (3) the device cache, allocator, and (empty) prefix
        trie rebuild from scratch and the claim loop re-admits each
        request from its swap handle — positions, plan state, and
        decoded output all survive."""
        nonlocal cache, alloc, pcache, crashes, last_rep, rep_base
        nonlocal rep_offset, host_swap_bytes_now, host_swap_bytes_peak
        for rec in swapped_recs.values():
            alloc.swap_to_full(rec["handle"], _gather_pages)
            nb = _payload_bytes(rec)
            host_swap_bytes_now += nb - rec["bytes"]
            rec["bytes"] = nb
        # reversed: each insert(0) lands the lowest slot at the queue
        # head, so re-admission replays in slot order
        for i in reversed(range(batch_slots)):
            r = slots[i]
            if r is not None:
                _swap_out(i)                  # crash ignores the budget
                rec = swapped_recs[r]
                alloc.swap_to_full(rec["handle"], _gather_pages)
                nb = _payload_bytes(rec)
                host_swap_bytes_now += nb - rec["bytes"]
                rec["bytes"] = nb
        host_swap_bytes_peak = max(host_swap_bytes_peak,
                                   host_swap_bytes_now)
        handles = alloc.swapped
        squeezed_n = len(alloc.squeezed)
        # device teardown + rebuild (same shapes — the jitted step's
        # trace still applies)
        cache = dec.init_cache(cfg, batch_slots, max_len)
        alloc = PageAllocator(n_pages, batch_slots, max_len // page, page,
                              audit=audit_pages)
        alloc.swapped = handles               # payload survives the crash
        alloc.squeeze(squeezed_n)             # injected pressure persists
        if pcache is not None:
            old = pcache
            pcache = PrefixCache(alloc)       # trie contents are lost...
            pcache.hits, pcache.misses = old.hits, old.misses
            pcache.tokens_saved = old.tokens_saved      # ...stats carry
            pcache.evictions = old.evictions
        for i in range(batch_slots):
            cache = dec.release_slot(cfg, cache, i)
        _push_tables()
        # fold the pre-crash re-plan count into the offset; the fresh
        # cache's counters restart the delta accounting
        if last_rep is not None:
            rep_offset += float((last_rep - rep_base).mean())
            last_rep = _plan_replans(cache)
            rep_base = last_rep.copy()
        crashes += 1
    from repro.kernels.ops import decode_fetch_stats
    blk = attn.decode_block_size(cfg, max_len)
    tile_bytes = 2 * blk * cfg.hd * jnp.dtype(_dtype(cfg)).itemsize

    # --- SLO degradation ladder over the per-slot plan knob vectors
    qosctl: Optional[QoSController] = None
    if cfg.sata.qos.ladder:
        has_qos_plan = any(
            isinstance(cache.get(n), dict) and "plan" in cache[n]
            and "budget" in cache[n]["plan"] for n in ("kv", "shared_kv"))
        if not has_qos_plan:
            raise ValueError(
                "sata_qos_ladder degrades the SATA decode plan — turn on "
                "sata_decode routing (the cache carries no qos plan)")
        nkb0 = max_len // blk
        p0 = cfg.sata.decode.blocks or nkb0
        qosctl = QoSController(
            batch_slots, p0=min(int(p0), nkb0),
            iv0=attn._resolve_replan(cfg)[0],
            clear_steps=cfg.sata.qos.clear_steps)

    # --- cascade token retirement (SpAtten): free cold blocks' pages
    # back to the pool MID-STREAM instead of holding every prefix token
    # until completion.  Lossy by design once a pass fires; "off" keeps
    # the whole stack bitwise identical (no plan fields, no passes).
    retire_on = cfg.sata.retire.mode == "on"
    if retire_on:
        if alloc is None or _plan_field(cache, "imp") is None:
            raise ValueError(
                "sata_retire='on' frees pages through the paged allocator "
                "and ranks blocks by the decode plan's importance "
                "accumulator — it needs kv_cache_layout='paged' AND sata "
                "decode routing")
        retire_keep = float(cfg.sata.retire.keep)
        retire_mark = float(cfg.sata.retire.watermark)

    def _retire_pass(force: bool) -> bool:
        """One cascade-retirement sweep: for every active slot past its
        live-token watermark (``force`` — pool pressure this step —
        sweeps every slot), retire the coldest completed blocks down to
        the ``sata_retire_keep`` budget and free their pages.

        Importance = the plan's exponentially-decayed selection
        accumulator (``plan["imp"]``), summed over layers and kv heads
        — the SpAtten cumulative-attention signal, proxied by the score
        pass's own selection output so it costs zero extra cache reads.
        Never candidates: the current append block (and anything after
        it), already-retired holes; ``retire_compact`` additionally
        skips pinned pages (trie-shared / other-slot / swap-resident
        refs).  Survivors keep their logical positions — the plan
        repair (``dec.retire_plan``) only unnames the dead blocks, so
        causality masks and RoPE are untouched.  Returns True when any
        page was freed (caller re-pushes tables + clears backoff)."""
        nonlocal cache, retire_events, pages_reclaimed, retired_tokens
        imp = None
        freed_any = False
        for i in range(batch_slots):
            r = slots[i]
            if r is None:
                continue
            ret = alloc.retired[i]
            live_tok = int(pos_h[i]) + 1 - page * len(ret)
            if not (force or live_tok >= retire_mark * max_len):
                continue
            cur_blk = int(pos_h[i]) // page
            live_lps = [lp for lp in range(int(alloc.n_mapped[i]))
                        if lp not in ret]
            cand = [lp for lp in live_lps if lp < cur_blk]
            keep_n = max(1, int(np.ceil(retire_keep * len(live_lps))))
            n_ret = min(len(live_lps) - keep_n, len(cand))
            if n_ret <= 0:
                continue
            if imp is None:                  # one device pull per sweep
                a = _plan_field(cache, "imp")
                imp = a.reshape(-1, *a.shape[-3:])     # (L, B, KV, nkb)
            score = imp[:, i].sum(axis=(0, 1))         # (nkb,)
            # coldest first; ties retire the OLDEST block (deterministic)
            cand.sort(key=lambda lp: (float(score[lp]), lp))
            chosen = cand[:n_ret]
            freed, skipped = alloc.retire_compact(i, chosen)
            retired_lps = [lp for lp in chosen if lp not in skipped]
            if not retired_lps:
                continue                     # every candidate was pinned
            cache = dec.retire_plan(cfg, cache, i, retired_lps)
            if freed:
                cache = dec.retire_phys_pages(cache, freed)
                freed_any = True
            retire_events += 1
            pages_reclaimed += len(freed)
            retired_tokens += page * len(retired_lps)
            retire_log.setdefault(r, []).append((steps, len(freed)))
        return freed_any

    # every slot starts RELEASED (no request → no re-plan beat, no
    # accounting); a claim re-activates it through reset_slot
    for i in range(batch_slots):
        cache = dec.release_slot(cfg, cache, i)
    # warm the jit trace before any latency clock starts — every slot a
    # request claims is reset first (paged: the unmapped tables route
    # the warm-up writes to the overflow page), so the warm-up never
    # reaches an output
    logits, cache = step(params, cache, jnp.asarray(tokens_h),
                         jnp.asarray(pos_h))
    jax.block_until_ready(logits)
    last_rep = _plan_replans(cache)               # skip warm-up's re-plan
    rep_base = None if last_rep is None else last_rep.copy()

    def _ctrs():
        """Counter snapshot for the checkpoint meta blob — restore
        unpacks the SAME order (keep the two sites in sync)."""
        return (produced, deferred_claims, stalled_steps, preemptions,
                fetch_tiles_plan, fetch_tiles_dense, plan_bytes,
                kernel_bytes_plan, kernel_bytes_dense, host_swaps,
                swap_restores, requeue_preemptions, tokens_salvaged,
                requeue_tokens_discarded, re_prefill_tokens,
                swap_cold_replans, crashes, protected_admissions,
                host_swap_bytes_now, host_swap_bytes_peak, restore_wall,
                rep_offset, cow_copies, corrupt_pages_injected,
                corrupt_pages_detected, quarantined_pages,
                trie_nodes_invalidated, load_spikes_seen, slow_steps_seen,
                degraded_steps, deferred_retries_skipped,
                retire_events, pages_reclaimed, retired_tokens)

    # --- cross-process serve checkpoint/resume
    ckpt = None
    if checkpoint_dir is not None:
        from repro.checkpoint.manager import CheckpointManager
        ckpt = CheckpointManager(checkpoint_dir, keep=2)
    last_ckpt = -1
    resumed_at: Optional[int] = None
    if resume:
        assert ckpt is not None, "resume=True needs checkpoint_dir"
        rstep = ckpt.latest_step()
        assert rstep is not None, "resume=True but no checkpoint on disk"
        cache = ckpt.restore(like=cache, step=rstep)
        m = pickle.loads(ckpt.load_meta(rstep))
        steps = m["steps"]
        last_ckpt = resumed_at = steps
        queue = m["queue"]
        outputs = m["outputs"]
        latency = m["latency"]
        slots = m["slots"]
        pos_h = m["pos_h"]
        tokens_h = m["tokens_h"]
        alloc = m["alloc"]
        pcache = m["pcache"]
        swapped_recs = m["swapped_recs"]
        preempt_count = m["preempt_count"]
        admit_seq = m["admit_seq"]
        admit_clock = m["admit_clock"]
        req_steps = m["req_steps"]
        timed_out = m["timed_out"]
        noted = m["noted"]
        qosctl = m["qosctl"]
        degrade_log = m["degrade_log"]
        retire_log = m.get("retire_log", {})
        defer_until = m["defer_until"]
        defer_backoff = m["defer_backoff"]
        last_rep = m["last_rep"]
        rep_base = m["rep_base"]
        rng.bit_generator.state = m["rng"]
        (produced, deferred_claims, stalled_steps, preemptions,
         fetch_tiles_plan, fetch_tiles_dense, plan_bytes,
         kernel_bytes_plan, kernel_bytes_dense, host_swaps,
         swap_restores, requeue_preemptions, tokens_salvaged,
         requeue_tokens_discarded, re_prefill_tokens,
         swap_cold_replans, crashes, protected_admissions,
         host_swap_bytes_now, host_swap_bytes_peak, restore_wall,
         rep_offset, cow_copies, corrupt_pages_injected,
         corrupt_pages_detected, quarantined_pages,
         trie_nodes_invalidated, load_spikes_seen, slow_steps_seen,
         degraded_steps, deferred_retries_skipped,
         retire_events, pages_reclaimed, retired_tokens) = m["ctrs"]
        # wall clocks re-anchor — resumed latencies measure THIS
        # process's wall; outputs/counters stay bitwise
        t_claim = {r: time.time() for r in m["t_claim_reqs"]}
        if alloc is not None:
            _push_tables()
    t0 = time.time()
    # paged backpressure can stall slots / defer claims / preempt-and-
    # restart, so budget extra lockstep steps beyond the contiguous-
    # layout worst case
    max_steps = 4 * (n_requests * gen_len + batch_slots + 1)
    while (queue or any(s is not None for s in slots)) and steps < max_steps:
        if (ckpt is not None and checkpoint_every > 0
                and steps % checkpoint_every == 0 and steps != last_ckpt):
            meta = {
                "steps": steps, "queue": list(queue), "outputs": outputs,
                "latency": latency, "slots": list(slots),
                "pos_h": pos_h.copy(), "tokens_h": tokens_h.copy(),
                "alloc": alloc, "pcache": pcache,
                "swapped_recs": swapped_recs,
                "preempt_count": preempt_count, "admit_seq": admit_seq,
                "admit_clock": admit_clock, "req_steps": req_steps,
                "timed_out": timed_out, "noted": noted,
                "qosctl": qosctl, "degrade_log": degrade_log,
                "retire_log": retire_log,
                "defer_until": defer_until, "defer_backoff": defer_backoff,
                "last_rep": last_rep, "rep_base": rep_base,
                "rng": rng.bit_generator.state,
                "t_claim_reqs": list(t_claim), "ctrs": _ctrs(),
            }
            # ONE pickle: alloc.swapped, the trie's allocator back-
            # pointer, and every swap record's handle keep their shared
            # identity through the dump (swap_in asserts on it)
            ckpt.save(steps, cache, blocking=True,
                      meta_blob=pickle.dumps(meta))
            last_ckpt = steps
        if kill_at_step is not None and steps == kill_at_step:
            raise ServeKilled(f"injected process kill at loop step {steps}")
        defer_now = False
        pressure_now = False
        if faults is not None:                    # injected adversity
            for kind, arg in faults.at(steps):
                if kind == "pool_squeeze":
                    alloc.squeeze(arg)
                elif kind == "pool_restore":
                    alloc.unsqueeze(arg)
                    _clear_backoff()              # capacity returned
                elif kind == "load_spike":
                    load_spikes_seen += 1
                    sev = 1 if arg is None else max(1, int(arg))
                    held = [j for j in range(batch_slots)
                            if slots[j] is not None]
                    if qosctl is not None:
                        # shed QUALITY, not requests: every active slot
                        # steps down `severity` rungs in place
                        _log_rungs(qosctl.press(held, sev))
                        pressure_now = True
                    else:
                        # no ladder — shed load the old way: one
                        # preemption per severity unit
                        for _ in range(sev):
                            held = [j for j in range(batch_slots)
                                    if slots[j] is not None]
                            if not held:
                                break
                            _preempt(_pick_victim(held, slots, outputs,
                                                  admit_seq, _protected()))
                            _push_tables()
                elif kind == "slow_step":
                    slow_steps_seen += 1
                    if qosctl is not None:        # deadline pressure
                        held = [j for j in range(batch_slots)
                                if slots[j] is not None]
                        _log_rungs(qosctl.press(held, 1))
                        pressure_now = True
                elif kind == "corrupt_page":
                    # flip one byte in the nth outstanding swap handle's
                    # first parked chunk (deterministic offset) — the
                    # checksum verify at swap-in must catch it
                    recs = sorted(swapped_recs)
                    if recs:
                        nth = 0 if arg is None else int(arg)
                        rec_c = swapped_recs[recs[nth % len(recs)]]
                        chunks = rec_c["handle"]["chunks"]
                        if chunks:
                            _, payload = chunks[0]
                            key = sorted(payload)[0]
                            # parked payloads can be read-only device
                            # views — corrupt a writable copy IN the
                            # payload dict (handle identity unchanged)
                            arr = np.array(payload[key])   # owning copy
                            payload[key] = arr
                            flat = arr.view(np.uint8).reshape(-1)
                            flat[(steps * 131 + nth) % flat.size] ^= 0x01
                            corrupt_pages_injected += 1
                elif kind == "defer_admission":
                    defer_now = True
                elif kind == "preempt":
                    tgt = arg
                    if tgt is None:
                        held = [j for j in range(batch_slots)
                                if slots[j] is not None]
                        tgt = (_pick_victim(held, slots, outputs,
                                            admit_seq, _protected())
                               if held else None)
                    if tgt is not None and slots[tgt] is not None:
                        _preempt(tgt)
                        _push_tables()
                elif kind == "crash_step":
                    _crash_restore()
        for i in range(batch_slots):              # claim free slots
            if slots[i] is not None or not queue or defer_now:
                continue
            r0 = queue[0]
            if steps < defer_until.get(r0, 0):
                # bounded deferred-admission backoff: a claim the pool
                # rejected re-checks at its scheduled step instead of
                # every step; the break keeps later queue entries
                # BEHIND the head (admission-order fair)
                deferred_retries_skipped += 1
                break
            if r0 in swapped_recs:
                # integrity gate BEFORE any page is reserved: a handle
                # corrupted while parked on the host must never scatter
                # into the pool
                try:
                    alloc.verify_handle(swapped_recs[r0]["handle"])
                except PageIntegrityError:
                    # quarantine: drop the handle, invalidate trie
                    # entries over its resident pages, and recover the
                    # victim by deterministic re-prefill below (its
                    # salvaged progress is lost with the payload)
                    rec = swapped_recs.pop(r0)
                    quarantined_pages += sum(
                        len(lps) for lps, _ in rec["handle"]["chunks"])
                    bad = alloc.discard_handle(rec["handle"])
                    if pcache is not None and bad:
                        trie_nodes_invalidated += \
                            pcache.invalidate_pages(bad)
                    host_swap_bytes_now -= rec["bytes"]
                    corrupt_pages_detected += 1
                    produced -= len(outputs[r0])
                    requeue_tokens_discarded += len(outputs[r0])
                    tokens_salvaged -= len(outputs[r0])   # salvage failed
                    outputs[r0] = []
            r0_protected = preempt_count.get(r0, 0) >= preempt_retry_limit
            # protected requests (at the retry limit) consume the
            # reserve admission holds back for them; everyone else
            # must leave it untouched
            reserve = (0 if (alloc is None or r0_protected)
                       else _reserve_need(exclude=r0))
            if r0 in swapped_recs:
                # --- re-admission from host swap: restore, not redo —
                # pages scatter back, the plan reinstalls reset-free,
                # and decode resumes at the swapped position with the
                # swapped next-token (outputs so far were kept)
                rec = swapped_recs[r0]
                needed = alloc.swap_pages_needed(rec["handle"]) + reserve
                if not alloc.can_admit(needed):
                    if pcache is not None:
                        pcache.evict(needed)
                    if not alloc.can_admit(needed):
                        deferred_claims += 1      # backpressure: wait
                        bo = min(max(defer_backoff.get(r0, 0) * 2, 1), 8)
                        defer_backoff[r0] = bo
                        defer_until[r0] = steps + bo
                        pressure_now = True
                        break
                t_res = time.time()
                ok = alloc.swap_in(i, rec["handle"], _scatter_pages)
                assert ok, "can_admit reserved the payload pages"
                cache = dec.restore_plan_state(cfg, cache, i, rec["plan"])
                _push_tables()
                queue.pop(0)
                slots[i] = r0
                admit_seq[r0] = admit_clock
                admit_clock += 1
                defer_until.pop(r0, None)
                defer_backoff.pop(r0, None)
                if qosctl is not None and qosctl.reset(i):
                    qos_dirty = True              # fresh episode: rung 0
                pos_h[i] = rec["pos"]
                tokens_h[i, 0] = rec["token"]
                snap = (rec["plan"].get("kv")
                        or rec["plan"].get("shared_kv"))
                if last_rep is not None:
                    if rec["pos"] > 0 and (
                            snap is None
                            or not np.asarray(snap.get("active",
                                                       True)).any()):
                        swap_cold_replans += 1    # structurally 0 when
                        #     capture/restore moved a live plan intact
                    if snap is not None and "replans" in snap:
                        # the device counter at slot i jumps to the
                        # restored value — absorb the jump into the
                        # baseline so it never counts as a re-plan
                        col = snap["replans"].astype(
                            np.float64).reshape(-1)
                        rep_base[:, i] += col - last_rep[:, i]
                        last_rep[:, i] = col
                host_swap_bytes_now -= rec["bytes"]
                del swapped_recs[r0]
                swap_restores += 1
                if r0_protected:
                    protected_admissions += 1
                restore_wall += time.time() - t_res
                continue
            # prefix match BEFORE admission: a matched prefix maps
            # cached pages, so it shrinks the claim's pool demand
            # (match tokens[:-1] — the tail must stay non-empty so
            # the prefill always produces last-token logits)
            m, phys_m = 0, []
            mig: Optional[Tuple[int, Dict[str, np.ndarray], int]] = None
            if pcache is not None and use_prefill:
                m, phys_m, _ = pcache.match(prompts[r0, :-1])
                if prefix_index is not None:
                    hit = prefix_index.lookup(replica_id,
                                              prompts[r0, :-1])
                    # migrate only when another replica's publication
                    # beats the local trie — re-importing this
                    # replica's own (evicted) pages is just a re-prefill
                    if hit is not None and hit[0] > m and hit[2] > 0:
                        mig = hit
            if alloc is not None:
                def _need():
                    if mig is not None:
                        # migrated pages are fresh local COPIES — the
                        # claim pays for every prompt page (the win is
                        # prefill compute, not pool pages)
                        return alloc.pages_for(max(prompt_len, 1))
                    return max(alloc.pages_for(max(prompt_len, 1))
                               - len(phys_m) + (1 if m % page else 0),
                               0)
                if not alloc.can_admit(_need() + reserve):
                    if pcache is not None:
                        pcache.evict(_need() + reserve)
                        # eviction may have dropped matched pages —
                        # re-walk before trusting the mapping
                        m, phys_m, _ = pcache.match(
                            prompts[r0, :-1])
                    if mig is not None and \
                            not alloc.can_admit(_need() + reserve):
                        # a migration is optional work — under pool
                        # pressure fall back to the plain (cheaper)
                        # admission before deferring
                        mig = None
                    if not alloc.can_admit(_need() + reserve):
                        deferred_claims += 1  # backpressure: wait
                        bo = min(max(defer_backoff.get(r0, 0) * 2, 1), 8)
                        defer_backoff[r0] = bo
                        defer_until[r0] = steps + bo
                        pressure_now = True
                        break
            r = queue.pop(0)
            slots[i] = r
            admit_seq[r] = admit_clock
            admit_clock += 1
            defer_until.pop(r, None)
            defer_backoff.pop(r, None)
            if qosctl is not None and qosctl.reset(i):
                qos_dirty = True                  # fresh episode: rung 0
            if r0_protected:
                protected_admissions += 1
            if preempt_count.get(r, 0) and use_prefill:
                re_prefill_tokens += prompt_len - m   # requeue redoes it
            outputs[r] = []
            t_claim[r] = time.time()          # claim → last token
            cache = dec.reset_slot(cfg, cache, i)
            if use_prefill:
                if pcache is not None and r not in noted:
                    # once per REQUEST: a preempted request's
                    # re-claim would otherwise double-count (its
                    # own registered pages guarantee the re-claim
                    # hits, inflating saved past total)
                    noted.add(r)
                    pcache.note(mig[0] if mig is not None else m)
                if mig is not None:
                    # cross-replica page migration: copy the remote
                    # replica's published prefix pages into freshly
                    # allocated LOCAL pages, register them in the local
                    # trie, and continue exactly like a local full-page
                    # hit (the slot owns the pages; the trie's register
                    # adds its retention ref, so CoW semantics from
                    # here on are the ordinary owner-after-register
                    # case)
                    rows, payload, _n_rem = mig
                    npg = rows // page
                    ok = alloc.ensure(i, rows - 1)
                    assert ok, "admission control reserved these pages"
                    phys_mig = [int(p_) for p_ in alloc.table[i, :npg]]
                    cache = dec.scatter_phys_pages(cache, phys_mig,
                                                   payload)
                    pcache.register(prompts[r, :rows], alloc.table[i])
                    _push_tables()
                    cross_replica_hits += 1
                    migrated_pages += npg
                    migrated_tokens += rows
                    prefix_index.remote_hits += 1
                    m, phys_m = rows, []   # slot already maps the pages
                if m and phys_m:
                    alloc.map_shared(i, phys_m)
                    if m % page:
                        # the tail's first rows land inside the
                        # last matched page: shared → CoW now
                        ok, cp = alloc.ensure_writable(i, m)
                        assert ok, "admission reserved the CoW page"
                        if cp is not None:
                            cache = dec.copy_phys_pages(cache, [cp])
                            cow_copies += 1
                if alloc is not None:
                    ok = alloc.ensure(i, prompt_len - 1)
                    assert ok, "admission control reserved these pages"
                    _push_tables()
                if m:
                    prefix = dec.gather_prefix_kv(cache,
                                                  alloc.table[i], m)
                    lg0, state = prefill_tail(
                        params,
                        jnp.asarray(prompts[r:r + 1, m:], jnp.int32),
                        prefix)
                else:
                    lg0, state = prefill(params, jnp.asarray(
                        prompts[r:r + 1], jnp.int32))
                phys = (alloc.table[i, :alloc.pages_for(prompt_len)]
                        if alloc is not None else None)
                cache = dec.install_prefill(cfg, cache, i, state, phys,
                                            prefix_len=m)
                if pcache is not None:
                    # retain the prompt's pages (full pages chain
                    # the trie; the final partial page becomes a
                    # terminal node, so the owner's own first
                    # append below will copy-on-write it)
                    pcache.register(prompts[r], alloc.table[i])
                    _push_tables()
                    if prefix_index is not None:
                        # publish the MATCHABLE full pages (matchers
                        # walk tokens[:-1]); full prompt pages are
                        # append-frozen under trie retention, so the
                        # host copy taken here stays valid forever
                        full = ((prompt_len - 1) // page) * page
                        if full:
                            npg_f = full // page
                            payload_f = dec.gather_phys_pages(
                                cache,
                                [int(p_) for p_
                                 in alloc.table[i, :npg_f]])
                            index_publishes += prefix_index.publish(
                                replica_id, prompts[r, :full], page,
                                payload_f)
                pos_h[i] = prompt_len
                # the prefill's last-position argmax IS the first
                # generated token — record it, don't just feed it
                first = int(jnp.argmax(lg0[0]))
                outputs[r].append(first)
                produced += 1
                tokens_h[i, 0] = first
                if len(outputs[r]) >= gen_len or pos_h[i] >= max_len:
                    latency[r] = time.time() - t_claim[r]
                    slots[i] = None           # gen_len=1: done already
                    cache = dec.release_slot(cfg, cache, i)
                    if alloc is not None:
                        alloc.free_slot(i)
                        _clear_backoff()
            else:
                pos_h[i] = 0
                tokens_h[i, 0] = int(prompts[r, 0])
        active = [i for i in range(batch_slots) if slots[i] is not None]
        stalled: List[int] = []
        if alloc is not None and active:
            while True:
                stalled = [i for i in active if slots[i] is not None
                           and not _step_writable(i)]
                runnable = [i for i in active if slots[i] is not None
                            and i not in stalled]
                if not stalled or runnable:
                    break
                # every active slot is stalled: first reclaim pages only
                # the prefix trie still holds, then — pages only free
                # when a request completes — livelock.  Preempt the
                # least-progress victim (``_pick_victim``; admission
                # order breaks ties, protected requests are spared):
                # host-swap keeps its decoded progress when the family
                # and host budget allow, requeue regenerates it —
                # either way deterministic decode leaves the final
                # outputs unchanged, and shared pages survive through
                # their other references.
                if pcache is not None and pcache.evict(1):
                    continue
                victim = _pick_victim(stalled, slots, outputs, admit_seq,
                                      _protected())
                _preempt(victim)
            stalled_steps += len(stalled)
            _push_tables()
            # preemption may have freed slots out of the stale list
            active = [i for i in range(batch_slots) if slots[i] is not None]
        if qosctl is not None:
            if stalled:
                pressure_now = True               # organic pool pressure
            # hysteresis clock ticks once per step, then the (possibly
            # changed) knob vectors push BEFORE this step's compute —
            # values only, so the jitted trace is untouched
            _log_rungs(qosctl.tick(active, pressure_now))
            degraded_steps += sum(1 for i in active if qosctl.rung[i] > 0)
            if qos_dirty:
                cache = dec.set_qos_knobs(cache, *qosctl.vectors())
                qos_dirty = False
        logits, cache = step(params, cache, jnp.asarray(tokens_h),
                             jnp.asarray(pos_h))
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        counts = _plan_counts(cache)
        live = [i for i in active if i not in stalled]
        frac = 0.0
        rep = _plan_replans(cache)
        if rep is not None:
            # per-(layer, slot) delta, attributed to LIVE slots only —
            # released slots never fire, and an idle slot's counter
            # must not dilute or inflate the live traffic blend.
            # Kept per slot (mean over layers): the partial re-plan
            # streams only the triggering slots' caches, so each live
            # slot is charged its own full/incremental blend
            delta = np.clip(rep - last_rep, 0.0, 1.0)
            last_rep = rep
            if live:
                frac = delta[:, live].mean(axis=0)           # (B_live,)
        if counts is not None and live:
            # count only slots holding live requests — idle slots still
            # run through the lockstep batch but serve nobody
            pb = cfg.sata.decode.blocks
            qn = sk = None
            if qosctl is not None:
                # mixed rungs: price each live slot at ITS degraded
                # budget / summary backend / re-plan mode, or the
                # reported savings overstate what degraded slots fetch
                kn = [qosctl.knobs(i) for i in live]
                pb = np.asarray([k[0] for k in kn], np.int64)
                qn = np.asarray([k[2] for k in kn], bool)
                sk = np.asarray([k[3] for k in kn], bool)
            lv = None
            if retire_on:
                # retired blocks left the ranking set — summary reads
                # and re-plan key streams price at the live count
                lv = np.asarray(
                    [max_len // blk - len(alloc.retired[i]) for i in live],
                    np.int64)
            st = decode_fetch_stats(counts[:, live], pos_h[live],
                                    k_block=blk, d=cfg.hd, replan=frac,
                                    nkb=max_len // blk,
                                    dtype_bytes=jnp.dtype(
                                        _dtype(cfg)).itemsize,
                                    summary=cfg.sata.decode.summary,
                                    replan_mode=cfg.sata.decode.replan_mode,
                                    sketch_factor=(
                                        cfg.sata.decode.sketch_factor),
                                    plan_blocks=pb, quant=qn, sketch=sk,
                                    live_blocks=lv)
            fetch_tiles_plan += st["kv_fetch_tiles_plan"]
            fetch_tiles_dense += st["kv_fetch_tiles_dense"]
            plan_bytes += st["plan_fetch_bytes_step"]
            kernel_bytes_plan += st["kv_fetch_bytes_plan"]
            kernel_bytes_dense += st["kv_fetch_bytes_dense"]
        now = time.time()
        for i in range(batch_slots):
            r = slots[i]
            if r is None:
                continue
            # watchdog clock: every step HOLDING the slot counts,
            # stalled or not — a runaway request must not sit on pool
            # pages forever just because it also stalls
            req_steps[r] = req_steps.get(r, 0) + 1
            if i not in stalled:                  # stalled: re-fed as-is
                outputs[r].append(int(nxt[i]))
                produced += 1
                pos_h[i] += 1
            done = len(outputs[r]) >= gen_len or pos_h[i] >= max_len
            expired = (max_steps_per_request is not None and not done
                       and req_steps[r] >= max_steps_per_request)
            if done or expired:
                latency[r] = now - t_claim[r]
                if expired:
                    # graceful retirement: partial output stands, pages
                    # free, the request is NOT requeued
                    timed_out.add(r)
                slots[i] = None                   # finished → free slot
                cache = dec.release_slot(cfg, cache, i)
                if alloc is not None:
                    alloc.free_slot(i)            # … and its pages
                    _clear_backoff()
            elif i not in stalled:
                tokens_h[i, 0] = int(nxt[i])
        if retire_on:
            # after the step: this step's selection is already folded
            # into the importance accumulator, and completed slots have
            # released — pool pressure (a deferral, a stall, a spike)
            # forces a sweep of every active slot, the watermark fires
            # per slot otherwise
            if _retire_pass(pressure_now or bool(stalled)):
                _push_tables()
                _clear_backoff()              # freed pages: re-check now
        steps += 1
    dt = time.time() - t0
    out: Dict[str, Any] = {
        "outputs": outputs, "tokens_generated": produced,
        "tok_per_s": produced / max(dt, 1e-9), "steps": steps,
        "request_latency_s": latency,
        "latency_mean_s": float(np.mean(list(latency.values())))
        if latency else 0.0,
        "timed_out": sorted(timed_out),
    }
    # per-request degradation timeline: every (step, rung) transition of
    # the slot while this request held it — empty means the request was
    # served at full quality end to end
    out["degradation"] = {r: list(degrade_log.get(r, [])) for r in outputs}
    if retire_on:
        # per-request retirement timelines ((step, pages_freed) per
        # pass) plus SpAtten's second cascade, report-only: per-KV-head
        # importance (the decayed accumulator summed over layers, slots
        # and blocks) — the signal a future head-pruning cascade would
        # rank on, surfaced with zero behavior change
        a = _plan_field(cache, "imp")
        head_imp = a.reshape(-1, *a.shape[-3:]).sum(axis=(0, 1, 3))
        out["retirement"] = {
            "events": retire_events,
            "pages_reclaimed": pages_reclaimed,
            "retired_tokens": retired_tokens,
            "timelines": {r: list(retire_log.get(r, [])) for r in outputs},
            "head_importance": [float(x) for x in head_imp],
            "keep_budget": retire_keep,
            "watermark": retire_mark,
        }
    if qosctl is not None:
        out["qos"] = {
            "rung_downs": qosctl.rung_downs,
            "rung_ups": qosctl.rung_ups,
            "degraded_steps": degraded_steps,
            "load_spikes": load_spikes_seen,
            "slow_steps": slow_steps_seen,
            "clear_steps": qosctl.clear_steps,
            "final_rungs": list(qosctl.rung),
        }
    if ckpt is not None:
        out["checkpoint"] = {"dir": str(checkpoint_dir),
                             "last_saved_step": last_ckpt,
                             "resumed_at": resumed_at}
    if fetch_tiles_dense:
        out["decode_fetch"] = {
            "kv_fetch_tiles_plan": fetch_tiles_plan,
            "kv_fetch_tiles_dense": fetch_tiles_dense,
            "kv_fetch_bytes_plan": fetch_tiles_plan * tile_bytes,
            "kv_fetch_bytes_dense": fetch_tiles_dense * tile_bytes,
            "fetch_reduction": fetch_tiles_dense / max(fetch_tiles_plan, 1),
            # plan-side (selection) traffic — exact full re-plans
            # stream all cached K, sketch re-plans only the surviving
            # candidate blocks, incremental steps read the summaries
            # (fp32 bounds or int8 codes+scale/zero) + planned keys;
            # true_reduction is per-backend honest because the summary
            # bytes above are sized by the configured backend
            "plan_fetch_bytes": plan_bytes,
            "summary_backend": cfg.sata.decode.summary,
            "replan_mode": cfg.sata.decode.replan_mode,
            "step_bytes_plan_route": kernel_bytes_plan + plan_bytes,
            "step_bytes_dense_route": kernel_bytes_dense,
            "true_reduction": kernel_bytes_dense
            / max(kernel_bytes_plan + plan_bytes, 1),
            "replans": rep_offset + float((last_rep - rep_base).mean()),
        }
    if alloc is not None:
        layers = int(jax.tree_util.tree_leaves(
            cache.get("kv", cache.get("shared_kv")))[0].shape[0])
        row_bytes = 2 * cfg.n_kv_heads * cfg.hd \
            * jnp.dtype(_dtype(cfg)).itemsize
        occ = alloc.stats(row_bytes=row_bytes, layers=layers)
        occ["contiguous_reserved_bytes"] = \
            batch_slots * max_len * row_bytes * layers
        occ["reserved_vs_contiguous"] = (
            occ["contiguous_reserved_bytes"]
            / max(occ["hbm_reserved_bytes"], 1))
        occ["deferred_claims"] = deferred_claims
        occ["stalled_steps"] = stalled_steps
        occ["preemptions"] = preemptions
        # fault-tolerance counters: swap preserves progress, requeue
        # discards it; crash restores everything from host swap
        occ["host_swaps"] = host_swaps
        occ["swap_restores"] = swap_restores
        occ["requeue_preemptions"] = requeue_preemptions
        occ["tokens_salvaged"] = tokens_salvaged
        occ["requeue_tokens_discarded"] = requeue_tokens_discarded
        occ["re_prefill_tokens"] = re_prefill_tokens
        occ["swap_cold_replans"] = swap_cold_replans
        occ["host_swap_bytes_peak"] = host_swap_bytes_peak
        occ["swap_restore_wall_s"] = restore_wall
        occ["crashes"] = crashes
        occ["preempt_retries_max"] = max(preempt_count.values(), default=0)
        occ["preempted_requests"] = sum(
            1 for c in preempt_count.values() if c > 0)
        occ["protected_admissions"] = protected_admissions
        occ["audits_run"] = alloc.audits_run
        occ["light_audits_run"] = alloc.light_audits_run
        occ["deferred_retries_skipped"] = deferred_retries_skipped
        # page integrity: every injected corruption must be detected at
        # the swap-in gate and quarantined (never scattered to the pool)
        occ["corrupt_pages_injected"] = corrupt_pages_injected
        occ["corrupt_pages_detected"] = corrupt_pages_detected
        occ["quarantined_pages"] = quarantined_pages
        occ["trie_nodes_invalidated"] = trie_nodes_invalidated
        out["page_occupancy"] = occ
    if pcache is not None:
        pstats = pcache.stats()
        pstats["prefill_tokens_total"] = len(latency) * prompt_len
        pstats["cow_copies"] = cow_copies
        pstats["shared_pages_peak"] = alloc.shared_pages_peak
        out["prefix_cache"] = pstats
    if prefix_index is not None:
        out["replica"] = {
            "replica_id": int(replica_id),
            "cross_replica_hits": cross_replica_hits,
            "cross_replica_hit_rate": cross_replica_hits
            / max(len(latency), 1),
            "migrated_pages": migrated_pages,
            "migrated_tokens": migrated_tokens,
            "index_pages_published": index_publishes,
            "index": prefix_index.stats(),
        }
    return out


def serve_replicated(arch: str, *, n_replicas: int = 2,
                     smoke: bool = True, seed: int = 0, cfg=None,
                     options: Optional[ServeOptions] = None,
                     resilience: Optional[ResilienceOptions] = None
                     ) -> Dict[str, Any]:
    """N-replica serve harness around one :class:`SharedPrefixIndex`.

    Each replica owns its own page pool, trie, and decode state
    (replicas run sequentially in-process — the point is the index
    protocol, not wall-clock overlap) and serves the same seeded
    workload: the situation where N frontends all carry one popular
    system prompt.  Replica 0 prefills its prefixes cold and publishes
    them; later replicas migrate those pages instead of re-running the
    shared-prefix prefill — the report aggregates the cross-replica hit
    rate and the prefill tokens the migrations saved.  Every replica's
    outputs are bitwise equal across replicas (same prompts, same
    math — migration only moves pages, never changes what they hold).
    """
    index = SharedPrefixIndex()
    opt = options or ServeOptions()
    reports = []
    for rid in range(int(n_replicas)):
        reports.append(serve(arch, smoke=smoke, seed=seed, cfg=cfg,
                             options=opt, resilience=resilience,
                             prefix_index=index, replica_id=rid))
    hits = sum(r["replica"]["cross_replica_hits"] for r in reports)
    requests = sum(len(r["outputs"]) for r in reports)
    for a, b in zip(reports, reports[1:]):
        assert a["outputs"] == b["outputs"], \
            "replicas serving the same workload must agree bitwise"
    return {
        "replicas": reports,
        "n_replicas": int(n_replicas),
        "requests": requests,
        "cross_replica_hits": hits,
        "cross_replica_hit_rate": hits / max(requests, 1),
        "migrated_pages": sum(r["replica"]["migrated_pages"]
                              for r in reports),
        "migrated_tokens": sum(r["replica"]["migrated_tokens"]
                               for r in reports),
        "prefill_tokens_saved": sum(
            r.get("prefix_cache", {}).get("prefill_tokens_saved", 0)
            for r in reports),
        "outputs_equal": True,
        "index": index.stats(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=1)
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged KV pool")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix page cache (implies --paged)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="prompts share their first N tokens")
    ap.add_argument("--faults-seed", type=int, default=None,
                    help="inject a seeded FaultPlan schedule "
                         "(implies --paged)")
    ap.add_argument("--max-steps-per-request", type=int, default=None,
                    help="deadline watchdog: retire a slot as timed_out "
                         "after N held steps")
    ap.add_argument("--replicas", type=int, default=0,
                    help="run N serve replicas around one shared prefix "
                         "index (implies --paged --prefix-cache)")
    args = ap.parse_args()
    cfg = (SMOKE if args.smoke else ARCHS)[args.arch]
    if args.replicas:
        args.paged = args.prefix_cache = True
    if args.paged or args.prefix_cache or args.faults_seed is not None:
        from repro.models.config import KVCacheConfig
        cfg = dataclasses.replace(
            cfg, kv=KVCacheConfig(layout="paged",
                                  prefix_cache=args.prefix_cache))
    faults = None
    if args.faults_seed is not None:
        faults = FaultPlan.seeded(args.faults_seed,
                                  steps=args.requests * args.gen_len,
                                  slots=args.slots)
        print(f"[serve] fault schedule (seed {args.faults_seed}):")
        print(faults.describe())
    opts = ServeOptions(n_requests=args.requests, batch_slots=args.slots,
                        gen_len=args.gen_len, prompt_len=args.prompt_len,
                        shared_prefix_len=args.shared_prefix_len)
    res = ResilienceOptions(
        max_steps_per_request=args.max_steps_per_request)
    if args.replicas:
        rep = serve_replicated(args.arch, n_replicas=args.replicas,
                               smoke=args.smoke, cfg=cfg, options=opts,
                               resilience=res)
        print(f"[serve] {rep['n_replicas']} replicas, "
              f"{rep['requests']} requests: cross-replica hit rate "
              f"{rep['cross_replica_hit_rate']:.2f} "
              f"({rep['cross_replica_hits']} migrations, "
              f"{rep['migrated_pages']} pages / "
              f"{rep['migrated_tokens']} tokens migrated), prefill "
              f"tokens saved {rep['prefill_tokens_saved']}, "
              f"outputs_equal={rep['outputs_equal']}")
        return
    out = serve(args.arch, smoke=args.smoke, cfg=cfg, options=opts,
                faults=faults, resilience=res)
    print(f"[serve] generated {out['tokens_generated']} tokens over "
          f"{len(out['outputs'])} requests "
          f"({out['tok_per_s']:.1f} tok/s on CPU, "
          f"mean request latency {out['latency_mean_s'] * 1e3:.1f} ms)")
    if "decode_fetch" in out:
        f = out["decode_fetch"]
        print(f"[serve] SATA decode attention-kernel KV fetch: "
              f"{f['kv_fetch_bytes_plan']} B vs "
              f"{f['kv_fetch_bytes_dense']} B dense "
              f"({f['fetch_reduction']:.2f}x kernel-side); with plan "
              f"traffic ({f['plan_fetch_bytes']} B, "
              f"{f['replans']:.0f} re-plans): {f['true_reduction']:.2f}x "
              f"end-to-end")
    if "page_occupancy" in out:
        o = out["page_occupancy"]
        print(f"[serve] paged pool: {o['pages_in_use_peak']}/{o['n_pages']}"
              f" pages peak, HBM used {o['hbm_used_peak_bytes']} B of "
              f"{o['hbm_reserved_bytes']} B reserved "
              f"({o['reserved_vs_contiguous']:.2f}x less reserved than "
              f"contiguous would need; {o['deferred_claims']} deferred "
              f"claims, {o['stalled_steps']} stalled steps)")
        if o["preemptions"] or o["crashes"]:
            print(f"[serve] fault tolerance: {o['host_swaps']} host-swaps "
                  f"({o['tokens_salvaged']} tokens salvaged, "
                  f"{o['swap_restores']} restores, re_prefill_tokens="
                  f"{o['re_prefill_tokens']}, cold_replans="
                  f"{o['swap_cold_replans']}), "
                  f"{o['requeue_preemptions']} requeues "
                  f"({o['requeue_tokens_discarded']} tokens discarded), "
                  f"{o['crashes']} crashes recovered, "
                  f"{o['audits_run']} invariant audits")
    if "prefix_cache" in out:
        p = out["prefix_cache"]
        print(f"[serve] prefix cache: hit-rate {p['hit_rate']:.2f} "
              f"({p['hits']}/{p['requests']}), prefill tokens saved "
              f"{p['prefill_tokens_saved']}/{p['prefill_tokens_total']}, "
              f"{p['cow_copies']} CoW copies, {p['cached_pages']} cached "
              f"pages ({p['evictions']} evicted), shared-page peak "
              f"{p['shared_pages_peak']}")


if __name__ == "__main__":
    main()
