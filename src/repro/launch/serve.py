"""Serving driver: batched request decoding with top-k selective
attention over a KV cache (continuous-batching-lite: fixed batch slots,
per-slot positions, new requests claim finished slots).

Usage (CPU, reduced arch):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --requests 8 --gen-len 16
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCHS, SMOKE
from repro.distributed import ctx as dctx
from repro.launch.mesh import make_local_mesh
from repro.models import decode as dec
from repro.models import model as mdl
from repro.train.step import make_serve_step


def serve(arch: str, smoke: bool = True, n_requests: int = 8,
          batch_slots: int = 4, gen_len: int = 16, max_len: int = 64,
          seed: int = 0, mesh=None, params=None) -> Dict[str, Any]:
    cfg = (SMOKE if smoke else ARCHS)[arch]
    mesh = mesh or make_local_mesh()
    if params is None:
        params = mdl.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)

    cache = dec.init_cache(cfg, batch_slots, max_len)
    if cfg.family in ("vlm", "audio"):
        batch_ctx = {}
        if cfg.family == "vlm":
            batch_ctx["image_embeds"] = jnp.asarray(rng.standard_normal(
                (batch_slots, cfg.n_image_tokens, cfg.d_model)), jnp.float32)
        else:
            batch_ctx["audio_embeds"] = jnp.asarray(rng.standard_normal(
                (batch_slots, cfg.encoder_len, cfg.d_model)), jnp.float32)
        cache = dec.prefill_context(params, cfg, cache, batch_ctx)

    step = jax.jit(lambda p, c, t, pos: dec.serve_step(p, cfg, c, t, pos))

    queue: List[int] = list(range(n_requests))
    outputs: Dict[int, List[int]] = {}
    slots = [None] * batch_slots                  # request id per slot
    produced = 0
    t0 = time.time()
    pos = 0
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch_slots, 1)),
                         jnp.int32)
    while (queue or any(s is not None for s in slots)) and pos < max_len:
        for i in range(batch_slots):              # claim free slots
            if slots[i] is None and queue:
                slots[i] = queue.pop(0)
                outputs[slots[i]] = []
        logits, cache = step(params, cache, tokens, jnp.int32(pos))
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        for i in range(batch_slots):
            if slots[i] is None:
                continue
            outputs[slots[i]].append(int(nxt[i]))
            produced += 1
            if len(outputs[slots[i]]) >= gen_len:
                slots[i] = None                   # finished → free the slot
        tokens = nxt[:, None]
        pos += 1
    dt = time.time() - t0
    return {"outputs": outputs, "tokens_generated": produced,
            "tok_per_s": produced / max(dt, 1e-9), "steps": pos}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    out = serve(args.arch, smoke=args.smoke, n_requests=args.requests,
                batch_slots=args.slots, gen_len=args.gen_len)
    print(f"[serve] generated {out['tokens_generated']} tokens over "
          f"{len(out['outputs'])} requests "
          f"({out['tok_per_s']:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
