"""Serving driver: batched request decoding with top-k selective
attention over a KV cache (continuous-batching-lite: fixed batch slots,
**per-slot positions**, new requests claim finished slots).

Each slot owns its decode position and its cache region: claiming a
slot resets both (``models.decode.reset_slot``), so a request never
inherits the previous occupant's KV contents — and requests of
different lengths decode concurrently at their own offsets.  Latency is
reported per request (claim → last token), not just aggregate tok/s.

With ``cfg.sata_decode`` routing on, every step fetches only the
planned KV blocks (``core/decode_plan.py`` + the decode gather kernel)
and the driver accumulates the fetch-byte savings against dense decode.

Usage (CPU, reduced arch):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --requests 8 --gen-len 16
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCHS, SMOKE
from repro.distributed import ctx as dctx
from repro.launch.mesh import make_local_mesh
from repro.models import decode as dec
from repro.models import model as mdl
from repro.train.step import make_serve_step


def _plan_counts(cache: Dict) -> Optional[np.ndarray]:
    """Layer-stacked (..., B, KV) plan occupancy, if SATA decode is on
    (hybrid keeps its attention cache under ``shared_kv``)."""
    for name in ("kv", "shared_kv"):
        kvc = cache.get(name)
        if isinstance(kvc, dict) and "plan" in kvc:
            cnt = np.asarray(kvc["plan"]["kv_counts"])
            return cnt.reshape(-1, *cnt.shape[-2:])      # (L, B, KV)
    return None


def serve(arch: str, smoke: bool = True, n_requests: int = 8,
          batch_slots: int = 4, gen_len: int = 16, max_len: int = 64,
          seed: int = 0, mesh=None, params=None,
          cfg=None) -> Dict[str, Any]:
    cfg = cfg or (SMOKE if smoke else ARCHS)[arch]
    mesh = mesh or make_local_mesh()
    if params is None:
        params = mdl.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)

    cache = dec.init_cache(cfg, batch_slots, max_len)
    if cfg.family in ("vlm", "audio"):
        batch_ctx = {}
        if cfg.family == "vlm":
            batch_ctx["image_embeds"] = jnp.asarray(rng.standard_normal(
                (batch_slots, cfg.n_image_tokens, cfg.d_model)), jnp.float32)
        else:
            batch_ctx["audio_embeds"] = jnp.asarray(rng.standard_normal(
                (batch_slots, cfg.encoder_len, cfg.d_model)), jnp.float32)
        cache = dec.prefill_context(params, cfg, cache, batch_ctx)

    step = jax.jit(lambda p, c, t, pos: dec.serve_step(p, cfg, c, t, pos))

    # one deterministic prompt token per request: a request's output
    # depends only on its own prompt, never on which slot served it
    prompts = rng.integers(0, cfg.vocab_size, n_requests)
    queue: List[int] = list(range(n_requests))
    outputs: Dict[int, List[int]] = {}
    latency: Dict[int, float] = {}
    t_claim: Dict[int, float] = {}
    slots: List[Optional[int]] = [None] * batch_slots
    pos_h = np.zeros(batch_slots, np.int32)       # per-slot positions
    tokens_h = np.zeros((batch_slots, 1), np.int32)
    produced = 0
    steps = 0
    fetch_tiles_plan = fetch_tiles_dense = 0
    from repro.kernels.ops import decode_fetch_stats
    from repro.models.attention import decode_block_size
    from repro.models.layers import _dtype
    blk = decode_block_size(cfg, max_len)
    tile_bytes = 2 * blk * cfg.hd * jnp.dtype(_dtype(cfg)).itemsize
    # warm the jit trace before any latency clock starts — every slot a
    # request claims is reset first, so the warm-up's cache writes never
    # reach an output
    logits, cache = step(params, cache, jnp.asarray(tokens_h),
                         jnp.asarray(pos_h))
    jax.block_until_ready(logits)
    t0 = time.time()
    max_steps = n_requests * gen_len + batch_slots + 1
    while (queue or any(s is not None for s in slots)) and steps < max_steps:
        for i in range(batch_slots):              # claim free slots
            if slots[i] is None and queue:
                r = queue.pop(0)
                slots[i] = r
                outputs[r] = []
                cache = dec.reset_slot(cfg, cache, i)
                pos_h[i] = 0
                tokens_h[i, 0] = int(prompts[r])
                t_claim[r] = time.time()
        logits, cache = step(params, cache, jnp.asarray(tokens_h),
                             jnp.asarray(pos_h))
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        counts = _plan_counts(cache)
        active = [i for i in range(batch_slots) if slots[i] is not None]
        if counts is not None and active:
            # count only slots holding live requests — idle slots still
            # run through the lockstep batch but serve nobody
            st = decode_fetch_stats(counts[:, active], pos_h[active],
                                    k_block=blk, d=cfg.hd)
            fetch_tiles_plan += st["kv_fetch_tiles_plan"]
            fetch_tiles_dense += st["kv_fetch_tiles_dense"]
        now = time.time()
        for i in range(batch_slots):
            r = slots[i]
            if r is None:
                continue
            outputs[r].append(int(nxt[i]))
            produced += 1
            pos_h[i] += 1
            if len(outputs[r]) >= gen_len or pos_h[i] >= max_len:
                latency[r] = now - t_claim[r]
                slots[i] = None                   # finished → free the slot
            else:
                tokens_h[i, 0] = int(nxt[i])
        steps += 1
    dt = time.time() - t0
    out: Dict[str, Any] = {
        "outputs": outputs, "tokens_generated": produced,
        "tok_per_s": produced / max(dt, 1e-9), "steps": steps,
        "request_latency_s": latency,
        "latency_mean_s": float(np.mean(list(latency.values())))
        if latency else 0.0,
    }
    if fetch_tiles_dense:
        out["decode_fetch"] = {
            "kv_fetch_tiles_plan": fetch_tiles_plan,
            "kv_fetch_tiles_dense": fetch_tiles_dense,
            "kv_fetch_bytes_plan": fetch_tiles_plan * tile_bytes,
            "kv_fetch_bytes_dense": fetch_tiles_dense * tile_bytes,
            "fetch_reduction": fetch_tiles_dense / max(fetch_tiles_plan, 1),
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    out = serve(args.arch, smoke=args.smoke, n_requests=args.requests,
                batch_slots=args.slots, gen_len=args.gen_len)
    print(f"[serve] generated {out['tokens_generated']} tokens over "
          f"{len(out['outputs'])} requests "
          f"({out['tok_per_s']:.1f} tok/s on CPU, "
          f"mean request latency {out['latency_mean_s'] * 1e3:.1f} ms)")
    if "decode_fetch" in out:
        f = out["decode_fetch"]
        print(f"[serve] SATA decode attention-kernel KV fetch: "
              f"{f['kv_fetch_bytes_plan']} B vs "
              f"{f['kv_fetch_bytes_dense']} B dense "
              f"({f['fetch_reduction']:.2f}x; selection-side reads scale "
              f"with sata_decode_replan — see ops.decode_fetch_stats)")


if __name__ == "__main__":
    main()
