import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis (deliverable g): three terms per (arch × shape) on
the single-pod mesh, with the dominant bottleneck identified.

    compute     = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
    memory      = HLO_bytes / (chips × 819 GB/s HBM)
    collective  = collective_bytes / (chips × 50 GB/s ICI link)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes and the
partitioned-HLO text for collective operand bytes — with a critical
correction: XLA's cost analysis counts a ``while`` body ONCE, so a
95-layer scanned model reports ~1 layer of work.  We therefore compile
each cell at 1-unit and 2-unit depth (unit = the scan period: 1 layer,
or one hybrid/VLM group), take per-unit deltas, and extrapolate
``total = fixed + n_units × per_unit``.  All counters from the SPMD
module are per-device, so terms divide by per-chip peaks directly.

MODEL_FLOPS = 6·N·tokens (train) / 2·N_active·tokens (inference); the
ratio MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is
"useful" (catches remat recompute + attention/selection overhead).

Usage:
  python -m repro.launch.roofline --arch olmo-1b --shape train_4k
  python -m repro.launch.roofline --all
  python -m repro.launch.roofline --table   # print markdown from cache
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import traceback

PEAK_FLOPS = 197e12          # bf16 per chip (v5e)
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "roofline"


def _units(cfg):
    """(unit size in layers, n_units, cfg builder for k units).

    Probe configs UNROLL all layer loops (``scan_layers=False``), run a
    single microbatch and a single attention query chunk — XLA's cost
    analysis counts a while body once regardless of trip count, so any
    loop left in the probe would silently undercount."""
    probe = dict(scan_layers=False, micro_steps=1, q_chunk=1 << 30)
    if cfg.family == "hybrid":
        u = cfg.hybrid_period
        build = lambda k: dataclasses.replace(cfg, n_layers=u * k, **probe)
    elif cfg.family == "vlm":
        u = cfg.cross_attn_period
        build = lambda k: dataclasses.replace(cfg, n_layers=u * k, **probe)
    elif cfg.family == "audio":
        u = 1
        build = lambda k: dataclasses.replace(cfg, n_layers=k,
                                              encoder_layers=k, **probe)
    else:
        u = 1
        build = lambda k: dataclasses.replace(cfg, n_layers=k, **probe)
    return u, cfg.n_layers // u, build


def _measure(arch, shape_name, cfg, cp=True):
    from repro.launch.dryrun import run_cell
    r = run_cell(arch, shape_name, multi_pod=False, save=False,
                 verbose=False, cfg=cfg, tag_suffix="__probe", cp=cp)
    flops = r["cost"].get("flops", 0.0)
    byts = r["cost"].get("bytes accessed", 0.0)
    coll = r["collectives"].get("total_bytes", 0.0)
    return flops, byts, coll, r


def analyse_cell(arch: str, shape_name: str, verbose: bool = True,
                 cp: bool = True, tag_suffix: str = ""):
    import jax
    from repro.configs.archs import ARCHS, SHAPES
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    unit, n_units, build = _units(cfg)

    f1, b1, c1, r1 = _measure(arch, shape_name, build(1), cp=cp)
    f2, b2, c2, r2 = _measure(arch, shape_name, build(2), cp=cp)
    pf = max(f2 - f1, 0.0)
    pb = max(b2 - b1, 0.0)
    pc = max(c2 - c1, 0.0)
    flops = max(f1 - pf, 0.0) + n_units * pf
    byts = max(b1 - pb, 0.0) + n_units * pb
    coll = max(c1 - pc, 0.0) + n_units * pc

    if cfg.rwkv and shape.kind != "decode":
        # the time recurrence stays a lax.scan even in probes (unrolling
        # 4k+ steps is infeasible) — add its per-step einsum flops
        # analytically: ~5·hd² MACs per head per step, ×3 for backward.
        b_loc = max(shape.global_batch // 16, 1)     # per-device batch
        h = cfg.d_model // cfg.rwkv_head_dim
        per_step = 5 * 2 * cfg.rwkv_head_dim ** 2 * h * b_loc
        mult = 3.0 if shape.kind == "train" else 1.0
        flops += cfg.n_layers * shape.seq_len * per_step * mult

    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    n_active = cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    chips = 256
    hlo_flops_global = flops * chips
    useful = model_flops / max(hlo_flops_global, 1.0)

    bound_note = {
        "compute_s": "scale sparsity/selective compute or raise per-chip "
                     "utilization (bigger MXU tiles, fewer remat passes)",
        "memory_s": "cut HBM traffic: fuse softmax/top-k, keep operands "
                    "in VMEM longer, or quantize the bandwidth-bound side",
        "collective_s": "reshard to shrink the gathered dim, overlap the "
                        "collective behind per-layer compute, or move the "
                        "axis with less traffic onto the slower links",
    }[dominant]

    out = {
        "cell": f"{arch}__{shape_name}__pod1{tag_suffix}",
        "arch": arch, "shape": shape_name,
        "per_device": {"hlo_flops": flops, "hlo_bytes": byts,
                       "collective_bytes": coll},
        "terms_s": terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": useful,
        "roofline_fraction": t_compute / max(max(terms.values()), 1e-30),
        "note": bound_note,
        "probe": {"unit_layers": unit, "n_units": n_units,
                  "f1": f1, "f2": f2, "c1": c1, "c2": c2, "b1": b1, "b2": b2},
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{out['cell']}.json").write_text(json.dumps(out, indent=1))
    if verbose:
        print(f"[roofline] {out['cell']}: compute {t_compute*1e3:.2f}ms "
              f"memory {t_memory*1e3:.2f}ms coll {t_coll*1e3:.2f}ms "
              f"→ {out['dominant']}-bound, useful {useful:.2f}, "
              f"roofline frac {out['roofline_fraction']:.2f}", flush=True)
    return out


def analyse_kernel(seq: int = 2048, d: int = 64, bh: int = 8,
                   block: int = 128, occ_frac: float = 0.5,
                   verbose: bool = True):
    """Roofline the SATA kernel's two schedules against each other.

    The dense grid's HBM term streams every K/V tile; the compacted grid
    streams only occupied tiles (``kernel_fetch_stats`` counts both).
    Compute is identical across schedules *per visited tile* — the dense
    grid visits empty tiles but ``@pl.when`` gates their math, so its
    compute term only pays the occupied MACs too; the gap is pure
    memory/scheduling.  Writes one
    ``results/roofline/sata_kernel__s{seq}_b{block}_occ{frac}.json``
    per call.
    """
    import numpy as np
    from repro.core.blockmap import fixed_occupancy_map
    from repro.kernels.ops import kernel_fetch_stats

    nqb = nkb = seq // block
    occ = max(1, int(occ_frac * nkb))
    bm = fixed_occupancy_map(np.random.default_rng(0), bh, nqb, nkb, occ)
    stats = kernel_fetch_stats(bm, q_block=block, k_block=block, d=d,
                               dtype_bytes=2, max_kv_blocks=occ)
    # per occupied tile: QK^T + PV → 2 · (block·block·d) MACs → 4·b²·d flops
    flops_per_tile = 4 * block * block * d
    occupied = int(bm.sum())
    t_compute = occupied * flops_per_tile / PEAK_FLOPS
    q_bytes = bh * nqb * block * d * 2            # one Q tile per row
    t_mem_dense = (stats["kv_fetch_bytes_dense"] + q_bytes) / HBM_BW
    t_mem_compact = (stats["kv_fetch_bytes_compact"] + q_bytes) / HBM_BW
    out = {
        "cell": f"sata_kernel__s{seq}_b{block}_occ{occ_frac}",
        "shape": {"bh": bh, "seq": seq, "d": d, "block": block,
                  "occ_frac": occ_frac},
        "fetch": stats,
        "terms_s": {
            "compute_s": t_compute,
            "memory_dense_s": t_mem_dense,
            "memory_compact_s": t_mem_compact,
        },
        "bound_dense": ("memory" if t_mem_dense > t_compute else "compute"),
        "bound_compact": ("memory" if t_mem_compact > t_compute
                          else "compute"),
        "modeled_speedup": (max(t_mem_dense, t_compute)
                            / max(t_mem_compact, t_compute)),
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{out['cell']}.json").write_text(json.dumps(out, indent=1))
    if verbose:
        print(f"[roofline] {out['cell']}: compute {t_compute*1e6:.1f}us, "
              f"mem dense {t_mem_dense*1e6:.1f}us → compact "
              f"{t_mem_compact*1e6:.1f}us "
              f"(fetch {stats['fetch_reduction']:.2f}x down, modeled "
              f"speedup {out['modeled_speedup']:.2f}x, "
              f"{out['bound_dense']}→{out['bound_compact']}-bound)",
              flush=True)
    return out


def print_table():
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        r = json.loads(p.read_text())
        if "dominant" not in r:
            continue        # kernel-schedule cells (--kernel) have their
            # own shape; they print at generation time, not in this table
        rows.append(r)
    print("| cell | compute (ms) | memory (ms) | collective (ms) | "
          "bound | MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        t = r["terms_s"]
        print(f"| {r['cell']} | {t['compute_s']*1e3:.2f} | "
              f"{t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} | "
              f"{r['dominant']} | {r['useful_ratio']:.2f} | "
              f"{r['roofline_fraction']:.2f} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--table", action="store_true")
    ap.add_argument("--kernel", action="store_true",
                    help="roofline the SATA kernel schedules (dense vs "
                         "compacted grid: time terms + fetch bytes)")
    args = ap.parse_args()
    if args.table:
        print_table()
        return
    if args.kernel:
        for occ in (0.25, 0.5, 0.75):
            analyse_kernel(occ_frac=occ)
        return
    from repro.configs.archs import all_cells
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    fails = []
    for arch, shape in cells:
        tag = f"{arch}__{shape}__pod1"
        if args.skip_done and (RESULTS / f"{tag}.json").exists():
            print(f"[roofline] {tag}: cached", flush=True)
            continue
        try:
            analyse_cell(arch, shape)
        except Exception as e:
            fails.append(tag)
            print(f"[roofline] {tag}: FAIL {e}", flush=True)
            traceback.print_exc()
    if fails:
        print(f"[roofline] failures: {fails}")
        sys.exit(1)


if __name__ == "__main__":
    main()
