"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation (dry-run deliverable)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.archs import ShapeSpec
from repro.models import decode as dec
from repro.models import model as mdl
from repro.models.config import ModelConfig
from repro.optim.adamw import OptConfig


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs_for(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Training/prefill batch inputs."""
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, s), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = sds((b, s), jnp.int32)
    if cfg.family == "vlm":
        batch["image_embeds"] = sds((b, cfg.n_image_tokens, cfg.d_model),
                                    jnp.float32)
    if cfg.family == "audio":
        batch["audio_embeds"] = sds((b, cfg.encoder_len, cfg.d_model),
                                    jnp.float32)
    return batch


def decode_specs_for(cfg: ModelConfig, shape: ShapeSpec
                     ) -> Tuple[Dict, Any, Any]:
    """(cache, tokens, pos) stand-ins for serve_step."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: dec.init_cache(cfg, b, s))
    tokens = sds((b, 1), jnp.int32)
    pos = sds((), jnp.int32)
    return cache, tokens, pos


def state_specs_for(cfg: ModelConfig, opt: OptConfig) -> Dict[str, Any]:
    """Abstract train state (params + Adam moments) — no allocation."""
    key = jax.random.PRNGKey(0)           # never materialized under eval_shape
    params = jax.eval_shape(lambda k: mdl.init_params(k, cfg), key)
    state = {"params": params,
             "opt": {"m": jax.tree.map(
                         lambda p: sds(p.shape, jnp.float32), params),
                     "v": jax.tree.map(
                         lambda p: sds(p.shape, jnp.float32), params),
                     "step": sds((), jnp.int32)}}
    return state
