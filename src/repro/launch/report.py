"""Generate EXPERIMENTS.md tables from results/{dryrun,roofline}/*.json.

Usage:  PYTHONPATH=src python -m repro.launch.report [dryrun|roofline]
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[3] / "results"


def dryrun_table() -> str:
    rows = []
    for p in sorted((ROOT / "dryrun").glob("*.json")):
        if "__probe" in p.name:
            continue
        r = json.loads(p.read_text())
        if not r.get("ok"):
            rows.append((r["cell"], "FAIL", "", "", "", ""))
            continue
        mem = r.get("memory", {})
        temp = mem.get("temp_size_in_bytes", 0) / 2 ** 30
        args = mem.get("argument_size_in_bytes", 0) / 2 ** 30
        flops = r.get("cost", {}).get("flops", 0)
        coll = r.get("collectives", {}).get("total_bytes", 0)
        per_kind = r.get("collectives", {}).get("per_kind", {})
        kinds = " ".join(f"{k.split('-')[-1][:4]}:{v:.2g}"
                         for k, v in sorted(per_kind.items()))
        rows.append((r["cell"], f"{r['compile_s']:.0f}s",
                     f"{args:.2f}", f"{temp:.2f}",
                     f"{flops:.3g}", kinds or f"{coll:.3g}"))
    out = ["| cell | compile | args GiB/dev | temp GiB/dev | "
           "HLO flops/dev* | collectives (B/dev*) |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    out.append("")
    out.append("\\* while-loop bodies counted once by XLA — see §Roofline "
               "for loop-corrected totals.")
    return "\n".join(out)


def roofline_table() -> str:
    rows = []
    for p in sorted((ROOT / "roofline").glob("*.json")):
        r = json.loads(p.read_text())
        t = r["terms_s"]
        rows.append((r["cell"].replace("__pod1", ""),
                     f"{t['compute_s']*1e3:.1f}",
                     f"{t['memory_s']*1e3:.1f}",
                     f"{t['collective_s']*1e3:.1f}",
                     r["dominant"],
                     f"{r['useful_ratio']:.2f}",
                     f"{r['roofline_fraction']:.3f}",
                     r["note"][:60] + "…"))
    out = ["| arch × shape | compute ms | memory ms | collective ms | "
           "bound | 6ND/HLO | roofline frac | to improve |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append("| " + " | ".join(r) + " |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "both"
    if which in ("dryrun", "both"):
        print("### Dry-run\n")
        print(dryrun_table())
    if which in ("roofline", "both"):
        print("\n### Roofline\n")
        print(roofline_table())
