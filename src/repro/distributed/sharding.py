"""Logical sharding rules: param/optimizer/activation PartitionSpecs.

Scheme (single pod: mesh (data=16, model=16); multi-pod adds "pod"):
  * FSDP: the d_model-sized dim of every weight shards over the data
    axes (ZeRO-3-style; XLA all-gathers weights around their use and
    reduce-scatters grads).
  * TP: heads / d_ff / vocab shard over "model".
  * MoE: experts over "model" (``expert_shard="expert"``) or d_ff over
    "model" with experts replicated (``"tensor"``, for E < mesh model
    size, e.g. grok-1's 8 experts).
  * Optimizer moments shard exactly like their params.
Specs are resolved per-leaf by parameter name; stacked layer dims
(leading scan axes) are unsharded.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _base_spec(path: Tuple[str, ...], ndim_tail: int, cfg: ModelConfig,
               dp, dp_orig=None) -> Tuple:
    """Spec for the logical (unstacked) trailing dims of a leaf.
    ``dp`` is None in infer_tp mode (weights not FSDP-sharded);
    ``dp_orig`` keeps the data axes for the MoE expert exception."""
    name = path[-1]
    in_moe = "moe" in path

    # MoE experts keep their train sharding in every mode (E or d_ff over
    # model, d_model over data): per-device slab ~2 GB, and the expert
    # matmuls' partial-sum all-reduces are cheaper than the alternatives
    # (F-sharded infer experts measured WORSE — §Perf iteration 6).
    eff_dp = dp if dp is not None else dp_orig
    if in_moe and name in ("wi", "wg"):          # (E, D, F)
        return ((("model",), (eff_dp,), (None,))
                if cfg.expert_shard == "expert"
                else ((None,), (eff_dp,), ("model",)))
    if in_moe and name == "wo":                  # (E, F, D)
        return ((("model",), (None,), (eff_dp,))
                if cfg.expert_shard == "expert"
                else ((None,), ("model",), (eff_dp,)))
    if in_moe and name == "router":              # (D, E)
        return ((dp,), (None,))

    table = {
        # in-projections (D, X): FSDP on D, TP on X
        "wq": "in", "wk": "in", "wv": "in", "wi": "in", "wg": "in",
        "wr": "in", "ck": "in", "cr": "in", "in_proj": "in",
        "shared_in": "in", "wa": "in_rep",
        # out-projections (X, D): TP on X, FSDP on D
        "wo": "out", "cv": "out", "out_proj": "out", "wb": "out_rep",
    }
    kind = table.get(name)
    if kind == "in":
        return ((dp,), ("model",))
    if kind == "out":
        return (("model",), (dp,))
    if kind == "in_rep":
        return ((dp,), (None,))
    if kind == "out_rep":
        return ((None,), (dp,))
    if name == "embedding":
        return (("model",), (dp,))
    if name == "unembed":
        return ((dp,), ("model",))
    if name == "conv_w":                         # (K, C)
        return ((None,), ("model",))
    if name == "bonus_u" and ndim_tail == 2:     # (H, hd)
        return (("model",), (None,))
    return tuple((None,) for _ in range(ndim_tail))


def _flatten(spec) -> Tuple:
    out = []
    for s in spec:
        if isinstance(s, tuple):
            s = s[0]
        out.append(s)
    return tuple(out)


def param_specs(param_shapes: Any, cfg: ModelConfig, mesh: Mesh,
                mode: str = "train") -> Any:
    """PartitionSpec tree matching an (abstract) param tree.

    mode="train": FSDP(+TP) — d_model dims shard over the data axes;
    XLA all-gathers weights around use (amortized over 4k-token steps).
    mode="infer_tp": TP-only — weights replicated over data, sharded over
    "model" only.  Decode steps touch every weight once per token, so
    FSDP's per-layer weight all-gather dominates decode collectives;
    TP-only eliminates it (used when the bf16 weights fit per device)."""
    dp_orig = ("pod", "data") if "pod" in mesh.axis_names else "data"
    dp = None if mode == "infer_tp" else dp_orig

    def spec_for(path, leaf):
        names = tuple(str(getattr(p, "key", getattr(p, "name", p)))
                      for p in path)
        ndim = len(leaf.shape)
        if ndim == 0:
            return P()
        # how many leading stack dims? infer: known 2D/3D logical shapes
        name = names[-1]
        moe3 = ("moe" in names and name in ("wi", "wg", "wo"))
        tail = 3 if moe3 else (2 if ndim >= 2 else 1)
        tail = min(tail, ndim)
        base = _base_spec(names, tail, cfg, dp, dp_orig=dp_orig)
        base = _flatten(base)[:tail]
        if ndim == 1 and name not in ():
            base = (None,)
        lead = (None,) * (ndim - len(base))
        spec = lead + tuple(base)
        # drop shardings that do not divide the dim (tiny smoke shapes)
        fixed = []
        for size, ax in zip(leaf.shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            n_shards = int(np.prod([mesh.shape[a] for a in
                                    (ax if isinstance(ax, tuple) else (ax,))]))
            fixed.append(ax if size % n_shards == 0 else None)
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(spec_for, param_shapes)


def batch_specs(batch_shapes: Any, mesh: Mesh) -> Any:
    """Inputs: batch dim over data axes, everything else replicated.
    Batches smaller than the data axes (long_500k's batch=1) replicate."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    dp_size = int(np.prod([mesh.shape[a] for a in
                           (dp if isinstance(dp, tuple) else (dp,))]))

    def spec_for(path, leaf):
        if len(leaf.shape) == 0:
            return P()
        lead = dp if leaf.shape[0] % dp_size == 0 else None
        return P(lead, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch_shapes)


CACHE_SEQ_SHARD = True     # shard KV caches over the sequence dim (context-
                           # parallel decode; pairs with bisect top-k).
                           # False = legacy kv-head/head-dim sharding.


def cache_specs(cache_shapes: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """KV/state caches.  Default: (L, B, S, KV, hd) with batch over data
    and the SEQUENCE dim over model (context-parallel decode: QK/AV are
    row-parallel; softmax/top-k reduce with tiny all-reduces).  Legacy
    mode shards kv-heads (or head_dim when kv doesn't divide)."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    model_size = mesh.shape["model"]

    def spec_for(path, leaf):
        shape = leaf.shape
        ndim = len(shape)
        if ndim == 0:
            return P()
        names = tuple(str(getattr(p, "key", getattr(p, "name", p)))
                      for p in path)
        # find the batch dim: first dim equal to a plausible batch is
        # ambiguous; by construction caches are stacked (L..., B, ...).
        name = names[0] if names else ""
        spec = [None] * ndim
        if name in ("kv", "cross_kv", "shared_kv"):
            # (..., B, S, KV, hd)
            spec[-4] = dp
            if CACHE_SEQ_SHARD and shape[-3] % model_size == 0:
                spec[-3] = "model"
            elif shape[-2] % model_size == 0:
                spec[-2] = "model"
            elif shape[-1] % model_size == 0:
                spec[-1] = "model"
        elif name == "mamba":
            # ssm: (L, B, H, N, P); conv: (L, B, K, C)
            spec[1] = dp
            if shape[2] % model_size == 0:
                spec[2] = "model"
            elif shape[-1] % model_size == 0:
                spec[-1] = "model"
        elif name == "rwkv":
            # state (L,B,H,hd,hd); tm_x/cm_x (L,B,D)
            spec[1] = dp
            if ndim >= 3 and shape[2] % model_size == 0:
                spec[2] = "model"
        elif name == "x0":
            spec[0] = dp
        # sanity: drop non-dividing shardings
        fixed = []
        for size, ax in zip(shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            n_shards = int(np.prod([mesh.shape[a] for a in
                                    (ax if isinstance(ax, tuple) else (ax,))]))
            fixed.append(ax if size % n_shards == 0 else None)
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shapes)


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
