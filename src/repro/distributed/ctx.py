"""Activation-sharding context: launchers install NamedShardings here;
model code calls ``constrain(x, kind)`` which is a no-op when unset.

Keeps the model definitions distribution-agnostic while pinning the
GSPMD propagation to batch-sharded activations (without this, FSDP
weight specs win propagation and activations shard d_model over the
data axis → per-device score/logit tensors keep the full batch)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax

_SPECS: Dict[str, Any] = {}
_MESH = None          # (mesh, dp_axes) when a launcher installed one


def set_activation_shardings(specs: Dict[str, Any], mesh=None) -> None:
    global _SPECS, _MESH
    _SPECS = dict(specs)
    if mesh is not None:
        dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
        _MESH = (mesh, dp)


def clear() -> None:
    global _SPECS, _MESH
    _SPECS = {}
    _MESH = None


def mesh_installed() -> bool:
    """True when a launcher has installed a multi-axis mesh — paths
    without an SPMD partitioning rule (e.g. pallas_call) must bail."""
    return _MESH is not None


def constrain(x: jax.Array, kind: str) -> jax.Array:
    s = _SPECS.get(kind)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


def constrain_scores(scores: jax.Array) -> jax.Array:
    """Attention scores (B, KV, G, Q, S): batch over data; put the model
    axis on the first of {KV, G, S} that divides (per-arch fallback —
    e.g. phi4's 24 heads don't split 16-way, so its keys dim shards and
    softmax reduces with a small all-reduce)."""
    if _MESH is None:
        return scores
    mesh, dp = _MESH
    from jax.sharding import NamedSharding, PartitionSpec as P
    m = mesh.shape["model"]
    b, kv, g, q, s = scores.shape
    spec = [dp, None, None, None, None]
    if kv % m == 0:
        spec[1] = "model"
    elif g % m == 0:
        spec[2] = "model"
    elif s % m == 0:
        spec[4] = "model"
    return jax.lax.with_sharding_constraint(
        scores, NamedSharding(mesh, P(*spec)))


def constrain_heads(x: jax.Array) -> jax.Array:
    """(B, Q, H, hd): batch over data, heads (or head_dim) over model."""
    if _MESH is None:
        return x
    mesh, dp = _MESH
    from jax.sharding import NamedSharding, PartitionSpec as P
    m = mesh.shape["model"]
    spec = [dp, None, None, None]
    if x.shape[2] % m == 0:
        spec[2] = "model"
    elif x.shape[3] % m == 0:
        spec[3] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# Context-parallel attention layout (§Perf hillclimb)
#
# Queries stay SEQUENCE-sharded over "model" (the layout the residual
# stream already has at block boundaries under sequence parallelism);
# K/V replicate over "model" (cheap for GQA: kv_heads×hd ≤ 1k) and the
# score tensor shards its query dim.  Every attention op — masking,
# top-k sort threshold, softmax, AV — is then row-parallel: no resharding
# of the biggest tensor and no head-divisibility constraint (phi4's 24
# heads stop mattering).  Replaces the head-sharded layout whose q-vs-kv
# mismatch made GSPMD insert per-layer gathers / involuntary remat.
# ---------------------------------------------------------------------------

_CP = False


def set_context_parallel(on: bool) -> None:
    global _CP
    _CP = bool(on)


def cp_enabled() -> bool:
    return _CP and _MESH is not None


def _ns(spec):
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh, dp = _MESH
    return NamedSharding(mesh, P(*[dp if s == "DP" else s for s in spec]))


def constrain_cp_q(q: jax.Array) -> jax.Array:
    if not cp_enabled() or q.shape[1] % _MESH[0].shape["model"] != 0:
        return q
    return jax.lax.with_sharding_constraint(
        q, _ns(("DP", "model", None, None)))


def constrain_cp_kv(kv: jax.Array) -> jax.Array:
    if not cp_enabled():
        return kv
    return jax.lax.with_sharding_constraint(
        kv, _ns(("DP", None, None, None)))


def constrain_cp_scores(s: jax.Array) -> jax.Array:
    """(B, KV, G, Q, S) — query dim over model."""
    if not cp_enabled() or s.shape[3] % _MESH[0].shape["model"] != 0:
        return s
    return jax.lax.with_sharding_constraint(
        s, _ns(("DP", None, None, "model", None)))


def make_activation_shardings(mesh, cfg, seq_shard: bool = False
                              ) -> Dict[str, Any]:
    """Standard batch-sharded activation layout for a model config.

    ``seq_shard`` enables Megatron-style sequence parallelism: the
    residual stream at block boundaries shards its sequence dim over
    "model" (norms/residuals are pointwise, so this is free; GSPMD
    all-gathers S at the QKV/MLP input and reduce-scatters after the
    output projection).  Divides saved remat activations by the model
    axis — required to fit the 95-100-layer models' training shapes."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    ns = lambda *spec: NamedSharding(mesh, P(*spec))
    specs = {
        "act": ns(dp, "model" if seq_shard else None, None),  # (B, S, D)
        "logits": ns(dp, None, "model"),        # (B, S, V)
    }
    if cfg.moe:
        specs["moe_tokens"] = ns(dp, None, None)                   # (G,T,D)
        specs["moe_dispatch"] = ns(dp, None, None, None)           # (G,T,E,C)
        if cfg.expert_shard == "expert":
            specs["moe_expert_in"] = ns(dp, "model", None, None)   # (G,E,C,D)
            specs["moe_expert_h"] = ns(dp, "model", None, None)    # (G,E,C,F)
        else:
            specs["moe_expert_in"] = ns(dp, None, None, None)
            specs["moe_expert_h"] = ns(dp, None, None, "model")
    return specs
