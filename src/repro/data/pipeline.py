"""Deterministic, shardable synthetic token pipeline.

Real-cluster semantics in miniature: every host derives its shard of
each global batch from (seed, step, host_id) — no coordination needed,
restart-safe (the pipeline "state" is just the step counter, stored in
checkpoints), and identical global batches regardless of host count
(elastic rescaling keeps the data order).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int


class SyntheticLM:
    """Markov-ish token stream with enough structure that loss decreases.

    Tokens follow a noisy arithmetic progression per sequence; labels are
    the next token.  ``loss_mask`` masks the final position.
    """

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.state = PipelineState(seed=seed, step=0)

    def save_state(self) -> Dict:
        return dataclasses.asdict(self.state)

    def restore_state(self, d: Dict) -> None:
        self.state = PipelineState(**d)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.state.seed * 1_000_003 + self.state.step) % (2 ** 63))
        self.state.step += 1
        v = self.cfg.vocab_size
        start = rng.integers(0, v, (self.batch, 1))
        stride = rng.integers(1, 7, (self.batch, 1))
        pos = np.arange(self.seq + 1)[None, :]
        toks = (start + stride * pos) % v
        noise = rng.integers(0, v, toks.shape)
        keep = rng.random(toks.shape) > 0.05
        toks = np.where(keep, toks, noise).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                 "loss_mask": np.ones((self.batch, self.seq), np.float32)}
        if self.cfg.family == "vlm":
            batch["image_embeds"] = rng.standard_normal(
                (self.batch, self.cfg.n_image_tokens, self.cfg.d_model)
            ).astype(np.float32)
        if self.cfg.family == "audio":
            batch["audio_embeds"] = rng.standard_normal(
                (self.batch, self.cfg.encoder_len, self.cfg.d_model)
            ).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
