"""The paper's four selective-attention workloads (Tab. I).

| model          | D_k    | K/N     | 0-skip | S_f      | paper GlobQ% | paper S_h |
|----------------|--------|---------|--------|----------|--------------|-----------|
| TTST           | 65536  | 15/30   | off    | N        | 24.2%        | 0.463 N   |
| KVT-DeiT-Tiny  | 64     | 50/198  | on     | 0.11 N   | 33.3%        | 0.053 N   |
| KVT-DeiT-Base  | 64     | 64/198  | on     | 0.11 N   | 46.4%        | 0.051 N   |
| DRSformer      | 4800   | 12/48   | on     | 0.125 N  | 14.8%        | 0.062 N   |

We do not have the authors' runtime traces; masks are drawn from the
locality-structured synthetic generator (``core.masks.SyntheticTrace``)
whose cluster/band/noise parameters are calibrated so the *post-schedule
statistics* land near Tab. I.  The calibration is part of the
reproduction and is reported side-by-side with the paper's numbers in
EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.masks import SyntheticTrace


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    n_tokens: int
    k: int
    d_k: int
    s_f: Optional[int]            # None → untiled (whole-head sorting)
    zero_skip: bool
    n_heads: int
    trace: SyntheticTrace
    paper_throughput_gain: float  # Fig. 4a claims
    paper_energy_gain: float
    paper_glob_q: float           # Tab. I
    paper_s_h_frac: float
    paper_n_dec: float


WORKLOADS: Dict[str, Workload] = {
    # Calibration notes (EXPERIMENTS.md §Tab1 reports ours vs paper):
    #  ttst      → thr 1.42 (1.47), en 1.25 (1.81), S_h 0.494 (0.463)
    #  kvt_tiny  → thr 1.81 (1.76), en 1.94 (2.10), GlobQ 0.332 (0.333)
    #  kvt_base  → thr 1.70 (1.59), en 1.77 (1.85)
    #  drsformer → thr 1.25 (1.50), en 1.71 (2.94), zero-skip 0.74
    # Residual gaps (ttst/drsformer energy) stem from trace microstructure
    # we cannot reconstruct without the authors' runtime traces; see
    # EXPERIMENTS.md §Discrepancies.
    "ttst": Workload(
        name="TTST", n_tokens=30, k=15, d_k=65536, s_f=None,
        zero_skip=False, n_heads=6,
        trace=SyntheticTrace(n_tokens=30, k=15, cluster_rank=1,
                             cluster_scale=5.0, noise=0.2),
        paper_throughput_gain=1.47, paper_energy_gain=1.81,
        paper_glob_q=0.242, paper_s_h_frac=0.463, paper_n_dec=1.55),
    "kvt_tiny": Workload(
        name="KVT-DeiT-Tiny", n_tokens=198, k=50, d_k=64, s_f=22,
        zero_skip=True, n_heads=3,
        trace=SyntheticTrace(n_tokens=198, k=50, cluster_rank=2,
                             cluster_scale=1.0, band_width=15.0,
                             band_scale=2.5, noise=0.35),
        paper_throughput_gain=1.76, paper_energy_gain=2.10,
        paper_glob_q=0.333, paper_s_h_frac=0.053, paper_n_dec=0.62),
    "kvt_base": Workload(
        name="KVT-DeiT-Base", n_tokens=198, k=64, d_k=64, s_f=22,
        zero_skip=True, n_heads=12,
        trace=SyntheticTrace(n_tokens=198, k=64, cluster_rank=2,
                             cluster_scale=1.0, band_width=18.0,
                             band_scale=3.0, noise=0.35),
        paper_throughput_gain=1.59, paper_energy_gain=1.85,
        paper_glob_q=0.464, paper_s_h_frac=0.051, paper_n_dec=1.38),
    "drsformer": Workload(
        name="DRSformer", n_tokens=48, k=12, d_k=4800, s_f=6,
        zero_skip=True, n_heads=6,
        trace=SyntheticTrace(n_tokens=48, k=12, cluster_rank=2,
                             cluster_scale=0.5, band_width=6.0,
                             band_scale=4.0, block_quant=12, noise=0.45),
        paper_throughput_gain=1.50, paper_energy_gain=2.94,
        paper_glob_q=0.148, paper_s_h_frac=0.062, paper_n_dec=0.05),
}
