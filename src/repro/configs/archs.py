"""The 10 assigned architectures (+ reduced smoke variants).

Exact configs from the assignment table; every entry is selectable via
``--arch <id>`` in the launchers.  ``SMOKE[id]`` is a same-family reduced
config for CPU tests; FULL configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.models.config import ModelConfig

ARCHS: Dict[str, ModelConfig] = {
    "phi4-mini-3.8b": ModelConfig(
        name="phi4-mini-3.8b", family="dense", n_layers=32, d_model=3072,
        n_heads=24, n_kv_heads=8, d_ff=8192, vocab_size=200064,
        attention_variant="topk", topk_k=64, micro_steps=4),
    "deepseek-67b": ModelConfig(
        name="deepseek-67b", family="dense", n_layers=95, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=22016, vocab_size=102400,
        attention_variant="topk", topk_k=64, micro_steps=16),
    "qwen3-4b": ModelConfig(
        name="qwen3-4b", family="dense", n_layers=36, d_model=2560,
        n_heads=32, n_kv_heads=8, d_ff=9728, vocab_size=151936,
        qk_norm=True, head_dim=128, attention_variant="topk", topk_k=64,
        micro_steps=4),
    "olmo-1b": ModelConfig(
        name="olmo-1b", family="dense", n_layers=16, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=8192, vocab_size=50304,
        norm_type="nonparam_ln", attention_variant="topk", topk_k=64),
    "llama-3.2-vision-90b": ModelConfig(
        name="llama-3.2-vision-90b", family="vlm", n_layers=100,
        d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
        vocab_size=128256, cross_attn_period=5, n_image_tokens=1600,
        attention_variant="topk", topk_k=64, micro_steps=16),
    "zamba2-2.7b": ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_ff=10240, vocab_size=32000,
        ssm=True, ssm_state=64, hybrid_period=6,
        attention_variant="topk", topk_k=64, micro_steps=4),
    "whisper-base": ModelConfig(
        name="whisper-base", family="audio", n_layers=6, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=51865,
        encoder_layers=6, encoder_len=1500, norm_type="layernorm",
        mlp_variant="gelu", rope_theta=10000.0,
        attention_variant="topk", topk_k=64),
    "qwen3-moe-235b-a22b": ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe", n_layers=94,
        d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
        vocab_size=151936, head_dim=128, qk_norm=True,
        moe=True, n_experts=128, experts_per_token=8,
        expert_shard="expert", attention_variant="topk", topk_k=64,
        micro_steps=8),
    "grok-1-314b": ModelConfig(
        name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=32768, vocab_size=131072,
        moe=True, n_experts=8, experts_per_token=2,
        expert_shard="tensor", attention_variant="topk", topk_k=64,
        micro_steps=16),
    "rwkv6-1.6b": ModelConfig(
        name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=7168, vocab_size=65536,
        rwkv=True, attention_variant="dense",    # SATA inapplicable (no QK)
        micro_steps=4),
}


def _smoke(full: ModelConfig) -> ModelConfig:
    """Reduced same-family config: small widths, few layers, tiny vocab."""
    kw = dict(
        name=full.name + "-smoke", family=full.family,
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=min(4, full.n_kv_heads),
        d_ff=128, vocab_size=256, head_dim=16,
        attention_variant=full.attention_variant, topk_k=4,
        qk_norm=full.qk_norm, norm_type=full.norm_type,
        mlp_variant=full.mlp_variant, q_chunk=8,
        dtype="float32", remat="none",
    )
    if full.moe:
        kw.update(moe=True, n_experts=4, experts_per_token=2,
                  moe_group_size=16, expert_shard=full.expert_shard)
    if full.family == "hybrid":
        kw.update(ssm=True, ssm_state=8, ssm_expand=2, ssm_head_dim=8,
                  ssm_chunk=8, hybrid_period=2, n_kv_heads=4)
    if full.family == "ssm":
        kw.update(rwkv=True, rwkv_head_dim=8, attention_variant="dense")
    if full.family == "audio":
        kw.update(encoder_layers=2, encoder_len=16, n_layers=2)
    if full.family == "vlm":
        kw.update(cross_attn_period=2, n_image_tokens=8)
    return ModelConfig(**kw)


SMOKE: Dict[str, ModelConfig] = {k: _smoke(v) for k, v in ARCHS.items()}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid archs
# (see DESIGN.md §Shape-cell skips).
LONG_OK = {"zamba2-2.7b", "rwkv6-1.6b"}


def cell_enabled(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_OK
    return True


def all_cells():
    return [(a, s) for a in ARCHS for s in SHAPES if cell_enabled(a, s)]
