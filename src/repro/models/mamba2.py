"""Mamba2 (SSD) block — chunked matmul form (MXU-friendly) + O(1) decode.

Implements the state-space duality algorithm: within a chunk the output
is a masked (decay-weighted) attention-like matmul; across chunks a
small recurrent state (B, H, N, P) is carried by ``lax.scan``.  This is
the standard TPU-native formulation (quadratic only within the chunk).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, _dtype, dense_init


def mamba2_init(key, cfg) -> Params:
    dt = _dtype(cfg)
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h = cfg.ssm_heads
    ks = jax.random.split(key, 5)
    conv_dim = di + 2 * n
    return {
        # in_proj → [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * n + h, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                     jnp.float32) * 0.1).astype(dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dt),
    }


def _split_proj(cfg, zxbcdt):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xbc: (B, S, C), w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out)


def _gated_rmsnorm(y, z, scale, eps=1e-6):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1, keepdims=True) + eps)
    return y / rms * scale


def mamba2_apply(params: Params, cfg, x: jax.Array) -> jax.Array:
    """Training/prefill forward. x: (B, S, D)."""
    b, s, d = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, s)
    if s % q != 0:
        q = s
    nc = s // q

    z, xbc, dt_raw = _split_proj(cfg, x @ params["in_proj"])
    xbc = _causal_conv(xbc, params["conv_w"])
    xs = xbc[..., :di].reshape(b, s, h, p).astype(jnp.float32)
    bmat = xbc[..., di:di + n].astype(jnp.float32)            # (B,S,N)
    cmat = xbc[..., di + n:].astype(jnp.float32)              # (B,S,N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])                 # (B,S,H)
    a = -jnp.exp(params["a_log"])                             # (H,) negative
    log_decay = dt * a                                        # (B,S,H) ≤ 0

    # chunk views
    xs_c = xs.reshape(b, nc, q, h, p)
    b_c = bmat.reshape(b, nc, q, n)
    c_c = cmat.reshape(b, nc, q, n)
    dt_c = dt.reshape(b, nc, q, h)
    ld_c = log_decay.reshape(b, nc, q, h)
    lcum = jnp.cumsum(ld_c, axis=2)                           # (B,C,Q,H)

    # ---- intra-chunk (quadratic within chunk) ----
    # decay(i,j) = exp(lcum_i - lcum_j) for i >= j
    dec = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]     # (B,C,Qi,Qj,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    dec = jnp.where(causal[None, None, :, :, None], dec, -jnp.inf)
    gij = jnp.exp(dec)                                        # (B,C,Qi,Qj,H)
    cb = jnp.einsum("bcin,bcjn->bcij", c_c, b_c)              # (B,C,Qi,Qj)
    w_ij = cb[..., None] * gij * dt_c[:, :, None, :, :]       # ×dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w_ij, xs_c)

    # ---- chunk states and inter-chunk scan ----
    # state contribution of chunk: S_c = Σ_j exp(lQ - l_j)·dt_j·B_j ⊗ x_j
    tail = jnp.exp(lcum[:, :, -1:, :] - lcum)                 # (B,C,Q,H)
    sc = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                    tail * dt_c, b_c, xs_c)                   # (B,C,H,N,P)
    chunk_decay = jnp.exp(lcum[:, :, -1, :])                  # (B,C,H)

    def scan_fn(hstate, inp):
        sc_t, cd_t = inp                                      # (B,H,N,P),(B,H)
        out = hstate                                          # state BEFORE chunk
        hstate = hstate * cd_t[..., None, None] + sc_t
        return hstate, out

    sc_t = jnp.moveaxis(sc, 1, 0)                             # (C,B,H,N,P)
    cd_t = jnp.moveaxis(chunk_decay, 1, 0)                    # (C,B,H)
    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, h_prev = jax.lax.scan(scan_fn, h0, (sc_t, cd_t))       # (C,B,H,N,P)
    h_prev = jnp.moveaxis(h_prev, 0, 1)                       # (B,C,H,N,P)

    y_inter = jnp.einsum("bcin,bchnp->bcihp",
                         c_c, h_prev) * jnp.exp(lcum)[..., None]
    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = y + params["d_skip"][None, None, :, None] * xs
    y = y.reshape(b, s, di)
    y = _gated_rmsnorm(y, z, params["norm_scale"])
    return y.astype(x.dtype) @ params["out_proj"]


# ---------------------------------------------------------------------------
# Decode (recurrent, O(1) per token)
# ---------------------------------------------------------------------------

def init_mamba_cache(cfg, batch: int, dtype) -> Dict[str, jax.Array]:
    di, n = cfg.d_inner, cfg.ssm_state
    conv_dim = di + 2 * n
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_head_dim),
                         jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def mamba2_decode(params: Params, cfg, x: jax.Array, cache: Dict
                  ) -> Tuple[jax.Array, Dict]:
    """One-token recurrent step. x: (B, 1, D)."""
    b = x.shape[0]
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt_raw = _split_proj(cfg, x @ params["in_proj"])
    hist = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)],
                           axis=1)                            # (B, K, C)
    w = params["conv_w"]
    conv_out = jax.nn.silu((hist * w[None]).sum(axis=1, keepdims=True))
    new_conv = hist[:, 1:]
    xs = conv_out[..., :di].reshape(b, h, p).astype(jnp.float32)
    bvec = conv_out[:, 0, di:di + n].astype(jnp.float32)
    cvec = conv_out[:, 0, di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    decay = jnp.exp(dt * (-jnp.exp(params["a_log"])))         # (B,H)
    state = cache["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, bvec, xs)
    y = jnp.einsum("bn,bhnp->bhp", cvec, state)
    y = y + params["d_skip"][None, :, None] * xs
    y = _gated_rmsnorm(y.reshape(b, 1, di), z, params["norm_scale"])
    out = y.astype(x.dtype) @ params["out_proj"]
    return out, {"ssm": state, "conv": new_conv}
