"""Model configuration — one frozen dataclass covers all assigned archs.

The ~26 SATA / KV-cache knobs live in **nested frozen dataclasses**
(``cfg.sata.kernel.block``, ``cfg.kv.page_size``, ...), grouped by the
subsystem that reads them:

    cfg.sata.kernel   SataKernelConfig   prefill kernel + selection
    cfg.sata.decode   SataDecodeConfig   incremental decode plan
    cfg.sata.qos      QosConfig          degradation ladder
    cfg.sata.retire   RetireConfig       cascade token retirement
    cfg.kv            KVCacheConfig      cache layout / page pool

The legacy flat spellings (``cfg.sata_block``, ``kv_page_size=...``)
keep working through a deprecation shim: ``ModelConfig(...)`` (and
therefore ``dataclasses.replace``) accepts the flat kwargs and folds
them into the nested groups, and flat attribute reads resolve through
properties — each flat name warns **once per process** on first use.
New code should use the nested paths; see the migration table in
README.md.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple, Union


@dataclasses.dataclass(frozen=True)
class SataKernelConfig:
    """Prefill-side SATA: chunked selection + compacted-grid kernel."""
    s_f: int = 128                       # SATA tile size (kernel plan)
    use: bool = False                    # route topk attn through the
                                         # compacted-grid Pallas kernel
    block: int = 128                     # kernel q/k tile edge
    schedule: str = "compact"            # compact | dense kernel grid
    selection: str = "auto"              # auto | chunked | dense —
                                         # chunked streams q_chunk×S
                                         # score tiles (no (BH,S,S)
                                         # buffer); auto follows the
                                         # topk_impl bisect decision
    max_kv_blocks: Optional[int] = None  # static per-row occupancy
                                         # bound (occupancy_bound on
                                         # calibration plans) — jitted
                                         # serving gets a compact grid
                                         # without a concrete mask
    bound_fallback: str = "dense"        # dense | truncate — when a
                                         # row's occupancy exceeds
                                         # max_kv_blocks, "dense" reruns
                                         # the batch on the full-width
                                         # (dense-cost) grid (loss-free
                                         # escape hatch); "truncate"
                                         # keeps the first `bound` blocks


@dataclasses.dataclass(frozen=True)
class SataDecodeConfig:
    """Decode-side SATA: the incremental KV-block plan + gather kernel."""
    mode: str = "auto"                   # auto | on | off — route
                                         # single-token decode through
                                         # the incremental KV-block
                                         # plan + gather kernel; auto
                                         # follows the bisect decision
                                         # at the cache length
    block: Optional[int] = None          # decode k-block edge
                                         # (default: sata.kernel.block)
    blocks: Optional[int] = None         # plan width P (selected
                                         # k-blocks kept per slot/head);
                                         # None = full nkb (exact —
                                         # nothing dropped)
    replan: Union[int, str] = 1          # full re-plan every N steps
                                         # (1 = every step = exact
                                         # top-k; >1 uses the block-
                                         # summary incremental plan in
                                         # between; "auto" derives the
                                         # trigger from observed plan
                                         # churn — see ``churn``)
    churn: float = 0.25                  # "auto" re-plan budget: full
                                         # re-plan once accumulated
                                         # blocks entering/retiring per
                                         # (slot, head) reaches this
                                         # fraction of the plan width P
    summary: str = "fp32"                # fp32 | int8 — decode
                                         # block-summary backend; int8
                                         # stores conservative quantized
                                         # bounds (+ per-block scale/
                                         # zero), ~4× less plan-side
                                         # summary traffic; summaries
                                         # only RANK — the exact token
                                         # threshold is unaffected
    replan_mode: str = "exact"           # exact | sketch — periodic
                                         # re-plan flavor; sketch ranks
                                         # super-block sketches first
                                         # and runs exact bisection only
                                         # on surviving candidates
                                         # (sub-linear re-plan traffic,
                                         # approximate)
    sketch_factor: int = 4               # blocks per super-block sketch
                                         # (largest divisor of nkb used)


@dataclasses.dataclass(frozen=True)
class QosConfig:
    """Per-slot degradation ladder (overload regime)."""
    ladder: bool = False                 # under pool / deadline
                                         # pressure the serve loop steps
                                         # slots down quality rungs
                                         # (budget → interval → int8 →
                                         # sketch) instead of
                                         # preempting; per-slot knob
                                         # vectors live in the plan
                                         # state so rungs apply without
                                         # re-tracing
    clear_steps: int = 4                 # hysteresis: consecutive
                                         # pressure-free steps before
                                         # stepping one rung back up


@dataclasses.dataclass(frozen=True)
class RetireConfig:
    """Cascade token retirement (SpAtten) → mid-stream page reclaim."""
    mode: str = "off"                    # off | on — accumulated block
                                         # importance rides the plan's
                                         # score pass; cold blocks are
                                         # retired, their pages freed
                                         # back to the pool mid-stream.
                                         # LOSSY by design once a pass
                                         # fires; "off" is bitwise
                                         # identical to the
                                         # pre-retirement stack
    decay: float = 0.9                   # exponential decay of the
                                         # accumulated per-block
                                         # importance per step
    watermark: float = 0.75              # per-slot live-token watermark
                                         # (fraction of max_len) that
                                         # triggers a retirement pass;
                                         # pool pressure (a deferred
                                         # claim) also triggers
    keep: float = 0.5                    # retained-token budget: a pass
                                         # keeps this fraction of the
                                         # slot's live blocks (the
                                         # hottest by importance; the
                                         # current append block and
                                         # trie-/swap-pinned pages are
                                         # never retired)


@dataclasses.dataclass(frozen=True)
class SataConfig:
    """All SATA knobs, grouped by the subsystem that reads them."""
    kernel: SataKernelConfig = SataKernelConfig()
    decode: SataDecodeConfig = SataDecodeConfig()
    qos: QosConfig = QosConfig()
    retire: RetireConfig = RetireConfig()


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    """Serving KV-cache layout."""
    layout: str = "contiguous"           # contiguous | paged — paged
                                         # serves from a global page
                                         # pool + per-slot page table
                                         # (pages allocated on append,
                                         # freed on reset_slot), so
                                         # short prefixes stop reserving
                                         # max_len HBM
    page_size: int = 0                   # tokens per page (0 = the
                                         # decode k-block edge; SATA
                                         # decode requires equality —
                                         # plan blocks ARE pages)
    pool_pages: int = 0                  # physical pages in the pool
                                         # (0 = slots·max_pages + 1:
                                         # contiguous-equivalent
                                         # capacity + overflow page)
    prefix_cache: bool = False           # shared-prefix page cache
                                         # (paged layout only): a
                                         # prompt-prefix trie maps
                                         # cached prompt pages into new
                                         # slots (refcounted, copy-on-
                                         # write on append; prefill runs
                                         # only on the unmatched tail)
    lazy_cow: bool = False               # lazy copy-on-write: a
                                         # partial-page prefix match
                                         # skips the eager CoW copy when
                                         # appended rows land past the
                                         # shared rows — the sole
                                         # appender holds a write lease
                                         # (revoked the moment another
                                         # slot maps the page) instead
                                         # of copying

    def __post_init__(self):
        if self.layout not in ("contiguous", "paged"):
            raise ValueError(f"kv.layout must be 'contiguous' or 'paged', "
                             f"got {self.layout!r}")
        if self.page_size < 0 or self.pool_pages < 0:
            raise ValueError("kv.page_size / kv.pool_pages must be >= 0")

    def check_decode_block(self, decode_block: Optional[int]) -> None:
        """Construction-time form of the paged-route equality SATA
        decode requires: when both ``page_size`` and the decode k-block
        edge are set explicitly, they must match (plan blocks ARE
        pages, so the decode kernel's index maps can dereference the
        page table).  Called from ``ModelConfig.__post_init__`` —
        page-size mismatches fail at config construction, not at the
        first ``init_kv_cache`` shape assert."""
        if (self.layout == "paged" and self.page_size
                and decode_block and decode_block != self.page_size):
            raise ValueError(
                f"paged SATA decode needs kv_page_size == the decode "
                f"k-block edge, got kv.page_size={self.page_size} vs "
                f"sata.decode.block={decode_block}: the plan's logical "
                f"blocks must BE pages for the decode kernel's index "
                f"maps to dereference the page table (set them equal, "
                f"or leave kv_page_size=0 to inherit the block edge)")


# flat legacy spelling -> (top-level field, *nested path)
_FLAT_MAP = {
    "sata_s_f": ("sata", "kernel", "s_f"),
    "use_sata_kernel": ("sata", "kernel", "use"),
    "sata_block": ("sata", "kernel", "block"),
    "sata_schedule": ("sata", "kernel", "schedule"),
    "sata_selection": ("sata", "kernel", "selection"),
    "sata_max_kv_blocks": ("sata", "kernel", "max_kv_blocks"),
    "sata_bound_fallback": ("sata", "kernel", "bound_fallback"),
    "sata_decode": ("sata", "decode", "mode"),
    "sata_decode_block": ("sata", "decode", "block"),
    "sata_decode_blocks": ("sata", "decode", "blocks"),
    "sata_decode_replan": ("sata", "decode", "replan"),
    "sata_decode_churn": ("sata", "decode", "churn"),
    "sata_summary": ("sata", "decode", "summary"),
    "sata_replan_mode": ("sata", "decode", "replan_mode"),
    "sata_sketch_factor": ("sata", "decode", "sketch_factor"),
    "sata_qos_ladder": ("sata", "qos", "ladder"),
    "sata_qos_clear_steps": ("sata", "qos", "clear_steps"),
    "sata_retire": ("sata", "retire", "mode"),
    "sata_retire_decay": ("sata", "retire", "decay"),
    "sata_retire_watermark": ("sata", "retire", "watermark"),
    "sata_retire_keep": ("sata", "retire", "keep"),
    "kv_cache_layout": ("kv", "layout"),
    "kv_page_size": ("kv", "page_size"),
    "kv_pool_pages": ("kv", "pool_pages"),
    "kv_prefix_cache": ("kv", "prefix_cache"),
    "kv_lazy_cow": ("kv", "lazy_cow"),
}

# flat names already warned about (one DeprecationWarning per flat name
# per process — construction and attribute reads share the registry)
_warned_flat: set = set()


def _warn_flat(name: str, how: str) -> None:
    if name in _warned_flat:
        return
    _warned_flat.add(name)
    path = ".".join(_FLAT_MAP[name])
    warnings.warn(
        f"flat config knob '{name}' ({how}) is deprecated; use the "
        f"nested 'cfg.{path}' (construction accepts "
        f"'{path.split('.')[0]}=...' groups)",
        DeprecationWarning, stacklevel=3)


def _fold_flat(kw: dict) -> dict:
    """Fold legacy flat kwargs in ``kw`` into the nested ``sata`` /
    ``kv`` groups (explicit flat values win over group values — that is
    what ``dataclasses.replace(cfg, sata_decode="on")`` means)."""
    flat = {k: kw.pop(k) for k in list(kw) if k in _FLAT_MAP}
    if not flat:
        return kw
    for name in flat:
        _warn_flat(name, "constructor kwarg")
    groups = {"sata": kw.get("sata", SataConfig()),
              "kv": kw.get("kv", KVCacheConfig())}
    for name, val in flat.items():
        path = _FLAT_MAP[name]
        top, inner = path[0], path[1:]
        node = groups[top]
        if len(inner) == 2:  # sata.<group>.<field>
            sub = getattr(node, inner[0])
            sub = dataclasses.replace(sub, **{inner[1]: val})
            node = dataclasses.replace(node, **{inner[0]: sub})
        else:                # kv.<field>
            node = dataclasses.replace(node, **{inner[0]: val})
        groups[top] = node
    kw.update(groups)
    return kw


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | vlm | hybrid | audio | moe | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None            # default d_model // n_heads

    # --- attention workload ---
    attention_variant: str = "topk"           # "dense" | "topk" (SATA)
    topk_k: int = 64                          # selected keys per query
    topk_impl: str = "auto"                   # sort | bisect | auto
    topk_blocks: int = 0                      # >0: block-topk granularity

    # --- SATA + KV-cache knobs (nested; flat spellings shimmed) ---
    sata: SataConfig = SataConfig()
    kv: KVCacheConfig = KVCacheConfig()

    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    q_chunk: int = 1024                       # query-chunked attention

    # --- norms / mlp ---
    norm_type: str = "rmsnorm"                # rmsnorm | layernorm | nonparam_ln
    mlp_variant: str = "swiglu"               # swiglu | gelu
    tie_embeddings: bool = False

    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    experts_per_token: int = 0
    moe_group_size: int = 128                 # GShard dispatch group
    capacity_factor: float = 1.25
    expert_shard: str = "expert"              # expert→model | tensor→model

    # --- SSM / hybrid (zamba2) ---
    ssm: bool = False                         # Mamba2 (SSD) backbone layers
    ssm_state: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    hybrid_period: int = 0                    # shared attn block every k layers

    # --- RWKV6 ---
    rwkv: bool = False
    rwkv_head_dim: int = 64

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_len: int = 1500                   # precomputed frame embeddings

    # --- VLM (llama-3.2-vision) ---
    cross_attn_period: int = 0                # cross-attn every k-th layer
    n_image_tokens: int = 0

    # --- numerics / memory ---
    dtype: str = "bfloat16"
    remat: str = "full"                       # none | dots | full
    scan_layers: bool = True
    micro_steps: int = 1                      # grad-accumulation microbatches
    rwkv_chunk: int = 256                     # time-scan remat chunk

    def __post_init__(self):
        # the paged-route footgun, caught at construction: an explicit
        # kv_page_size that disagrees with an explicit decode block
        # edge can never serve (init_kv_cache keeps the clamped-shape
        # backstop for the defaulted cases construction can't see)
        self.kv.check_decode_block(self.sata.decode.block)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:                 # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Total parameters N (for MODEL_FLOPS = 6·N·D roofline term)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.rwkv:
            att = d * (4 * d) + d * d            # r,k,v,g (+w lora-ish) + out
            ffn = 2 * d * self.d_ff + self.d_ff * d
            per_layer = att + ffn
            return emb + self.n_layers * per_layer
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.moe:
            ffn = self.n_experts * (3 * d * self.d_ff) + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff if self.mlp_variant == "swiglu" \
                else 2 * d * self.d_ff
        if self.ssm:
            # mamba2 block: in_proj (z,x,B,C,dt) + conv + out_proj
            di, ns = self.d_inner, self.ssm_state
            proj_in = d * (2 * di + 2 * ns * 1 + self.ssm_heads)
            mamba = proj_in + di * self.ssm_conv + di * d
            n_attn = (self.n_layers // self.hybrid_period
                      if self.hybrid_period else 0)
            return (emb + self.n_layers * (mamba + ffn // 1)
                    + (attn + 3 * d * self.d_ff) * (1 if n_attn else 0))
        n_cross = (self.n_layers // self.cross_attn_period
                   if self.cross_attn_period else 0)
        total = emb + self.n_layers * (attn + ffn) + n_cross * attn
        if self.encoder_layers:
            total += self.encoder_layers * (attn + ffn) \
                + self.n_layers * attn               # decoder cross-attn
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dense_ffn = self.n_experts * (3 * d * self.d_ff)
        active_ffn = self.experts_per_token * (3 * d * self.d_ff)
        return self.param_count() - self.n_layers * (dense_ffn - active_ffn)


# --- legacy flat-kwarg constructor shim -----------------------------------
# ``dataclasses.replace`` passes unknown change-keys straight through to
# ``cls(**merged)``, so wrapping __init__ makes BOTH
# ``ModelConfig(..., sata_block=64)`` and
# ``dataclasses.replace(cfg, sata_decode="on")`` fold into the nested
# groups.
_generated_init = ModelConfig.__init__


def _compat_init(self, *args, **kw):
    _generated_init(self, *args, **_fold_flat(kw))


_compat_init.__wrapped__ = _generated_init
ModelConfig.__init__ = _compat_init


def _make_flat_property(flat_name: str, path: Tuple[str, ...]):
    def _get(self):
        _warn_flat(flat_name, "attribute read")
        node = self
        for p in path:
            node = getattr(node, p)
        return node
    _get.__name__ = flat_name
    _get.__doc__ = f"Deprecated flat alias for ``cfg.{'.'.join(path)}``."
    return property(_get)


for _name, _path in _FLAT_MAP.items():
    setattr(ModelConfig, _name, _make_flat_property(_name, _path))
del _name, _path
