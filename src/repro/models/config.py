"""Model configuration — one frozen dataclass covers all assigned archs."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | vlm | hybrid | audio | moe | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None            # default d_model // n_heads

    # --- attention / SATA ---
    attention_variant: str = "topk"           # "dense" | "topk" (SATA workload)
    topk_k: int = 64                          # selected keys per query
    topk_impl: str = "auto"                   # sort | bisect | auto
    topk_blocks: int = 0                      # >0: block-topk granularity
    sata_s_f: int = 128                       # SATA tile size (kernel plan)
    use_sata_kernel: bool = False             # route topk attn through the
                                              # compacted-grid Pallas kernel
    sata_block: int = 128                     # kernel q/k tile edge
    sata_schedule: str = "compact"            # compact | dense kernel grid
    sata_selection: str = "auto"              # auto | chunked | dense —
                                              # chunked streams q_chunk×S
                                              # score tiles (no (BH,S,S)
                                              # buffer); auto follows the
                                              # topk_impl bisect decision
    sata_max_kv_blocks: Optional[int] = None  # static per-row occupancy
                                              # bound (occupancy_bound on
                                              # calibration plans) — jitted
                                              # serving gets a compact grid
                                              # without a concrete mask
    sata_bound_fallback: str = "dense"        # dense | truncate — when a
                                              # row's occupancy exceeds
                                              # sata_max_kv_blocks, "dense"
                                              # reruns the batch on the
                                              # full-width (dense-cost)
                                              # grid (loss-free escape
                                              # hatch); "truncate" keeps
                                              # the first `bound` blocks
    sata_decode: str = "auto"                 # auto | on | off — route
                                              # single-token decode through
                                              # the incremental KV-block
                                              # plan + gather kernel; auto
                                              # follows the bisect decision
                                              # at the cache length
    sata_decode_block: Optional[int] = None   # decode k-block edge
                                              # (default: sata_block)
    sata_decode_blocks: Optional[int] = None  # plan width P (selected
                                              # k-blocks kept per slot/
                                              # head); None = full nkb
                                              # (exact — nothing dropped)
    sata_decode_replan: Union[int, str] = 1   # full re-plan every N steps
                                              # (1 = every step = exact
                                              # top-k; >1 uses the block-
                                              # summary incremental plan
                                              # in between; "auto" derives
                                              # the trigger from observed
                                              # plan churn — see
                                              # sata_decode_churn)
    sata_decode_churn: float = 0.25           # "auto" re-plan budget: full
                                              # re-plan once accumulated
                                              # blocks entering/retiring
                                              # per (slot, head) reaches
                                              # this fraction of the plan
                                              # width P
    sata_summary: str = "fp32"                # fp32 | int8 — decode
                                              # block-summary backend;
                                              # int8 stores conservative
                                              # quantized bounds (+ per-
                                              # block scale/zero), ~4×
                                              # less plan-side summary
                                              # traffic; summaries only
                                              # RANK — the exact token
                                              # threshold is unaffected
    sata_replan_mode: str = "exact"           # exact | sketch — periodic
                                              # re-plan flavor; sketch
                                              # ranks super-block
                                              # sketches first and runs
                                              # exact bisection only on
                                              # surviving candidates
                                              # (sub-linear re-plan
                                              # traffic, approximate)
    sata_sketch_factor: int = 4               # blocks per super-block
                                              # sketch (largest divisor
                                              # of nkb is used)
    sata_qos_ladder: bool = False             # per-slot degradation
                                              # ladder: under pool /
                                              # deadline pressure the
                                              # serve loop steps slots
                                              # down quality rungs
                                              # (budget → interval →
                                              # int8 → sketch) instead
                                              # of preempting; per-slot
                                              # knob vectors live in the
                                              # plan state so rungs
                                              # apply without re-tracing
    sata_qos_clear_steps: int = 4             # hysteresis: consecutive
                                              # pressure-free steps
                                              # before stepping one rung
                                              # back up
    sata_retire: str = "off"                  # off | on — cascade token
                                              # retirement (SpAtten):
                                              # accumulated block
                                              # importance rides the
                                              # plan's score pass; cold
                                              # blocks are retired, their
                                              # pages freed back to the
                                              # pool mid-stream.  LOSSY
                                              # by design once a pass
                                              # fires; "off" is bitwise
                                              # identical to the
                                              # pre-retirement stack
    sata_retire_decay: float = 0.9            # exponential decay of the
                                              # accumulated per-block
                                              # importance per step
    sata_retire_watermark: float = 0.75       # per-slot live-token
                                              # watermark (fraction of
                                              # max_len) that triggers a
                                              # retirement pass; pool
                                              # pressure (a deferred
                                              # claim) also triggers
    sata_retire_keep: float = 0.5             # retained-token budget: a
                                              # pass keeps this fraction
                                              # of the slot's live blocks
                                              # (the hottest by
                                              # importance; the current
                                              # append block and trie-/
                                              # swap-pinned pages are
                                              # never retired)

    # --- serving KV-cache layout ---
    kv_cache_layout: str = "contiguous"       # contiguous | paged — paged
                                              # serves from a global page
                                              # pool + per-slot page table
                                              # (pages allocated on append,
                                              # freed on reset_slot), so
                                              # short prefixes stop
                                              # reserving max_len HBM
    kv_page_size: int = 0                     # tokens per page (0 = the
                                              # decode k-block edge; SATA
                                              # decode requires equality —
                                              # plan blocks ARE pages)
    kv_pool_pages: int = 0                    # physical pages in the pool
                                              # (0 = slots·max_pages + 1:
                                              # contiguous-equivalent
                                              # capacity + overflow page)
    kv_prefix_cache: bool = False             # shared-prefix page cache
                                              # (paged layout only): a
                                              # prompt-prefix trie maps
                                              # cached prompt pages into
                                              # new slots (refcounted,
                                              # copy-on-write on append;
                                              # prefill runs only on the
                                              # unmatched tail)
    kv_lazy_cow: bool = False                 # lazy copy-on-write: a
                                              # partial-page prefix match
                                              # skips the eager CoW copy
                                              # when appended rows land
                                              # past the shared rows —
                                              # the sole appender holds a
                                              # write lease (revoked the
                                              # moment another slot maps
                                              # the page) instead of
                                              # copying
    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    q_chunk: int = 1024                       # query-chunked attention

    # --- norms / mlp ---
    norm_type: str = "rmsnorm"                # rmsnorm | layernorm | nonparam_ln
    mlp_variant: str = "swiglu"               # swiglu | gelu
    tie_embeddings: bool = False

    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    experts_per_token: int = 0
    moe_group_size: int = 128                 # GShard dispatch group
    capacity_factor: float = 1.25
    expert_shard: str = "expert"              # expert→model | tensor→model

    # --- SSM / hybrid (zamba2) ---
    ssm: bool = False                         # Mamba2 (SSD) backbone layers
    ssm_state: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    hybrid_period: int = 0                    # shared attn block every k layers

    # --- RWKV6 ---
    rwkv: bool = False
    rwkv_head_dim: int = 64

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_len: int = 1500                   # precomputed frame embeddings

    # --- VLM (llama-3.2-vision) ---
    cross_attn_period: int = 0                # cross-attn every k-th layer
    n_image_tokens: int = 0

    # --- numerics / memory ---
    dtype: str = "bfloat16"
    remat: str = "full"                       # none | dots | full
    scan_layers: bool = True
    micro_steps: int = 1                      # grad-accumulation microbatches
    rwkv_chunk: int = 256                     # time-scan remat chunk

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:                 # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Total parameters N (for MODEL_FLOPS = 6·N·D roofline term)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.rwkv:
            att = d * (4 * d) + d * d            # r,k,v,g (+w lora-ish) + out
            ffn = 2 * d * self.d_ff + self.d_ff * d
            per_layer = att + ffn
            return emb + self.n_layers * per_layer
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.moe:
            ffn = self.n_experts * (3 * d * self.d_ff) + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff if self.mlp_variant == "swiglu" \
                else 2 * d * self.d_ff
        if self.ssm:
            # mamba2 block: in_proj (z,x,B,C,dt) + conv + out_proj
            di, ns = self.d_inner, self.ssm_state
            proj_in = d * (2 * di + 2 * ns * 1 + self.ssm_heads)
            mamba = proj_in + di * self.ssm_conv + di * d
            n_attn = (self.n_layers // self.hybrid_period
                      if self.hybrid_period else 0)
            return (emb + self.n_layers * (mamba + ffn // 1)
                    + (attn + 3 * d * self.d_ff) * (1 if n_attn else 0))
        n_cross = (self.n_layers // self.cross_attn_period
                   if self.cross_attn_period else 0)
        total = emb + self.n_layers * (attn + ffn) + n_cross * attn
        if self.encoder_layers:
            total += self.encoder_layers * (attn + ffn) \
                + self.n_layers * attn               # decoder cross-attn
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dense_ffn = self.n_experts * (3 * d * self.d_ff)
        active_ffn = self.experts_per_token * (3 * d * self.d_ff)
        return self.param_count() - self.n_layers * (dense_ffn - active_ffn)
