"""Shared neural layers — pure-functional JAX (params = nested dicts)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def _dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """bf16 matmul with fp32 accumulation (MXU semantics)."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Non-differentiable sorts (top-k selection primitives).
# lax.sort's JVP rule builds batched gathers that (a) this jax build
# mis-handles under lax.map and (b) are pointless for discrete selection.
# custom_jvp with zero tangents keeps sort out of the AD graph entirely;
# lax.top_k is avoided because its TopK custom-call cannot be partitioned
# by GSPMD (it would all-gather the operand across the mesh).
# ---------------------------------------------------------------------------

@jax.custom_jvp
def sort_ascending(x: jax.Array) -> jax.Array:
    return jax.lax.sort(x, dimension=-1)


@sort_ascending.defjvp
def _sort_ascending_jvp(primals, tangents):
    out = sort_ascending(primals[0])
    return out, jnp.zeros_like(out)


@jax.custom_jvp
def _argsort_desc_f32(x: jax.Array) -> jax.Array:
    iota = jnp.broadcast_to(
        jnp.arange(x.shape[-1], dtype=jnp.int32), x.shape)
    _, si = jax.lax.sort((x, iota), dimension=-1, num_keys=1)
    return jnp.flip(si, axis=-1).astype(jnp.float32)


@_argsort_desc_f32.defjvp
def _argsort_desc_jvp(primals, tangents):
    out = _argsort_desc_f32(primals[0])
    return out, jnp.zeros_like(out)


def argsort_descending(x: jax.Array) -> jax.Array:
    """Indices sorting the last dim in descending order; no gradient."""
    return _argsort_desc_f32(x).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(cfg, d: int) -> Params:
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    if cfg.norm_type == "nonparam_ln":       # OLMo: no learnable params
        return {}
    raise ValueError(cfg.norm_type)


def apply_norm(params: Params, cfg, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        out = xf / rms * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        if cfg.norm_type == "layernorm":
            out = out * params["scale"] + params["bias"]
    return out.astype(x.dtype)


def rms_head_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm over head_dim (Qwen3 qk-norm)."""
    xf = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf / rms * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]                             # broadcast heads
    cos = cos[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, d: int, d_ff: int) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_variant == "swiglu":
        return {"wi": dense_init(ks[0], d, d_ff, dt),
                "wg": dense_init(ks[1], d, d_ff, dt),
                "wo": dense_init(ks[2], d_ff, d, dt)}
    return {"wi": dense_init(ks[0], d, d_ff, dt),
            "wo": dense_init(ks[2], d_ff, d, dt)}


def mlp_apply(params: Params, cfg, x: jax.Array) -> jax.Array:
    if cfg.mlp_variant == "swiglu":
        h = jax.nn.silu(matmul(x, params["wg"])) * matmul(x, params["wi"])
    else:
        h = jax.nn.gelu(matmul(x, params["wi"]))
    return matmul(h, params["wo"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, cfg) -> Params:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"embedding": dense_init(k1, cfg.vocab_size, cfg.d_model, dt, scale=0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, cfg.d_model, cfg.vocab_size, dt)
    return p


def embed_apply(params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed_apply(params: Params, cfg, x: jax.Array) -> jax.Array:
    w = params.get("unembed")
    if w is None:
        w = params["embedding"].T
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # logits stay fp32
