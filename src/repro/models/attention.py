"""Attention — GQA with RoPE, qk-norm, and Top-K *selective token
attention* (the SATA workload, KVT/TTST-style) as a first-class variant.

Selective variant: per query, keep the top-``k`` key logits (threshold at
the k-th value — identical softmax result as index masking), softmax in
fp32 over the kept set.  Query-chunked so the (q, s) score tile never
exceeds ``q_chunk × S`` — the TPU analogue of SATA's S_f tiling, and the
granularity at which the Pallas block-sparse kernel skips empty tiles.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import ctx as dctx
from repro.distributed.ctx import constrain_heads, constrain_scores
from repro.models.layers import (Params, _dtype, apply_rope, dense_init,
                                 rms_head_norm)

NEG_INF = -2.0 ** 30


def attention_init(key, cfg, cross: bool = False) -> Params:
    dt = _dtype(cfg)
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, cfg.n_heads * hd, dt),
         "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
         "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
         "wo": dense_init(ks[3], cfg.n_heads * hd, d, dt)}
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((hd,), jnp.float32)
        p["k_scale"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(params: Params, cfg, x: jax.Array,
                 kv_src: Optional[jax.Array] = None):
    b = x.shape[0]
    hd = cfg.hd
    src = x if kv_src is None else kv_src
    q = (x @ params["wq"]).reshape(b, x.shape[1], cfg.n_heads, hd)
    k = (src @ params["wk"]).reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    v = (src @ params["wv"]).reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_head_norm(q, params["q_scale"])
        k = rms_head_norm(k, params["k_scale"])
    return q, k, v


def kth_largest(scores: jax.Array, k: int) -> jax.Array:
    """k-th largest value per row via HLO sort (NOT lax.top_k: TopK is a
    custom call the SPMD partitioner cannot shard — it would all-gather
    the full score tensor across the data axis)."""
    from repro.models.layers import sort_ascending
    srt = sort_ascending(scores)
    return jax.lax.slice_in_dim(srt, scores.shape[-1] - k,
                                scores.shape[-1] - k + 1, axis=-1)


def kth_largest_bisect(scores: jax.Array, k: int, iters: int = 16
                       ) -> jax.Array:
    """Distributed-friendly top-k threshold: fixed-iteration bisection on
    the score range, converging to the k-th largest value.

    Every iteration is an elementwise compare + a tiny row reduction —
    fully shardable along the key dim (a sequence-sharded KV cache needs
    only (B,KV,G,1)-sized all-reduces per step instead of resharding the
    whole score tensor for a sort).  Counting runs on a bf16 copy (half
    the bandwidth of the dominant pass; selection boundaries are already
    fuzzy at bf16 score precision) and 16 iterations resolve the
    threshold to range/2^16.  Returns a threshold t with
    count(scores >= t) >= k (ties may admit a few extra keys — the same
    superset semantics as the sort threshold)."""
    valid = scores > NEG_INF / 2
    sc = jnp.where(valid, scores, jnp.inf)
    lo = jnp.minimum(jnp.min(sc, axis=-1, keepdims=True), 0.0) - 1.0
    hi = jnp.max(jnp.where(valid, scores, -jnp.inf), axis=-1, keepdims=True)
    cnt_src = jnp.where(valid, scores, -jnp.inf).astype(jnp.bfloat16)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((cnt_src >= mid.astype(jnp.bfloat16))
                      .astype(jnp.int32), axis=-1, keepdims=True)
        take = cnt >= k                    # threshold lies at or above mid
        return (jnp.where(take, mid, lo), jnp.where(take, hi, mid))

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    # Loop invariant: count(cnt_src >= bf16(lo)) >= k.  The caller must
    # apply the mask with the SAME bf16 comparison or the invariant
    # breaks (fp32 compare against a bf16-counted threshold undershoots).
    return jax.lax.stop_gradient(lo)


def topk_mask_bisect(scores: jax.Array, k: int) -> jax.Array:
    """Boolean top-k mask via bisection, compare-consistent with the
    bf16 counting pass (guarantees >= k selected per row)."""
    lo = kth_largest_bisect(scores, k)
    valid = scores > NEG_INF / 2
    cnt_src = jnp.where(valid, scores, -jnp.inf).astype(jnp.bfloat16)
    return cnt_src >= lo.astype(jnp.bfloat16)


def topk_threshold_mask(scores: jax.Array, k: int,
                        impl: str = "auto") -> jax.Array:
    """Keep entries >= the k-th largest per row (== top-k up to ties).

    The threshold is a discrete selection decision (zero tangent), so
    gradients flow only through the kept logits — standard for trained
    top-k attention, and it keeps sort out of the backward graph.

    impl: "sort" (exact, O(S log S)), "bisect" (sharded/decode-friendly),
    or "auto" (bisect for long rows)."""
    n = scores.shape[-1]
    if k >= n:
        return jnp.ones_like(scores, dtype=bool)
    if impl == "bisect" or (impl == "auto" and n >= 8192):
        return topk_mask_bisect(scores, k)
    return scores >= kth_largest(scores, k)


def _attend(q: jax.Array, k: jax.Array, v: jax.Array, cfg,
            q_pos: jax.Array, k_pos: jax.Array,
            valid_k: Optional[jax.Array] = None,
            causal: bool = True) -> jax.Array:
    """Grouped-query attention over one query chunk.

    q: (B, Q, H, hd); k/v: (B, S, KV, hd); positions for masking.
    Scores laid out (B, KV, G, Q, S) — no repeat-materialization of K.
    """
    b, nq, h, hd = q.shape
    kv = cfg.n_kv_heads
    g = h // kv
    qg = q.reshape(b, nq, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / np.sqrt(hd))
    scores = (dctx.constrain_cp_scores(scores) if dctx.cp_enabled()
              else constrain_scores(scores))
    mask = jnp.ones(scores.shape[-2:], dtype=bool)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if valid_k is not None:
        mask = mask & valid_k[None, :]
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    if cfg.attention_variant == "topk":
        sel = topk_threshold_mask(scores, cfg.topk_k,
                                  impl=getattr(cfg, "topk_impl", "auto"))
        scores = jnp.where(sel, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v,
                     preferred_element_type=jnp.float32).astype(v.dtype)
    return out.reshape(b, nq, h, hd)


def _selective_ref(qf: jax.Array, kf: jax.Array, vf: jax.Array,
                   sel: jax.Array) -> jax.Array:
    """Pure-jnp exact selective attention over flattened heads — the
    math the Pallas kernel computes, used as its differentiation rule."""
    d = qf.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", qf.astype(jnp.float32),
                   kf.astype(jnp.float32)) * (1.0 / np.sqrt(d))
    s = jnp.where(sel, s, NEG_INF)
    any_key = sel.any(axis=-1, keepdims=True)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(any_key, p, 0.0)
    out = jnp.einsum("bqk,bkd->bqd", p, vf.astype(jnp.float32))
    return out.astype(qf.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _sata_kernel_call(qf, kf, vf, sel, blk: int, schedule: str):
    """Pallas forward + reference-recompute backward: ``pl.pallas_call``
    defines no VJP, so training paths differentiate through
    ``_selective_ref`` (identical math; dense recompute — see ROADMAP
    open item on fusing selection into the kernel)."""
    from repro.kernels.ops import sata_attention as sata_kernel_attention
    out, _ = sata_kernel_attention(qf, kf, vf, sel, q_block=blk,
                                   k_block=blk, exact=True,
                                   schedule=schedule)
    return out


def _sata_kernel_fwd(qf, kf, vf, sel, blk, schedule):
    return _sata_kernel_call(qf, kf, vf, sel, blk, schedule), \
        (qf, kf, vf, sel)


def _sata_kernel_bwd(blk, schedule, res, g):
    qf, kf, vf, sel = res
    _, vjp = jax.vjp(lambda q, k, v: _selective_ref(q, k, v, sel),
                     qf, kf, vf)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, np.zeros(sel.shape, jax.dtypes.float0)


_sata_kernel_call.defvjp(_sata_kernel_fwd, _sata_kernel_bwd)


def _attend_sata_kernel(q: jax.Array, k: jax.Array, v: jax.Array, cfg,
                        q_pos: jax.Array, k_pos: jax.Array,
                        causal: bool) -> jax.Array:
    """Top-k attention through the compacted-grid SATA Pallas kernel.

    q: (B, S, H, hd); k/v: (B, S, KV, hd).  Scores are computed once for
    top-k selection (as in ``_attend``); the attention itself then runs
    through plan → permute → kernel (``kernels.ops.sata_attention``,
    exact mode), so K/V tiles emptied by the SATA sort are neither
    fetched nor visited.  Differentiable: the kernel call carries a
    custom VJP that recomputes through ``_selective_ref``.  Only valid
    when S divides ``cfg.sata_block`` — ``attention_apply`` falls back
    to ``_attend`` otherwise.
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    # expand KV heads to per-query heads and flatten to (B·H, S, hd)
    kq = jnp.repeat(k, g, axis=2) if g > 1 else k
    vq = jnp.repeat(v, g, axis=2) if g > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = kq.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vf = vq.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    scores = jnp.einsum("bqd,bkd->bqk", qf, kf,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / np.sqrt(hd))
    admissible = jnp.ones((s, s), dtype=bool)
    if causal:
        admissible = admissible & (k_pos[None, :] <= q_pos[:, None])
    scores = jnp.where(admissible[None], scores, NEG_INF)
    sel = topk_threshold_mask(scores, cfg.topk_k,
                              impl=getattr(cfg, "topk_impl", "auto"))
    sel = sel & admissible[None]
    out = _sata_kernel_call(qf, kf, vf, sel, cfg.sata_block,
                            getattr(cfg, "sata_schedule", "compact"))
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


def _sata_kernel_ok(cfg, s: int, cross: bool) -> bool:
    """Static routing decision for the Pallas path: the sequence must
    tile exactly by ``cfg.sata_block``, and on a real TPU the block edge
    must be MXU-tileable (multiple of 128) or Mosaic fails to lower —
    anything else takes the ``_attend`` fallback.  Sharded runs (cp or
    a launcher-installed mesh) also fall back: ``pallas_call`` has no
    SPMD partitioning rule, so routing it would force-replicate the
    (B·H, S, S) score tensor onto every device."""
    if not getattr(cfg, "use_sata_kernel", False) or cross:
        return False
    if cfg.attention_variant != "topk" or dctx.cp_enabled() \
            or dctx.mesh_installed():
        return False
    blk = getattr(cfg, "sata_block", 128)
    if s % blk != 0:
        return False
    from repro.kernels.ops import default_interpret
    return default_interpret() or blk % 128 == 0


def attention_apply(params: Params, cfg, x: jax.Array,
                    positions: Optional[jax.Array] = None,
                    kv_src: Optional[jax.Array] = None,
                    causal: Optional[bool] = None,
                    use_rope: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill), query-chunked.

    ``kv_src`` switches to cross-attention (keys/values from the context
    sequence; non-causal, no RoPE on context keys).
    """
    b, s, d = x.shape
    cross = kv_src is not None
    causal = (cfg.causal and not cross) if causal is None else causal
    q, k, v = _project_qkv(params, cfg, x, kv_src)
    s_kv = k.shape[1]
    q_pos = jnp.arange(s) if positions is None else positions
    k_pos = jnp.arange(s_kv)
    if use_rope and not cross:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, k_pos, cfg.rope_theta)
    if dctx.cp_enabled():
        # context-parallel layout: q sequence-sharded, k/v replicated on
        # "model" — scores/softmax/top-k become row-parallel.
        k = dctx.constrain_cp_kv(k)
        v = dctx.constrain_cp_kv(v)
        if s <= 8192:
            # short sequences: single chunk, q stays sequence-sharded
            # (per-device scores are already 1/model-sized).
            q = dctx.constrain_cp_q(q)
            qc = s
        else:
            # long prefill: a single (S×S) f32 score tensor would not
            # fit even sharded (32k: 17 GB/dev for deepseek).  Gather q
            # batch-only, map over q chunks, and shard each chunk's
            # score ROWS over "model" (constrain_cp_scores) — balanced
            # across the model axis, ~1 GB/chunk transient.
            q = dctx.constrain_cp_kv(q)
            qc = min(cfg.q_chunk, s)
    else:
        q = constrain_heads(q)
        k = constrain_heads(k)
        v = constrain_heads(v)
        qc = min(cfg.q_chunk, s)
    if s % qc != 0:
        qc = s                                       # fallback: single chunk
    n_chunks = s // qc

    if _sata_kernel_ok(cfg, s, cross):
        out = _attend_sata_kernel(q, k, v, cfg, q_pos, k_pos, causal)
    elif n_chunks == 1:
        out = _attend(q, k, v, cfg, q_pos, k_pos, causal=causal)
    else:
        qs = q.reshape(b, n_chunks, qc, cfg.n_heads, cfg.hd)
        ps = q_pos.reshape(n_chunks, qc)

        def chunk(i):
            return _attend(qs[:, i], k, v, cfg, ps[i], k_pos, causal=causal)

        out = jax.lax.map(chunk, jnp.arange(n_chunks))   # (C, B, qc, H, hd)
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, cfg.n_heads, cfg.hd)
    return out.reshape(b, s, cfg.n_heads * cfg.hd) @ params["wo"]


# ---------------------------------------------------------------------------
# Decode path (KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> Dict[str, jax.Array]:
    hd = cfg.hd
    return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype)}


def attention_decode(params: Params, cfg, x: jax.Array, cache: Dict,
                     pos: jax.Array, use_rope: bool = True
                     ) -> Tuple[jax.Array, Dict]:
    """One-token decode: update cache at ``pos``, attend over the prefix.

    x: (B, 1, D); cache k/v: (B, S_max, KV, hd); pos: scalar int32.
    """
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, cfg, x)
    if use_rope:
        posv = jnp.full((1,), pos, dtype=jnp.int32)
        q = apply_rope(q, posv, cfg.rope_theta)
        k_new = apply_rope(k_new, posv, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                            k_new.astype(cache["k"].dtype),
                                            pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                            v_new.astype(cache["v"].dtype),
                                            pos, axis=1)
    s_max = k.shape[1]
    k_pos = jnp.arange(s_max)
    valid = k_pos <= pos
    out = _attend(q, k, v, cfg, jnp.full((1,), pos), k_pos,
                  valid_k=valid, causal=False)
    y = out.reshape(b, 1, cfg.n_heads * cfg.hd) @ params["wo"]
    return y, {"k": k, "v": v}


def cross_attention_decode(params: Params, cfg, x: jax.Array,
                           context_kv: Dict) -> jax.Array:
    """Decode-time cross-attention over precomputed context K/V."""
    b = x.shape[0]
    q = (x @ params["wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_head_norm(q, params["q_scale"])
    k, v = context_kv["k"], context_kv["v"]
    out = _attend(q, k, v, cfg, jnp.zeros((1,), jnp.int32),
                  jnp.arange(k.shape[1]), causal=False)
    return out.reshape(b, 1, cfg.n_heads * cfg.hd) @ params["wo"]


def precompute_cross_kv(params: Params, cfg, context: jax.Array) -> Dict:
    b, s, _ = context.shape
    k = (context @ params["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = (context @ params["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        k = rms_head_norm(k, params["k_scale"])
    return {"k": k, "v": v}
