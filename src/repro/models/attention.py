"""Attention — GQA with RoPE, qk-norm, and Top-K *selective token
attention* (the SATA workload, KVT/TTST-style) as a first-class variant.

Selective variant: per query, keep the top-``k`` key logits (threshold at
the k-th value — identical softmax result as index masking), softmax in
fp32 over the kept set.  Query-chunked so the (q, s) score tile never
exceeds ``q_chunk × S`` — the TPU analogue of SATA's S_f tiling, and the
granularity at which the Pallas block-sparse kernel skips empty tiles.

Kernel-route selection is two-pass and chunked by default wherever the
bisect threshold applies (``_chunked_selection_on``): pass 1
(``_select_chunked``) streams ``q_chunk × S`` score tiles and bisects
each row's top-k threshold with ``kth_largest_bisect`` — its
compare+count reduction is row-local, so only (B·H, S, 1) thresholds
persist — and, fused in the same stream, reduces each resident tile to
the kernel's block occupancy map.  The Pallas kernel then re-derives the
element mask per tile from the threshold (threshold mode), so the dense
(B·H, S, S) fp32 score tensor and boolean mask are never materialized.
Training follows suit: the chunked route's custom VJP
(``_sata_kernel_chunked_call``) saves (q, k, v, thresholds) — O(S)
selection state instead of the dense route's (B·H, S, S) ``sel``
residual — and its backward recomputes attention per q-chunk through
``_selective_ref_chunked`` (``jax.checkpoint`` per chunk), keeping the
backward's peak at one score tile as well.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection import (NEG_INF, kth_largest_bisect,  # noqa: F401
                                  select_thresholds_chunked,
                                  topk_mask_bisect)
from repro.distributed import ctx as dctx
from repro.distributed.ctx import constrain_heads, constrain_scores
from repro.models.layers import (Params, _dtype, apply_rope, dense_init,
                                 rms_head_norm)


def attention_init(key, cfg, cross: bool = False) -> Params:
    dt = _dtype(cfg)
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, cfg.n_heads * hd, dt),
         "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
         "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
         "wo": dense_init(ks[3], cfg.n_heads * hd, d, dt)}
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((hd,), jnp.float32)
        p["k_scale"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(params: Params, cfg, x: jax.Array,
                 kv_src: Optional[jax.Array] = None):
    b = x.shape[0]
    hd = cfg.hd
    src = x if kv_src is None else kv_src
    q = (x @ params["wq"]).reshape(b, x.shape[1], cfg.n_heads, hd)
    k = (src @ params["wk"]).reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    v = (src @ params["wv"]).reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_head_norm(q, params["q_scale"])
        k = rms_head_norm(k, params["k_scale"])
    return q, k, v


def kth_largest(scores: jax.Array, k: int) -> jax.Array:
    """k-th largest value per row via HLO sort (NOT lax.top_k: TopK is a
    custom call the SPMD partitioner cannot shard — it would all-gather
    the full score tensor across the data axis)."""
    from repro.models.layers import sort_ascending
    srt = sort_ascending(scores)
    return jax.lax.slice_in_dim(srt, scores.shape[-1] - k,
                                scores.shape[-1] - k + 1, axis=-1)


BISECT_AUTO_MIN_S = 8192     # "auto" switches sort → bisect at this row len


def _use_bisect_impl(impl: str, n: int) -> bool:
    """Single source of truth for the sort-vs-bisect threshold decision:
    ``topk_threshold_mask`` and the chunked-selection routing
    (``_chunked_selection_on``) must agree, or "auto" routing would
    silently change the selected superset."""
    return impl == "bisect" or (impl == "auto" and n >= BISECT_AUTO_MIN_S)


def topk_threshold_mask(scores: jax.Array, k: int,
                        impl: str = "auto") -> jax.Array:
    """Keep entries >= the k-th largest per row (== top-k up to ties).

    The threshold is a discrete selection decision (zero tangent), so
    gradients flow only through the kept logits — standard for trained
    top-k attention, and it keeps sort out of the backward graph.

    impl: "sort" (exact, O(S log S)), "bisect" (sharded/decode-friendly),
    or "auto" (bisect for long rows)."""
    n = scores.shape[-1]
    if k >= n:
        return jnp.ones_like(scores, dtype=bool)
    if _use_bisect_impl(impl, n):
        return topk_mask_bisect(scores, k)
    return scores >= kth_largest(scores, k)


def _attend(q: jax.Array, k: jax.Array, v: jax.Array, cfg,
            q_pos: jax.Array, k_pos: jax.Array,
            valid_k: Optional[jax.Array] = None,
            causal: bool = True) -> jax.Array:
    """Grouped-query attention over one query chunk.

    q: (B, Q, H, hd); k/v: (B, S, KV, hd); positions for masking.
    Scores laid out (B, KV, G, Q, S) — no repeat-materialization of K.
    """
    b, nq, h, hd = q.shape
    kv = cfg.n_kv_heads
    g = h // kv
    qg = q.reshape(b, nq, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / np.sqrt(hd))
    scores = (dctx.constrain_cp_scores(scores) if dctx.cp_enabled()
              else constrain_scores(scores))
    # mask carries an optional batch axis: per-slot decode validity
    # (``valid_k`` (B, S)) differs across the batch, everything else
    # broadcasts from (1, Q, S).
    mask = jnp.ones((1,) + scores.shape[-2:], dtype=bool)
    if causal:
        mask = mask & (k_pos[None, None, :] <= q_pos[None, :, None])
    if valid_k is not None:
        vk = valid_k if valid_k.ndim == 2 else valid_k[None]
        mask = mask & vk[:, None, :]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    if cfg.attention_variant == "topk":
        sel = topk_threshold_mask(scores, cfg.topk_k,
                                  impl=getattr(cfg, "topk_impl", "auto"))
        scores = jnp.where(sel, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v,
                     preferred_element_type=jnp.float32).astype(v.dtype)
    return out.reshape(b, nq, h, hd)


def _select_chunked(qf: jax.Array, kf: jax.Array, k_sel: int, *,
                    q_pos: jax.Array, k_pos: jax.Array,
                    causal: bool = True,
                    sm_scale: Optional[float] = None,
                    chunk: Optional[int] = None,
                    q_block: int = 128, k_block: int = 128
                    ) -> Tuple[jax.Array, jax.Array]:
    """Chunked selection pipeline, pass 1 (+ fused pass 2): stream
    ``chunk × Sk`` score tiles, bisect each row's top-k threshold
    (``kth_largest_bisect`` — its compare+count reduction is row-local,
    so chunking over queries is exact), and reduce the same resident
    tile to block occupancy.  The model-layer entry point; the
    implementation is ``core.selection.select_thresholds_chunked`` (the
    kernel planner calls it there without importing the model layer).

    qf: (BH, Sq, D); kf: (BH, Sk, D); q_pos (Sq,) / k_pos (Sk,).
    Returns ``(thresholds (BH, Sq, 1) fp32, block_map (BH, nqb, nkb))``.
    Nothing quadratic is ever live: peak selection state is one
    (BH, chunk, Sk) score tile, and only O(Sq) thresholds plus the
    block-granular occupancy map persist.
    """
    return select_thresholds_chunked(qf, kf, k_sel, q_pos=q_pos,
                                     k_pos=k_pos, causal=causal,
                                     sm_scale=sm_scale, chunk=chunk,
                                     q_block=q_block, k_block=k_block)


def _selective_ref(qf: jax.Array, kf: jax.Array, vf: jax.Array,
                   sel: jax.Array) -> jax.Array:
    """Pure-jnp exact selective attention over flattened heads — the
    math the Pallas kernel computes, used as its differentiation rule."""
    d = qf.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", qf.astype(jnp.float32),
                   kf.astype(jnp.float32)) * (1.0 / np.sqrt(d))
    s = jnp.where(sel, s, NEG_INF)
    any_key = sel.any(axis=-1, keepdims=True)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(any_key, p, 0.0)
    out = jnp.einsum("bqk,bkd->bqd", p, vf.astype(jnp.float32))
    return out.astype(qf.dtype)


def _selective_ref_chunked(qf, kf, vf, thr, q_pos, k_pos, *,
                           causal: bool, chunk: int) -> jax.Array:
    """Chunked exact selective attention re-derived from the per-row
    top-k *threshold* — the differentiation rule for the chunked kernel
    route.  Rides ``core.blockmap.stream_score_chunks`` with
    ``remat=True``, so the backward recomputes one (BH, chunk, Sk)
    score tile at a time instead of saving (BH, Sq, Sk)."""
    from repro.core.blockmap import bisect_select, stream_score_chunks
    bh, s, d = qf.shape

    def _fn(sc, adm, t_c):
        sel = bisect_select(sc, t_c) & adm
        sc = jnp.where(sel, sc, NEG_INF)
        any_key = sel.any(axis=-1, keepdims=True)
        p = jax.nn.softmax(sc, axis=-1)
        p = jnp.where(any_key, p, 0.0)
        return jnp.einsum("bqk,bkd->bqd", p, vf.astype(jnp.float32))

    out = stream_score_chunks(qf, kf, _fn, chunk=chunk, causal=causal,
                              q_pos=q_pos, k_pos=k_pos, extras=(thr,),
                              remat=True)
    return jnp.moveaxis(out, 0, 1).reshape(bh, s, d).astype(qf.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _sata_kernel_call(qf, kf, vf, sel, blk: int, schedule: str,
                      max_kv_blocks: Optional[int]):
    """Pallas forward + reference-recompute backward: ``pl.pallas_call``
    defines no VJP, so training paths differentiate through
    ``_selective_ref`` (identical math; dense recompute).  The residual
    carries the full (BH, Sq, Sk) ``sel`` mask — the chunked route
    (``_sata_kernel_chunked_call``) replaces it with O(Sq) thresholds."""
    from repro.kernels.ops import sata_attention as sata_kernel_attention
    out, _ = sata_kernel_attention(qf, kf, vf, sel, q_block=blk,
                                   k_block=blk, exact=True,
                                   schedule=schedule,
                                   max_kv_blocks=max_kv_blocks)
    return out


def _sata_kernel_fwd(qf, kf, vf, sel, blk, schedule, max_kv_blocks):
    return _sata_kernel_call(qf, kf, vf, sel, blk, schedule,
                             max_kv_blocks), (qf, kf, vf, sel)


def _check_bwd_untruncated(max_kv_blocks, nkb: int,
                           on_exceed: str = "truncate") -> None:
    """A truncating ``max_kv_blocks`` drops occupied tiles in the
    *forward* kernel, but the reference recompute differentiates the
    full selected set — the gradients would belong to a different
    function than the value.  Refuse to train through it rather than
    bias gradients silently.  The ``"dense"`` overflow fallback is
    exempt: its forward is loss-free by construction (rows within the
    bound drop nothing, and an overflow re-routes to the full-width
    schedule), so value and gradient describe the same function."""
    if max_kv_blocks is not None and max_kv_blocks < nkb \
            and on_exceed != "dense":
        raise NotImplementedError(
            f"backward through a truncating max_kv_blocks "
            f"({max_kv_blocks} < nkb={nkb}) would differentiate a "
            f"different function than the forward computes — unset "
            f"sata_max_kv_blocks (or use the full nkb, or "
            f"sata_bound_fallback='dense') for training")


def _sata_kernel_bwd(blk, schedule, max_kv_blocks, res, g):
    qf, kf, vf, sel = res
    _check_bwd_untruncated(max_kv_blocks, sel.shape[-1] // blk)
    _, vjp = jax.vjp(lambda q, k, v: _selective_ref(q, k, v, sel),
                     qf, kf, vf)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, np.zeros(sel.shape, jax.dtypes.float0)


_sata_kernel_call.defvjp(_sata_kernel_fwd, _sata_kernel_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11))
def _sata_kernel_chunked_call(qf, kf, vf, thr, bm, q_pos, k_pos,
                              blk: int, causal: bool, chunk: int,
                              max_kv_blocks: Optional[int],
                              on_exceed: str = "truncate"):
    """Chunked-selection kernel route: the Pallas kernel re-derives the
    element mask per tile from ``thr`` (threshold mode), and the custom
    VJP recomputes through ``_selective_ref_chunked`` from the same
    threshold — the residual is (q, k, v, thr): O(Sq) selection state
    instead of the dense route's (BH, Sq, Sk) ``sel`` mask."""
    from repro.kernels.ops import sata_attention as sata_kernel_attention
    out, _ = sata_kernel_attention(
        qf, kf, vf, None, q_block=blk, k_block=blk, exact=True,
        schedule="compact", selection="chunked", causal=causal,
        sel_chunk=chunk, max_kv_blocks=max_kv_blocks,
        thresholds=thr, block_map=bm, q_pos=q_pos, k_pos=k_pos,
        on_exceed=on_exceed)
    return out


def _sata_kernel_chunked_fwd(qf, kf, vf, thr, bm, q_pos, k_pos,
                             blk, causal, chunk, max_kv_blocks,
                             on_exceed):
    out = _sata_kernel_chunked_call(qf, kf, vf, thr, bm, q_pos, k_pos,
                                    blk, causal, chunk, max_kv_blocks,
                                    on_exceed)
    return out, (qf, kf, vf, thr, bm, q_pos, k_pos)


def _sata_kernel_chunked_bwd(blk, causal, chunk, max_kv_blocks,
                             on_exceed, res, g):
    qf, kf, vf, thr, bm, q_pos, k_pos = res
    _check_bwd_untruncated(max_kv_blocks, bm.shape[-1], on_exceed)
    _, vjp = jax.vjp(
        lambda q, k, v: _selective_ref_chunked(q, k, v, thr, q_pos, k_pos,
                                               causal=causal, chunk=chunk),
        qf, kf, vf)
    dq, dk, dv = vjp(g)
    f0 = lambda a: np.zeros(a.shape, jax.dtypes.float0)   # int/bool inputs
    # the threshold is a discrete selection decision — zero tangent,
    # matching the dense route's float0 on `sel`
    return dq, dk, dv, jnp.zeros_like(thr), f0(bm), f0(q_pos), f0(k_pos)


_sata_kernel_chunked_call.defvjp(_sata_kernel_chunked_fwd,
                                 _sata_kernel_chunked_bwd)


def _chunked_selection_on(cfg, s: int) -> bool:
    """Route top-k selection through the chunked (mask-free) pipeline?

    ``cfg.sata_selection``: "chunked" / "dense" force a route; "auto"
    goes chunked exactly when ``topk_threshold_mask`` would pick the
    bisect threshold anyway (``topk_impl`` "bisect", or "auto" at long
    S) — the chunked pass-1 threshold is bit-identical to the dense
    bisect one, so "auto" never changes the selected superset.  The
    chunked route only exists on the compact grid, so a
    ``sata_schedule="dense"`` baseline keeps dense selection under
    "auto" and is rejected under a forced "chunked"."""
    mode = cfg.sata.kernel.selection
    schedule = cfg.sata.kernel.schedule
    if mode == "chunked":
        if schedule != "compact":
            raise ValueError(
                "sata_selection='chunked' requires sata_schedule="
                "'compact' (the dense grid has no threshold mode)")
        return True
    if mode == "dense" or schedule != "compact":
        return False
    return _use_bisect_impl(getattr(cfg, "topk_impl", "auto"), s)


def _attend_sata_kernel(q: jax.Array, k: jax.Array, v: jax.Array, cfg,
                        q_pos: jax.Array, k_pos: jax.Array,
                        causal: bool) -> jax.Array:
    """Top-k attention through the compacted-grid SATA Pallas kernel.

    q: (B, S, H, hd); k/v: (B, S, KV, hd).  Two selection routes feed
    the kernel (``_chunked_selection_on`` picks one):

    * dense — scores are computed once as a full (B·H, S, S) fp32
      tensor, top-k masked, and the attention runs through
      plan → permute → kernel (``kernels.ops.sata_attention``, exact
      mode), so K/V tiles emptied by the SATA sort are neither fetched
      nor visited.  The custom VJP recomputes through
      ``_selective_ref`` from the stored ``sel`` mask.
    * chunked — ``_select_chunked`` streams ``q_chunk × S`` score tiles
      to bisect each row's top-k threshold and reduce tile occupancy in
      the same pass; the kernel then re-derives the element mask per
      tile from the (B·H, S, 1) thresholds (threshold mode), so neither
      the score tensor nor the boolean mask is ever materialized.  The
      custom VJP recomputes through ``_selective_ref_chunked`` from the
      threshold — the residual shrinks from O(S²) to O(S).  Keys stay
      unsorted (the token-level SATA sort would itself need a quadratic
      Gram matrix) and the schedule is always the compact grid.

    Only valid when S divides ``cfg.sata_block`` — ``attention_apply``
    falls back to ``_attend`` otherwise.
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    # expand KV heads to per-query heads and flatten to (B·H, S, hd)
    kq = jnp.repeat(k, g, axis=2) if g > 1 else k
    vq = jnp.repeat(v, g, axis=2) if g > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = kq.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vf = vq.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    blk = cfg.sata.kernel.block
    mkb = cfg.sata.kernel.max_kv_blocks
    if _chunked_selection_on(cfg, s):
        from repro.core.blockmap import resolve_sel_chunk
        chunk = resolve_sel_chunk(min(cfg.q_chunk, s), s, blk)
        qp = q_pos.astype(jnp.int32)
        kp = k_pos.astype(jnp.int32)
        thr, bm = _select_chunked(qf, kf, cfg.topk_k, q_pos=qp, k_pos=kp,
                                  causal=causal, chunk=chunk,
                                  q_block=blk, k_block=blk)
        out = _sata_kernel_chunked_call(
            qf, kf, vf, thr, bm, qp, kp, blk, causal, chunk, mkb,
            cfg.sata.kernel.bound_fallback)
    else:
        scores = jnp.einsum("bqd,bkd->bqk", qf, kf,
                            preferred_element_type=jnp.float32)
        scores = scores * (1.0 / np.sqrt(hd))
        admissible = jnp.ones((s, s), dtype=bool)
        if causal:
            admissible = admissible & (k_pos[None, :] <= q_pos[:, None])
        scores = jnp.where(admissible[None], scores, NEG_INF)
        sel = topk_threshold_mask(scores, cfg.topk_k,
                                  impl=getattr(cfg, "topk_impl", "auto"))
        sel = sel & admissible[None]
        out = _sata_kernel_call(qf, kf, vf, sel, blk,
                                cfg.sata.kernel.schedule,
                                mkb)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


def _sata_kernel_ok(cfg, s: int, cross: bool) -> bool:
    """Static routing decision for the Pallas path: the sequence must
    tile exactly by ``cfg.sata_block``, and on a real TPU the block edge
    must be MXU-tileable (multiple of 128) or Mosaic fails to lower —
    anything else takes the ``_attend`` fallback.  Sharded runs (cp or
    a launcher-installed mesh) also fall back: ``pallas_call`` has no
    SPMD partitioning rule, so routing it would force-replicate the
    (B·H, S, S) score tensor onto every device."""
    if not cfg.sata.kernel.use or cross:
        return False
    if cfg.attention_variant != "topk" or dctx.cp_enabled() \
            or dctx.mesh_installed():
        return False
    blk = cfg.sata.kernel.block
    if s % blk != 0:
        return False
    from repro.kernels.ops import default_interpret
    return default_interpret() or blk % 128 == 0


def attention_apply(params: Params, cfg, x: jax.Array,
                    positions: Optional[jax.Array] = None,
                    kv_src: Optional[jax.Array] = None,
                    causal: Optional[bool] = None,
                    use_rope: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill), query-chunked.

    ``kv_src`` switches to cross-attention (keys/values from the context
    sequence; non-causal, no RoPE on context keys).
    """
    b, s, d = x.shape
    cross = kv_src is not None
    causal = (cfg.causal and not cross) if causal is None else causal
    q, k, v = _project_qkv(params, cfg, x, kv_src)
    s_kv = k.shape[1]
    q_pos = jnp.arange(s) if positions is None else positions
    k_pos = jnp.arange(s_kv)
    if use_rope and not cross:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, k_pos, cfg.rope_theta)
    if dctx.cp_enabled():
        # context-parallel layout: q sequence-sharded, k/v replicated on
        # "model" — scores/softmax/top-k become row-parallel.
        k = dctx.constrain_cp_kv(k)
        v = dctx.constrain_cp_kv(v)
        if s <= 8192:
            # short sequences: single chunk, q stays sequence-sharded
            # (per-device scores are already 1/model-sized).
            q = dctx.constrain_cp_q(q)
            qc = s
        else:
            # long prefill: a single (S×S) f32 score tensor would not
            # fit even sharded (32k: 17 GB/dev for deepseek).  Gather q
            # batch-only, map over q chunks, and shard each chunk's
            # score ROWS over "model" (constrain_cp_scores) — balanced
            # across the model axis, ~1 GB/chunk transient.
            q = dctx.constrain_cp_kv(q)
            qc = min(cfg.q_chunk, s)
    else:
        q = constrain_heads(q)
        k = constrain_heads(k)
        v = constrain_heads(v)
        qc = min(cfg.q_chunk, s)
    if s % qc != 0:
        qc = s                                       # fallback: single chunk
    n_chunks = s // qc

    if _sata_kernel_ok(cfg, s, cross):
        out = _attend_sata_kernel(q, k, v, cfg, q_pos, k_pos, causal)
    elif n_chunks == 1:
        out = _attend(q, k, v, cfg, q_pos, k_pos, causal=causal)
    else:
        qs = q.reshape(b, n_chunks, qc, cfg.n_heads, cfg.hd)
        ps = q_pos.reshape(n_chunks, qc)

        def chunk(i):
            return _attend(qs[:, i], k, v, cfg, ps[i], k_pos, causal=causal)

        out = jax.lax.map(chunk, jnp.arange(n_chunks))   # (C, B, qc, H, hd)
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, cfg.n_heads, cfg.hd)
    return out.reshape(b, s, cfg.n_heads * cfg.hd) @ params["wo"]


# ---------------------------------------------------------------------------
# Decode path (KV cache)
# ---------------------------------------------------------------------------

def decode_block_size(cfg, max_len: int) -> int:
    """Decode k-block edge: ``sata_decode_block`` (default
    ``sata_block``), clamped so at least one block tiles the cache."""
    blk = cfg.sata.decode.block or \
        cfg.sata.kernel.block
    return min(blk, max_len)


def paged_kv_on(cfg) -> bool:
    """Serve from the paged pool layout (``core/paging.py``)?"""
    return cfg.kv.layout == "paged"


def prefix_cache_on(cfg) -> bool:
    """Shared-prefix page cache (``core.paging.PrefixCache``)?  Only
    meaningful on the paged layout — sharing IS page-table aliasing."""
    if not cfg.kv.prefix_cache:
        return False
    if not paged_kv_on(cfg):
        raise ValueError(
            "kv_prefix_cache=True requires kv_cache_layout='paged' — "
            "prefix sharing aliases physical pages through the page "
            "table, which the contiguous layout does not have")
    return True


def kv_page_size(cfg, max_len: int) -> int:
    """Tokens per page: ``kv_page_size`` or the decode k-block edge —
    the equality SATA decode requires (plan blocks ARE pages)."""
    page = cfg.kv.page_size or decode_block_size(cfg, max_len)
    return min(int(page), max_len)


def sata_decode_on(cfg, max_len: int) -> bool:
    """Route single-token decode through the incremental KV-block plan
    + gather kernel?  ``sata_decode``: "on"/"off" force; "auto" follows
    the same bisect decision as prefill selection — SATA decode needs
    per-row bisect thresholds, so it turns on exactly when
    ``topk_threshold_mask`` would bisect a ``max_len`` row anyway.
    Sharded runs fall back (``pallas_call`` has no SPMD rule)."""
    mode = cfg.sata.decode.mode
    if mode == "off" or cfg.attention_variant != "topk":
        return False
    if dctx.cp_enabled() or dctx.mesh_installed():
        return False
    blk = decode_block_size(cfg, max_len)
    if max_len % blk != 0:
        if mode == "on":
            raise ValueError(
                f"sata_decode='on' needs the cache length ({max_len}) to "
                f"tile by the decode block ({blk}) — set sata_decode_block")
        return False
    if mode == "on":
        return True
    return _use_bisect_impl(getattr(cfg, "topk_impl", "auto"), max_len)


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> Dict[str, jax.Array]:
    """Serving self-attention cache for one layer.

    Contiguous layout: per-slot ``k``/``v`` (B, max_len, KV, hd)
    regions.  Paged layout (``kv_cache_layout="paged"``): a global
    ``k_pages``/``v_pages`` pool (n_pages, page, KV, hd) plus a
    per-slot ``page_table`` (B, max_pages) int32 — pages map on append
    and free on request completion (``core/paging.py``), so ``max_len``
    bounds only the *logical* address space, not reserved HBM.  Either
    way a SATA decode ``plan`` rides alongside when routing is on; in
    the paged layout its block edge must equal the page size (plan
    blocks ARE pages, so the decode kernel can dereference the table)."""
    hd = cfg.hd
    sata = sata_decode_on(cfg, max_len)
    if paged_kv_on(cfg):
        from repro.core.paging import OVERFLOW_PAGE
        page = kv_page_size(cfg, max_len)
        if max_len % page:
            raise ValueError(f"max_len ({max_len}) must tile by the page "
                             f"size ({page})")
        max_pages = max_len // page
        n_pages = cfg.kv.pool_pages or batch * max_pages + 1
        cache = {
            "k_pages": jnp.zeros((n_pages, page, cfg.n_kv_heads, hd), dtype),
            "v_pages": jnp.zeros((n_pages, page, cfg.n_kv_heads, hd), dtype),
            "page_table": jnp.full((batch, max_pages), OVERFLOW_PAGE,
                                   jnp.int32),
        }
        if prefix_cache_on(cfg):
            # per-physical-page refcounts (driver-pushed): the paged
            # write path write-protects shared pages with them
            cache["page_ref"] = jnp.zeros((n_pages,), jnp.int32)
            if sata:
                # per-physical-page K summaries: registered prompt
                # pages keep their block bounds here, so a cache-hit
                # install seeds the decode plan's matched blocks
                # without re-reading their keys (bit-identical to a
                # from-scratch recompute under either backend — fp32
                # by min/max associativity, int8 because identical
                # fp32 bounds quantize identically)
                from repro.core.paging import init_page_summaries
                cache.update(init_page_summaries(
                    n_pages, cfg.n_kv_heads, hd,
                    cfg.sata.decode.summary))
        if sata:
            blk = decode_block_size(cfg, max_len)
            if blk != page:
                raise ValueError(
                    f"paged SATA decode needs kv_page_size == the decode "
                    f"k-block edge ({page} != {blk}): the plan's logical "
                    f"blocks must BE pages for the kernel's index maps to "
                    f"dereference the page table")
    else:
        cache = {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
                 "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype)}
    if sata:
        from repro.core.decode_plan import init_decode_plan
        qos = bool(cfg.sata.qos.ladder)
        if qos and cfg.sata.decode.replan == "auto":
            raise ValueError(
                "sata_qos_ladder drives the re-plan beat through the "
                "per-slot interval vector — set an integer "
                "sata_decode_replan, not 'auto'")
        cache["plan"] = init_decode_plan(
            batch, cfg.n_kv_heads, max_len, hd,
            decode_block_size(cfg, max_len),
            cfg.sata.decode.blocks,
            summary=cfg.sata.decode.summary,
            qos=qos,
            retire=cfg.sata.retire.mode == "on",
            # the ladder's full-quality rung starts at the configured
            # beat; the per-slot interval vector owns it from there
            replan_interval=_resolve_replan(cfg)[0] if qos else 1)
    return cache


def _per_slot_positions(pos: jax.Array, batch: int) -> jax.Array:
    """Normalize ``pos`` to per-slot (B,) int32 — scalar callers (all
    slots in lockstep) broadcast; serving passes a vector so each slot
    decodes at its own position."""
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (batch,))


def _cache_scatter(cache: jax.Array, new: jax.Array, pos: jax.Array
                   ) -> jax.Array:
    """Write each slot's new K/V row at its own position.
    cache: (B, S, KV, hd); new: (B, 1, KV, hd); pos: (B,)."""
    upd = jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0))
    return upd(cache, new.astype(cache.dtype), pos)


def _resolve_replan(cfg) -> Tuple[int, Optional[float]]:
    """``sata_decode_replan`` → (interval, churn_budget): an integer
    keeps the fixed-interval trigger (budget None, bit-compatible);
    ``"auto"`` switches to the churn-adaptive trigger with
    ``sata_decode_churn`` as the accumulated-churn budget."""
    rp = cfg.sata.decode.replan
    if rp == "auto":
        return 1, float(cfg.sata.decode.churn)
    return int(rp), None


def _attend_sata_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                        k_new: jax.Array, cfg, pos: jax.Array,
                        plan: Dict, *, k_block: int,
                        page_table: Optional[jax.Array] = None
                        ) -> Tuple[jax.Array, Dict]:
    """Decode attention through the incremental plan + gather kernel.

    q: (B, 1, H, hd); k/v: the updated cache — (B, S, KV, hd)
    contiguous, or the (n_pages, page, KV, hd) pool when ``page_table``
    is given (paged layout; ``k_block`` == page); k_new: (B, 1, KV, hd)
    the key row just written (summaries absorb it incrementally);
    pos: (B,).  Returns ((B, 1, H, hd), plan')."""
    from repro.core.decode_plan import (decode_plan_update,
                                        update_block_summaries)
    from repro.kernels.ops import sata_decode_attention
    b, _, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    # heads are kv-major (see _attend's grouped reshape), so the G query
    # heads sharing a KV head sit contiguously
    qg = q[:, 0].reshape(b, kv, g, hd)
    # summarize the value actually WRITTEN to the cache (same dtype
    # cast), so incremental summaries match a from-scratch recompute
    # over cache contents bit for bit
    plan = update_block_summaries(plan, k_new.astype(k.dtype), pos,
                                  k_block=k_block)
    interval, churn_budget = _resolve_replan(cfg)
    plan, thr = decode_plan_update(
        plan, qg, k, pos, topk_k=cfg.topk_k, k_block=k_block,
        replan_interval=interval, churn_budget=churn_budget,
        page_table=page_table,
        replan_mode=cfg.sata.decode.replan_mode,
        sketch_factor=cfg.sata.decode.sketch_factor,
        retire_decay=cfg.sata.retire.decay)
    out = sata_decode_attention(qg, k, v, plan["kv_indices"],
                                plan["kv_counts"], thr, pos,
                                k_block=k_block, page_table=page_table)
    return out.reshape(b, 1, h, hd), plan


def attention_decode(params: Params, cfg, x: jax.Array, cache: Dict,
                     pos: jax.Array, use_rope: bool = True
                     ) -> Tuple[jax.Array, Dict]:
    """One-token decode: update cache at ``pos``, attend over the prefix.

    x: (B, 1, D); cache k/v: (B, S_max, KV, hd) contiguous, or the
    paged pool (``k_pages``/``v_pages`` + ``page_table`` — see
    ``init_kv_cache``); pos: scalar int32 (all slots in lockstep) or
    (B,) int32 per-slot positions (continuous batching: each slot
    decodes at its own offset).

    When the cache carries a ``plan`` (``init_kv_cache`` attaches one
    iff ``sata_decode_on``), attention runs through the incremental
    KV-block plan + gather kernel instead of attending densely over the
    whole prefix — fetch cost scales with the selected blocks.
    """
    b = x.shape[0]
    pos = _per_slot_positions(pos, b)
    q, k_new, v_new = _project_qkv(params, cfg, x)
    if use_rope:
        posv = pos[:, None]                                  # (B, 1)
        q = apply_rope(q, posv, cfg.rope_theta)
        k_new = apply_rope(k_new, posv, cfg.rope_theta)
    if "k_pages" in cache:
        return _paged_decode_step(params, cfg, cache, q, k_new, v_new, pos)
    k = _cache_scatter(cache["k"], k_new, pos)
    v = _cache_scatter(cache["v"], v_new, pos)
    new_cache = {"k": k, "v": v}
    if "plan" in cache:
        blk = decode_block_size(cfg, k.shape[1])
        out, new_cache["plan"] = _attend_sata_decode(
            q, k, v, k_new, cfg, pos, cache["plan"], k_block=blk)
    else:
        s_max = k.shape[1]
        k_pos = jnp.arange(s_max)
        valid = k_pos[None, :] <= pos[:, None]               # (B, S)
        out = _attend(q, k, v, cfg, jnp.zeros((1,), jnp.int32), k_pos,
                      valid_k=valid, causal=False)
    y = out.reshape(b, 1, cfg.n_heads * cfg.hd) @ params["wo"]
    return y, new_cache


def _paged_decode_step(params: Params, cfg, cache: Dict, q: jax.Array,
                       k_new: jax.Array, v_new: jax.Array,
                       pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """One-token decode against the paged pool: scatter the new K/V row
    into each slot's current page (``page_table[b, pos // page]``),
    then attend — through the paged plan + gather kernel when a plan
    rides along, else densely over the gathered logical view.  A slot
    whose current page is unmapped writes to the overflow page (its
    output is garbage by construction and the serving driver discards
    it — see ``core/paging.py`` on stalls).

    With the prefix cache on, the cache carries driver-pushed per-page
    refcounts (``page_ref``): a write that would land in a SHARED page
    (refcount > 1 — the driver must copy-on-write it first) re-routes
    to the overflow page instead.  This is write-protection, not
    recovery — the structural guarantee that shared prompt pages are
    immutable holds even against a driver bug, at the price of that
    slot's token being garbage (position-masked, driver re-feeds on
    the stall path)."""
    from repro.core.paging import OVERFLOW_PAGE, logical_kv_view
    b = q.shape[0]
    kp, vp, tbl = cache["k_pages"], cache["v_pages"], cache["page_table"]
    page = kp.shape[1]
    phys = jnp.take_along_axis(tbl, (pos // page)[:, None], axis=1)[:, 0]
    ref = cache.get("page_ref")
    if ref is not None:
        phys = jnp.where(ref[phys] > 1, OVERFLOW_PAGE, phys)
    off = pos % page
    kp = kp.at[phys, off].set(k_new[:, 0].astype(kp.dtype))
    vp = vp.at[phys, off].set(v_new[:, 0].astype(vp.dtype))
    new_cache = {**cache, "k_pages": kp, "v_pages": vp}
    if "plan" in cache:
        out, new_cache["plan"] = _attend_sata_decode(
            q, kp, vp, k_new, cfg, pos, cache["plan"], k_block=page,
            page_table=tbl)
    else:
        k = logical_kv_view(kp, tbl)
        v = logical_kv_view(vp, tbl)
        k_pos = jnp.arange(k.shape[1])
        valid = k_pos[None, :] <= pos[:, None]               # (B, S)
        out = _attend(q, k, v, cfg, jnp.zeros((1,), jnp.int32), k_pos,
                      valid_k=valid, causal=False)
    y = out.reshape(b, 1, cfg.n_heads * cfg.hd) @ params["wo"]
    return y, new_cache


def cross_attention_decode(params: Params, cfg, x: jax.Array,
                           context_kv: Dict) -> jax.Array:
    """Decode-time cross-attention over precomputed context K/V.

    ``context_kv`` may carry ``"valid"`` (B, S_ctx) bool — the length
    mask for padded encoder contexts (audio frames / image tokens are
    padded to a fixed ``encoder_len``/``n_image_tokens``); without it
    every context position attends."""
    b = x.shape[0]
    q = (x @ params["wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_head_norm(q, params["q_scale"])
    k, v = context_kv["k"], context_kv["v"]
    out = _attend(q, k, v, cfg, jnp.zeros((1,), jnp.int32),
                  jnp.arange(k.shape[1]), valid_k=context_kv.get("valid"),
                  causal=False)
    return out.reshape(b, 1, cfg.n_heads * cfg.hd) @ params["wo"]


def precompute_cross_kv(params: Params, cfg, context: jax.Array) -> Dict:
    b, s, _ = context.shape
    k = (context @ params["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = (context @ params["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        k = rms_head_norm(k, params["k_scale"])
    return {"k": k, "v": v}
