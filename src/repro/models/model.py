"""Model assembly: family-specific stacks with scan-over-layers + remat.

Families
  dense / moe   — pre-norm decoder (attn + mlp|moe), scanned
  vlm           — decoder with a cross-attention layer every
                  ``cross_attn_period`` layers (grouped nested scan)
  hybrid        — Mamba2 backbone with a *shared* attention block every
                  ``hybrid_period`` layers (zamba2)
  audio         — encoder-decoder (whisper backbone; frontend stubbed to
                  precomputed frame embeddings)
  ssm           — RWKV6 stack (attention-free)

All stacks scan over stacked layer params (bounded HLO for 95-100 layer
models) with a configurable remat policy.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models import attention as attn
from repro.models import mamba2, moe, rwkv6
from repro.models.config import ModelConfig
from repro.models.layers import (Params, _dtype, apply_norm, embed_apply,
                                 embed_init, mlp_apply, mlp_init, norm_init,
                                 unembed_apply, dense_init)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _decoder_block_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": norm_init(cfg, cfg.d_model),
         "attn": attn.attention_init(k1, cfg, cross=cross),
         "ln2": norm_init(cfg, cfg.d_model)}
    if cfg.moe:
        p["moe"] = moe.moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k3, cfg, cfg.d_model, cfg.d_ff)
    if cross:
        p["lnx"] = norm_init(cfg, cfg.d_model)
    return p


def _decoder_block_apply(p: Params, cfg, x, causal=None):
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["ln1"], cfg, x)
    x = x + attn.attention_apply(p["attn"], cfg, h, causal=causal)
    h = apply_norm(p["ln2"], cfg, x)
    if cfg.moe:
        y, aux = moe.moe_apply(p["moe"], cfg, h)
        x = x + y
    else:
        x = x + mlp_apply(p["mlp"], cfg, h)
    return x, aux


def _cross_block_init(key, cfg) -> Params:
    """VLM cross-attention layer (llama-3.2-vision style gated x-attn)."""
    k1, k2 = jax.random.split(key)
    return {"ln1": norm_init(cfg, cfg.d_model),
            "attn": attn.attention_init(k1, cfg, cross=True),
            "gate": jnp.zeros((), jnp.float32),
            "ln2": norm_init(cfg, cfg.d_model),
            "mlp": mlp_init(k2, cfg, cfg.d_model, cfg.d_ff),
            "gate_mlp": jnp.zeros((), jnp.float32)}


def _cross_block_apply(p: Params, cfg, x, context):
    h = apply_norm(p["ln1"], cfg, x)
    y = attn.attention_apply(p["attn"], cfg, h, kv_src=context)
    x = x + jnp.tanh(p["gate"]).astype(x.dtype) * y
    h = apply_norm(p["ln2"], cfg, x)
    x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * mlp_apply(p["mlp"], cfg, h)
    return x


def _encdec_dec_block_init(key, cfg) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": norm_init(cfg, cfg.d_model),
            "attn": attn.attention_init(k1, cfg),
            "lnx": norm_init(cfg, cfg.d_model),
            "attn_cross": attn.attention_init(k2, cfg, cross=True),
            "ln2": norm_init(cfg, cfg.d_model),
            "mlp": mlp_init(k3, cfg, cfg.d_model, cfg.d_ff)}


def _encdec_dec_block_apply(p: Params, cfg, x, context):
    h = apply_norm(p["ln1"], cfg, x)
    x = x + attn.attention_apply(p["attn"], cfg, h)
    h = apply_norm(p["lnx"], cfg, x)
    x = x + attn.attention_apply(p["attn_cross"], cfg, h, kv_src=context)
    h = apply_norm(p["ln2"], cfg, x)
    return x + mlp_apply(p["mlp"], cfg, h)


def _mamba_block_init(key, cfg) -> Params:
    return {"ln": norm_init(cfg, cfg.d_model),
            "mixer": mamba2.mamba2_init(key, cfg)}


def _mamba_block_apply(p: Params, cfg, x):
    return x + mamba2.mamba2_apply(p["mixer"], cfg,
                                   apply_norm(p["ln"], cfg, x))


def _rwkv_block_init(key, cfg) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": norm_init(cfg, cfg.d_model),
            "tmix": rwkv6.rwkv6_init(k1, cfg),
            "ln2": norm_init(cfg, cfg.d_model)}


def _rwkv_block_apply(p: Params, cfg, x):
    h = apply_norm(p["ln1"], cfg, x)
    y, _, _ = rwkv6.rwkv6_time_mix(p["tmix"], cfg, h)
    x = x + y
    h = apply_norm(p["ln2"], cfg, x)
    y, _ = rwkv6.rwkv6_channel_mix(p["tmix"], cfg, h)
    return x + y


# ---------------------------------------------------------------------------
# Stacked-scan helpers
# ---------------------------------------------------------------------------

def _stack_init(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)


def maybe_scan(cfg, f, carry, xs):
    """lax.scan, or an unrolled Python loop when ``cfg.scan_layers`` is
    False (used by the roofline probes: XLA cost analysis counts a while
    body once regardless of trip count, so probes must unroll)."""
    if cfg.scan_layers:
        return jax.lax.scan(f, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _scan_stack(cfg, stacked: Params, x, body):
    """scan x through stacked layer params, accumulating aux losses."""
    def scan_body(carry, layer_params):
        h, aux = carry
        h, a = body(layer_params, h)
        return (constrain(h, "act"), aux + a), None

    (x, aux), _ = maybe_scan(cfg, _remat(cfg, scan_body),
                             (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


# ---------------------------------------------------------------------------
# init / forward
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    kemb, kstack, kextra, kfinal = jax.random.split(key, 4)
    params: Params = {"embed": embed_init(kemb, cfg),
                      "final_ln": norm_init(cfg, cfg.d_model)}

    if cfg.family in ("dense", "moe"):
        params["layers"] = _stack_init(
            kstack, cfg.n_layers, lambda k: _decoder_block_init(k, cfg))

    elif cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_period
        n_self = cfg.n_layers - n_cross
        per_group = n_self // n_cross
        self_stack = _stack_init(
            kstack, n_self, lambda k: _decoder_block_init(k, cfg))
        # regroup leaf arrays (L_self, ...) → (G, per_group, ...)
        params["layers"] = jax.tree.map(
            lambda a: a.reshape((n_cross, per_group) + a.shape[1:]),
            self_stack)
        params["cross_layers"] = _stack_init(
            kextra, n_cross, lambda k: _cross_block_init(k, cfg))

    elif cfg.family == "hybrid":
        params["layers"] = _stack_init(
            kstack, cfg.n_layers, lambda k: _mamba_block_init(k, cfg))
        params["shared_attn"] = _decoder_block_init(kextra, cfg)
        params["shared_in"] = dense_init(
            jax.random.fold_in(kextra, 1), 2 * cfg.d_model, cfg.d_model,
            _dtype(cfg))

    elif cfg.family == "audio":
        params["enc_layers"] = _stack_init(
            kextra, cfg.encoder_layers, lambda k: _decoder_block_init(k, cfg))
        params["enc_ln"] = norm_init(cfg, cfg.d_model)
        params["layers"] = _stack_init(
            kstack, cfg.n_layers, lambda k: _encdec_dec_block_init(k, cfg))

    elif cfg.family == "ssm":
        params["layers"] = _stack_init(
            kstack, cfg.n_layers, lambda k: _rwkv_block_init(k, cfg))

    else:
        raise ValueError(cfg.family)
    return params


def _run_encoder(params, cfg, audio_embeds):
    def body(p, h):
        h, aux = _decoder_block_apply(p, cfg, h, causal=False)
        return h, aux
    x, _ = _scan_stack(cfg, params["enc_layers"], audio_embeds, body)
    return apply_norm(params["enc_ln"], cfg, x)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, jax.Array]:
    """→ (logits fp32 (B,S,V), aux_loss)."""
    x = embed_apply(params["embed"], batch["tokens"]).astype(_dtype(cfg))
    x = constrain(x, "act")
    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe"):
        def body(p, h):
            return _decoder_block_apply(p, cfg, h)
        x, aux = _scan_stack(cfg, params["layers"], x, body)

    elif cfg.family == "vlm":
        context = batch["image_embeds"].astype(_dtype(cfg))

        def outer(carry, inp):
            h, aux = carry
            self_group, cross_p = inp

            def body(p, hh):
                return _decoder_block_apply(p, cfg, hh)
            h, a = _scan_stack(cfg, self_group, h, body)
            h = _remat(cfg, lambda p, hh: _cross_block_apply(
                p, cfg, hh, context))(cross_p, h)
            return (h, aux + a), None

        (x, aux), _ = maybe_scan(
            cfg, outer, (x, aux), (params["layers"], params["cross_layers"]))

    elif cfg.family == "hybrid":
        x0 = x
        period = cfg.hybrid_period
        n_groups = cfg.n_layers // period
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]),
            params["layers"])

        def outer(carry, group_params):
            h, aux = carry

            def body(p, hh):
                return _mamba_block_apply(p, cfg, hh), jnp.zeros((), jnp.float32)
            h, a = _scan_stack(cfg, group_params, h, body)
            # shared attention block on concat(hidden, embeddings); only the
            # block's *delta* feeds back into the backbone (zamba2-style).
            cat = jnp.concatenate([h, x0], axis=-1) @ params["shared_in"]
            y, a2 = _decoder_block_apply(params["shared_attn"], cfg, cat)
            return (h + (y - cat), aux + a + a2), None

        (x, aux), _ = maybe_scan(cfg, outer, (x, aux), grouped)

    elif cfg.family == "audio":
        context = _run_encoder(params, cfg,
                               batch["audio_embeds"].astype(_dtype(cfg)))

        def body(p, h):
            return _encdec_dec_block_apply(p, cfg, h, context), \
                jnp.zeros((), jnp.float32)
        x, aux = _scan_stack(cfg, params["layers"], x, body)

    elif cfg.family == "ssm":
        def body(p, h):
            return _rwkv_block_apply(p, cfg, h), jnp.zeros((), jnp.float32)
        x, aux = _scan_stack(cfg, params["layers"], x, body)

    x = apply_norm(params["final_ln"], cfg, x)
    logits = constrain(unembed_apply(params["embed"], cfg, x), "logits")
    return logits, aux


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross-entropy (labels pre-shifted by the pipeline)."""
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("loss_mask", jnp.ones_like(nll))
    loss = (nll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    total = loss + 0.01 * aux
    return total, {"nll": loss, "aux": aux}
