"""Mixture-of-Experts FFN — GShard-style grouped dense dispatch.

Token-choice top-k routing with per-group expert capacity: tokens are
blocked into groups of ``moe_group_size``; inside a group each expert
accepts at most ``C = ceil(group·top_k/E · capacity_factor)`` tokens
(position-in-expert via cumulative sum; overflow drops, standard GShard).
Dispatch/combine are one-hot einsums — fully static shapes, shardable
with groups→data and experts→model (``expert_shard="expert"``) or
experts replicated + d_ff→model (``expert_shard="tensor"``, for archs
whose expert count is smaller than the model axis, e.g. grok-1's 8).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models.layers import Params, _dtype, dense_init


def moe_init(key, cfg) -> Params:
    dt = _dtype(cfg)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)

    def experts(k, d_in, d_out):
        return (jax.random.normal(k, (e, d_in, d_out), jnp.float32)
                * (1.0 / math.sqrt(d_in))).astype(dt)

    return {"router": dense_init(ks[0], d, e, jnp.float32, scale=scale),
            "wi": experts(ks[1], d, f),
            "wg": experts(ks[2], d, f),
            "wo": experts(ks[3], f, d)}


def _capacity(cfg, group: int) -> int:
    return max(1, int(math.ceil(group * cfg.experts_per_token
                                / cfg.n_experts * cfg.capacity_factor)))


def moe_apply(params: Params, cfg, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    gsz = min(cfg.moe_group_size, b * s)
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    n_groups = t // gsz
    tokens = tokens.reshape(n_groups, gsz, d)
    # pin the group dim to the data axes: flattening (batch × seq) mixes
    # two sharded dims and GSPMD may otherwise replicate the dispatch
    # einsum's operands (60 GiB/dev for grok on the multi-pod mesh).
    tokens = constrain(tokens, "moe_tokens")
    cap = _capacity(cfg, gsz)

    logits = jnp.einsum("gtd,de->gte", tokens.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, T, E)

    # --- top-k token-choice routing (sort-based: lax.top_k is a custom
    # call the SPMD partitioner replicates; variadic HLO sort shards).
    # Indices are discrete (zero tangent); gates re-gathered from probs
    # so the router still trains through the gate values. ---
    from repro.models.layers import argsort_descending
    expert_ids = argsort_descending(probs)[..., :k]          # (G, T, k)
    gate_vals = jnp.take_along_axis(probs, expert_ids, axis=-1)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True),
                                     1e-9)                   # renormalize
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)  # (G,T,k,E)

    # position-in-expert: cumsum over (token, k-slot) order
    flat = onehot.reshape(n_groups, gsz * k, e)
    pie = (jnp.cumsum(flat, axis=1) - flat).reshape(n_groups, gsz, k, e)
    keep = (pie < cap) & (onehot > 0)
    pie = jnp.where(keep, pie, 0.0)
    slot = jax.nn.one_hot(pie.astype(jnp.int32), cap, dtype=jnp.float32)
    slot = slot * keep[..., None].astype(jnp.float32)        # (G,T,k,E,C)

    dispatch = constrain((onehot[..., None] * slot).sum(axis=2),
                         "moe_dispatch")                     # (G,T,E,C)
    combine = constrain((gate_vals[..., None, None] * onehot[..., None]
                         * slot).sum(axis=2), "moe_dispatch")  # (G,T,E,C)

    xin = jnp.einsum("gtd,gtec->gecd", tokens,
                     dispatch.astype(x.dtype))               # (G,E,C,D)
    xin = constrain(xin, "moe_expert_in")
    h = jnp.einsum("gecd,edf->gecf", xin, params["wi"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    hg = jnp.einsum("gecd,edf->gecf", xin, params["wg"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    h = constrain(jax.nn.silu(hg) * h, "moe_expert_h")
    out = jnp.einsum("gecf,efd->gecd", h, params["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    y = jnp.einsum("gecd,gtec->gtd", out, combine.astype(x.dtype))

    # Switch-style load-balancing loss
    density = onehot.sum(axis=2).mean(axis=1)                # (G, E) tokens frac
    router_mean = probs.mean(axis=1)                         # (G, E)
    aux = (density * router_mean).sum(axis=-1).mean() * (e ** 2) / k

    return y.reshape(b, s, d), aux.astype(jnp.float32)
