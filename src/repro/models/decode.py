"""Serving path: per-family cache init + single-token decode step.

``serve_step`` consumes one new token against a KV cache of logical
length ``max_len`` (the decode_* / long_* dry-run shapes).  Caches are
stacked (L, ...) and scanned alongside the layer params so the HLO
stays small for deep models.

Two cache layouts (``cfg.kv_cache_layout``): contiguous per-slot
regions, or the **paged pool** (``core/paging.py``) — a global
``(n_pages, page, KV, hd)`` pool per layer plus a per-slot page table,
where the serving driver allocates pages on append and frees them when
a request completes (``set_page_table`` pushes the host allocator's
table to the device).  ``prefill_prompt``/``install_prefill`` implement
the prefill→decode handoff: a prompt prefills in one full-sequence pass
and lands in a claimed slot with its decode plan pre-seeded, so the
first decode steps are planned instead of cold.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.ctx import constrain
from repro.models import attention as attn
from repro.models import mamba2, moe, rwkv6
from repro.models.config import ModelConfig
from repro.models.layers import (_dtype, apply_norm, embed_apply,
                                 mlp_apply, unembed_apply)
from repro.models.model import Params, _decoder_block_apply, maybe_scan


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Stacked (L, ...) caches per family.

    With ``cfg.kv_cache_layout == "paged"`` the self-attention caches
    hold a page pool + per-slot page table instead of contiguous
    per-slot regions (see ``attn.init_kv_cache``); the serving driver
    owns allocation (``core.paging.PageAllocator``) and pushes table
    updates with ``set_page_table``.  The vlm family's nested cache
    grouping is not paged yet."""
    dt = _dtype(cfg)
    if attn.paged_kv_on(cfg) and cfg.family == "vlm":
        raise NotImplementedError(
            "paged KV serving does not cover the vlm family's nested "
            "(n_cross, n_inner) cache grouping yet — use "
            "kv_cache_layout='contiguous'")

    def stack(n, make):
        one = make()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)

    if cfg.family in ("dense", "moe"):
        return {"kv": stack(cfg.n_layers,
                            lambda: attn.init_kv_cache(cfg, batch, max_len, dt))}
    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_period
        n_self = cfg.n_layers - n_cross
        kv = stack(n_self, lambda: attn.init_kv_cache(cfg, batch, max_len, dt))
        kv = jax.tree.map(
            lambda a: a.reshape((n_cross, n_self // n_cross) + a.shape[1:]), kv)
        return {"kv": kv,
                "cross_kv": stack(n_cross, lambda: {
                    "k": jnp.zeros((batch, cfg.n_image_tokens,
                                    cfg.n_kv_heads, cfg.hd), dt),
                    "v": jnp.zeros((batch, cfg.n_image_tokens,
                                    cfg.n_kv_heads, cfg.hd), dt)})}
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.hybrid_period
        # one KV cache PER shared-block application: the weights are
        # shared, the attention histories are not.
        return {"mamba": stack(cfg.n_layers,
                               lambda: mamba2.init_mamba_cache(cfg, batch, dt)),
                "shared_kv": stack(n_groups,
                                   lambda: attn.init_kv_cache(
                                       cfg, batch, max_len, dt))}
    if cfg.family == "audio":
        return {"kv": stack(cfg.n_layers,
                            lambda: attn.init_kv_cache(cfg, batch, max_len, dt)),
                "cross_kv": stack(cfg.n_layers, lambda: {
                    "k": jnp.zeros((batch, cfg.encoder_len,
                                    cfg.n_kv_heads, cfg.hd), dt),
                    "v": jnp.zeros((batch, cfg.encoder_len,
                                    cfg.n_kv_heads, cfg.hd), dt)})}
    if cfg.family == "ssm":
        return {"rwkv": stack(cfg.n_layers,
                              lambda: rwkv6.init_rwkv_cache(cfg, batch, dt))}
    raise ValueError(cfg.family)


def _context_valid(batch: Dict, s_ctx: int, n_layers: int):
    """Optional per-request encoder-length mask: ``context_lengths``
    (B,) int in the batch marks how many of the padded ``s_ctx``
    positions are real (audio frames / image tokens are padded to a
    fixed length).  Returns (L, B, S_ctx) bool stacked for the layer
    scan, or None when no lengths are given (all positions attend)."""
    lengths = batch.get("context_lengths")
    if lengths is None:
        return None
    valid = jnp.arange(s_ctx)[None, :] < jnp.asarray(lengths)[:, None]
    return jnp.broadcast_to(valid, (n_layers,) + valid.shape)


def prefill_context(params: Params, cfg: ModelConfig, cache: Dict,
                    batch: Dict[str, jax.Array]) -> Dict:
    """Populate cross-attention K/V from the modality context
    (image embeds for vlm; encoder output for audio).  An optional
    ``batch["context_lengths"]`` (B,) masks padded context positions in
    every decode-time cross-attention (see ``_context_valid``)."""
    if cfg.family == "vlm":
        ctx = batch["image_embeds"].astype(_dtype(cfg))
        cross_kv = jax.vmap(
            lambda p: attn.precompute_cross_kv(p["attn"], cfg, ctx))(
            params["cross_layers"])
        valid = _context_valid(batch, ctx.shape[1],
                               cfg.n_layers // cfg.cross_attn_period)
        if valid is not None:
            cross_kv = {**cross_kv, "valid": valid}
        return {**cache, "cross_kv": cross_kv}
    if cfg.family == "audio":
        from repro.models.model import _run_encoder
        enc = _run_encoder(params, cfg, batch["audio_embeds"].astype(_dtype(cfg)))
        cross_kv = jax.vmap(
            lambda p: attn.precompute_cross_kv(p["attn_cross"], cfg, enc))(
            params["layers"])
        valid = _context_valid(batch, enc.shape[1], cfg.n_layers)
        if valid is not None:
            cross_kv = {**cross_kv, "valid": valid}
        return {**cache, "cross_kv": cross_kv}
    return cache


def _reset_kv_slot(kv_cache: Dict, slot: int, batch_axis: int) -> Dict:
    """Reset one batch slot's SATA plan (if any) to the init state.
    The K/V buffers themselves need no zeroing: every read path masks
    key positions ``<= pos`` (dense decode's ``valid_k``, the gather
    kernel's in-body ``kpos <= pos``, both planners), and the claimed
    slot restarts at ``pos = 0`` overwriting each position before it
    ever becomes readable — so the previous occupant's K/V is already
    invisible, and skipping the zeroing avoids copying the full
    layer-stacked cache on every claim."""
    if "plan" not in kv_cache:
        return kv_cache
    from repro.core.decode_plan import reset_plan_slot
    return {**kv_cache,
            "plan": reset_plan_slot(kv_cache["plan"], slot,
                                    batch_axis=batch_axis)}


def reset_slot(cfg: ModelConfig, cache: Dict, slot: int) -> Dict:
    """Clear one batch slot's per-request decode state across all
    layers — a serving slot claimed by a new request must not inherit
    the previous request's plan summaries or recurrent states (position
    masking already hides its K/V, see ``_reset_kv_slot``).
    Cross-attention context (``cross_kv``) is left alone: the serving
    driver re-prefills it per request."""
    cache = dict(cache)
    if "kv" in cache:
        # vlm nests the self-attn cache (n_cross, n_inner, B, ...)
        axis = 2 if cfg.family == "vlm" else 1
        cache["kv"] = _reset_kv_slot(cache["kv"], slot, axis)
    if "shared_kv" in cache:
        cache["shared_kv"] = _reset_kv_slot(cache["shared_kv"], slot, 1)
    for name in ("mamba", "rwkv"):
        if name in cache:
            # recurrent states have no position axis to mask — zeroing
            # IS the reset, and they are O(B·d) small
            cache[name] = jax.tree.map(lambda a: a.at[:, slot].set(0),
                                       cache[name])
    return cache


def release_slot(cfg: ModelConfig, cache: Dict, slot: int) -> Dict:
    """Mark a serving slot's decode plan inactive when its request
    completes or is preempted: an empty slot must not keep aging onto
    re-plan beats (forcing the mixed full+incremental branch for the
    whole batch) or counting re-plans into the traffic accounting.
    The next claim re-activates it through ``reset_slot``."""
    from repro.core.decode_plan import release_plan_slot

    def rel(kv_cache: Dict, batch_axis: int) -> Dict:
        if "plan" not in kv_cache:
            return kv_cache
        return {**kv_cache, "plan": release_plan_slot(
            kv_cache["plan"], slot, batch_axis=batch_axis)}

    cache = dict(cache)
    if "kv" in cache:
        cache["kv"] = rel(cache["kv"], 2 if cfg.family == "vlm" else 1)
    if "shared_kv" in cache:
        cache["shared_kv"] = rel(cache["shared_kv"], 1)
    return cache


def set_page_table(cfg: ModelConfig, cache: Dict, table,
                   page_ref=None) -> Dict:
    """Push the host allocator's page table into the device cache.
    ``table``: (B, max_pages) int32 (``PageAllocator.table``).  The
    table is identical across layers (all layers of a slot grow in
    lockstep), so it broadcasts over the stacked cache's layer axis.
    ``page_ref`` (n_pages,) pushes the per-page refcounts alongside
    when the prefix cache is on — the paged write path write-protects
    shared pages (refcount > 1) with them."""
    cache = dict(cache)
    tbl = jnp.asarray(np.asarray(table), jnp.int32)
    for name in ("kv", "shared_kv"):
        kvc = cache.get(name)
        if isinstance(kvc, dict) and "page_table" in kvc:
            n = kvc["page_table"].shape[0]
            kvc = {**kvc, "page_table": jnp.broadcast_to(
                tbl, (n,) + tbl.shape)}
            if page_ref is not None and "page_ref" in kvc:
                ref = jnp.asarray(np.asarray(page_ref), jnp.int32)
                kvc["page_ref"] = jnp.broadcast_to(ref, (n,) + ref.shape)
            cache[name] = kvc
    return cache


def set_qos_knobs(cache: Dict, budget, interval, quant, sketch) -> Dict:
    """Push the serve loop's per-slot degradation-ladder knob vectors
    into the device plan state (``init_decode_plan(..., qos=True)``).
    budget/interval: (B,) int; quant/sketch: (B,) bool.  Like the page
    table, the knobs are identical across layers (a rung degrades the
    whole slot), so they broadcast over the stacked plan's layer axis.
    Only VALUES change — the pytree structure is stable, so a rung
    change never re-traces the jitted step."""
    cache = dict(cache)
    vecs = {"budget": jnp.asarray(np.asarray(budget), jnp.int32),
            "interval": jnp.asarray(np.asarray(interval), jnp.int32),
            "quant": jnp.asarray(np.asarray(quant), bool),
            "sketch": jnp.asarray(np.asarray(sketch), bool)}
    for name in ("kv", "shared_kv"):
        kvc = cache.get(name)
        if isinstance(kvc, dict) and isinstance(kvc.get("plan"), dict) \
                and "budget" in kvc["plan"]:
            plan = dict(kvc["plan"])
            n = plan["budget"].shape[0]
            for k, v in vecs.items():
                plan[k] = jnp.broadcast_to(v, (n,) + v.shape)
            cache[name] = {**kvc, "plan": plan}
    return cache


def copy_phys_pages(cache: Dict, pairs) -> Dict:
    """Copy-on-write, device side: for each ``(src, dst)`` physical
    page pair the allocator remapped (``PageAllocator.ensure_writable``)
    copy the K/V page rows — and the per-page summary rows, so a
    copied page's summary stays coherent — across all layers.  The
    rows beyond the writer's position are garbage either way
    (position-masked on every read path), so a whole-page copy is
    exact."""
    if not pairs:
        return cache
    src = jnp.asarray([s for s, _ in pairs], jnp.int32)
    dst = jnp.asarray([d for _, d in pairs], jnp.int32)
    cache = dict(cache)
    for name in ("kv", "shared_kv"):
        kvc = cache.get(name)
        if isinstance(kvc, dict) and "k_pages" in kvc:
            kvc = dict(kvc)
            for f in ("k_pages", "v_pages", "page_k_min", "page_k_max",
                      "page_k_scale", "page_k_zero"):
                if f in kvc:
                    kvc[f] = kvc[f].at[:, dst].set(kvc[f][:, src])
            cache[name] = kvc
    return cache


def retire_phys_pages(cache: Dict, phys) -> Dict:
    """Device side of a retirement pass (``PageAllocator.
    retire_compact``): scrub the freed physical pages back to their
    init state — K/V rows zeroed and the per-page summary rows reset to
    the empty sentinel (fp32 ±inf bounds; int8 zero codes with the
    ``scale = -1`` sentinel) — across all layers, the same
    ``.at[:, pages]`` move shape as ``copy_phys_pages``.  Correctness
    never depends on this (a retired hole maps the overflow page so
    the freed rows are unreachable, and a re-claimed page is rewritten
    before any position-masked read can see it), but a freed page's
    stale summary row must not survive into a future prefix-cache
    registration, and scrubbing keeps the pool's audit surface clean."""
    if phys is None or not len(phys):
        return cache
    idx = jnp.asarray(np.asarray(phys, np.int32))
    cache = dict(cache)
    for name in ("kv", "shared_kv"):
        kvc = cache.get(name)
        if isinstance(kvc, dict) and "k_pages" in kvc:
            kvc = dict(kvc)
            for f in ("k_pages", "v_pages"):
                kvc[f] = kvc[f].at[:, idx].set(0)
            if "page_k_min" in kvc:
                if "page_k_scale" in kvc:           # int8 backend
                    kvc["page_k_min"] = kvc["page_k_min"].at[:, idx].set(0)
                    kvc["page_k_max"] = kvc["page_k_max"].at[:, idx].set(0)
                    kvc["page_k_scale"] = \
                        kvc["page_k_scale"].at[:, idx].set(-1.0)
                    kvc["page_k_zero"] = \
                        kvc["page_k_zero"].at[:, idx].set(0.0)
                else:
                    kvc["page_k_min"] = \
                        kvc["page_k_min"].at[:, idx].set(jnp.inf)
                    kvc["page_k_max"] = \
                        kvc["page_k_max"].at[:, idx].set(-jnp.inf)
            cache[name] = kvc
    return cache


def retire_plan(cfg: ModelConfig, cache: Dict, slot: int, blocks) -> Dict:
    """Apply ``decode_plan.retire_plan_blocks`` to every plan-bearing
    cache group — the plan-state repair half of a retirement pass
    (summaries → empty sentinel, importance zeroed, planned rows
    re-compacted over the survivors).  Values-only like
    ``set_qos_knobs``: the pytree structure is unchanged, so the jitted
    step never re-traces."""
    from repro.core.decode_plan import retire_plan_blocks
    cache = dict(cache)
    for name in ("kv", "shared_kv"):
        kvc = cache.get(name)
        if isinstance(kvc, dict) and isinstance(kvc.get("plan"), dict) \
                and "live_blk" in kvc["plan"]:
            axis = 2 if (name == "kv" and cfg.family == "vlm") else 1
            cache[name] = {**kvc, "plan": retire_plan_blocks(
                kvc["plan"], slot, blocks, batch_axis=axis)}
    return cache


# --- host-swap preemption: device↔host page payloads + plan state -------

# Every per-physical-page array a page row lives in: K/V rows plus the
# page-summary rows (fp32 bounds, and scale/zero under the int8
# backend).  Swap must move them together — a restored page whose
# summary row stayed behind would rank blocks from another request's
# bounds.
_PAGE_POOL_FIELDS = ("k_pages", "v_pages", "page_k_min", "page_k_max",
                     "page_k_scale", "page_k_zero")


def gather_phys_pages(cache: Dict, phys) -> Dict[str, np.ndarray]:
    """Pull physical pages' device rows to host numpy — the
    ``GatherFn`` payload for ``PageAllocator.swap_out``.  Keys are
    ``"{cache_name}.{field}"``; each value is the field's rows at the
    given physical pages, in order, as numpy (the device→host copy is
    exact for every dtype involved: fp32/bf16 K/V, fp32 or int8
    summaries).  ``scatter_phys_pages`` round-trips it bitwise."""
    idx = jnp.asarray(np.asarray(phys, np.int32))
    out: Dict[str, np.ndarray] = {}
    for name in ("kv", "shared_kv"):
        kvc = cache.get(name)
        if isinstance(kvc, dict) and "k_pages" in kvc:
            for f in _PAGE_POOL_FIELDS:
                if f in kvc:
                    out[f"{name}.{f}"] = np.asarray(kvc[f][:, idx])
    return out


def scatter_phys_pages(cache: Dict, phys, payload: Dict[str, np.ndarray]
                       ) -> Dict:
    """Land a gathered payload in (freshly allocated) physical pages —
    the ``ScatterFn`` for ``PageAllocator.swap_in``.  ``phys`` need not
    equal the pages the payload was gathered from: page contents are
    physical-position-independent (the table provides the mapping, and
    the decode plan indexes *logical* blocks), so restoring into any
    free pages is exact."""
    idx = jnp.asarray(np.asarray(phys, np.int32))
    cache = dict(cache)
    for name in ("kv", "shared_kv"):
        kvc = cache.get(name)
        if isinstance(kvc, dict) and "k_pages" in kvc:
            kvc = dict(kvc)
            for f in _PAGE_POOL_FIELDS:
                key = f"{name}.{f}"
                if f in kvc and key in payload:
                    kvc[f] = kvc[f].at[:, idx].set(
                        jnp.asarray(payload[key], kvc[f].dtype))
            cache[name] = kvc
    return cache


def capture_plan_state(cfg: ModelConfig, cache: Dict, slot: int
                       ) -> Dict[str, Dict[str, np.ndarray]]:
    """Host snapshot of one serving slot's complete decode-plan state
    across the cache's plan-bearing groups — the piece of a host-swap
    besides the pages themselves.  Restoring it with
    ``restore_plan_state`` is reset-free: summaries, selected blocks,
    beat phase (``step``), churn, and the cumulative re-plan counter
    all resume exactly where the victim left off."""
    from repro.core.decode_plan import capture_plan_slot
    out: Dict[str, Dict[str, np.ndarray]] = {}
    for name in ("kv", "shared_kv"):
        kvc = cache.get(name)
        if isinstance(kvc, dict) and "plan" in kvc:
            axis = 2 if (name == "kv" and cfg.family == "vlm") else 1
            out[name] = capture_plan_slot(kvc["plan"], slot,
                                          batch_axis=axis)
    return out


def restore_plan_state(cfg: ModelConfig, cache: Dict, slot: int,
                       saved: Dict[str, Dict[str, np.ndarray]]) -> Dict:
    """Reinstall a ``capture_plan_state`` snapshot into ``slot``
    (bitwise — see ``decode_plan.install_plan_slot``)."""
    from repro.core.decode_plan import install_plan_slot
    cache = dict(cache)
    for name, snap in saved.items():
        kvc = dict(cache[name])
        axis = 2 if (name == "kv" and cfg.family == "vlm") else 1
        kvc["plan"] = install_plan_slot(kvc["plan"], slot, snap,
                                        batch_axis=axis)
        cache[name] = kvc
    return cache


def gather_prefix_kv(cache: Dict, table_row, prefix_len: int) -> Dict:
    """Gather a slot's first ``prefix_len`` cached K/V rows from the
    page pool into the logical layout — the matched shared prefix a
    tail prefill attends over.  ``table_row``: the slot's page-table
    row (host numpy).  Returns {"k", "v"}: (L, 1, prefix_len, KV, hd).
    This read is inherent to exact attention (the tail's queries need
    every prefix key); what the prefix cache skips is the *compute*
    that produced those rows."""
    kv = cache["kv"]
    page = kv["k_pages"].shape[2]
    n_lp = -(-prefix_len // page)
    phys = jnp.asarray(np.asarray(table_row[:n_lp]), jnp.int32)

    def g(pool):
        x = pool[:, phys]                        # (L, n_lp, page, KV, hd)
        x = x.reshape(x.shape[0], n_lp * page, *x.shape[3:])
        return x[:, None, :prefix_len]

    return {"k": g(kv["k_pages"]), "v": g(kv["v_pages"])}


# ---------------------------------------------------------------------------
# Prompt prefill → decode handoff
# ---------------------------------------------------------------------------

def prefill_prompt(params: Params, cfg: ModelConfig, tokens: jax.Array,
                   max_len: int, prefix_kv: Optional[Dict] = None
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Full-sequence prompt prefill for serving (dense/moe families).

    Runs the decoder over the whole (B, S_p) prompt at once — the
    prefill analogue of ``serve_step``'s per-token loop — and returns
    everything the decode path needs to continue WITHOUT a cold start:

      * ``logits`` (B, V) at the last prompt position (the first
        generated token's distribution);
      * ``k``/``v`` (L, B, S_p, KV, hd) per-layer prompt K/V rows, for
        ``install_prefill`` to place into the serving cache (contiguous
        slot region or allocated pages);
      * when SATA decode routing is on, ``plan``: a per-layer seeded
        decode-plan state (``core.decode_plan.plan_from_prefill``) —
        block summaries over the written keys plus the prompt tail's
        selected blocks, with ``step`` already off the re-plan beat, so
        decode step 0 runs the *planned* incremental path instead of a
        cold full re-plan over the prefix.

    **Continuation mode** (``prefix_kv`` given — the shared-prefix
    cache hit path): ``tokens`` is only the UNMATCHED TAIL of the
    prompt and ``prefix_kv`` = {"k", "v"} (L, B, m, KV, hd) holds the
    matched prefix's cached rows (RoPE already applied at their
    positions when they were first written).  The tail runs at
    positions ``m..m+S_p-1`` attending over prefix + tail — the exact
    computation a full-prompt prefill performs for those rows, minus
    every FLOP the matched positions would have cost — and the seeded
    plan is built over the concatenated keys, so it is bit-identical
    to the plan a full-prompt prefill would have seeded.

    Attention runs the exact dense reference (``attn._attend``, the
    same top-k mask decode uses) rather than ``attention_apply``'s
    kernel routing: prompt lengths need not tile ``sata_block``, and
    the handoff's contract with the decode path is selection-exact
    math, not a particular schedule — kernel-routed prefill agrees to
    the usual fp32 accumulation tolerance.
    """
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"prefill_prompt covers the dense/moe serving families "
            f"(got {cfg.family!r}) — other families prefill token-by-"
            f"token through serve_step")
    from repro.core.decode_plan import plan_from_prefill
    b, sp = tokens.shape
    m = 0 if prefix_kv is None else int(prefix_kv["k"].shape[2])
    # strictly less: the first decode step writes at pos == m + sp, and
    # a clamped scatter at max_len would silently corrupt the last
    # prompt row instead of erroring
    assert m + sp < max_len, (m, sp, max_len)
    dt = _dtype(cfg)
    kvh, hd = cfg.n_kv_heads, cfg.hd
    g = cfg.n_heads // kvh
    seed_plan = attn.sata_decode_on(cfg, max_len)
    blk = attn.decode_block_size(cfg, max_len)
    positions = jnp.arange(sp) + m                # tail positions
    k_positions = jnp.arange(m + sp)              # prefix + tail keys
    x = constrain(embed_apply(params["embed"], tokens).astype(dt), "act")

    def body(h, inp):
        p = inp if prefix_kv is None else inp[0]
        hn = apply_norm(p["ln1"], cfg, h)
        q, k, v = attn._project_qkv(p["attn"], cfg, hn)
        q = attn.apply_rope(q, positions, cfg.rope_theta)
        k = attn.apply_rope(k, positions, cfg.rope_theta)
        kc, vc = k.astype(dt), v.astype(dt)
        if prefix_kv is None:
            k_all, v_all = k, v
        else:
            # cached prefix rows are bitwise the rows the skipped
            # positions would have produced (same tokens, positions,
            # params), so attending over the concat is the full
            # prefill's math for the tail rows
            k_all = jnp.concatenate([inp[1], kc], axis=1)
            v_all = jnp.concatenate([inp[2], vc], axis=1)
        out = attn._attend(q, k_all, v_all, cfg, positions, k_positions,
                           causal=True)
        y = out.reshape(b, sp, cfg.n_heads * hd) @ p["attn"]["wo"]
        h = _dec_mlp(p, cfg, h + y)
        if not seed_plan:
            return h, (kc, vc)
        # seed the handoff from the WRITTEN keys (cache dtype), padded
        # to the logical cache length the decode plan is sized for
        k_pad = jnp.zeros((b, max_len, kvh, hd), dt).at[:, :m + sp].set(
            k_all.astype(dt))
        qg = q[:, -1].reshape(b, kvh, g, hd)
        seed = plan_from_prefill(
            k_pad, qg, jnp.full((b,), m + sp - 1, jnp.int32),
            topk_k=cfg.topk_k, k_block=blk,
            plan_blocks=cfg.sata.decode.blocks,
            summary=cfg.sata.decode.summary)
        return h, (kc, vc, seed)

    xs = (params["layers"] if prefix_kv is None else
          (params["layers"], prefix_kv["k"], prefix_kv["v"]))
    x, ys = maybe_scan(cfg, body, x, xs)
    x = apply_norm(params["final_ln"], cfg, x[:, -1:])
    logits = constrain(unembed_apply(params["embed"], cfg, x), "logits")
    state = {"k": ys[0], "v": ys[1]}
    if seed_plan:
        state["plan"] = ys[2]
    return logits[:, 0], state


def install_prefill(cfg: ModelConfig, cache: Dict, slot: int,
                    state: Dict[str, Any], phys_pages=None, *,
                    prefix_len: int = 0) -> Dict:
    """Place one prefilled request (``prefill_prompt`` output, B=1)
    into serving slot ``slot``: the prompt K/V rows into the slot's
    contiguous region — or, paged, row-scattered through the
    driver-provided ``phys_pages`` (the slot's mapped pages in
    ascending logical order; rows past the written extent stay
    garbage, masked by position on every read) — and the seeded plan
    rows into the slot's plan state with its ``step`` off the re-plan
    beat, which is what makes decode step 0 planned rather than a cold
    full re-plan.

    ``prefix_len > 0`` is the shared-prefix install (paged only):
    ``state`` came from a continuation prefill over the unmatched
    tail, positions ``prefix_len..prefix_len+S_p-1``, and the matched
    pages are already mapped in ``phys_pages`` — only the tail rows
    are written (the matched pages' contents are exactly the rows a
    full prefill would have rewritten, and shared pages are immutable
    anyway).  When the cache carries the per-physical-page summary
    arrays (``page_k_min``/``page_k_max``, plus scale/zero rows under
    the int8 backend), the plan summaries of fully-matched blocks are
    seeded FROM the summary cache — bit-identical to the seed's
    recompute (fp32: min/max associativity; int8: identical fp32
    bounds quantize identically), and a test pins it — and every full
    prompt page's summary is (re)registered for future hits."""
    ks, vs = state["k"], state["v"]          # (L, 1, S_p, KV, hd)
    sp = ks.shape[2]
    total = prefix_len + sp
    kv = dict(cache["kv"])
    seed = dict(state["plan"]) if "plan" in state else None
    if "k_pages" in kv:
        assert phys_pages is not None, "paged install needs the pages"
        page = kv["k_pages"].shape[2]
        row = np.asarray(phys_pages).reshape(-1)
        assert row.shape[0] * page >= total, (row.shape[0], page, total)
        tok = np.arange(prefix_len, total)
        phys_w = jnp.asarray(row[tok // page], jnp.int32)     # (S_p,)
        off_w = jnp.asarray(tok % page, jnp.int32)
        kv["k_pages"] = kv["k_pages"].at[:, phys_w, off_w].set(
            ks[:, 0].astype(kv["k_pages"].dtype))
        kv["v_pages"] = kv["v_pages"].at[:, phys_w, off_w].set(
            vs[:, 0].astype(kv["v_pages"].dtype))
        if seed is not None and "page_k_min" in kv:
            n_shared = prefix_len // page        # fully-matched blocks
            n_full = total // page               # full prompt pages
            if n_shared:
                cached_min = kv["page_k_min"][:, row[:n_shared]]
                cached_max = kv["page_k_max"][:, row[:n_shared]]
                seed["k_min"] = seed["k_min"].at[:, 0, :, :n_shared].set(
                    cached_min.transpose(0, 2, 1, 3))
                seed["k_max"] = seed["k_max"].at[:, 0, :, :n_shared].set(
                    cached_max.transpose(0, 2, 1, 3))
                if "page_k_scale" in kv:     # int8 summary backend
                    cached_sc = kv["page_k_scale"][:, row[:n_shared]]
                    cached_zp = kv["page_k_zero"][:, row[:n_shared]]
                    seed["k_scale"] = seed["k_scale"] \
                        .at[:, 0, :, :n_shared].set(
                            cached_sc.transpose(0, 2, 1))
                    seed["k_zero"] = seed["k_zero"] \
                        .at[:, 0, :, :n_shared].set(
                            cached_zp.transpose(0, 2, 1))
            if n_full:
                kv["page_k_min"] = kv["page_k_min"].at[:, row[:n_full]].set(
                    seed["k_min"][:, 0, :, :n_full].transpose(0, 2, 1, 3))
                kv["page_k_max"] = kv["page_k_max"].at[:, row[:n_full]].set(
                    seed["k_max"][:, 0, :, :n_full].transpose(0, 2, 1, 3))
                if "page_k_scale" in kv:
                    kv["page_k_scale"] = kv["page_k_scale"] \
                        .at[:, row[:n_full]].set(
                            seed["k_scale"][:, 0, :, :n_full]
                            .transpose(0, 2, 1))
                    kv["page_k_zero"] = kv["page_k_zero"] \
                        .at[:, row[:n_full]].set(
                            seed["k_zero"][:, 0, :, :n_full]
                            .transpose(0, 2, 1))
    else:
        assert prefix_len == 0, "shared-prefix install is paged-only"
        kv["k"] = kv["k"].at[:, slot, :sp].set(
            ks[:, 0].astype(kv["k"].dtype))
        kv["v"] = kv["v"].at[:, slot, :sp].set(
            vs[:, 0].astype(kv["v"].dtype))
    if seed is not None and "plan" in kv:
        plan = dict(kv["plan"])
        for name in ("k_min", "k_max", "k_scale", "k_zero",
                     "kv_indices", "kv_counts", "step", "churn"):
            if name in plan:
                plan[name] = plan[name].at[:, slot].set(seed[name][:, 0])
        kv["plan"] = plan
    return {**cache, "kv": kv}


def _dec_mlp(p, cfg, x):
    h = apply_norm(p["ln2"], cfg, x)
    if cfg.moe:
        y, _ = moe.moe_apply(p["moe"], cfg, h)
        return x + y
    return x + mlp_apply(p["mlp"], cfg, h)


def serve_step(params: Params, cfg: ModelConfig, cache: Dict,
               tokens: jax.Array, pos: jax.Array
               ) -> Tuple[jax.Array, Dict]:
    """tokens: (B, 1) current token ids; pos: scalar position (all
    slots in lockstep) or (B,) int32 per-slot positions (continuous
    batching — each serving slot decodes at its own offset).
    → (logits (B, 1, V) fp32, updated cache)."""
    x = constrain(embed_apply(params["embed"], tokens).astype(_dtype(cfg)),
                  "act")

    if cfg.family in ("dense", "moe"):
        def body(h, inp):
            p, kv = inp
            hn = apply_norm(p["ln1"], cfg, h)
            y, kv = attn.attention_decode(p["attn"], cfg, hn, kv, pos)
            h = _dec_mlp(p, cfg, h + y)
            return h, kv
        x, new_kv = maybe_scan(cfg, body, x, (params["layers"], cache["kv"]))
        cache = {**cache, "kv": new_kv}

    elif cfg.family == "vlm":
        def outer(h, inp):
            self_group, cross_p, kv_group, cross_kv = inp

            def inner(hh, inp2):
                p, kv = inp2
                hn = apply_norm(p["ln1"], cfg, hh)
                y, kv = attn.attention_decode(p["attn"], cfg, hn, kv, pos)
                hh = _dec_mlp(p, cfg, hh + y)
                return hh, kv
            h, kv_group = jax.lax.scan(inner, h, (self_group, kv_group))
            hn = apply_norm(cross_p["ln1"], cfg, h)
            y = attn.cross_attention_decode(cross_p["attn"], cfg, hn, cross_kv)
            h = h + jnp.tanh(cross_p["gate"]).astype(h.dtype) * y
            hn = apply_norm(cross_p["ln2"], cfg, h)
            h = h + jnp.tanh(cross_p["gate_mlp"]).astype(h.dtype) * \
                mlp_apply(cross_p["mlp"], cfg, hn)
            return h, kv_group
        x, new_kv = maybe_scan(
            cfg, outer, x, (params["layers"], params["cross_layers"],
                            cache["kv"], cache["cross_kv"]))
        cache = {**cache, "kv": new_kv}

    elif cfg.family == "hybrid":
        x0 = x          # current token's embedding (matches forward's
                        # per-position concat with the embedding stream)
        period = cfg.hybrid_period
        n_groups = cfg.n_layers // period
        grouped_p = jax.tree.map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]),
            params["layers"])
        grouped_c = jax.tree.map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]),
            cache["mamba"])

        def outer(h, inp):
            p_group, c_group, skv = inp

            def inner(hh, inp2):
                p, c = inp2
                y, c = mamba2.mamba2_decode(
                    p["mixer"], cfg, apply_norm(p["ln"], cfg, hh), c)
                return hh + y, c
            h, c_group = jax.lax.scan(inner, h, (p_group, c_group))
            cat = jnp.concatenate([h, x0], axis=-1) @ params["shared_in"]
            sp = params["shared_attn"]
            hn = apply_norm(sp["ln1"], cfg, cat)
            y, skv = attn.attention_decode(sp["attn"], cfg, hn, skv, pos)
            cat2 = _dec_mlp(sp, cfg, cat + y)
            return h + (cat2 - cat), (c_group, skv)

        x, (new_mamba, shared_kv) = maybe_scan(
            cfg, outer, x, (grouped_p, grouped_c, cache["shared_kv"]))
        new_mamba = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_mamba)
        cache = {**cache, "mamba": new_mamba, "shared_kv": shared_kv}

    elif cfg.family == "audio":
        def body(h, inp):
            p, kv, ckv = inp
            hn = apply_norm(p["ln1"], cfg, h)
            y, kv = attn.attention_decode(p["attn"], cfg, hn, kv, pos)
            h = h + y
            hn = apply_norm(p["lnx"], cfg, h)
            h = h + attn.cross_attention_decode(p["attn_cross"], cfg, hn, ckv)
            hn = apply_norm(p["ln2"], cfg, h)
            h = h + mlp_apply(p["mlp"], cfg, hn)
            return h, kv
        x, new_kv = maybe_scan(
            cfg, body, x, (params["layers"], cache["kv"], cache["cross_kv"]))
        cache = {**cache, "kv": new_kv}

    elif cfg.family == "ssm":
        def body(h, inp):
            p, c = inp
            hn = apply_norm(p["ln1"], cfg, h)
            y, st, tm_x = rwkv6.rwkv6_time_mix(
                p["tmix"], cfg, hn, state=c["state"], last_x=c["tm_x"])
            h = h + y
            hn = apply_norm(p["ln2"], cfg, h)
            y, cm_x = rwkv6.rwkv6_channel_mix(p["tmix"], cfg, hn,
                                              last_x=c["cm_x"])
            return h + y, {"state": st, "tm_x": tm_x, "cm_x": cm_x}
        x, new_c = maybe_scan(cfg, body, x, (params["layers"], cache["rwkv"]))
        cache = {**cache, "rwkv": new_c}

    x = apply_norm(params["final_ln"], cfg, x)
    logits = constrain(unembed_apply(params["embed"], cfg, x), "logits")
    return logits, cache
