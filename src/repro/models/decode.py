"""Serving path: per-family cache init + single-token decode step.

``serve_step`` consumes one new token against a KV cache of length
``max_len`` (the decode_* / long_* dry-run shapes).  Caches are stacked
(L, ...) and scanned alongside the layer params so the HLO stays small
for deep models.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models import attention as attn
from repro.models import mamba2, moe, rwkv6
from repro.models.config import ModelConfig
from repro.models.layers import (_dtype, apply_norm, embed_apply,
                                 mlp_apply, unembed_apply)
from repro.models.model import Params, _decoder_block_apply, maybe_scan


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Stacked (L, ...) caches per family."""
    dt = _dtype(cfg)

    def stack(n, make):
        one = make()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)

    if cfg.family in ("dense", "moe"):
        return {"kv": stack(cfg.n_layers,
                            lambda: attn.init_kv_cache(cfg, batch, max_len, dt))}
    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_period
        n_self = cfg.n_layers - n_cross
        kv = stack(n_self, lambda: attn.init_kv_cache(cfg, batch, max_len, dt))
        kv = jax.tree.map(
            lambda a: a.reshape((n_cross, n_self // n_cross) + a.shape[1:]), kv)
        return {"kv": kv,
                "cross_kv": stack(n_cross, lambda: {
                    "k": jnp.zeros((batch, cfg.n_image_tokens,
                                    cfg.n_kv_heads, cfg.hd), dt),
                    "v": jnp.zeros((batch, cfg.n_image_tokens,
                                    cfg.n_kv_heads, cfg.hd), dt)})}
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.hybrid_period
        # one KV cache PER shared-block application: the weights are
        # shared, the attention histories are not.
        return {"mamba": stack(cfg.n_layers,
                               lambda: mamba2.init_mamba_cache(cfg, batch, dt)),
                "shared_kv": stack(n_groups,
                                   lambda: attn.init_kv_cache(
                                       cfg, batch, max_len, dt))}
    if cfg.family == "audio":
        return {"kv": stack(cfg.n_layers,
                            lambda: attn.init_kv_cache(cfg, batch, max_len, dt)),
                "cross_kv": stack(cfg.n_layers, lambda: {
                    "k": jnp.zeros((batch, cfg.encoder_len,
                                    cfg.n_kv_heads, cfg.hd), dt),
                    "v": jnp.zeros((batch, cfg.encoder_len,
                                    cfg.n_kv_heads, cfg.hd), dt)})}
    if cfg.family == "ssm":
        return {"rwkv": stack(cfg.n_layers,
                              lambda: rwkv6.init_rwkv_cache(cfg, batch, dt))}
    raise ValueError(cfg.family)


def _context_valid(batch: Dict, s_ctx: int, n_layers: int):
    """Optional per-request encoder-length mask: ``context_lengths``
    (B,) int in the batch marks how many of the padded ``s_ctx``
    positions are real (audio frames / image tokens are padded to a
    fixed length).  Returns (L, B, S_ctx) bool stacked for the layer
    scan, or None when no lengths are given (all positions attend)."""
    lengths = batch.get("context_lengths")
    if lengths is None:
        return None
    valid = jnp.arange(s_ctx)[None, :] < jnp.asarray(lengths)[:, None]
    return jnp.broadcast_to(valid, (n_layers,) + valid.shape)


def prefill_context(params: Params, cfg: ModelConfig, cache: Dict,
                    batch: Dict[str, jax.Array]) -> Dict:
    """Populate cross-attention K/V from the modality context
    (image embeds for vlm; encoder output for audio).  An optional
    ``batch["context_lengths"]`` (B,) masks padded context positions in
    every decode-time cross-attention (see ``_context_valid``)."""
    if cfg.family == "vlm":
        ctx = batch["image_embeds"].astype(_dtype(cfg))
        cross_kv = jax.vmap(
            lambda p: attn.precompute_cross_kv(p["attn"], cfg, ctx))(
            params["cross_layers"])
        valid = _context_valid(batch, ctx.shape[1],
                               cfg.n_layers // cfg.cross_attn_period)
        if valid is not None:
            cross_kv = {**cross_kv, "valid": valid}
        return {**cache, "cross_kv": cross_kv}
    if cfg.family == "audio":
        from repro.models.model import _run_encoder
        enc = _run_encoder(params, cfg, batch["audio_embeds"].astype(_dtype(cfg)))
        cross_kv = jax.vmap(
            lambda p: attn.precompute_cross_kv(p["attn_cross"], cfg, enc))(
            params["layers"])
        valid = _context_valid(batch, enc.shape[1], cfg.n_layers)
        if valid is not None:
            cross_kv = {**cross_kv, "valid": valid}
        return {**cache, "cross_kv": cross_kv}
    return cache


def _reset_kv_slot(kv_cache: Dict, slot: int, batch_axis: int) -> Dict:
    """Reset one batch slot's SATA plan (if any) to the init state.
    The K/V buffers themselves need no zeroing: every read path masks
    key positions ``<= pos`` (dense decode's ``valid_k``, the gather
    kernel's in-body ``kpos <= pos``, both planners), and the claimed
    slot restarts at ``pos = 0`` overwriting each position before it
    ever becomes readable — so the previous occupant's K/V is already
    invisible, and skipping the zeroing avoids copying the full
    layer-stacked cache on every claim."""
    if "plan" not in kv_cache:
        return kv_cache
    from repro.core.decode_plan import reset_plan_slot
    return {**kv_cache,
            "plan": reset_plan_slot(kv_cache["plan"], slot,
                                    batch_axis=batch_axis)}


def reset_slot(cfg: ModelConfig, cache: Dict, slot: int) -> Dict:
    """Clear one batch slot's per-request decode state across all
    layers — a serving slot claimed by a new request must not inherit
    the previous request's plan summaries or recurrent states (position
    masking already hides its K/V, see ``_reset_kv_slot``).
    Cross-attention context (``cross_kv``) is left alone: the serving
    driver re-prefills it per request."""
    cache = dict(cache)
    if "kv" in cache:
        # vlm nests the self-attn cache (n_cross, n_inner, B, ...)
        axis = 2 if cfg.family == "vlm" else 1
        cache["kv"] = _reset_kv_slot(cache["kv"], slot, axis)
    if "shared_kv" in cache:
        cache["shared_kv"] = _reset_kv_slot(cache["shared_kv"], slot, 1)
    for name in ("mamba", "rwkv"):
        if name in cache:
            # recurrent states have no position axis to mask — zeroing
            # IS the reset, and they are O(B·d) small
            cache[name] = jax.tree.map(lambda a: a.at[:, slot].set(0),
                                       cache[name])
    return cache


def _dec_mlp(p, cfg, x):
    h = apply_norm(p["ln2"], cfg, x)
    if cfg.moe:
        y, _ = moe.moe_apply(p["moe"], cfg, h)
        return x + y
    return x + mlp_apply(p["mlp"], cfg, h)


def serve_step(params: Params, cfg: ModelConfig, cache: Dict,
               tokens: jax.Array, pos: jax.Array
               ) -> Tuple[jax.Array, Dict]:
    """tokens: (B, 1) current token ids; pos: scalar position (all
    slots in lockstep) or (B,) int32 per-slot positions (continuous
    batching — each serving slot decodes at its own offset).
    → (logits (B, 1, V) fp32, updated cache)."""
    x = constrain(embed_apply(params["embed"], tokens).astype(_dtype(cfg)),
                  "act")

    if cfg.family in ("dense", "moe"):
        def body(h, inp):
            p, kv = inp
            hn = apply_norm(p["ln1"], cfg, h)
            y, kv = attn.attention_decode(p["attn"], cfg, hn, kv, pos)
            h = _dec_mlp(p, cfg, h + y)
            return h, kv
        x, new_kv = maybe_scan(cfg, body, x, (params["layers"], cache["kv"]))
        cache = {**cache, "kv": new_kv}

    elif cfg.family == "vlm":
        def outer(h, inp):
            self_group, cross_p, kv_group, cross_kv = inp

            def inner(hh, inp2):
                p, kv = inp2
                hn = apply_norm(p["ln1"], cfg, hh)
                y, kv = attn.attention_decode(p["attn"], cfg, hn, kv, pos)
                hh = _dec_mlp(p, cfg, hh + y)
                return hh, kv
            h, kv_group = jax.lax.scan(inner, h, (self_group, kv_group))
            hn = apply_norm(cross_p["ln1"], cfg, h)
            y = attn.cross_attention_decode(cross_p["attn"], cfg, hn, cross_kv)
            h = h + jnp.tanh(cross_p["gate"]).astype(h.dtype) * y
            hn = apply_norm(cross_p["ln2"], cfg, h)
            h = h + jnp.tanh(cross_p["gate_mlp"]).astype(h.dtype) * \
                mlp_apply(cross_p["mlp"], cfg, hn)
            return h, kv_group
        x, new_kv = maybe_scan(
            cfg, outer, x, (params["layers"], params["cross_layers"],
                            cache["kv"], cache["cross_kv"]))
        cache = {**cache, "kv": new_kv}

    elif cfg.family == "hybrid":
        x0 = x          # current token's embedding (matches forward's
                        # per-position concat with the embedding stream)
        period = cfg.hybrid_period
        n_groups = cfg.n_layers // period
        grouped_p = jax.tree.map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]),
            params["layers"])
        grouped_c = jax.tree.map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]),
            cache["mamba"])

        def outer(h, inp):
            p_group, c_group, skv = inp

            def inner(hh, inp2):
                p, c = inp2
                y, c = mamba2.mamba2_decode(
                    p["mixer"], cfg, apply_norm(p["ln"], cfg, hh), c)
                return hh + y, c
            h, c_group = jax.lax.scan(inner, h, (p_group, c_group))
            cat = jnp.concatenate([h, x0], axis=-1) @ params["shared_in"]
            sp = params["shared_attn"]
            hn = apply_norm(sp["ln1"], cfg, cat)
            y, skv = attn.attention_decode(sp["attn"], cfg, hn, skv, pos)
            cat2 = _dec_mlp(sp, cfg, cat + y)
            return h + (cat2 - cat), (c_group, skv)

        x, (new_mamba, shared_kv) = maybe_scan(
            cfg, outer, x, (grouped_p, grouped_c, cache["shared_kv"]))
        new_mamba = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_mamba)
        cache = {**cache, "mamba": new_mamba, "shared_kv": shared_kv}

    elif cfg.family == "audio":
        def body(h, inp):
            p, kv, ckv = inp
            hn = apply_norm(p["ln1"], cfg, h)
            y, kv = attn.attention_decode(p["attn"], cfg, hn, kv, pos)
            h = h + y
            hn = apply_norm(p["lnx"], cfg, h)
            h = h + attn.cross_attention_decode(p["attn_cross"], cfg, hn, ckv)
            hn = apply_norm(p["ln2"], cfg, h)
            h = h + mlp_apply(p["mlp"], cfg, hn)
            return h, kv
        x, new_kv = maybe_scan(
            cfg, body, x, (params["layers"], cache["kv"], cache["cross_kv"]))
        cache = {**cache, "kv": new_kv}

    elif cfg.family == "ssm":
        def body(h, inp):
            p, c = inp
            hn = apply_norm(p["ln1"], cfg, h)
            y, st, tm_x = rwkv6.rwkv6_time_mix(
                p["tmix"], cfg, hn, state=c["state"], last_x=c["tm_x"])
            h = h + y
            hn = apply_norm(p["ln2"], cfg, h)
            y, cm_x = rwkv6.rwkv6_channel_mix(p["tmix"], cfg, hn,
                                              last_x=c["cm_x"])
            return h + y, {"state": st, "tm_x": tm_x, "cm_x": cm_x}
        x, new_c = maybe_scan(cfg, body, x, (params["layers"], cache["rwkv"]))
        cache = {**cache, "rwkv": new_c}

    x = apply_norm(params["final_ln"], cfg, x)
    logits = constrain(unembed_apply(params["embed"], cfg, x), "logits")
    return logits, cache
