"""RWKV6 "Finch" block — data-dependent decay linear recurrence.

Attention-free: per head a (hd × hd) state carries the kᵀv outer-product
history with a *data-dependent* per-channel decay w_t (the Finch
contribution).  Training/prefill runs a time scan; decode is a single
O(1) state update.  SATA is inapplicable here (no QK selection mask) —
see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, _dtype, dense_init


def rwkv6_init(key, cfg) -> Params:
    dt = _dtype(cfg)
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    ks = jax.random.split(key, 10)
    lora = max(32, d // 64)
    return {
        # time-mix
        "mix_r": jnp.full((d,), 0.5, jnp.float32),
        "mix_k": jnp.full((d,), 0.5, jnp.float32),
        "mix_v": jnp.full((d,), 0.5, jnp.float32),
        "mix_g": jnp.full((d,), 0.5, jnp.float32),
        "mix_w": jnp.full((d,), 0.5, jnp.float32),
        "wr": dense_init(ks[0], d, d, dt),
        "wk": dense_init(ks[1], d, d, dt),
        "wv": dense_init(ks[2], d, d, dt),
        "wg": dense_init(ks[3], d, d, dt),
        "wo": dense_init(ks[4], d, d, dt),
        # Finch data-dependent decay (LoRA form)
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "wa": dense_init(ks[5], d, lora, dt),
        "wb": dense_init(ks[6], lora, d, dt),
        "bonus_u": jnp.zeros((h, hd), jnp.float32),
        "ln_scale": jnp.ones((d,), jnp.float32),
        # channel-mix
        "cmix_k": jnp.full((d,), 0.5, jnp.float32),
        "cmix_r": jnp.full((d,), 0.5, jnp.float32),
        "ck": dense_init(ks[7], d, cfg.d_ff, dt),
        "cv": dense_init(ks[8], cfg.d_ff, d, dt),
        "cr": dense_init(ks[9], d, d, dt),
    }


def _shift(x: jax.Array, last: jax.Array = None) -> jax.Array:
    """Token shift: previous token's features (zeros / cache at t=0)."""
    if last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = last[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xs, ratio):
    # keep the block in the activation dtype (f32 ratios must not
    # promote the residual stream — scan carries are dtype-strict)
    return (x * ratio + xs * (1.0 - ratio)).astype(x.dtype)


def _decay(params, xw):
    """Finch decay: w = exp(-exp(w0 + tanh(x·A)·B)) ∈ (0, 1)."""
    lora = jnp.tanh(xw @ params["wa"]) @ params["wb"]
    return jnp.exp(-jnp.exp(params["w0"] + lora.astype(jnp.float32)))


def _group_norm(x, scale, hd, eps=1e-5):
    b, s, d = x.shape
    xg = x.reshape(b, s, d // hd, hd).astype(jnp.float32)
    mu = xg.mean(-1, keepdims=True)
    var = ((xg - mu) ** 2).mean(-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(b, s, d) * scale)


def rwkv6_time_mix(params: Params, cfg, x: jax.Array,
                   state: jax.Array = None, last_x: jax.Array = None
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,S,D) → (out, final_state, final_x).  state: (B,H,hd,hd)."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    xs = _shift(x, last_x)
    r = _mix(x, xs, params["mix_r"]) @ params["wr"]
    k = _mix(x, xs, params["mix_k"]) @ params["wk"]
    v = _mix(x, xs, params["mix_v"]) @ params["wv"]
    g = _mix(x, xs, params["mix_g"]) @ params["wg"]
    w = _decay(params, _mix(x, xs, params["mix_w"]))          # (B,S,D)

    rh = r.reshape(b, s, h, hd).astype(jnp.float32)
    kh = k.reshape(b, s, h, hd).astype(jnp.float32)
    vh = v.reshape(b, s, h, hd).astype(jnp.float32)
    wh = w.reshape(b, s, h, hd)

    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(st, inp):
        r_t, k_t, v_t, w_t = inp                              # (B,H,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         st + params["bonus_u"][..., None] * kv)
        st = st * w_t[..., None] + kv
        return st, out

    seq = (jnp.moveaxis(rh, 1, 0), jnp.moveaxis(kh, 1, 0),
           jnp.moveaxis(vh, 1, 0), jnp.moveaxis(wh, 1, 0))

    # Chunked time scan: an unchunked backward would checkpoint the
    # (B,H,hd,hd) state at *every* timestep (tens of GB at 4k+ seq).
    # Outer scan saves the state once per chunk; the inner scan replays
    # under jax.checkpoint.
    chunk = getattr(cfg, "rwkv_chunk", 256)
    if s > chunk and s % chunk == 0:
        seq_c = jax.tree.map(
            lambda a: a.reshape((s // chunk, chunk) + a.shape[1:]), seq)

        @jax.checkpoint
        def chunk_step(st, inp_chunk):
            return jax.lax.scan(step, st, inp_chunk)

        state, outs = jax.lax.scan(chunk_step, state, seq_c)
        outs = outs.reshape((s,) + outs.shape[2:])
    else:
        state, outs = jax.lax.scan(step, state, seq)          # (S,B,H,hd)
    y = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)
    y = _group_norm(y, params["ln_scale"], hd)
    y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    return y @ params["wo"], state, x[:, -1, :]


def rwkv6_channel_mix(params: Params, cfg, x: jax.Array,
                      last_x: jax.Array = None
                      ) -> Tuple[jax.Array, jax.Array]:
    xs = _shift(x, last_x)
    k = _mix(x, xs, params["cmix_k"]) @ params["ck"]
    k = jnp.square(jax.nn.relu(k))
    r = jax.nn.sigmoid((_mix(x, xs, params["cmix_r"]) @ params["cr"])
                       .astype(jnp.float32)).astype(x.dtype)
    return r * (k @ params["cv"]), x[:, -1, :]


def init_rwkv_cache(cfg, batch: int, dtype) -> Dict[str, jax.Array]:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    return {"state": jnp.zeros((batch, d // hd, hd, hd), jnp.float32),
            "tm_x": jnp.zeros((batch, d), dtype),
            "cm_x": jnp.zeros((batch, d), dtype)}


def rwkv6_decode(params: Params, cfg, x: jax.Array, cache: Dict
                 ) -> Tuple[jax.Array, Dict]:
    """One-token step (B,1,D) reusing the scan path with S=1."""
    y, state, tm_x = rwkv6_time_mix(params, cfg, x,
                                    state=cache["state"],
                                    last_x=cache["tm_x"])
    return y, {"state": state, "tm_x": tm_x, "cm_x": cache["cm_x"]}
